//! Regenerates Table 1 end-to-end (both data scales, every cluster size)
//! and checks the paper's qualitative claims. `cargo bench --bench table1`.

use blink::experiments::{self, report};
use blink::util::stats;

fn main() {
    let t0 = std::time::Instant::now();
    let table = experiments::table1(1);
    report::print_table1(&table);
    println!("\n[generated in {:.1} s]", t0.elapsed().as_secs_f64());

    // ---- paper-claim checks -------------------------------------------
    let paper_picks_100 = [
        ("als", 1),
        ("bayes", 7),
        ("gbt", 1),
        ("km", 4),
        ("lr", 5),
        ("pca", 1),
        ("rfc", 4),
        ("svm", 7),
    ];
    let mut ok = 0;
    for (name, want) in paper_picks_100 {
        let row = table.at_100.iter().find(|r| r.app == name).unwrap();
        let hit = row.blink_pick == want && row.optimal == want;
        println!(
            "claim[100 %] {name}: pick {} / optimal {} vs paper {want} {}",
            row.blink_pick,
            row.optimal,
            if hit { "OK" } else { "MISS" }
        );
        ok += hit as usize;
    }
    // enlarged: optimal picks everywhere except KM (the paper's one miss)
    for row in &table.enlarged {
        let hit = if row.app == "km" {
            row.blink_pick != row.optimal // reproduces the documented miss
        } else {
            row.blink_pick == row.optimal
        };
        println!(
            "claim[enlarged] {}: pick {} / first-eviction-free {} {}",
            row.app,
            row.blink_pick,
            row.optimal,
            if hit { "OK" } else { "MISS" }
        );
        ok += hit as usize;
    }
    // average sampling overhead vs optimal cost (paper: 4.6 % at 100 %)
    let overheads: Vec<f64> = table
        .at_100
        .iter()
        .map(|r| r.sample_cost_machine_min / r.runs[r.optimal - 1].1)
        .collect();
    println!(
        "sample-cost overhead vs optimal run: mean {:.1} % (paper: 4.6 %)",
        stats::mean(&overheads) * 100.0
    );
    println!("claims passed: {ok}/16");
    assert!(ok >= 15, "Table 1 reproduction degraded: {ok}/16");
}
