//! Regenerates Table 2 (cluster bounds on a fixed 12-machine cluster,
//! ±5 % probes around the predicted max data scale).
//! `cargo bench --bench table2`.

use blink::experiments::{self, report};

fn main() {
    let t0 = std::time::Instant::now();
    let rows = experiments::table2(1);
    report::print_table2(&rows);
    println!("\n[generated in {:.1} s]", t0.elapsed().as_secs_f64());

    // paper claim: predicted bound within +-5 % of the true boundary
    for row in &rows {
        let err = (row.predicted_scale - row.true_boundary).abs() / row.true_boundary;
        println!("claim {}: bound error {:.2} % (<5 %?)", row.app, err * 100.0);
        assert!(err < 0.05, "{}: bound error {err}", row.app);
        // the -5 % probe must be eviction-free; +5 % must not be
        let at = |off: f64| row.probes.iter().find(|p| (p.0 - off).abs() < 1e-9).unwrap().1;
        assert!(at(-0.05), "{}: -5 % probe should fit", row.app);
        assert!(!at(0.05), "{}: +5 % probe should evict", row.app);
    }
    println!("Table 2 claims OK");
}
