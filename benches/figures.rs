//! Regenerates every figure of the evaluation section and asserts the
//! paper's qualitative claims. `cargo bench --bench figures [-- <figN>]`.

use blink::experiments::{self, report};
use blink::util::stats;

fn main() {
    // cargo bench passes a `--bench` flag; only non-dash args are filters
    let filter: Option<String> = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let want = |id: &str| filter.as_deref().map(|f| f == id).unwrap_or(true);
    let t0 = std::time::Instant::now();

    if want("fig1") {
        let f = experiments::fig1(1);
        report::print_fig1(&f);
        // claims: areas A/B/C exist, optimum at 7, Ernest picks area A and
        // is accurate only in area B
        assert_eq!(f.optimal, 7, "svm area C at 7 machines");
        assert!(f.ernest_pick < 7, "Ernest mispicks into area A");
        let (n1, t1, c1, _) = f.series[0];
        let (_, t7, c7, _) = f.series[6];
        assert_eq!(n1, 1);
        assert!(c1 / c7 > 8.0, "area A cost blow-up ({c1} vs {c7})");
        assert!(t1 > t7, "time falls with machines");
        // Ernest accurate in area B (within 25 % at n=8..12)...
        for i in 7..12 {
            let rel = (f.ernest_time_min[i] - f.series[i].1).abs() / f.series[i].1;
            assert!(rel < 0.25, "ernest area-B accuracy at n={}: {rel}", i + 1);
        }
        // ...and catastrophically optimistic at n=1
        assert!(f.series[0].1 / f.ernest_time_min[0] > 4.0);
        println!("fig1 claims OK\n");
    }

    if want("fig2") {
        let dag = blink::dag::fig2_logistic_regression();
        let counts = dag.compute_counts_uncached();
        println!("FIGURE 2 — LR merged DAG compute counts: {counts:?}");
        assert_eq!(counts[1], 8);
        assert_eq!(counts[2], 6);
        println!("fig2 claims OK\n");
    }

    if want("fig4") {
        let scales = experiments::fig4(1);
        report::print_fig4(&scales);
        for sc in &scales {
            assert!(sc.sizes_mb.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9));
            assert!(stats::cv(&sc.times_s) > 0.001);
        }
        println!("fig4 claims OK\n");
    }

    // figs 6 + 10 share one Table-1 run
    if want("fig6") || want("fig10") {
        let table = experiments::table1(1);
        if want("fig6") {
            let rows = experiments::fig6(&table);
            report::print_fig6(&rows);
            let (vs_avg, vs_worst) = experiments::fig6_ratios(&rows);
            // paper: 52.6 % of average, 25.1 % of worst
            assert!(vs_avg < 0.75, "blink should beat the average ({vs_avg})");
            assert!(vs_worst < 0.45, "and crush the worst ({vs_worst})");
            assert!(vs_worst < vs_avg);
            println!("fig6 claims OK\n");
        }
        if want("fig10") {
            let f = experiments::fig10(&table, 1);
            report::print_fig10(&f);
            let avg = stats::mean(&f.rows.iter().map(|r| r.overhead).collect::<Vec<_>>());
            assert!(avg < 0.25, "sampling overhead small ({avg})");
            assert!(f.ernest_over_blink > 5.0, "Ernest sampling far costlier");
            // Block-s costs more than Block-n on average (paper: 4.9x)
            let mean_of = |ap: &str| {
                stats::mean(
                    &f.rows
                        .iter()
                        .filter(|r| r.approach == ap)
                        .map(|r| r.overhead)
                        .collect::<Vec<_>>(),
                )
            };
            assert!(mean_of("Block-s") > mean_of("Block-n"));
            println!("fig10 claims OK\n");
        }
    }

    if want("fig7") {
        let rows = experiments::fig7();
        report::print_fig7(&rows);
        let worst = rows.iter().max_by(|a, b| a.error.partial_cmp(&b.error).unwrap()).unwrap();
        assert_eq!(worst.app, "gbt", "GBT is the worst-predicted app");
        assert!(worst.error > 0.10, "GBT error is large");
        let others: Vec<f64> =
            rows.iter().filter(|r| r.app != "gbt").map(|r| r.error).collect();
        assert!(stats::mean(&others) < 0.05, "non-GBT apps predict well");
        println!("fig7 claims OK\n");
    }

    if want("fig8") {
        let pts = experiments::fig8();
        report::print_fig8(&pts);
        let first = pts.first().unwrap();
        let last = pts.last().unwrap();
        assert!(last.sample_cost_machine_min > first.sample_cost_machine_min);
        assert!(last.accuracy > first.accuracy, "more samples buy accuracy");
        assert!(last.accuracy > 0.9, "10-sample accuracy high");
        assert!(last.cv_rel_err < first.cv_rel_err, "CV error falls (Fig. 9)");
        println!("fig8 claims OK\n");
    }

    if want("fig9") {
        report::print_fig9(&experiments::fig9_sizes());
        println!();
    }

    if want("sec4") {
        let p = experiments::sec4_parallelism(1);
        let c = experiments::sec4_single_vs_cluster(1);
        report::print_sec4(&p, &c);
        assert!(p.time_high_s > p.time_low_s, "more tasks, longer sample run");
        assert!(p.size_high_mb > p.size_low_mb, "more tasks, larger measured size");
        assert!(c.cost_cluster > 5.0 * c.cost_single, "cluster sampling is wasteful");
        println!("sec4 claims OK\n");
    }

    if want("fig11") {
        let f = experiments::fig11(1);
        report::print_fig11(&f);
        assert_eq!(f.blink_pick, 7, "blink picks 7 for km @ 200 %");
        assert_eq!(f.true_optimal, 8, "true optimum is 8");
        let ev: usize = f.evictions_per_machine.iter().sum();
        assert!(ev > 0, "skew-driven evictions occurred");
        let max = *f.tasks_per_machine.iter().max().unwrap();
        let min = *f.tasks_per_machine.iter().min().unwrap();
        assert!(max > min, "task distribution skewed");
        println!("fig11 claims OK\n");
    }

    println!("[figures done in {:.1} s]", t0.elapsed().as_secs_f64());
}
