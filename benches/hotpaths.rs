//! Micro-benchmarks of the hot paths (the §Perf instrument panel):
//! simulator task throughput, memory-manager ops, NNLS fitting (Rust vs
//! PJRT Pallas kernel), planner search (pruned vs frozen exhaustive), the
//! multi-tenant fleet plan, the sharded profile-store serve loop (cold
//! misses vs lock-free hot reads), selector, and listener-log
//! serialization.
//! `cargo bench --bench hotpaths`.
//!
//! Recording a baseline:
//! `BLINK_BENCH_JSON=BENCH_hotpaths.json cargo bench --bench hotpaths`;
//! CI smoke adds `BLINK_BENCH_SMOKE=1` (fewer samples, same schema).

use blink::blink::models::{FitBackend, FitProblem, RustFit};
use blink::blink::{
    adapt, plan, plan_exhaustive, plan_fleet, select_cluster_size, serve_batch, AdaptConfig,
    Advisor, FleetPlanInput, PlanInput, ProfileStore,
};
use blink::cost::{pricing_by_name, PerInstanceHour};
use blink::memory::{EvictionPolicy, PartitionKey, UnifiedMemory};
use blink::metrics::{EventLog, RunSummary};
use blink::sim::{simulate, ClusterSpec, InstanceCatalog, MachineSpec, SimOptions};
use blink::util::bench::Bencher;
use blink::workloads::{app_by_name, SynthConfig, FULL_SCALE};

fn main() {
    let mut b = Bencher::from_env();

    // ---- simulator: full svm actual run (2000 parts x 101 jobs) --------
    let svm = app_by_name("svm").unwrap();
    let profile = svm.profile(FULL_SCALE);
    let tasks = profile.parallelism * (profile.iterations + 1);
    let m = b.bench("sim/svm-100pct-7-machines", || {
        simulate(
            &profile,
            &ClusterSpec::workers(7),
            SimOptions { seed: 1, detailed_log: false, ..Default::default() },
        )
        .unwrap()
    });
    println!(
        "  -> {:.2} M simulated tasks/s",
        tasks as f64 / m.mean_s() / 1e6
    );

    // area-A (recompute-heavy, memory churn) variant
    let m = b.bench("sim/svm-100pct-2-machines-areaA", || {
        simulate(
            &profile,
            &ClusterSpec::workers(2),
            SimOptions { seed: 1, detailed_log: false, ..Default::default() },
        )
        .unwrap()
    });
    println!("  -> {:.2} M tasks/s", tasks as f64 / m.mean_s() / 1e6);

    // engine with a disturbance scenario (journal + event-queue overhead)
    let spot_fleet =
        blink::sim::FleetSpec::homogeneous(blink::sim::InstanceType::paper_worker(), 7).unwrap();
    let m = b.bench("engine/svm-100pct-7-machines-spot", || {
        blink::sim::engine::run(
            &profile,
            &spot_fleet,
            &blink::sim::scenario::SpotPreemption::default(),
            SimOptions { seed: 1, detailed_log: false, ..Default::default() },
        )
        .unwrap()
    });
    println!("  -> {:.2} M tasks/s under spot preemption", tasks as f64 / m.mean_s() / 1e6);

    // arena journal + detailed log: every task event flows through the
    // flat event arena before the barrier flush
    let quiet_fleet =
        blink::sim::FleetSpec::homogeneous(blink::sim::InstanceType::paper_worker(), 4).unwrap();
    let m = b.bench("engine/arena-svm-100pct-4-machines-detailed", || {
        blink::sim::engine::run(
            &profile,
            &quiet_fleet,
            &blink::sim::scenario::NoDisturbances,
            SimOptions { seed: 1, detailed_log: true, ..Default::default() },
        )
        .unwrap()
    });
    println!("  -> {:.2} M detailed tasks/s through the arena", tasks as f64 / m.mean_s() / 1e6);

    // ---- memory manager --------------------------------------------------
    b.bench("memory/insert-evict-10k", || {
        let mut mem = UnifiedMemory::new(1000.0, 500.0, EvictionPolicy::Lru);
        for i in 0..10_000 {
            mem.insert(PartitionKey { dataset: i % 4, index: i }, 1.0, 3, 1);
        }
        mem.stats().evictions
    });

    // ---- predictor fit: rust vs pjrt --------------------------------------
    let problems: Vec<FitProblem> = (0..16)
        .map(|i| {
            let xs: Vec<Vec<f64>> =
                (1..=4).map(|s| vec![1.0, s as f64 + i as f64 * 0.1]).collect();
            let y: Vec<f64> = xs.iter().map(|r| 2.0 + 3.0 * r[1]).collect();
            FitProblem { x: xs, y, w: vec![1.0; 4] }
        })
        .collect();
    let mut rust = RustFit::default();
    b.bench("fit/rust-nnls-16-problems", || rust.fit_batch(&problems));

    if blink::runtime::artifacts_available() {
        match blink::runtime::Runtime::from_repo_root() {
            Ok(mut rt) => {
                // compile once outside the timing loop
                let _ = rt.get("linfit").expect("linfit compiles");
                let mut fit = blink::runtime::PjrtFit::new(&mut rt);
                b.bench("fit/pjrt-linfit-16-problems", || fit.fit_batch(&problems));
            }
            Err(e) => eprintln!("skipping pjrt bench: {e:#}"),
        }
    } else {
        eprintln!("skipping pjrt bench: run `make artifacts`");
    }

    // ---- planner: branch-and-bound vs the frozen exhaustive grid ----------
    let als = app_by_name("als").unwrap();
    let als_profile = als.profile(FULL_SCALE);
    let input = PlanInput {
        profile: &als_profile,
        cached_total_mb: als.total_true_cached_mb(FULL_SCALE),
        exec_total_mb: als.exec_mem_mb(FULL_SCALE),
    };
    let catalog = InstanceCatalog::all();
    let pricing = PerInstanceHour::hourly();
    let pruned_s =
        b.bench("planner/plan-cloud-x64", || plan(&input, &catalog, &pricing, 64)).median_s();
    let full_s = b
        .bench("planner/plan-exhaustive-cloud-x64", || {
            plan_exhaustive(&input, &catalog, &pricing, 64)
        })
        .median_s();
    println!(
        "  -> pruning speedup {:.2}x on {} types x 64 counts",
        full_s / pruned_s,
        catalog.instances.len()
    );

    // cloud-scale catalog: 512 generated types, same footprint and count
    // range as plan-cloud-x64 so the medians compare directly
    let generated = InstanceCatalog::generate(42, 512);
    let gen_s = b
        .bench("planner/plan-generated-512", || plan(&input, &generated, &pricing, 64))
        .median_s();
    println!(
        "  -> generated-512 at {:.2}x the 6-type cloud median",
        gen_s / pruned_s
    );

    // ---- serve: the sharded profile store hot path ------------------------
    // one JSONL batch of recommend queries over 100 seeded synthetic apps
    // (the PR 5 generator), the advisor-as-a-service workload shape
    let serve_input = (1..=100u64)
        .map(|s| format!("{{\"query\":\"recommend\",\"app\":\"synth:mixed:{s}\",\"scale\":800}}"))
        .collect::<Vec<_>>()
        .join("\n");

    // cold path: every query is a profile miss (fresh store per sample,
    // 100 sampling phases + fits inside the timed region)
    b.bench("serve/cold-100-profile-misses", || {
        let store = ProfileStore::builder().shards(8).build();
        serve_batch(&store, &serve_input, 1).len()
    });

    // hot path: a warmed store answers the same batch lock-free; the
    // 1-thread vs 8-thread pair is the read-path scaling instrument
    let store = ProfileStore::builder().shards(8).build();
    serve_batch(&store, &serve_input, 0); // warm all 100 profiles
    let one_s = b
        .bench("serve/hot-queries-1-thread", || serve_batch(&store, &serve_input, 1).len())
        .median_s();
    let eight_s = b
        .bench("serve/hot-queries-8-threads", || serve_batch(&store, &serve_input, 8).len())
        .median_s();
    println!(
        "  -> hot store: {:.0} q/s at 1 thread, {:.0} q/s at 8 threads ({:.2}x)",
        100.0 / one_s,
        100.0 / eight_s,
        one_s / eight_s
    );

    // ---- adaptive: the observe -> refit -> re-plan -> act loop -------------
    // one noisy-preset synthetic workload (heavy measurement noise on tiny
    // caches, the §6.2 regime the sample fit mis-estimates); the timed
    // region is the whole loop — static engine run with job-barrier
    // observation intake, RLS refits, the divergence check, and the gated
    // corrective run when it fires
    let noisy = SynthConfig::by_name("noisy").unwrap().generate(17);
    let mut fit_backend = RustFit::default();
    let mut advisor = Advisor::builder().max_machines(12).build(&mut fit_backend);
    let trained = advisor.profile(&noisy);
    let paper_catalog = InstanceCatalog::by_name("paper").unwrap();
    let adapt_pricing = pricing_by_name("machine-seconds").unwrap();
    let m = b.bench("adaptive/replan-noisy-preset", || {
        adapt(
            &trained,
            300.0,
            &paper_catalog,
            adapt_pricing.as_ref(),
            &blink::sim::scenario::NoDisturbances,
            &AdaptConfig::default(),
        )
        .unwrap()
        .observations
    });
    println!("  -> adaptive loop at {:.1} runs/s", 1.0 / m.mean_s());

    // ---- fleet: the shared multi-tenant plan ------------------------------
    // three paper tenants (svm + km + lr) over the full cloud catalog:
    // the §5.4 bound on the summed working sets, evaluated per
    // (type x count), plus the serialized-runtime cost ranking
    let fleet_apps: Vec<_> =
        ["svm", "km", "lr"].iter().map(|n| app_by_name(n).unwrap()).collect();
    let fleet_profiles: Vec<_> = fleet_apps.iter().map(|a| a.profile(FULL_SCALE)).collect();
    let fleet_inputs: Vec<FleetPlanInput<'_>> = fleet_apps
        .iter()
        .zip(&fleet_profiles)
        .map(|(a, p)| FleetPlanInput {
            name: a.name.clone(),
            profile: p,
            cached_total_mb: a.total_true_cached_mb(FULL_SCALE),
            exec_total_mb: a.exec_mem_mb(FULL_SCALE),
        })
        .collect();
    let m = b.bench("fleet/plan-3-tenants", || {
        plan_fleet(&fleet_inputs, &catalog, &pricing, 64).grid.len()
    });
    println!("  -> 3-tenant shared plan at {:.0} plans/s", 1.0 / m.mean_s());

    // ---- selector ---------------------------------------------------------
    let machine = MachineSpec::worker_node();
    b.bench("selector/sweep-64-sizes", || {
        let mut acc = 0;
        for c in 1..=64 {
            acc += select_cluster_size(c as f64 * 1000.0, 5000.0, &machine, 64).machines;
        }
        acc
    });

    // ---- listener logs ------------------------------------------------------
    let res = simulate(
        &app_by_name("km").unwrap().profile(FULL_SCALE),
        &ClusterSpec::workers(4),
        SimOptions { seed: 1, ..Default::default() },
    )
    .unwrap();
    let text = res.log.to_jsonl();
    println!("  (log: {} events, {} KB)", res.log.events.len(), text.len() / 1024);
    b.bench("metrics/serialize-jsonl", || res.log.to_jsonl());
    b.bench("metrics/parse-jsonl+summarize", || {
        RunSummary::from_log(&EventLog::from_jsonl(&text).unwrap())
    });

    match b.write_json_from_env("hotpaths") {
        Ok(Some(path)) => println!("bench json -> {path}"),
        Ok(None) => {}
        Err(e) => eprintln!("bench json write failed: {e}"),
    }

    println!("\nall hot-path benches done");
}
