//! Differential test harness over the synthetic workload space.
//!
//! The paper reproduction pins 16 hand-measured rows; this module turns
//! the repo into a property-tested framework over *unbounded* app shapes:
//! for each [`crate::workloads::synth`] workload it asserts cross-layer
//! invariants across a scenario × catalog × pricing matrix:
//!
//! * **recommend = exhaustive search** — the §5.4 analytic pick equals a
//!   brute-force scan of the eviction-free condition over every count;
//! * **planner degeneracy** — on a single-type catalog the catalog search
//!   collapses to `select_cluster_size`, and ranked picks stay ordered
//!   (eviction-free first, then cheapest);
//! * **generated-catalog exactness** — over a seeded generated catalog
//!   with an explicit storage-fraction grid, the pruned `plan_search` is
//!   byte-identical to the exhaustive `(type × fraction × count)`
//!   reference;
//! * **deficit monotonicity** — the per-machine cache deficit never
//!   shrinks as the data scale grows (fixed cluster);
//! * **max-scale inversion** — just below `TrainedProfile::max_scale` the
//!   workload fits the cluster, just above it does not;
//! * **calm engine = analytic quote** — under `NoDisturbances` the priced
//!   realized timeline equals the naive `machines × duration` quote for
//!   every pricing model;
//! * **scenario signatures** — every `sim::scenario::by_name` scenario
//!   leaves its fingerprint on the realized run (machines lost/joined,
//!   stretched runtime);
//! * **adaptive loop** ([`check_adaptive`]) — the observe → refit →
//!   re-plan → act loop never realizes a higher cost than the static
//!   pick, never re-plans a well-estimated workload, always re-plans a
//!   systematically under-fit one, and replays bit-identically under
//!   every worker count;
//! * **multi-tenant fleet** ([`check_fleet`]) — a one-tenant fleet run is
//!   byte-identical to the single-tenant engine, adding a tenant never
//!   shrinks any type's eviction-free floor, and the interleaved N-tenant
//!   run replays byte-for-byte under every worker count and both
//!   fairness knobs.
//!
//! Every [`Violation`] carries the workload's generation seed, so any
//! counterexample found in CI reproduces from the log
//! (`blink synth --preset <p> --seed <s> --check`).

use std::fmt;

use crate::blink::{
    adaptive, machine_split, plan_exhaustive, plan_exhaustive_search, plan_fleet, plan_search,
    results_bytes, select_cluster_size, serve_batch, Advisor, FleetPlanInput, PlanInput,
    ProfileStore, RustFit, SearchSpace, TrainedProfile,
};
use crate::cost::pricing_by_name;
use crate::memory::EvictionPolicy;
use crate::metrics::RunSummary;
use crate::sim::{
    engine, scenario, FleetFairness, FleetSpec, InstanceCatalog, MachineSpec, SimOptions,
    TenantSpec, WorkloadProfile,
};
use crate::util::par::sweep_range_with;
use crate::workloads::{AppModel, SynthConfig};

/// One failed invariant, with everything needed to reproduce it.
#[derive(Debug, Clone)]
pub struct Violation {
    pub workload: String,
    /// The generator seed of the workload (`blink synth --seed <s>`).
    pub seed: u64,
    pub invariant: &'static str,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] workload {} (generator seed {}): {}",
            self.invariant, self.workload, self.seed, self.detail
        )
    }
}

/// The differential matrix: which scales, scenarios, catalogs and pricing
/// models every workload is checked against.
#[derive(Debug, Clone)]
pub struct MatrixSpec {
    /// Probe scales for the analytic invariants (paper units).
    pub scales: Vec<f64>,
    /// The scale engine-level invariants run at.
    pub engine_scale: f64,
    /// Scenarios resolved via [`scenario::by_name`].
    pub scenario_names: Vec<&'static str>,
    /// Catalogs resolved via [`InstanceCatalog::by_name`].
    pub catalog_names: Vec<&'static str>,
    /// Pricing models resolved via [`pricing_by_name`].
    pub pricing_names: Vec<&'static str>,
    pub max_machines: usize,
    /// Seed of the engine runs (task-duration noise stream).
    pub engine_seed: u64,
    /// `(seed, types)` of the [`InstanceCatalog::generate`] catalog the
    /// `plan-generated-exact` invariant plans over. Kept small so the
    /// quadratic exhaustive reference stays cheap per workload.
    pub generated_catalog: (u64, usize),
    /// Storage-fraction grid for the `plan-generated-exact` invariant.
    pub fraction_grid: Vec<f64>,
}

impl Default for MatrixSpec {
    fn default() -> Self {
        MatrixSpec {
            scales: vec![100.0, 400.0, 1000.0, 2000.0],
            engine_scale: 300.0,
            scenario_names: vec![
                "none",
                "spot",
                "straggler",
                "failure",
                "autoscale",
                "deficit",
                "contention",
            ],
            catalog_names: vec!["paper", "cloud"],
            pricing_names: vec!["machine-seconds", "hourly"],
            max_machines: 12,
            engine_seed: 11,
            generated_catalog: (7, 12),
            fraction_grid: vec![0.3, 0.5, 0.7],
        }
    }
}

/// Outcome of a matrix run.
#[derive(Debug, Clone, Default)]
pub struct MatrixReport {
    pub workloads: usize,
    pub checks: usize,
    pub violations: Vec<Violation>,
}

impl MatrixReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Panic with every violation (and its reproduction seed) when any
    /// invariant failed — the test-facing entry point.
    pub fn assert_ok(&self) {
        if !self.ok() {
            let lines: Vec<String> = self.violations.iter().map(|v| v.to_string()).collect();
            panic!(
                "differential matrix: {} of {} checks failed over {} workloads:\n{}",
                self.violations.len(),
                self.checks,
                self.workloads,
                lines.join("\n")
            );
        }
    }
}

fn violation(app: &AppModel, seed: u64, invariant: &'static str, detail: String) -> Violation {
    Violation { workload: app.name.clone(), seed, invariant, detail }
}

/// Brute-force §5.4: the minimal count satisfying the eviction-free
/// condition on predicted footprints, or `None` when no count ≤ max does.
fn exhaustive_pick(
    cached: f64,
    exec: f64,
    machine: &MachineSpec,
    max_machines: usize,
) -> Option<usize> {
    (1..=max_machines).find(|&n| {
        let (_, capacity) = machine_split(exec, machine, n);
        cached / n as f64 < capacity
    })
}

/// Analytic invariants: recommend vs exhaustive search, planner
/// degeneracy + ranking, deficit monotonicity, max-scale inversion.
/// Returns `(checks_run, violations)`.
pub fn check_profile(
    app: &AppModel,
    seed: u64,
    profile: &TrainedProfile,
    spec: &MatrixSpec,
) -> (usize, Vec<Violation>) {
    let mut checks = 0usize;
    let mut out = Vec::new();
    let worker = MachineSpec::worker_node();

    // recommend = exhaustive search, at every probe scale
    for &scale in &spec.scales {
        checks += 1;
        let rec = profile.recommend(scale, &worker);
        if profile.no_cached_data() {
            if rec.machines != 1 {
                out.push(violation(
                    app,
                    seed,
                    "recommend-uncached",
                    format!("no cached data but pick = {} at scale {scale}", rec.machines),
                ));
            }
            continue;
        }
        let cached = profile.predicted_cached_mb(scale);
        let exec = profile.predicted_exec_mb(scale);
        let want = exhaustive_pick(cached, exec, &worker, spec.max_machines);
        let sel = rec.selection.as_ref().expect("cached data implies a selection");
        match want {
            Some(n) if !sel.saturated && n == rec.machines => {}
            None if sel.saturated && rec.machines == spec.max_machines => {}
            _ => out.push(violation(
                app,
                seed,
                "recommend-exhaustive",
                format!(
                    "scale {scale}: pick {} (saturated {}) vs exhaustive {want:?}",
                    rec.machines, sel.saturated
                ),
            )),
        }
    }

    // planner degeneracy + ranked ordering, per catalog and pricing
    for catalog_name in &spec.catalog_names {
        let catalog = InstanceCatalog::by_name(catalog_name).expect("matrix catalog exists");
        for pricing_name in &spec.pricing_names {
            let pricing = pricing_by_name(pricing_name).expect("matrix pricing exists");
            let scale = spec.engine_scale;
            checks += 1;
            let advice = profile.plan(scale, &catalog, pricing.as_ref());
            let plan = &advice.plan;
            if plan.ranked.len() != catalog.instances.len() {
                out.push(violation(
                    app,
                    seed,
                    "plan-coverage",
                    format!(
                        "catalog '{catalog_name}': {} picks for {} types",
                        plan.ranked.len(),
                        catalog.instances.len()
                    ),
                ));
            }
            // the pruned grid keeps, per type, exactly the counts from the
            // §5.4 lower bound up (the whole 1..=max grid when every type
            // saturates and plan() falls back to the exhaustive search)
            let expected_grid: usize = if plan.ranked.iter().all(|t| t.selection.saturated) {
                catalog.instances.len() * spec.max_machines
            } else {
                plan.ranked
                    .iter()
                    .map(|t| spec.max_machines - t.selection.machines + 1)
                    .sum()
            };
            if plan.grid.len() != expected_grid {
                out.push(violation(
                    app,
                    seed,
                    "plan-grid",
                    format!(
                        "catalog '{catalog_name}': grid size {} (expected {expected_grid})",
                        plan.grid.len()
                    ),
                ));
            }
            // pruning must be invisible outside the grid: ranked picks and
            // Pareto front byte-identical to the frozen exhaustive search
            checks += 1;
            let wp = app.profile(scale);
            let input = PlanInput {
                profile: &wp,
                cached_total_mb: profile.predicted_cached_mb(scale),
                exec_total_mb: profile.predicted_exec_mb(scale),
            };
            let full = plan_exhaustive(&input, &catalog, pricing.as_ref(), spec.max_machines);
            if plan.ranked != full.ranked || plan.pareto != full.pareto {
                out.push(violation(
                    app,
                    seed,
                    "plan-pruned-exact",
                    format!(
                        "catalog '{catalog_name}' pricing '{pricing_name}': \
                         branch-and-bound diverged from the exhaustive grid"
                    ),
                ));
            }
            // free picks precede saturated ones; free block sorted by cost
            let mut seen_saturated = false;
            let mut last_cost = f64::NEG_INFINITY;
            for pick in &plan.ranked {
                if pick.candidate.eviction_free {
                    if seen_saturated || pick.candidate.predicted_cost < last_cost {
                        out.push(violation(
                            app,
                            seed,
                            "plan-ranking",
                            format!(
                                "catalog '{catalog_name}' pricing '{pricing_name}': ranked order broken at {}",
                                pick.candidate.instance
                            ),
                        ));
                        break;
                    }
                    last_cost = pick.candidate.predicted_cost;
                } else {
                    seen_saturated = true;
                }
            }
        }
        // degeneracy: each type alone reproduces the §5.4 pick. The pick
        // is pricing-independent, so one pricing model suffices.
        let pricing = pricing_by_name(spec.pricing_names[0]).expect("matrix pricing exists");
        let scale = spec.engine_scale;
        for instance in &catalog.instances {
            checks += 1;
            let single = InstanceCatalog::single(instance.clone());
            let one = profile.plan(scale, &single, pricing.as_ref());
            let sel = select_cluster_size(
                profile.predicted_cached_mb(scale),
                profile.predicted_exec_mb(scale),
                &instance.spec,
                spec.max_machines,
            );
            match one.plan.best() {
                Some(best) if best.candidate.machines == sel.machines => {}
                other => out.push(violation(
                    app,
                    seed,
                    "plan-degeneracy",
                    format!(
                        "single-type '{}': plan {:?} vs selector {}",
                        instance.name,
                        other.map(|p| p.candidate.machines),
                        sel.machines
                    ),
                )),
            }
        }
    }

    // the fraction-dimension search: over a seeded generated catalog with
    // an explicit storage-fraction grid, the pruned plan_search must be
    // byte-identical to the exhaustive (type × fraction × count) reference
    {
        checks += 1;
        let (gseed, gtypes) = spec.generated_catalog;
        let catalog = InstanceCatalog::generate(gseed, gtypes);
        let pricing = pricing_by_name(spec.pricing_names[0]).expect("matrix pricing exists");
        let scale = spec.engine_scale;
        let wp = app.profile(scale);
        let input = PlanInput {
            profile: &wp,
            cached_total_mb: profile.predicted_cached_mb(scale),
            exec_total_mb: profile.predicted_exec_mb(scale),
        };
        let space = SearchSpace {
            max_machines: spec.max_machines,
            storage_fractions: spec.fraction_grid.clone(),
        };
        let fast = plan_search(&input, &catalog, pricing.as_ref(), &space);
        let full = plan_exhaustive_search(&input, &catalog, pricing.as_ref(), &space);
        if fast.ranked != full.ranked || fast.pareto != full.pareto {
            out.push(violation(
                app,
                seed,
                "plan-generated-exact",
                format!(
                    "generated:{gseed}:{gtypes} with fractions {:?}: pruned search \
                     diverged from the exhaustive grid",
                    spec.fraction_grid
                ),
            ));
        }
    }

    // cache deficit is monotone in scale on a fixed cluster
    if !profile.no_cached_data() {
        checks += 1;
        let n = 4usize;
        let mut scales = spec.scales.clone();
        scales.sort_by(f64::total_cmp);
        let deficit = |scale: f64| {
            let (_, capacity) = machine_split(profile.predicted_exec_mb(scale), &worker, n);
            (profile.predicted_cached_mb(scale) / n as f64 - capacity).max(0.0)
        };
        let mut last = f64::NEG_INFINITY;
        for &scale in &scales {
            let d = deficit(scale);
            if d + 1e-6 < last {
                out.push(violation(
                    app,
                    seed,
                    "deficit-monotone",
                    format!("deficit shrank to {d} MB at scale {scale} (was {last})"),
                ));
                break;
            }
            last = d;
        }
    }

    // max-scale inversion: just below the bound fits, just above does not
    for n in [4usize, spec.max_machines] {
        checks += 1;
        let bound = profile.max_scale(&worker, n);
        if !bound.is_finite() {
            if !profile.no_cached_data() {
                out.push(violation(
                    app,
                    seed,
                    "max-scale-finite",
                    format!("cached data but max_scale({n}) is infinite"),
                ));
            }
            continue;
        }
        if bound > 1e9 {
            // a ~zero fitted slope makes the bound effectively unbounded
            // (bounds::max_scale bails after its bracket guard) — there is
            // no boundary to invert
            continue;
        }
        let fits = |scale: f64| {
            let (_, capacity) = machine_split(profile.predicted_exec_mb(scale), &worker, n);
            profile.predicted_cached_mb(scale) / n as f64 < capacity
        };
        if !fits(bound * 0.995) {
            out.push(violation(
                app,
                seed,
                "max-scale-inverse",
                format!("scale {:.2} (0.995 × bound) does not fit {n} machines", bound * 0.995),
            ));
        }
        if fits(bound * 1.05) {
            out.push(violation(
                app,
                seed,
                "max-scale-inverse",
                format!("scale {:.2} (1.05 × bound) still fits {n} machines", bound * 1.05),
            ));
        }
    }

    (checks, out)
}

/// Engine-level invariants: calm realized price equals the analytic quote
/// for every pricing model, and every scenario leaves its signature on the
/// realized run. Returns `(checks_run, violations)`.
pub fn check_engine(
    app: &AppModel,
    seed: u64,
    profile: &TrainedProfile,
    spec: &MatrixSpec,
) -> (usize, Vec<Violation>) {
    let mut checks = 0usize;
    let mut out = Vec::new();
    let scale = spec.engine_scale;
    let wp = app.profile(scale);
    let opts = || SimOptions {
        policy: EvictionPolicy::Lru,
        seed: spec.engine_seed,
        compute: None,
        detailed_log: false,
    };

    // calm engine run == naive quote, on each catalog's best pick
    for catalog_name in &spec.catalog_names {
        let catalog = InstanceCatalog::by_name(catalog_name).expect("matrix catalog exists");
        let pricing0 = pricing_by_name(spec.pricing_names[0]).expect("matrix pricing exists");
        let advice = profile.plan(scale, &catalog, pricing0.as_ref());
        let Some(best) = advice.plan.best() else { continue };
        let Some(instance) = catalog.get(&best.candidate.instance) else { continue };
        let machines = best.candidate.machines;
        let fleet = match FleetSpec::homogeneous(instance.clone(), machines) {
            Ok(f) => f,
            Err(e) => {
                out.push(violation(
                    app,
                    seed,
                    "calm-quote",
                    format!("pick {} x{machines} is not a valid fleet: {e}", instance.name),
                ));
                continue;
            }
        };
        checks += 1;
        let calm = match engine::run(&wp, &fleet, &scenario::NoDisturbances, opts()) {
            Ok(r) => r,
            Err(e) => {
                out.push(violation(app, seed, "calm-quote", format!("engine failed: {e}")));
                continue;
            }
        };
        let s = RunSummary::from_log(&calm.sim.log);
        if s.machines_lost != 0 || s.machines_joined != 0 {
            out.push(violation(
                app,
                seed,
                "calm-quote",
                format!("NoDisturbances lost {} / joined {}", s.machines_lost, s.machines_joined),
            ));
        }
        for pricing_name in &spec.pricing_names {
            checks += 1;
            let pricing = pricing_by_name(pricing_name).expect("matrix pricing exists");
            let quote = pricing.price(instance, machines, s.duration_s);
            let realized = pricing.price_timeline(&calm.timeline);
            if (realized - quote).abs() > 1e-6 * quote.max(1.0) {
                out.push(violation(
                    app,
                    seed,
                    "calm-quote",
                    format!(
                        "'{pricing_name}' on {} x{machines}: realized {realized} vs quote {quote}",
                        instance.name
                    ),
                ));
            }
        }
    }

    // scenario signatures on a fixed 4-worker fleet
    let fleet = FleetSpec::homogeneous(crate::sim::InstanceType::paper_worker(), 4)
        .expect("4 workers is a valid fleet");
    let base = match engine::run(&wp, &fleet, &scenario::NoDisturbances, opts()) {
        Ok(r) => RunSummary::from_log(&r.sim.log),
        Err(e) => {
            out.push(violation(app, seed, "scenario-baseline", format!("engine failed: {e}")));
            return (checks + 1, out);
        }
    };
    for name in &spec.scenario_names {
        checks += 1;
        let sc = scenario::by_name(name).expect("matrix scenario exists");
        let run = match engine::run(&wp, &fleet, sc.as_ref(), opts()) {
            Ok(r) => r,
            Err(e) => {
                out.push(violation(
                    app,
                    seed,
                    "scenario-signature",
                    format!("'{name}' engine failed: {e}"),
                ));
                continue;
            }
        };
        let s = RunSummary::from_log(&run.sim.log);
        let fail = |what: &str| {
            format!(
                "'{name}' (engine seed {}): {what} (lost {}, joined {}, {:.1}s vs calm {:.1}s)",
                spec.engine_seed, s.machines_lost, s.machines_joined, s.duration_s, base.duration_s
            )
        };
        let bad: Option<String> = match *name {
            "none" => (s.duration_s != base.duration_s
                || s.machines_lost != 0
                || s.machines_joined != 0)
                .then(|| fail("must replay the baseline exactly")),
            "spot" => (s.machines_lost < 1).then(|| fail("must reclaim a machine")),
            "straggler" => {
                (s.duration_s <= base.duration_s).then(|| fail("must stretch the run"))
            }
            "failure" => (s.machines_lost < 1 || s.machines_joined < 1)
                .then(|| fail("must lose and restart a machine")),
            "autoscale" => (s.machines_joined < 1).then(|| fail("must add machines")),
            "deficit" => {
                // the conditional controller: scale out iff the fleet's
                // storage floor cannot hold the measured working set
                let demand: f64 = wp.cached.iter().map(|d| d.measured_total_mb).sum();
                let capacity = 4.0
                    * crate::sim::InstanceType::paper_worker().spec.storage_floor_mb();
                if demand > capacity {
                    (s.machines_joined < 1)
                        .then(|| fail("must add machines to cover the deficit"))
                } else {
                    (s.duration_s != base.duration_s || s.machines_joined != 0)
                        .then(|| fail("no deficit: must replay the baseline exactly"))
                }
            }
            "contention" => {
                // foreign memory pressure keeps the fleet intact; evicted
                // blocks recompute, so the run can only hold or stretch
                (s.machines_lost != 0
                    || s.machines_joined != 0
                    || s.duration_s + 1e-9 < base.duration_s)
                    .then(|| fail("pressure must keep the fleet intact and never shorten the run"))
            }
            other => Some(format!("unknown scenario '{other}' in the matrix spec")),
        };
        if let Some(detail) = bad {
            out.push(violation(app, seed, "scenario-signature", detail));
        }
    }

    (checks, out)
}

/// The serve determinism contract (`blink serve` / [`serve_batch`]): one
/// JSONL batch over `count` seeded synthetic workloads — recommend,
/// max_scale and plan queries via their `synth:<preset>:<seed>` spellings,
/// plus deliberately malformed lines — answered at a grid of
/// `shard × thread` settings. Two invariants:
///
/// * **serve-deterministic** — every run's [`results_bytes`] payload is
///   byte-identical to the single-shard serial reference, no matter how
///   many shards spread the keys or how many threads race the batch;
/// * **serve-one-phase-per-key** — each distinct workload pays exactly one
///   sampling phase per store, however many of its queries race.
///
/// Returns `(checks_run, violations)`; violations carry `first_seed` so a
/// counterexample batch reproduces from the log.
pub fn check_serve(preset: &str, first_seed: u64, count: usize) -> (usize, Vec<Violation>) {
    let mut checks = 0usize;
    let mut out = Vec::new();
    let mut lines: Vec<String> = Vec::new();
    for seed in first_seed..first_seed + count as u64 {
        let app = format!("synth:{preset}:{seed}");
        lines.push(format!("{{\"query\":\"recommend\",\"app\":\"{app}\",\"scale\":800}}"));
        lines.push(format!("{{\"query\":\"max_scale\",\"app\":\"{app}\",\"machines\":4}}"));
        if seed % 3 == 0 {
            lines.push(format!(
                "{{\"query\":\"plan\",\"app\":\"{app}\",\"scale\":400,\"catalog\":\"paper\"}}"
            ));
        }
        if seed % 4 == 0 {
            lines.push("definitely not a json query".to_string());
        }
    }
    let input = lines.join("\n");
    let workload = format!("serve:{preset}x{count}");
    let fail = |invariant: &'static str, detail: String, out: &mut Vec<Violation>| {
        out.push(Violation { workload: workload.clone(), seed: first_seed, invariant, detail });
    };
    let reference_store = ProfileStore::builder().shards(1).build();
    let reference = results_bytes(&serve_batch(&reference_store, &input, 1));
    for &shards in &[1usize, 2, 8, 64] {
        for &threads in &[1usize, 2, 4, 8] {
            let store = ProfileStore::builder().shards(shards).build();
            let got = results_bytes(&serve_batch(&store, &input, threads));
            checks += 1;
            if got != reference {
                fail(
                    "serve-deterministic",
                    format!("{shards} shards x {threads} threads diverged from serial/1-shard"),
                    &mut out,
                );
            }
            checks += 1;
            if store.sampling_phases() != count {
                fail(
                    "serve-one-phase-per-key",
                    format!(
                        "{shards} shards x {threads} threads: {} sampling phases for {count} apps",
                        store.sampling_phases()
                    ),
                    &mut out,
                );
            }
        }
    }
    (checks, out)
}

/// The adaptive-loop contract (`blink adapt` / [`adaptive::adapt`]): run
/// the observe → refit → re-plan → act loop over `count` seeded synthetic
/// workloads from `preset` and assert the differential invariants:
///
/// * **adaptive-dominates** — the realized adaptive cost never exceeds
///   the static pick's realized cost (the act gate only adopts a cheaper
///   corrective run, so the loop can refuse but never regress);
/// * **adaptive-no-replan** — on the well-estimated `linear` preset the
///   refit stays inside the default divergence threshold at every job
///   barrier, so the re-planner must never fire;
/// * **adaptive-replan-fired** — on the `superlinear` preset, whose growth
///   exponent the three sample scales systematically under-fit, at least
///   one workload in the batch must re-plan;
/// * **adaptive-deterministic** — re-running the whole loop under every
///   worker count of the thread matrix reproduces the serial reference's
///   [`adaptive::AdaptOutcome::fingerprint`] byte for byte.
///
/// Returns `(checks_run, violations)`; every violation carries the
/// generator seed so a counterexample reproduces from the log
/// (`blink adapt --app synth:<preset>:<seed>` once spelled via `synth`).
pub fn check_adaptive(preset: &str, first_seed: u64, count: usize) -> (usize, Vec<Violation>) {
    let mut checks = 0usize;
    let mut out = Vec::new();
    let cfg = SynthConfig::by_name(preset).expect("known synth preset");
    let catalog = InstanceCatalog::by_name("paper").expect("paper catalog exists");
    let pricing = pricing_by_name("machine-seconds").expect("matrix pricing exists");
    let scale = MatrixSpec::default().engine_scale;
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().max_machines(12).build(&mut backend);
    let mut profiles: Vec<(u64, AppModel, TrainedProfile)> = Vec::new();
    for (seed, app) in cfg.generate_many(first_seed, count) {
        let profile = advisor.profile(&app);
        profiles.push((seed, app, profile));
    }
    if profiles.is_empty() {
        return (checks, out);
    }
    let run = |seed: u64, profile: &TrainedProfile| {
        adaptive::adapt(
            profile,
            scale,
            &catalog,
            pricing.as_ref(),
            &scenario::NoDisturbances,
            &adaptive::AdaptConfig { seed, ..Default::default() },
        )
    };

    let mut replans = 0usize;
    let mut reference: Vec<String> = Vec::new();
    for (seed, app, profile) in &profiles {
        checks += 1;
        let outcome = match run(*seed, profile) {
            Ok(o) => o,
            Err(e) => {
                out.push(violation(app, *seed, "adaptive-run", format!("adapt failed: {e}")));
                reference.push(String::new());
                continue;
            }
        };
        if outcome.adaptive_cost > outcome.static_cost * (1.0 + 1e-9) {
            out.push(violation(
                app,
                *seed,
                "adaptive-dominates",
                format!(
                    "adaptive cost {} exceeds the static pick's {}",
                    outcome.adaptive_cost, outcome.static_cost
                ),
            ));
        }
        checks += 1;
        if preset == "linear" {
            if let Some(d) = &outcome.decision {
                out.push(violation(
                    app,
                    *seed,
                    "adaptive-no-replan",
                    format!(
                        "well-estimated preset re-planned at job {} (divergence {:.3})",
                        d.job, d.divergence
                    ),
                ));
            }
        }
        if outcome.decision.is_some() {
            replans += 1;
        }
        reference.push(outcome.fingerprint());
    }
    if preset == "superlinear" {
        checks += 1;
        if replans == 0 {
            out.push(Violation {
                workload: format!("adapt:{preset}x{count}"),
                seed: first_seed,
                invariant: "adaptive-replan-fired",
                detail: format!(
                    "no workload in seeds {first_seed}..{} re-planned",
                    first_seed + count as u64
                ),
            });
        }
    }

    // determinism: the whole loop re-run under each worker count must
    // reproduce the serial fingerprints byte for byte
    for &workers in &[1usize, 2, 8, 64] {
        checks += 1;
        let got = sweep_range_with(workers, 0, profiles.len() - 1, |i| {
            let (seed, _, profile) = &profiles[i];
            run(*seed, profile).map(|o| o.fingerprint()).unwrap_or_default()
        });
        for (i, fp) in got.iter().enumerate() {
            if *fp != reference[i] {
                let (seed, app, _) = &profiles[i];
                out.push(violation(
                    app,
                    *seed,
                    "adaptive-deterministic",
                    format!("{workers}-worker fingerprint diverged from the serial reference"),
                ));
            }
        }
    }
    (checks, out)
}

/// The multi-tenant fleet contract (`blink fleet` / [`engine::run_fleet`]
/// / [`plan_fleet`]): generate `count` tenants from consecutive seeds at
/// the matrix engine scale and assert three invariants on one shared
/// 4-worker fleet:
///
/// * **fleet-degeneracy** — a one-tenant fleet run is byte-identical to
///   the single-tenant engine: same event log (JSONL bytes), same
///   bit-level duration;
/// * **fleet-floor-monotone** — adding a tenant never *shrinks* any
///   catalog type's minimal eviction-free machine count (the §5.4 bound
///   over summed working sets only grows), and a type with no
///   eviction-free count for k tenants has none for k+1 either;
/// * **fleet-deterministic** — the full interleaved run under the
///   `contention` scenario replays byte-for-byte
///   ([`crate::sim::FleetRunResult::fingerprint`]) under every worker
///   count of the thread matrix, for both fairness knobs.
///
/// Returns `(checks_run, violations)`; violations carry the workload's
/// generator seed (batch-level ones the first seed) so a counterexample
/// reproduces from the log.
pub fn check_fleet(preset: &str, first_seed: u64, count: usize) -> (usize, Vec<Violation>) {
    let mut checks = 0usize;
    let mut out = Vec::new();
    let cfg = SynthConfig::by_name(preset).expect("known synth preset");
    let spec = MatrixSpec::default();
    let scale = spec.engine_scale;
    let apps: Vec<(u64, AppModel)> = cfg.generate_many(first_seed, count).into_iter().collect();
    if apps.is_empty() {
        return (checks, out);
    }
    let wps: Vec<WorkloadProfile> = apps.iter().map(|(_, a)| a.profile(scale)).collect();
    let fleet = FleetSpec::homogeneous(crate::sim::InstanceType::paper_worker(), 4)
        .expect("4 workers is a valid fleet");
    let opts = || SimOptions {
        policy: EvictionPolicy::Lru,
        seed: spec.engine_seed,
        compute: None,
        detailed_log: false,
    };

    // degeneracy: one tenant on the fleet == the single-tenant engine
    for ((gseed, app), wp) in apps.iter().zip(&wps) {
        checks += 1;
        let single = match engine::run(wp, &fleet, &scenario::NoDisturbances, opts()) {
            Ok(r) => r,
            Err(e) => {
                out.push(violation(app, *gseed, "fleet-degeneracy", format!("engine failed: {e}")));
                continue;
            }
        };
        let tenant = TenantSpec { name: app.name.clone(), profile: wp.clone() };
        let wrapped = match engine::run_fleet(
            std::slice::from_ref(&tenant),
            &fleet,
            &scenario::NoDisturbances,
            FleetFairness::SharedLru,
            opts(),
        ) {
            Ok(r) => r,
            Err(e) => {
                out.push(violation(app, *gseed, "fleet-degeneracy", format!("fleet failed: {e}")));
                continue;
            }
        };
        if wrapped.logs.len() != 1
            || wrapped.logs[0].to_jsonl() != single.sim.log.to_jsonl()
            || wrapped.duration_s.to_bits() != single.timeline.duration_s.to_bits()
        {
            out.push(violation(
                app,
                *gseed,
                "fleet-degeneracy",
                "one-tenant fleet run diverged from the single-tenant engine".to_string(),
            ));
        }
    }

    // floor monotonicity: plan each tenant-count prefix over the true
    // footprints; per type the eviction-free floor never shrinks
    let pricing = pricing_by_name(spec.pricing_names[0]).expect("matrix pricing exists");
    for catalog_name in &spec.catalog_names {
        let catalog = InstanceCatalog::by_name(catalog_name).expect("matrix catalog exists");
        let mut prev: Vec<Option<usize>> = vec![None; catalog.instances.len()];
        for k in 1..=apps.len() {
            checks += 1;
            let inputs: Vec<FleetPlanInput<'_>> = apps[..k]
                .iter()
                .zip(&wps[..k])
                .map(|((_, a), w)| FleetPlanInput {
                    name: a.name.clone(),
                    profile: w,
                    cached_total_mb: a.total_true_cached_mb(scale),
                    exec_total_mb: a.exec_mem_mb(scale),
                })
                .collect();
            let plan = plan_fleet(&inputs, &catalog, pricing.as_ref(), spec.max_machines);
            for (i, instance) in catalog.instances.iter().enumerate() {
                let floor = plan.min_eviction_free_machines(&instance.name);
                let (gseed, app) = &apps[k - 1];
                match (prev[i], floor, k) {
                    (_, _, 1) => {}
                    (Some(p), Some(n), _) if n < p => out.push(violation(
                        app,
                        *gseed,
                        "fleet-floor-monotone",
                        format!(
                            "catalog '{catalog_name}' type '{}': floor shrank {p} -> {n} \
                             adding tenant {k}",
                            instance.name
                        ),
                    )),
                    (None, Some(n), _) => out.push(violation(
                        app,
                        *gseed,
                        "fleet-floor-monotone",
                        format!(
                            "catalog '{catalog_name}' type '{}': saturated at {} tenants but \
                             eviction-free at {n} machines for {k}",
                            instance.name,
                            k - 1
                        ),
                    )),
                    _ => {}
                }
                prev[i] = floor;
            }
        }
    }

    // determinism: the full interleaved run under contention pressure
    // replays byte-for-byte at every pool size, for both fairness knobs
    let tenants: Vec<TenantSpec> = apps
        .iter()
        .zip(&wps)
        .map(|((_, a), w)| TenantSpec { name: a.name.clone(), profile: w.clone() })
        .collect();
    let contention = scenario::by_name("contention").expect("contention scenario exists");
    let batch = |invariant: &'static str, detail: String, out: &mut Vec<Violation>| {
        out.push(Violation {
            workload: format!("fleet:{preset}x{count}"),
            seed: first_seed,
            invariant,
            detail,
        });
    };
    for fairness in [FleetFairness::SharedLru, FleetFairness::ReservationFloors] {
        let reference = match engine::run_fleet(
            &tenants,
            &fleet,
            contention.as_ref(),
            fairness,
            opts(),
        ) {
            Ok(r) => r.fingerprint(),
            Err(e) => {
                checks += 1;
                batch(
                    "fleet-deterministic",
                    format!("{fairness:?} reference run failed: {e}"),
                    &mut out,
                );
                continue;
            }
        };
        for &workers in &[0usize, 1, 2, 8] {
            checks += 1;
            let got = sweep_range_with(workers, 0, 2, |_| {
                engine::run_fleet(&tenants, &fleet, contention.as_ref(), fairness, opts())
                    .map(|r| r.fingerprint())
                    .unwrap_or_default()
            });
            if got.iter().any(|fp| *fp != reference) {
                batch(
                    "fleet-deterministic",
                    format!(
                        "{fairness:?}: a {workers}-worker replay diverged from the serial \
                         reference fingerprint"
                    ),
                    &mut out,
                );
            }
        }
    }

    (checks, out)
}

/// Run the full differential matrix over `count` workloads generated from
/// consecutive seeds `first_seed..first_seed+count`. One advisor session
/// profiles everything (each workload pays exactly one sampling phase).
pub fn run_matrix(
    cfg: &SynthConfig,
    first_seed: u64,
    count: usize,
    spec: &MatrixSpec,
) -> MatrixReport {
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().max_machines(spec.max_machines).build(&mut backend);
    let mut report = MatrixReport { workloads: count, ..Default::default() };
    for (seed, app) in cfg.generate_many(first_seed, count) {
        let profile = advisor.profile(&app);
        let (c1, v1) = check_profile(&app, seed, &profile, spec);
        let (c2, v2) = check_engine(&app, seed, &profile, spec);
        report.checks += c1 + c2;
        report.violations.extend(v1);
        report.violations.extend(v2);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::app_by_name;

    #[test]
    fn violations_print_the_reproduction_seed() {
        let app = SynthConfig::smoke().generate(99);
        let v = violation(&app, 99, "demo", "detail".into());
        let text = v.to_string();
        assert!(text.contains("seed 99"), "{text}");
        assert!(text.contains(&app.name), "{text}");
        assert!(text.contains("[demo]"), "{text}");
    }

    #[test]
    fn exhaustive_pick_matches_selector_on_paper_apps() {
        let worker = MachineSpec::worker_node();
        for app in crate::workloads::all_apps() {
            let cached = app.total_true_cached_mb(1000.0);
            let exec = app.exec_mem_mb(1000.0);
            let sel = select_cluster_size(cached, exec, &worker, 12);
            match exhaustive_pick(cached, exec, &worker, 12) {
                Some(n) => {
                    assert!(!sel.saturated, "{}", app.name);
                    assert_eq!(n, sel.machines, "{}", app.name);
                }
                None => assert!(sel.saturated, "{}", app.name),
            }
        }
    }

    #[test]
    fn paper_fixture_passes_the_analytic_invariants() {
        // the harness is not synthetic-only: the paper's svm model
        // satisfies every analytic invariant too
        let app = app_by_name("svm").unwrap();
        let spec = MatrixSpec::default();
        let mut b = RustFit::default();
        let mut advisor = Advisor::builder().max_machines(spec.max_machines).build(&mut b);
        let profile = advisor.profile(&app);
        let (checks, violations) = check_profile(&app, 0, &profile, &spec);
        assert!(checks > 10);
        assert!(violations.is_empty(), "{violations:?}");
    }
}
