//! Seeded synthetic workload generator: unlimited iterative app shapes.
//!
//! The paper's evaluation stops at 16 hand-measured rows; Blink's core
//! claim — tiny sample runs predict cached-dataset sizes well enough to
//! pick the optimal cluster — should hold for *any* iterative application.
//! This module generates first-class [`AppModel`]s from a seed and a
//! [`SynthConfig`]: configurable DAG depth/width, number and growth law of
//! cached datasets (linear / sublinear / superlinear in scale, plus noisy
//! "measured" variants mimicking the §4 sampling error), skewed task
//! durations, Block-s preparation phases and multi-dataset cache
//! contention. Generated workloads flow through the whole stack unchanged:
//! `Advisor::profile`, `planner::plan`/`risk_adjusted`, every
//! `sim::scenario` under the event engine, and the CLI (`blink synth`).
//!
//! Generation is deterministic: the same `(preset, seed)` always produces
//! the same model (the differential testkit prints seeds on failure so any
//! counterexample reproduces from the log).

use crate::dag::{AppDag, Transform};
use crate::util::prng::Rng;
use crate::util::units::Mb;

use super::apps::{AppModel, DagSpec, SizeLaw, SizeNoise};
use super::FULL_SCALE;

/// Growth law of a cached dataset's size in the data scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Growth {
    /// `θ0 + θ1·s^0.85` — e.g. deduplicated or compressed features.
    Sublinear,
    /// `θ0 + θ1·s` — the paper's Eq. 1 (validated in §4.4).
    Linear,
    /// `θ0 + θ1·s^1.12` — e.g. pairwise features or index blowup.
    Superlinear,
}

impl Growth {
    pub const ALL: [Growth; 3] = [Growth::Sublinear, Growth::Linear, Growth::Superlinear];

    /// The exponent γ of the generated [`SizeLaw`].
    pub fn gamma(self) -> f64 {
        match self {
            Growth::Sublinear => 0.85,
            Growth::Linear => 1.0,
            Growth::Superlinear => 1.12,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Growth::Sublinear => "sublinear",
            Growth::Linear => "linear",
            Growth::Superlinear => "superlinear",
        }
    }
}

/// Largest scale any sampling policy touches (GBT-style extended sampling
/// stops at 10). Generated laws are clamped so the single sample node
/// never evicts — the §5.1 eviction-retry loop stays a corner case the
/// paper fixtures exercise, not the synthetic common path.
const MAX_SAMPLE_SCALE: f64 = 10.0;

/// Cached-footprint budget (MB) at [`MAX_SAMPLE_SCALE`]: well under the
/// i3 sample node's ~830 MB worst-case caching capacity.
const SAMPLE_CACHED_BUDGET_MB: Mb = 600.0;

/// Knobs of the generator. All ranges are inclusive and sampled uniformly;
/// build one via a preset ([`SynthConfig::by_name`]) and override fields.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// Preset name, baked into generated workload names.
    pub preset: &'static str,
    /// Number of cached datasets (multi-dataset = cache contention).
    pub datasets: (usize, usize),
    /// Growth laws to draw from, uniformly.
    pub growth: &'static [Growth],
    /// Measurement-noise amplitude (mimics the §4/§6.2 sampling error).
    pub noise_amp: (f64, f64),
    /// Size at which the measurement noise has halved, MB.
    pub noise_half_mb: (f64, f64),
    /// Total true cached size at 100 % scale, MB.
    pub cached_full_mb: (f64, f64),
    /// Total execution memory at 100 % scale, MB.
    pub exec_full_mb: (f64, f64),
    /// Input size at 100 % scale, MB.
    pub input_full_mb: (f64, f64),
    /// DFS block count of the full input.
    pub blocks: (usize, usize),
    /// Iterative actions after materialization.
    pub iterations: (usize, usize),
    /// Log-space sigma of task-duration noise (partition/task skew).
    pub skew_sigma: (f64, f64),
    /// Probability of a forced Block-s preparation phase.
    pub prep_probability: f64,
    /// Probability of a KM-style parallelism cap (coalesced stages).
    pub coalesce_probability: f64,
    /// Probability of the no-cached-data atypical case (§5.1 case 1).
    pub uncached_probability: f64,
    /// Layers of the generated merged DAG.
    pub dag_depth: (usize, usize),
    /// Datasets per layer.
    pub dag_width: (usize, usize),
}

impl SynthConfig {
    /// The default preset: every knob in play.
    pub fn mixed() -> SynthConfig {
        SynthConfig {
            preset: "mixed",
            datasets: (1, 3),
            growth: &Growth::ALL,
            noise_amp: (0.02, 0.15),
            noise_half_mb: (0.5, 4.0),
            cached_full_mb: (500.0, 40_000.0),
            exec_full_mb: (100.0, 15_000.0),
            input_full_mb: (200.0, 40_000.0),
            blocks: (50, 2000),
            iterations: (3, 20),
            skew_sigma: (0.05, 0.3),
            prep_probability: 0.3,
            coalesce_probability: 0.15,
            uncached_probability: 0.05,
            dag_depth: (1, 4),
            dag_width: (1, 3),
        }
    }

    /// One fixed growth law for every cached dataset.
    pub fn growth_only(g: Growth) -> SynthConfig {
        let growth: &'static [Growth] = match g {
            Growth::Sublinear => &[Growth::Sublinear],
            Growth::Linear => &[Growth::Linear],
            Growth::Superlinear => &[Growth::Superlinear],
        };
        SynthConfig { preset: g.name(), growth, uncached_probability: 0.0, ..Self::mixed() }
    }

    /// Heavy measurement noise on tiny caches — the GBT/§6.2 regime.
    pub fn noisy() -> SynthConfig {
        SynthConfig {
            preset: "noisy",
            noise_amp: (0.3, 0.9),
            noise_half_mb: (0.02, 1.0),
            cached_full_mb: (20.0, 2_000.0),
            uncached_probability: 0.0,
            ..Self::mixed()
        }
    }

    /// Several large cached datasets contending for storage memory.
    pub fn contended() -> SynthConfig {
        SynthConfig {
            preset: "contended",
            datasets: (2, 3),
            cached_full_mb: (20_000.0, 60_000.0),
            uncached_probability: 0.0,
            ..Self::mixed()
        }
    }

    /// The no-cached-data atypical case, always.
    pub fn uncached() -> SynthConfig {
        SynthConfig { preset: "uncached", uncached_probability: 1.0, ..Self::mixed() }
    }

    /// Tiny, fast workloads for smoke tests.
    pub fn smoke() -> SynthConfig {
        SynthConfig {
            preset: "smoke",
            datasets: (1, 2),
            cached_full_mb: (200.0, 4_000.0),
            exec_full_mb: (50.0, 2_000.0),
            input_full_mb: (100.0, 2_000.0),
            blocks: (50, 300),
            iterations: (2, 6),
            uncached_probability: 0.0,
            ..Self::mixed()
        }
    }

    /// Look a preset up by CLI name.
    pub fn by_name(name: &str) -> Option<SynthConfig> {
        match name {
            "mixed" => Some(Self::mixed()),
            "linear" => Some(Self::growth_only(Growth::Linear)),
            "sublinear" => Some(Self::growth_only(Growth::Sublinear)),
            "superlinear" => Some(Self::growth_only(Growth::Superlinear)),
            "noisy" => Some(Self::noisy()),
            "contended" => Some(Self::contended()),
            "uncached" => Some(Self::uncached()),
            "smoke" => Some(Self::smoke()),
            _ => None,
        }
    }

    /// Every preset name (the CLI help and error messages).
    pub fn names() -> &'static [&'static str] {
        &["mixed", "linear", "sublinear", "superlinear", "noisy", "contended", "uncached", "smoke"]
    }

    /// Generate one workload. Deterministic in `(preset, seed)`.
    pub fn generate(&self, seed: u64) -> AppModel {
        let mut rng = Rng::new(seed).fork(self.preset);
        let uf = |rng: &mut Rng, (lo, hi): (f64, f64)| rng.range(lo, hi);
        let ui = |rng: &mut Rng, (lo, hi): (usize, usize)| lo + rng.below(hi - lo + 1);

        let uncached = rng.f64() < self.uncached_probability;
        let n_ds = if uncached { 0 } else { ui(&mut rng, self.datasets) };

        let mut cached_laws = Vec::with_capacity(n_ds);
        if n_ds > 0 {
            let total_full = uf(&mut rng, self.cached_full_mb);
            let shares: Vec<f64> = (0..n_ds).map(|_| rng.range(0.2, 1.0)).collect();
            let share_sum: f64 = shares.iter().sum();
            for share in shares {
                let g = self.growth[rng.below(self.growth.len())];
                let full = total_full * share / share_sum;
                let theta0 = rng.range(0.0, 20.0).min(full / 2.0);
                let theta1 = (full - theta0).max(1.0) / FULL_SCALE.powf(g.gamma());
                cached_laws.push(SizeLaw::power(theta0, theta1, g.gamma()));
            }
            // clamp the sampling-scale footprint so sampling never evicts
            let at_sample: Mb = cached_laws.iter().map(|l| l.at(MAX_SAMPLE_SCALE)).sum();
            if at_sample > SAMPLE_CACHED_BUDGET_MB {
                let k = SAMPLE_CACHED_BUDGET_MB / at_sample;
                for law in &mut cached_laws {
                    law.theta0 *= k;
                    law.theta1 *= k;
                }
            }
        }

        let exec_full = uf(&mut rng, self.exec_full_mb);
        let exec_theta0 = rng.range(20.0, 200.0).min(exec_full / 2.0);
        let exec_law = SizeLaw::new(exec_theta0, (exec_full - exec_theta0).max(0.0) / FULL_SCALE);

        let iterations = ui(&mut rng, self.iterations);
        let depth = ui(&mut rng, self.dag_depth).max(1);
        let width = ui(&mut rng, self.dag_width).max(1);

        AppModel {
            name: format!("synth-{}-{seed:04x}", self.preset),
            input_mb_full: uf(&mut rng, self.input_full_mb),
            blocks_full: ui(&mut rng, self.blocks),
            cached_laws,
            exec_law,
            size_noise: SizeNoise::with_bias(
                uf(&mut rng, self.noise_amp),
                uf(&mut rng, self.noise_half_mb),
                rng.range(0.2, 0.8),
            ),
            iterations,
            compute_s_per_mb: rng.range(0.005, 0.5),
            cached_speedup: 97.0,
            recompute_factor: rng.range(0.3, 6.0),
            serial_fixed_s: rng.range(0.1, 8.0),
            serial_per_scale_s: rng.range(0.0, 0.03),
            shuffle_mb_full: rng.range(10.0, 1500.0),
            task_overhead_s: 0.01,
            task_time_sigma: uf(&mut rng, self.skew_sigma),
            per_partition_overhead_mb: rng.range(0.001, 0.04),
            parallelism_cap: (rng.f64() < self.coalesce_probability)
                .then(|| 50 + rng.below(200)),
            force_block_s: rng.f64() < self.prep_probability,
            enlarged_scale: 2.0 * FULL_SCALE,
            dag_spec: DagSpec::Layered { depth, width, cached: n_ds, iterations },
        }
    }

    /// Generate `count` workloads from consecutive seeds
    /// `first_seed..first_seed+count`, each paired with its seed — the
    /// one seed-pairing convention shared by the CLI, the testkit matrix
    /// and the examples, so reproduction seeds never desynchronize.
    pub fn generate_many(&self, first_seed: u64, count: usize) -> Vec<(u64, AppModel)> {
        (0..count as u64)
            .map(|i| {
                let seed = first_seed.wrapping_add(i);
                (seed, self.generate(seed))
            })
            .collect()
    }
}

/// Build a layered merged DAG: `depth` layers of `width` datasets (the
/// first node of each layer joins the whole previous layer, the rest chain
/// narrowly), `cached` of them marked `.cache()`, feeding `iterations`
/// Wide-transform actions off the final layer. Acyclic by construction;
/// the cached count always matches exactly (extra cached nodes extend the
/// chain when `cached > depth`).
pub fn layered_dag(depth: usize, width: usize, cached: usize, iterations: usize) -> AppDag {
    let mut g = AppDag::new();
    let src = g.source("input");
    let mut prev_layer = vec![src];
    let mut cached_left = cached;
    for d in 0..depth.max(1) {
        let mut layer = Vec::with_capacity(width.max(1));
        for w in 0..width.max(1) {
            let t = if w % 2 == 1 { Transform::Wide } else { Transform::Narrow };
            let parents: Vec<usize> = if w == 0 {
                prev_layer.clone()
            } else {
                vec![prev_layer[w % prev_layer.len()]]
            };
            layer.push(g.dataset(&format!("d{d}_{w}"), t, &parents));
        }
        if cached_left > 0 {
            g.cache(layer[0]);
            cached_left -= 1;
        }
        prev_layer = layer;
    }
    while cached_left > 0 {
        let id = g.dataset(&format!("extra_{cached_left}"), Transform::Narrow, &[prev_layer[0]]);
        g.cache(id);
        prev_layer = vec![id];
        cached_left -= 1;
    }
    for i in 0..iterations.max(1) {
        let it = g.dataset(&format!("iter_{i}"), Transform::Wide, &[prev_layer[0]]);
        g.action(&format!("action_{i}"), it);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blink::sample_runs::{SampleRunsManager, SamplingOutcome, DEFAULT_SCALES};

    #[test]
    fn generation_is_deterministic_per_preset_and_seed() {
        let cfg = SynthConfig::mixed();
        let (a, b) = (cfg.generate(42), cfg.generate(42));
        assert_eq!(a.name, b.name);
        assert_eq!(a.cached_laws, b.cached_laws);
        assert_eq!(a.exec_law, b.exec_law);
        assert_eq!(a.input_mb_full, b.input_mb_full);
        assert_eq!(a.iterations, b.iterations);
        // generate_many pairs each workload with exactly the seed that
        // regenerates it (the CLI/testkit reproduction convention)
        let many = cfg.generate_many(42, 3);
        assert_eq!(many.len(), 3);
        for (seed, app) in &many {
            assert_eq!(app.name, cfg.generate(*seed).name);
            assert_eq!(app.input_mb_full, cfg.generate(*seed).input_mb_full);
        }
        assert_eq!(many[0].0, 42);
        assert_eq!(many[2].0, 44);
        // a different seed or preset produces a different model
        assert_ne!(a.input_mb_full, cfg.generate(43).input_mb_full);
        assert_ne!(
            a.input_mb_full,
            SynthConfig::smoke().generate(42).input_mb_full,
            "preset is part of the stream"
        );
    }

    #[test]
    fn every_preset_resolves_and_generates_valid_dags() {
        for name in SynthConfig::names() {
            let cfg = SynthConfig::by_name(name).unwrap();
            assert_eq!(cfg.preset, *name);
            for seed in 0..8 {
                let app = cfg.generate(seed);
                let dag = app.dag();
                assert!(dag.is_acyclic(), "{}", app.name);
                assert_eq!(
                    dag.cached_datasets().len(),
                    app.cached_laws.len(),
                    "{}: DAG cached sets must match the size laws",
                    app.name
                );
                assert!(!dag.actions.is_empty(), "{}", app.name);
                assert!(app.input_mb_full > 0.0 && app.blocks_full > 0, "{}", app.name);
            }
        }
        assert!(SynthConfig::by_name("meteor").is_none());
    }

    #[test]
    fn sample_scale_footprint_stays_within_the_sample_node_budget() {
        for name in SynthConfig::names() {
            let cfg = SynthConfig::by_name(name).unwrap();
            for seed in 0..32 {
                let app = cfg.generate(seed);
                let at_sample: f64 =
                    (0..app.cached_laws.len()).map(|i| app.true_cached_mb(i, 10.0)).sum();
                assert!(
                    at_sample <= SAMPLE_CACHED_BUDGET_MB + 1e-6,
                    "{} (seed {seed}): {at_sample} MB at scale 10",
                    app.name
                );
            }
        }
    }

    #[test]
    fn growth_laws_shape_the_size_curve() {
        let sub = SynthConfig::growth_only(Growth::Sublinear).generate(7);
        let sup = SynthConfig::growth_only(Growth::Superlinear).generate(7);
        for app in [&sub, &sup] {
            for law in &app.cached_laws {
                assert!(law.theta1 > 0.0);
            }
        }
        // superlinear laws accelerate: size(2s) - size(s) grows with s
        let l = sup.cached_laws[0];
        let d1 = l.at(200.0) - l.at(100.0);
        let d2 = l.at(400.0) - l.at(200.0);
        assert!(d2 > d1, "superlinear must accelerate: {d1} vs {d2}");
        // sublinear laws decelerate per doubling
        let l = sub.cached_laws[0];
        let r1 = l.at(200.0) / l.at(100.0);
        let r2 = l.at(400.0) / l.at(200.0);
        assert!(r2 < r1 * 1.001, "sublinear must decelerate: {r1} vs {r2}");
    }

    #[test]
    fn uncached_preset_hits_atypical_case_1_end_to_end() {
        let app = SynthConfig::uncached().generate(3);
        assert!(app.cached_laws.is_empty());
        let mgr = SampleRunsManager::default();
        match mgr.run(&app, &DEFAULT_SCALES) {
            SamplingOutcome::NoCachedData { sample_cost_machine_s } => {
                assert!(sample_cost_machine_s > 0.0);
            }
            other => panic!("expected NoCachedData, got {other:?}"),
        }
    }

    #[test]
    fn sampling_generated_workloads_never_evicts_on_the_sample_node() {
        // the generator's clamp makes the §5.1 retry loop unnecessary:
        // every run completes at its requested scale
        let cfg = SynthConfig::contended(); // the heaviest cache footprint
        let mgr = SampleRunsManager::default();
        for seed in 0..6 {
            let app = cfg.generate(seed);
            match mgr.run(&app, &DEFAULT_SCALES) {
                SamplingOutcome::Profiled(runs) => {
                    for r in &runs {
                        assert!(!r.rescaled, "{} (seed {seed}) evicted while sampling", app.name);
                        assert_eq!(r.summary.evictions, 0);
                    }
                }
                other => panic!("{} caches data, got {other:?}", app.name),
            }
        }
    }

    #[test]
    fn layered_dag_counts_match_for_edge_shapes() {
        // cached > depth spills into chain extensions; width 1 degenerates
        // to the classic iterative chain
        let g = layered_dag(2, 1, 4, 3);
        assert!(g.is_acyclic());
        assert_eq!(g.cached_datasets().len(), 4);
        assert_eq!(g.actions.len(), 3);
        let g = layered_dag(3, 3, 0, 1);
        assert!(g.cached_datasets().is_empty());
        assert!(g.is_acyclic());
    }
}
