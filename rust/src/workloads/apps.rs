//! Per-application models + registry.
//!
//! Numbers: `input_mb_full` / `blocks_full` copy Table 1's "Scale 100 %"
//! rows. The cached-size and execution-memory laws are calibrated against
//! the worker memory geometry so the minimum eviction-free cluster sizes
//! reproduce the paper's bold picks (see module docs in `workloads`).
//! Cost coefficients are tuned for the *shape* of Table 1's time/cost
//! surfaces (areas A/B/C, who is worst where), not its absolute minutes.

use crate::dag::{AppDag, Transform};
use crate::util::units::{gb, Mb};

/// `size(scale) = θ0 + θ1 · scale^γ` — the paper's linear law (Eq. 1,
/// γ = 1; scale 1000 = 100 %) extended with a growth exponent so synthetic
/// workloads ([`super::synth`]) can cache sublinearly or superlinearly
/// growing datasets. [`SizeLaw::new`] keeps γ = 1 and the exact legacy
/// arithmetic, so every paper calibration stays bit-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SizeLaw {
    pub theta0: Mb,
    pub theta1: Mb,
    /// Growth exponent γ (1 = the paper's linear law).
    pub gamma: f64,
}

impl SizeLaw {
    pub const fn new(theta0: Mb, theta1: Mb) -> Self {
        SizeLaw { theta0, theta1, gamma: 1.0 }
    }

    /// A power-law variant (`γ ≠ 1` grows sub-/superlinearly in scale).
    pub const fn power(theta0: Mb, theta1: Mb, gamma: f64) -> Self {
        SizeLaw { theta0, theta1, gamma }
    }

    pub fn at(&self, scale: f64) -> Mb {
        if self.gamma == 1.0 {
            // the paper's exact expression — `powf(1.0)` is not guaranteed
            // to be the identity, and Table 1/2 must stay bit-identical
            self.theta0 + self.theta1 * scale
        } else {
            self.theta0 + self.theta1 * scale.powf(self.gamma)
        }
    }
}

/// Deterministic measurement-quirk envelope: listener-reported sizes of
/// tiny cached datasets deviate relatively by up to `amp`, decaying as the
/// dataset grows past `half_mb` (JVM object/page quantization effects —
/// the §6.2 explanation for GBT's poor 3-sample fit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SizeNoise {
    pub amp: f64,
    pub half_mb: Mb,
    /// Systematic under-measurement share (fraction of `rel_amp`): tiny
    /// caches report smaller than physical (headers/pages not amortized).
    pub bias: f64,
}

impl SizeNoise {
    pub const fn new(amp: f64, half_mb: Mb) -> Self {
        SizeNoise { amp, half_mb, bias: 0.5 }
    }

    pub const fn with_bias(amp: f64, half_mb: Mb, bias: f64) -> Self {
        SizeNoise { amp, half_mb, bias }
    }

    /// Relative amplitude at a given true size.
    pub fn rel_amp(&self, size_mb: Mb) -> f64 {
        self.amp / (1.0 + (size_mb / self.half_mb).powf(1.5))
    }
}

/// How an application's merged transformation DAG is produced.
#[derive(Debug, Clone)]
pub enum DagSpec {
    /// A hand-built paper DAG (the Fig. 2 shapes of the eight fixtures).
    Builtin(fn() -> AppDag),
    /// A parameterized layered DAG (synthetic workloads): `depth` layers
    /// of `width` datasets, `cached` of them marked `.cache()`, feeding
    /// `iterations` actions. Built by [`super::synth::layered_dag`].
    Layered { depth: usize, width: usize, cached: usize, iterations: usize },
}

impl DagSpec {
    pub fn build(&self) -> AppDag {
        match self {
            DagSpec::Builtin(f) => f(),
            DagSpec::Layered { depth, width, cached, iterations } => {
                super::synth::layered_dag(*depth, *width, *cached, *iterations)
            }
        }
    }
}

/// Static model of one application — a HiBench fixture from the registry
/// below, or a generated one from [`super::synth`].
#[derive(Debug, Clone)]
pub struct AppModel {
    pub name: String,
    /// Original (100 %) input size and DFS block count (Table 1).
    pub input_mb_full: Mb,
    pub blocks_full: usize,
    /// True size law per cached dataset (most apps cache exactly one).
    pub cached_laws: Vec<SizeLaw>,
    /// Execution-memory law (total across the cluster).
    pub exec_law: SizeLaw,
    pub size_noise: SizeNoise,
    /// Iterative actions after materialization.
    pub iterations: usize,
    /// Compute cost per MB of partition data (s/MB).
    pub compute_s_per_mb: f64,
    /// Cached read vs recompute speedup (paper measures ~97x).
    pub cached_speedup: f64,
    /// Lineage multiplier for recomputation.
    pub recompute_factor: f64,
    /// Driver-side serial seconds per job: fixed part (scheduler, task
    /// serialization) plus a per-scale part (driver-side aggregation over
    /// results whose size grows with the data).
    pub serial_fixed_s: f64,
    pub serial_per_scale_s: f64,
    /// Shuffle bytes per iteration at 100 % scale.
    pub shuffle_mb_full: Mb,
    pub task_overhead_s: f64,
    pub task_time_sigma: f64,
    /// Deserialization metadata per cached partition (MB): the reason the
    /// measured dataset size depends on the parallelism level (§4.2's
    /// 728.9 MB @10 tasks vs 747.8 MB @1000 tasks experiment). Blink keeps
    /// tasks proportional to the data scale precisely so this term stays
    /// linear in the scale.
    pub per_partition_overhead_mb: f64,
    /// KM coalesces iteration stages to a fixed partition count.
    pub parallelism_cap: Option<usize>,
    /// Force Block-s sampling regardless of block count (the paper applies
    /// Block-s to KM because its coalesced partitioning breaks whole-block
    /// selection).
    pub force_block_s: bool,
    /// The paper's enlarged evaluation scale (Table 1 bottom half).
    pub enlarged_scale: f64,
    pub dag_spec: DagSpec,
}

/// A generic iterative-ML merged DAG: input -> features (cached) -> per-
/// iteration branch + final action, mirroring Fig. 2's structure.
fn iterative_dag(cached_names: &[&str], iterations: usize) -> AppDag {
    let mut g = AppDag::new();
    let src = g.source("input");
    let mut prev = g.dataset("parsed", Transform::Narrow, &[src]);
    for name in cached_names {
        let d = g.dataset(name, Transform::Narrow, &[prev]);
        g.cache(d);
        prev = d;
    }
    for i in 0..iterations.max(1) {
        let grad = g.dataset(&format!("iter_{i}"), Transform::Wide, &[prev]);
        g.action(&format!("action_{i}"), grad);
    }
    g
}

fn als_dag() -> AppDag {
    // ALS caches ratings; user/item factor updates alternate per iteration
    iterative_dag(&["ratings"], 10)
}
fn bayes_dag() -> AppDag {
    iterative_dag(&["tf_features"], 5)
}
fn gbt_dag() -> AppDag {
    iterative_dag(&["treeInput"], 50)
}
fn km_dag() -> AppDag {
    iterative_dag(&["points"], 10)
}
fn lr_dag() -> AppDag {
    // the Fig. 2 example app — keep its published shape for LR
    crate::dag::fig2_logistic_regression()
}
fn pca_dag() -> AppDag {
    iterative_dag(&["rowMatrix"], 5)
}
fn rfc_dag() -> AppDag {
    iterative_dag(&["bagged"], 30)
}
fn svm_dag() -> AppDag {
    iterative_dag(&["trainingSet"], 100)
}

/// The registry, alphabetical like Table 1.
pub fn all_apps() -> Vec<AppModel> {
    vec![
        AppModel {
            name: "als".to_string(),
            input_mb_full: gb(5.6),
            blocks_full: 100,
            cached_laws: vec![SizeLaw::new(3.0, 5.197)],
            exec_law: SizeLaw::new(100.0, 0.8),
            size_noise: SizeNoise::new(0.22, 4.0),
            iterations: 10,
            compute_s_per_mb: 1.0,
            cached_speedup: 97.0,
            recompute_factor: 1.5,
            serial_fixed_s: 9.0,
            serial_per_scale_s: 0.0,
            shuffle_mb_full: 400.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.12,
            per_partition_overhead_mb: 0.02,
            parallelism_cap: None,
            force_block_s: false,
            enlarged_scale: 10_000.0, // 10^3 %
            dag_spec: DagSpec::Builtin(als_dag),
        },
        AppModel {
            name: "bayes".to_string(),
            input_mb_full: gb(17.6),
            blocks_full: 2000,
            cached_laws: vec![SizeLaw::new(5.0, 40.1)],
            exec_law: SizeLaw::new(200.0, 7.8),
            size_noise: SizeNoise::new(0.05, 2.0),
            iterations: 5,
            compute_s_per_mb: 0.02,
            cached_speedup: 97.0,
            recompute_factor: 8.0,
            serial_fixed_s: 4.5,
            serial_per_scale_s: 0.0235,
            shuffle_mb_full: 800.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.12,
            per_partition_overhead_mb: 0.02,
            parallelism_cap: None,
            force_block_s: false,
            enlarged_scale: 1_500.0, // 150 %
            dag_spec: DagSpec::Builtin(bayes_dag),
        },
        AppModel {
            name: "gbt".to_string(),
            input_mb_full: 30.6,
            blocks_full: 100,
            cached_laws: vec![SizeLaw::new(0.0, 0.0217)],
            exec_law: SizeLaw::new(2.0, 0.004),
            size_noise: SizeNoise::with_bias(1.0, 0.04, 0.8),
            iterations: 50,
            compute_s_per_mb: 10.0,
            cached_speedup: 97.0,
            recompute_factor: 2.0,
            serial_fixed_s: 0.54,
            serial_per_scale_s: 0.009,
            shuffle_mb_full: 10.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.15,
            per_partition_overhead_mb: 0.001,
            parallelism_cap: None,
            force_block_s: false,
            enlarged_scale: 1_797_000.0, // 18x10^4 % (53.7 GB / 30.6 MB)
            dag_spec: DagSpec::Builtin(gbt_dag),
        },
        AppModel {
            name: "km".to_string(),
            input_mb_full: gb(21.5),
            blocks_full: 2000,
            cached_laws: vec![SizeLaw::new(2.0, 23.0)],
            exec_law: SizeLaw::new(100.0, 1.4),
            size_noise: SizeNoise::new(0.05, 2.0),
            iterations: 10,
            compute_s_per_mb: 0.008,
            cached_speedup: 97.0,
            recompute_factor: 20.0,
            serial_fixed_s: 2.0,
            serial_per_scale_s: 0.014,
            shuffle_mb_full: 100.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.35,
            per_partition_overhead_mb: 0.02,
            parallelism_cap: Some(100),
            force_block_s: true,
            enlarged_scale: 2_000.0, // 200 %
            dag_spec: DagSpec::Builtin(km_dag),
        },
        AppModel {
            name: "lr".to_string(),
            input_mb_full: gb(22.4),
            blocks_full: 2000,
            cached_laws: vec![SizeLaw::new(8.0, 16.992)],
            exec_law: SizeLaw::new(500.0, 17.5),
            size_noise: SizeNoise::new(0.05, 2.0),
            iterations: 100,
            compute_s_per_mb: 0.02,
            cached_speedup: 97.0,
            recompute_factor: 2.0,
            serial_fixed_s: 0.18,
            serial_per_scale_s: 0.0005,
            shuffle_mb_full: 200.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.12,
            per_partition_overhead_mb: 0.02,
            parallelism_cap: None,
            force_block_s: false,
            enlarged_scale: 2_000.0, // 200 %
            dag_spec: DagSpec::Builtin(lr_dag),
        },
        AppModel {
            name: "pca".to_string(),
            input_mb_full: gb(1.5),
            blocks_full: 50,
            cached_laws: vec![SizeLaw::new(2.0, 0.878)],
            exec_law: SizeLaw::new(400.0, 0.1),
            size_noise: SizeNoise::new(0.08, 0.3),
            iterations: 5,
            compute_s_per_mb: 8.0,
            cached_speedup: 97.0,
            recompute_factor: 1.5,
            serial_fixed_s: 21.0,
            serial_per_scale_s: 0.063,
            shuffle_mb_full: 300.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.12,
            per_partition_overhead_mb: 0.02,
            parallelism_cap: None,
            force_block_s: false,
            enlarged_scale: 49_870.0, // 5x10^3 % (74.8 GB / 1.5 GB)
            dag_spec: DagSpec::Builtin(pca_dag),
        },
        AppModel {
            name: "rfc".to_string(),
            input_mb_full: gb(29.8),
            blocks_full: 2000,
            cached_laws: vec![SizeLaw::new(6.0, 19.994)],
            exec_law: SizeLaw::new(300.0, 2.7),
            size_noise: SizeNoise::new(0.05, 2.0),
            iterations: 30,
            compute_s_per_mb: 0.45,
            cached_speedup: 97.0,
            recompute_factor: 0.3,
            serial_fixed_s: 2.3,
            serial_per_scale_s: 0.058,
            shuffle_mb_full: 2000.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.12,
            per_partition_overhead_mb: 0.02,
            parallelism_cap: None,
            force_block_s: false,
            enlarged_scale: 2_000.0, // 200 %
            dag_spec: DagSpec::Builtin(rfc_dag),
        },
        AppModel {
            name: "svm".to_string(),
            input_mb_full: gb(59.6),
            blocks_full: 2000,
            cached_laws: vec![SizeLaw::new(10.0, 40.99)],
            exec_law: SizeLaw::new(150.0, 5.85),
            size_noise: SizeNoise::new(0.02, 5.0),
            iterations: 100,
            compute_s_per_mb: 0.03,
            cached_speedup: 97.0,
            recompute_factor: 1.2,
            serial_fixed_s: 0.2,
            serial_per_scale_s: 0.00015,
            shuffle_mb_full: 50.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.12,
            per_partition_overhead_mb: 0.02,
            parallelism_cap: None,
            force_block_s: false,
            enlarged_scale: 1_500.0, // 150 %
            dag_spec: DagSpec::Builtin(svm_dag),
        },
    ]
}

pub fn app_by_name(name: &str) -> Option<AppModel> {
    all_apps().into_iter().find(|a| a.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_law_evaluates() {
        let l = SizeLaw::new(10.0, 41.0);
        assert_eq!(l.at(0.0), 10.0);
        assert_eq!(l.at(1000.0), 41_010.0);
    }

    #[test]
    fn noise_decays_with_size() {
        let n = SizeNoise::with_bias(1.0, 0.04, 0.8);
        assert!(n.rel_amp(0.02) > 0.3, "KB-scale wobbles hard");
        assert!(n.rel_amp(20.0) < 0.01, "MB-scale barely wobbles");
        assert!(n.rel_amp(0.02) > n.rel_amp(0.2));
    }

    #[test]
    fn enlarged_scales_match_table1_sizes() {
        // Table 1 bottom: ALS 56 GB, GBT 53.7 GB, PCA 74.8 GB, SVM 89.4 GB
        let check = |name: &str, want_gb: f64| {
            let a = app_by_name(name).unwrap();
            let got = a.input_mb(a.enlarged_scale) / 1024.0;
            assert!(
                (got - want_gb).abs() / want_gb < 0.02,
                "{name}: {got:.1} GB vs {want_gb} GB"
            );
        };
        check("als", 56.0);
        check("gbt", 53.7);
        check("pca", 74.8);
        check("svm", 89.4);
        check("km", 43.0);
        check("rfc", 59.6);
    }
}
