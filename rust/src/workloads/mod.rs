//! Application models: the eight HiBench fixtures the paper evaluates (§6)
//! plus a seeded synthetic-workload generator ([`synth`]) that opens the
//! same [`AppModel`] interface to unbounded app shapes.
//!
//! Blink treats applications as black boxes; what the reproduction needs
//! per app is (a) its merged DAG shape (which datasets are cached), (b) the
//! *true* linear law `size(scale) = θ0 + θ1·scale` of each cached dataset
//! (the paper validates linearity in §4.4), (c) an execution-memory law,
//! (d) iteration counts and cost coefficients that reproduce the *shape*
//! of Table 1 (who wins at which cluster size, where areas A/B/C fall),
//! and (e) a small-sample measurement quirk model (§6.2: listener-reported
//! sizes of KB-scale cached data wobble — the GBT effect of Figs. 8/9).
//!
//! Scale units follow the paper: `scale = 1` is 0.1 % of the original
//! input, `scale = 1000` is the full 100 % dataset.
//!
//! Calibration: the `θ` values below were derived from the paper's Table 1
//! picks and the worker-node memory geometry (M = 7192.8 MB, R = 3596.4 MB)
//! so that the minimum eviction-free cluster size at 100 % and at the
//! paper's enlarged scales lands on the published values (LR's enlarged
//! scale is the one case our linear-law geometry cannot place at the
//! paper's 12 — see DESIGN.md §5).

pub mod apps;
pub mod synth;

pub use apps::{all_apps, app_by_name, AppModel, DagSpec, SizeLaw, SizeNoise};
pub use synth::{layered_dag, Growth, SynthConfig};

use crate::dag::AppDag;
use crate::hdfs::{DfsFile, Sampler};
use crate::sim::{CachedData, WorkloadProfile};
use crate::util::prng::hash_unit;
use crate::util::units::Mb;

/// Full-scale reference in paper scale units (100 % = 1000 x 0.1 %).
pub const FULL_SCALE: f64 = 1000.0;

impl AppModel {
    /// Input bytes at a given scale.
    pub fn input_mb(&self, scale: f64) -> Mb {
        self.input_mb_full * scale / FULL_SCALE
    }

    /// Stage parallelism at a given scale: proportional block count,
    /// optionally capped (KM coalesces to 100 partitions).
    pub fn parallelism(&self, scale: f64) -> usize {
        let blocks = (self.blocks_full as f64 * scale / FULL_SCALE).round() as usize;
        let blocks = blocks.max(1);
        match self.parallelism_cap {
            Some(cap) => blocks.min(cap),
            None => blocks,
        }
    }

    /// True physical size of cached dataset `i` at a scale.
    pub fn true_cached_mb(&self, i: usize, scale: f64) -> Mb {
        self.cached_laws[i].at(scale)
    }

    /// Listener-reported size: true size distorted by the deterministic
    /// small-sample measurement quirk. Identical across repeated runs at
    /// the same scale (Fig. 4) but wobbling across scales when the
    /// absolute size is tiny (Fig. 9). KB-scale caches systematically
    /// *under*-measure (object-header/page overheads not yet amortized),
    /// which is what drags GBT's 3-sample extrapolation down to the
    /// paper's 13.8 MB vs 21.7 MB actual (§6.2).
    pub fn measured_cached_mb(&self, i: usize, scale: f64) -> Mb {
        let true_mb = self.true_cached_mb(i, scale);
        let z = 2.0 * hash_unit(&self.name, (scale * 1000.0) as u64 ^ (i as u64) << 48) - 1.0;
        let rel = self.size_noise.rel_amp(true_mb);
        (true_mb * (1.0 - self.size_noise.bias * rel + rel * z)).max(0.0)
    }

    /// Total execution memory (across the cluster) at a scale.
    pub fn exec_mem_mb(&self, scale: f64) -> Mb {
        self.exec_law.at(scale)
    }

    /// Total true cached bytes at a scale.
    pub fn total_true_cached_mb(&self, scale: f64) -> Mb {
        (0..self.cached_laws.len())
            .map(|i| self.true_cached_mb(i, scale))
            .sum()
    }

    /// The DFS file holding the original input.
    pub fn dfs_file(&self) -> DfsFile {
        DfsFile::ingest(
            &self.name,
            self.input_mb_full,
            self.input_mb_full / self.blocks_full as f64,
        )
    }

    /// Build the executable profile for a run at `scale`.
    ///
    /// `sampled` carries the Block-s preparation cost for sample runs
    /// (actual runs pass `None`).
    pub fn profile(&self, scale: f64) -> WorkloadProfile {
        self.profile_with_prep(scale, 0.0)
    }

    pub fn profile_with_prep(&self, scale: f64, prep_s: f64) -> WorkloadProfile {
        self.profile_with_parallelism(scale, prep_s, self.parallelism(scale))
    }

    /// Profile with an explicit parallelism override (the §4.2 experiment
    /// runs the same data at 10 vs 1000 tasks). Both the physical and the
    /// measured cached sizes carry the per-partition metadata overhead, so
    /// parallelism visibly influences the dataset size.
    pub fn profile_with_parallelism(
        &self,
        scale: f64,
        prep_s: f64,
        parallelism: usize,
    ) -> WorkloadProfile {
        let overhead = self.per_partition_overhead_mb * parallelism as f64;
        let cached = (0..self.cached_laws.len())
            .map(|i| CachedData {
                id: i,
                true_total_mb: self.true_cached_mb(i, scale) + overhead,
                measured_total_mb: self.measured_cached_mb(i, scale) + overhead,
            })
            .collect();
        WorkloadProfile {
            name: self.name.to_string(),
            scale,
            input_mb: self.input_mb(scale),
            parallelism,
            cached,
            iterations: self.iterations,
            compute_s_per_mb: self.compute_s_per_mb,
            cached_speedup: self.cached_speedup,
            recompute_factor: self.recompute_factor,
            serial_s: self.serial_fixed_s + self.serial_per_scale_s * scale,
            shuffle_mb: self.shuffle_mb_full * scale / FULL_SCALE,
            exec_mem_total_mb: self.exec_mem_mb(scale),
            task_overhead_s: self.task_overhead_s,
            task_time_sigma: self.task_time_sigma,
            sample_prep_s: prep_s,
        }
    }

    /// The sampling approach used for this app (§4.2 / Table 1 row 2):
    /// Block-n when enough whole blocks exist, Block-s otherwise or when
    /// the app's partitioning forces it.
    pub fn sample_approach(
        &self,
        sampler: &Sampler,
        fraction: f64,
    ) -> crate::hdfs::SampleApproach {
        if self.force_block_s {
            crate::hdfs::SampleApproach::BlockS
        } else {
            sampler.choose(&self.dfs_file(), fraction)
        }
    }

    /// Sample-run profile at a tiny scale, paying Block-s preparation if
    /// the sampler decides the input has too few blocks for Block-n.
    pub fn sample_profile(&self, scale: f64, sampler: &Sampler) -> WorkloadProfile {
        let file = self.dfs_file();
        let fraction = scale / FULL_SCALE;
        let approach = self.sample_approach(sampler, fraction);
        let s = sampler.sample_with(&file, fraction, approach);
        self.profile_with_prep(scale, s.prep_cost_s)
    }

    /// The merged transformation DAG (Fig. 2 style) for this app.
    pub fn dag(&self) -> AppDag {
        self.dag_spec.build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdfs::SampleApproach;

    #[test]
    fn eight_apps_registered() {
        let apps = all_apps();
        assert_eq!(apps.len(), 8);
        let names: Vec<&str> = apps.iter().map(|a| a.name.as_str()).collect();
        assert_eq!(names, ["als", "bayes", "gbt", "km", "lr", "pca", "rfc", "svm"]);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(app_by_name("svm").unwrap().name, "svm");
        assert!(app_by_name("nope").is_none());
    }

    #[test]
    fn size_laws_are_linear_and_positive() {
        for app in all_apps() {
            for i in 0..app.cached_laws.len() {
                let s1 = app.true_cached_mb(i, 1.0);
                let s2 = app.true_cached_mb(i, 2.0);
                let s3 = app.true_cached_mb(i, 3.0);
                assert!(s1 > 0.0, "{}", app.name);
                // exact linearity of the true law
                assert!(((s3 - s2) - (s2 - s1)).abs() < 1e-9, "{}", app.name);
            }
        }
    }

    #[test]
    fn measured_sizes_deterministic_per_scale() {
        let app = app_by_name("gbt").unwrap();
        let a = app.measured_cached_mb(0, 2.0);
        let b = app.measured_cached_mb(0, 2.0);
        assert_eq!(a, b, "Fig. 4: same scale, same measured size");
        assert_ne!(a, app.measured_cached_mb(0, 3.0));
    }

    #[test]
    fn measurement_quirk_fades_at_large_scale() {
        for app in all_apps() {
            let t = app.true_cached_mb(0, FULL_SCALE);
            let m = app.measured_cached_mb(0, FULL_SCALE);
            assert!(
                (m - t).abs() / t < 0.01,
                "{}: measured {m} vs true {t} at full scale",
                app.name
            );
        }
    }

    #[test]
    fn gbt_samples_are_kilobytes() {
        // "during the 3 sample runs, the training data is only a few KB"
        let gbt = app_by_name("gbt").unwrap();
        for s in [1.0, 2.0, 3.0] {
            let mb = gbt.true_cached_mb(0, s);
            assert!(mb < 0.1, "gbt sample cached {mb} MB at scale {s}");
        }
    }

    #[test]
    fn sampling_approaches_match_paper() {
        // §6: Block-n for bayes, lr, rfc, svm; Block-s for als, gbt, km, pca
        let sampler = Sampler::default();
        let expect = [
            ("als", SampleApproach::BlockS),
            ("bayes", SampleApproach::BlockN),
            ("gbt", SampleApproach::BlockS),
            ("km", SampleApproach::BlockS),
            ("lr", SampleApproach::BlockN),
            ("pca", SampleApproach::BlockS),
            ("rfc", SampleApproach::BlockN),
            ("svm", SampleApproach::BlockN),
        ];
        for (name, want) in expect {
            let app = app_by_name(name).unwrap();
            let got = app.sample_approach(&sampler, 0.001);
            assert_eq!(got, want, "{name}");
        }
    }

    #[test]
    fn parallelism_proportional_and_km_capped() {
        let svm = app_by_name("svm").unwrap();
        assert_eq!(svm.parallelism(1.0) * 2, svm.parallelism(2.0));
        assert_eq!(svm.parallelism(FULL_SCALE), 2000);
        let km = app_by_name("km").unwrap();
        assert_eq!(km.parallelism(FULL_SCALE), 100, "KM coalesces to 100");
        assert_eq!(km.parallelism(2.0 * FULL_SCALE), 100);
    }

    #[test]
    fn profiles_carry_prep_cost_only_for_block_s() {
        let sampler = Sampler::default();
        let svm = app_by_name("svm").unwrap(); // Block-n
        assert_eq!(svm.sample_profile(1.0, &sampler).sample_prep_s, 0.0);
        let km = app_by_name("km").unwrap(); // Block-s
        assert!(km.sample_profile(1.0, &sampler).sample_prep_s > 0.0);
    }

    #[test]
    fn dags_are_valid_and_cache_declared_datasets() {
        for app in all_apps() {
            let dag = app.dag();
            assert!(dag.is_acyclic(), "{}", app.name);
            assert_eq!(
                dag.cached_datasets().len(),
                app.cached_laws.len(),
                "{}: DAG cached sets match size laws",
                app.name
            );
            assert!(!dag.actions.is_empty(), "{}", app.name);
        }
    }
}
