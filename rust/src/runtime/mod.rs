//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` (build time, Python) lowers every L2 function to HLO
//! *text* under `artifacts/`; this module is the request-path half: a
//! [`Runtime`] owns one `PjRtClient` (CPU plugin) and a cache of compiled
//! executables keyed by artifact name, validated against
//! `artifacts/manifest.json`. Python never runs here.
//!
//! The two consumers are [`crate::blink`] (batched `linfit` fits through
//! [`PjrtFit`]) and [`crate::compute`] (workload iteration kernels for
//! RealCompute tasks).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::blink::models::{FitBackend, FitProblem, FitResult};
use crate::util::json::{self, Json};

/// Offline stand-in for the `xla` PJRT bindings.
///
/// The build image has no registry, so the crate ships this stub with the
/// exact call surface this file uses. `PjRtClient::cpu()` reports the
/// runtime as unavailable, which sends [`crate::coordinator::Backend::auto`]
/// down the pure-Rust `rust-nnls` path — the same graceful degradation as a
/// checkout where `make artifacts` was never run. Dropping in the real
/// bindings is: add the `xla` dependency, delete this module.
mod xla {
    use std::fmt;

    #[derive(Debug)]
    pub struct Error;

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("PJRT bindings not compiled into this build (xla stub)")
        }
    }

    impl std::error::Error for Error {}

    pub struct Literal;

    impl Literal {
        pub fn vec1(_data: &[f32]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            Err(Error)
        }

        pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
            Err(Error)
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            Err(Error)
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            Err(Error)
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            Err(Error)
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            Err(Error)
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            Err(Error)
        }

        pub fn platform_name(&self) -> String {
            "unavailable".to_string()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            Err(Error)
        }
    }
}

/// Shape info from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest entry missing shape"))?
        .iter()
        .map(|v| v.as_f64().map(|f| f as usize))
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| anyhow!("non-numeric shape"))?;
    let dtype = j
        .get("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest entry missing dtype"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

/// Parse `artifacts/manifest.json`.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
        format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
    })?;
    let j = json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
    if j.get("format").and_then(Json::as_str) != Some("hlo-text") {
        bail!("unsupported artifact format");
    }
    let entries = j
        .get("entries")
        .ok_or_else(|| anyhow!("manifest missing entries"))?;
    let Json::Obj(map) = entries else { bail!("entries not an object") };
    let mut specs = Vec::new();
    for (name, e) in map {
        let file = dir.join(
            e.get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing file"))?,
        );
        let inputs = e
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{name}: missing inputs"))?
            .iter()
            .map(tensor_spec)
            .collect::<Result<Vec<_>>>()?;
        let outputs = e
            .get("outputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{name}: missing outputs"))?
            .iter()
            .map(tensor_spec)
            .collect::<Result<Vec<_>>>()?;
        specs.push(ArtifactSpec { name: name.clone(), file, inputs, outputs });
    }
    Ok(specs)
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on f32 buffers; validates shapes against the manifest.
    /// Returns one flat `Vec<f32>` per output, in manifest order.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, spec)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if data.len() != spec.elements() {
                bail!(
                    "{}: input {i} has {} elements, manifest says {:?}",
                    self.spec.name,
                    data.len(),
                    spec.shape
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| {
                let v = lit.to_vec::<f32>()?;
                if v.len() != spec.elements() {
                    bail!("{}: output size mismatch", self.spec.name);
                }
                Ok(v)
            })
            .collect()
    }
}

/// The PJRT runtime: client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    compiled: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a CPU-backed runtime over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let specs = load_manifest(&dir)?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, specs, compiled: HashMap::new() })
    }

    /// Default artifacts location relative to the repo root.
    pub fn from_repo_root() -> Result<Runtime> {
        Runtime::new(repo_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    /// Compile (once) and return an executable by artifact name.
    pub fn get(&mut self, name: &str) -> Result<&Executable> {
        if !self.compiled.contains_key(name) {
            let spec = self
                .specs
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}' in {}", self.dir.display()))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compiled.insert(spec.name.clone(), Executable { spec, exe });
        }
        Ok(&self.compiled[name])
    }
}

/// Locate `artifacts/` from the crate root (works from tests and benches).
pub fn repo_artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when `make artifacts` has been run (integration tests skip
/// gracefully otherwise).
pub fn artifacts_available() -> bool {
    repo_artifacts_dir().join("manifest.json").exists()
}

// ------------------------------------------------------------------------
// linfit: the Blink predictor hot path through PJRT
// ------------------------------------------------------------------------

/// AOT shape contract of the `linfit` artifact (python/compile/kernels).
pub const LINFIT_BATCH: usize = 64;
pub const LINFIT_POINTS: usize = 16;
pub const LINFIT_FEATURES: usize = 4;

/// `FitBackend` implementation dispatching batched NNLS to the compiled
/// Pallas kernel. Problems are padded to the artifact's fixed shapes
/// (padding rows carry weight 0, padding features are zero columns, and
/// surplus batch slots are zero problems) and chunked by `LINFIT_BATCH`.
pub struct PjrtFit<'a> {
    pub runtime: &'a mut Runtime,
    /// Kernel dispatches performed (observability for benches).
    pub dispatches: usize,
}

impl<'a> PjrtFit<'a> {
    pub fn new(runtime: &'a mut Runtime) -> PjrtFit<'a> {
        PjrtFit { runtime, dispatches: 0 }
    }

    fn fit_chunk(&mut self, chunk: &[FitProblem]) -> Result<Vec<FitResult>> {
        assert!(chunk.len() <= LINFIT_BATCH);
        let (b, n, k) = (LINFIT_BATCH, LINFIT_POINTS, LINFIT_FEATURES);
        let mut x = vec![0.0f32; b * n * k];
        let mut y = vec![0.0f32; b * n];
        let mut w = vec![0.0f32; b * n];
        for (pi, p) in chunk.iter().enumerate() {
            if p.x.len() > n {
                bail!("linfit artifact supports at most {n} points, got {}", p.x.len());
            }
            for (ri, row) in p.x.iter().enumerate() {
                if row.len() > k {
                    bail!("linfit artifact supports at most {k} features, got {}", row.len());
                }
                for (ci, &v) in row.iter().enumerate() {
                    x[pi * n * k + ri * k + ci] = v as f32;
                }
                y[pi * n + ri] = p.y[ri] as f32;
                w[pi * n + ri] = p.w[ri] as f32;
            }
        }
        let exe = self.runtime.get("linfit")?;
        let outs = exe.run_f32(&[&x, &y, &w])?;
        self.dispatches += 1;
        let theta = &outs[0]; // [B, K]
        let rmse = &outs[1]; // [B]
        Ok(chunk
            .iter()
            .enumerate()
            .map(|(pi, p)| {
                let kk = p.x.first().map(|r| r.len()).unwrap_or(0);
                FitResult {
                    theta: (0..kk).map(|ci| theta[pi * k + ci] as f64).collect(),
                    rmse: rmse[pi] as f64,
                }
            })
            .collect())
    }
}

impl FitBackend for PjrtFit<'_> {
    fn fit_batch(&mut self, problems: &[FitProblem]) -> Vec<FitResult> {
        let mut out = Vec::with_capacity(problems.len());
        for chunk in problems.chunks(LINFIT_BATCH) {
            match self.fit_chunk(chunk) {
                Ok(mut r) => out.append(&mut r),
                Err(e) => panic!("PJRT linfit dispatch failed: {e:#}"),
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "pjrt-linfit"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("blink-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"format\": \"other\"}").unwrap();
        assert!(load_manifest(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_loads_when_artifacts_built() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let specs = load_manifest(&repo_artifacts_dir()).unwrap();
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        assert!(names.contains(&"linfit"));
        let linfit = specs.iter().find(|s| s.name == "linfit").unwrap();
        assert_eq!(
            linfit.inputs[0].shape,
            vec![LINFIT_BATCH, LINFIT_POINTS, LINFIT_FEATURES]
        );
        assert_eq!(linfit.outputs[0].shape, vec![LINFIT_BATCH, LINFIT_FEATURES]);
    }

    // execution tests live in rust/tests/pjrt.rs (integration) so the CPU
    // client is only spun up once per process
}
