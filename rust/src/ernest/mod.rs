//! Ernest baseline (Venkataraman et al., NSDI'16) — the runtime-prediction
//! approach the paper compares against (§2, Fig. 1, Fig. 10).
//!
//! Ernest models application runtime as
//!
//! ```text
//! time(scale, n) = θ0 + θ1·(scale/n) + θ2·log(n) + θ3·n
//! ```
//!
//! (serial term, parallel work, tree-aggregation, per-machine overhead),
//! fit by NNLS on training runs chosen by *optimal experiment design* over
//! 1 %–10 % samples and 1..max machines. The model deliberately has no
//! memory/caching term: on cache-bound workloads it is accurate only in
//! area B and extrapolates area A catastrophically — the Fig. 1 effect
//! this reproduction must show.

use crate::linalg;
use crate::memory::EvictionPolicy;
use crate::metrics::RunSummary;
use crate::sim::{simulate, ClusterSpec, SimOptions};
use crate::workloads::{AppModel, FULL_SCALE};

/// One Ernest training experiment: a (data fraction, cluster size) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Experiment {
    /// Fraction of the full input (Ernest samples 1 %–10 %).
    pub fraction: f64,
    pub machines: usize,
}

/// The experiment set Ernest's optimal-experiment-design step selects:
/// 7 runs spanning the (fraction, machines) envelope (§6.3 runs 7 sample
/// runs on 1–12 machines with 1 %–10 % samples).
pub fn experiment_design(max_machines: usize) -> Vec<Experiment> {
    let hi = max_machines.max(2);
    vec![
        Experiment { fraction: 0.01, machines: 1 },
        Experiment { fraction: 0.01, machines: hi / 2 },
        Experiment { fraction: 0.02, machines: hi / 4 + 1 },
        Experiment { fraction: 0.05, machines: hi / 2 },
        Experiment { fraction: 0.05, machines: hi },
        Experiment { fraction: 0.10, machines: hi / 2 },
        Experiment { fraction: 0.10, machines: hi },
    ]
}

/// Ernest's feature map.
fn features(scale_frac: f64, n: usize) -> Vec<f64> {
    let nf = n as f64;
    vec![1.0, scale_frac / nf, nf.ln(), nf]
}

/// A fitted Ernest model.
#[derive(Debug, Clone)]
pub struct ErnestModel {
    pub theta: Vec<f64>,
    /// Total cost of the training runs, machine-seconds (Fig. 10's bar).
    pub training_cost_machine_s: f64,
}

impl ErnestModel {
    /// Train on a workload by actually executing the designed experiments.
    pub fn train(app: &AppModel, max_machines: usize, seed: u64) -> ErnestModel {
        let design = experiment_design(max_machines);
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut cost = 0.0;
        for (i, e) in design.iter().enumerate() {
            let scale = e.fraction * FULL_SCALE; // paper units
            let profile = app.profile(scale);
            let cluster = ClusterSpec::workers(e.machines);
            let res = simulate(
                &profile,
                &cluster,
                SimOptions {
                    policy: EvictionPolicy::Lru,
                    seed: seed + i as u64,
                    compute: None,
                    detailed_log: false,
                },
            )
            .expect("experiment-design clusters are valid");
            let s = RunSummary::from_log(&res.log);
            x.push(features(e.fraction, e.machines));
            y.push(s.duration_s);
            cost += s.cost_machine_s;
        }
        let w = vec![1.0; y.len()];
        let theta = linalg::nnls(&x, &y, &w, 20_000);
        ErnestModel { theta, training_cost_machine_s: cost }
    }

    /// Predicted runtime (seconds) of the actual run (`fraction = 1`) on n
    /// machines.
    pub fn predict_time_s(&self, n: usize) -> f64 {
        linalg::predict(&features(1.0, n), &self.theta)
    }

    /// Predicted cost (machine-seconds) on n machines.
    pub fn predict_cost_machine_s(&self, n: usize) -> f64 {
        self.predict_time_s(n) * n as f64
    }

    /// The cluster size Ernest would recommend for minimum cost.
    pub fn cheapest_cluster(&self, max_machines: usize) -> usize {
        (1..=max_machines)
            .min_by(|&a, &b| {
                self.predict_cost_machine_s(a)
                    .partial_cmp(&self.predict_cost_machine_s(b))
                    .unwrap()
            })
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::app_by_name;

    #[test]
    fn design_spans_the_envelope() {
        let d = experiment_design(12);
        assert_eq!(d.len(), 7);
        assert!(d.iter().any(|e| e.machines == 1));
        assert!(d.iter().any(|e| e.machines == 12));
        assert!(d.iter().all(|e| (0.01..=0.10).contains(&e.fraction)));
    }

    #[test]
    fn model_coefficients_nonnegative() {
        let app = app_by_name("svm").unwrap();
        let m = ErnestModel::train(&app, 12, 1);
        assert_eq!(m.theta.len(), 4);
        assert!(m.theta.iter().all(|&t| t >= 0.0), "{:?}", m.theta);
        assert!(m.training_cost_machine_s > 0.0);
    }

    #[test]
    fn svm_prediction_misses_area_a() {
        // Fig. 1: Ernest's training samples all fit in memory, so its
        // full-scale prediction ignores cache-miss recomputation and is
        // wildly optimistic on small clusters.
        let app = app_by_name("svm").unwrap();
        let model = ErnestModel::train(&app, 12, 2);
        let predicted_1 = model.predict_time_s(1);
        let actual_1 = {
            let res = simulate(
                &app.profile(FULL_SCALE),
                &ClusterSpec::workers(1),
                SimOptions::default(),
            )
            .unwrap();
            RunSummary::from_log(&res.log).duration_s
        };
        assert!(
            actual_1 > predicted_1 * 4.0,
            "area-A blindness: actual {actual_1} vs predicted {predicted_1}"
        );
    }

    #[test]
    fn svm_recommends_too_few_machines() {
        // Fig. 1: "Ernest predicts that a single machine cluster size leads
        // to minimal cost" while the true optimum is 7.
        let app = app_by_name("svm").unwrap();
        let model = ErnestModel::train(&app, 12, 3);
        let pick = model.cheapest_cluster(12);
        assert!(pick < 7, "ernest picked {pick}, expected an area-A pick");
    }

    #[test]
    fn training_costs_far_more_than_blink_sampling() {
        // Fig. 10: Ernest's sample runs cost ~16x Blink's
        use crate::blink::{Blink, RustFit};
        let app = app_by_name("svm").unwrap();
        let ernest = ErnestModel::train(&app, 12, 4);
        let mut backend = RustFit::default();
        let mut blink = Blink::new(&mut backend);
        let d = blink.decide(&app, FULL_SCALE, &crate::sim::MachineSpec::worker_node());
        assert!(
            ernest.training_cost_machine_s > 5.0 * d.sample_cost_machine_s,
            "ernest {} vs blink {}",
            ernest.training_cost_machine_s,
            d.sample_cost_machine_s
        );
    }
}
