//! `blink` — CLI entrypoint of the L3 coordinator.
//!
//! Every subcommand is a query against the session-oriented advisor API
//! (`blink::blink::Advisor` — profile once, query many) or an experiment
//! driver, and every answer is a typed report that renders as text or as
//! a single JSON document via the global `--format` flag:
//!
//! ```text
//! blink decide      --app svm --scale 1000        # recommend a cluster size
//! blink advise      --app als --catalog cloud     # fleet-aware (type x count) plan
//! blink simulate    --app svm --scenario spot     # engine run under a disturbance
//! blink adapt       --app svm --scale 1000        # observe, refit and re-plan mid-run
//! blink fleet       --apps svm,km,lr              # plan + run N tenants on one shared fleet
//! blink run         --app km  --scale 2000        # recommend + actual run
//! blink bounds      --app lr  --machines 12       # Table-2 max data scale
//! blink experiment  --id table1                   # regenerate a paper table/figure
//! blink apps                                      # list workload models
//! blink synth --preset mixed --count 8 --check    # seeded synthetic workloads
//! blink decide --app svm --format json            # machine-readable answer
//! ```

use blink::blink::OutputFormat;
use blink::coordinator::{self, AdaptQuery, FleetQuery, ServeQuery, SimulateQuery, SynthQuery};
use blink::util::cli::{App, CliError, Command, Matches, Opt};

fn app() -> App {
    App {
        name: "blink",
        about: "lightweight sample runs for cost optimization of big data applications",
        commands: vec![
            Command {
                name: "decide",
                about: "sample, predict and select the optimal cluster size",
                opts: vec![
                    Opt::with_default("app", "workload (als|bayes|gbt|km|lr|pca|rfc|svm)", "svm"),
                    Opt::with_default("scale", "target data scale (1000 = 100 %)", "1000"),
                    Opt::switch("verbose", "print per-dataset models"),
                ],
            },
            Command {
                name: "advise",
                about: "rank (instance type x count) candidates from a catalog under a pricing model",
                opts: vec![
                    Opt::with_default("app", "workload (als|bayes|gbt|km|lr|pca|rfc|svm)", "als"),
                    Opt::with_default("scale", "target data scale (1000 = 100 %)", "1000"),
                    Opt::with_default(
                        "catalog",
                        "instance catalog (paper|cloud|all|generated:<seed>:<n>)",
                        "cloud",
                    ),
                    Opt::with_default(
                        "pricing",
                        "pricing model (machine-seconds|hourly|per-second|spot)",
                        "hourly",
                    ),
                    Opt::with_default("max-machines", "largest candidate cluster size", "12"),
                    Opt::with_default(
                        "scenario",
                        "cross-validate top picks via engine runs (spot|straggler|failure|autoscale|deficit|contention|none)",
                        "none",
                    ),
                    Opt::with_default(
                        "fractions",
                        "comma-separated storage fractions to search as a plan dimension (empty = keep each type's configured split)",
                        "",
                    ),
                ],
            },
            Command {
                name: "simulate",
                about: "run the event-driven engine under a disturbance scenario and price the realized timeline",
                opts: vec![
                    Opt::with_default("app", "workload (als|bayes|gbt|km|lr|pca|rfc|svm)", "svm"),
                    Opt::with_default("scale", "target data scale (1000 = 100 %)", "1000"),
                    Opt::with_default("machines", "fleet size", "8"),
                    Opt::with_default("instance", "instance type name (e.g. i5-worker, gp.xlarge)", "gp.xlarge"),
                    Opt::with_default(
                        "scenario",
                        "disturbance scenario (spot|straggler|failure|autoscale|deficit|contention|none)",
                        "spot",
                    ),
                    Opt::with_default(
                        "pricing",
                        "pricing model (machine-seconds|hourly|per-second|spot)",
                        "spot",
                    ),
                    Opt::with_default("seed", "simulation seed", "1"),
                ],
            },
            Command {
                name: "adapt",
                about: "observe a live run, refit the size models and re-plan mid-run when they diverge",
                opts: vec![
                    Opt::with_default("app", "workload (als|bayes|gbt|km|lr|pca|rfc|svm)", "svm"),
                    Opt::with_default("scale", "target data scale (1000 = 100 %)", "1000"),
                    Opt::with_default(
                        "catalog",
                        "instance catalog (paper|cloud|all|generated:<seed>:<n>)",
                        "cloud",
                    ),
                    Opt::with_default(
                        "pricing",
                        "pricing model (machine-seconds|hourly|per-second|spot)",
                        "hourly",
                    ),
                    Opt::with_default("max-machines", "largest candidate cluster size", "12"),
                    Opt::with_default(
                        "scenario",
                        "base disturbance scenario (spot|straggler|failure|autoscale|deficit|contention|none)",
                        "none",
                    ),
                    Opt::with_default("seed", "simulation seed", "11"),
                    Opt::with_default(
                        "threshold",
                        "relative refit divergence that triggers a re-plan",
                        "0.5",
                    ),
                ],
            },
            Command {
                name: "fleet",
                about: "plan N concurrent tenants onto one shared fleet, then realize the pick with the interleaved engine",
                opts: vec![
                    Opt::with_default(
                        "apps",
                        "comma-separated tenants (registered apps or synth:<preset>:<seed>)",
                        "svm,km,lr",
                    ),
                    Opt::with_default("scale", "target data scale (1000 = 100 %)", "1000"),
                    Opt::with_default(
                        "catalog",
                        "instance catalog (paper|cloud|all|generated:<seed>:<n>)",
                        "cloud",
                    ),
                    Opt::with_default(
                        "pricing",
                        "pricing model (machine-seconds|hourly|per-second|spot)",
                        "hourly",
                    ),
                    Opt::with_default("max-machines", "largest candidate fleet size", "16"),
                    Opt::with_default(
                        "fairness",
                        "shared-store arbitration (shared-lru|reservation-floors)",
                        "shared-lru",
                    ),
                    Opt::with_default(
                        "scenario",
                        "disturbance scenario (spot|straggler|failure|autoscale|deficit|contention|none)",
                        "none",
                    ),
                    Opt::with_default("seed", "simulation seed", "1"),
                ],
            },
            Command {
                name: "run",
                about: "recommend, then simulate the actual run at the recommendation",
                opts: vec![
                    Opt::with_default("app", "workload", "svm"),
                    Opt::with_default("scale", "target data scale", "1000"),
                    Opt::with_default("seed", "simulation seed", "1"),
                ],
            },
            Command {
                name: "bounds",
                about: "predict the max eviction-free data scale for a fixed cluster",
                opts: vec![
                    Opt::with_default("app", "workload", "svm"),
                    Opt::with_default("machines", "cluster size", "12"),
                ],
            },
            Command {
                name: "experiment",
                about: "regenerate a paper table/figure (table1 table2 fig1 fig2 fig4 fig6..fig11 all)",
                opts: vec![
                    Opt::with_default("id", "experiment id", "table1"),
                    Opt::with_default("seed", "simulation seed", "1"),
                ],
            },
            Command { name: "apps", about: "list the workload models", opts: vec![] },
            Command {
                name: "serve",
                about: "answer a JSONL batch of recommend/plan/max_scale queries from a sharded profile store",
                opts: vec![
                    Opt::value("queries", "JSONL query file (one JSON doc per line)"),
                    Opt::with_default(
                        "profiles",
                        "directory of saved profiles to preload (fingerprint-validated)",
                        "",
                    ),
                    Opt::with_default(
                        "save-profiles",
                        "directory to write the store's trained profiles into",
                        "",
                    ),
                    Opt::with_default("shards", "profile store shard count", "8"),
                    Opt::with_default("threads", "worker threads (0 = auto, 1 = serial)", "0"),
                    Opt::with_default("max-machines", "largest candidate cluster size", "12"),
                ],
            },
            Command {
                name: "synth",
                about: "generate seeded synthetic workloads and run each through the advisor",
                opts: vec![
                    Opt::with_default(
                        "preset",
                        "generator preset (mixed|linear|sublinear|superlinear|noisy|contended|uncached|smoke)",
                        "mixed",
                    ),
                    Opt::with_default("seed", "first generator seed", "1"),
                    Opt::with_default("count", "number of workloads (consecutive seeds)", "8"),
                    Opt::with_default("scale", "target data scale (1000 = 100 %)", "1000"),
                    Opt::with_default(
                        "catalog",
                        "instance catalog (paper|cloud|all|generated:<seed>:<n>)",
                        "cloud",
                    ),
                    Opt::with_default(
                        "pricing",
                        "pricing model (machine-seconds|hourly|per-second|spot)",
                        "hourly",
                    ),
                    Opt::with_default("max-machines", "largest candidate cluster size", "12"),
                    Opt::switch("check", "assert the testkit invariants on every workload"),
                ],
            },
        ],
        globals: vec![Opt::with_default("format", "output format (text|json)", "text")],
    }
}

fn dispatch(cmd: &Command, m: &Matches, format: OutputFormat) -> anyhow::Result<()> {
    match cmd.name {
        "decide" => coordinator::cmd_decide(
            m.get("app").unwrap(),
            m.get_f64("scale").unwrap_or(1000.0),
            m.has("verbose"),
            format,
        )
        .map(|_| ()),
        "advise" => coordinator::cmd_advise(
            m.get("app").unwrap(),
            m.get_f64("scale").unwrap_or(1000.0),
            m.get("catalog").unwrap(),
            m.get("pricing").unwrap(),
            m.get_usize("max-machines").unwrap_or(12),
            m.get("scenario").unwrap(),
            m.get("fractions").unwrap_or(""),
            format,
        )
        .map(|_| ()),
        "simulate" => coordinator::cmd_simulate(
            &SimulateQuery {
                app: m.get("app").unwrap(),
                scale: m.get_f64("scale").unwrap_or(1000.0),
                machines: m.get_usize("machines").unwrap_or(8),
                instance: m.get("instance").unwrap(),
                scenario: m.get("scenario").unwrap(),
                pricing: m.get("pricing").unwrap(),
                seed: m.get_u64("seed").unwrap_or(1),
            },
            format,
        )
        .map(|_| ()),
        "adapt" => coordinator::cmd_adapt(
            &AdaptQuery {
                app: m.get("app").unwrap(),
                scale: m.get_f64("scale").unwrap_or(1000.0),
                catalog: m.get("catalog").unwrap(),
                pricing: m.get("pricing").unwrap(),
                max_machines: m.get_usize("max-machines").unwrap_or(12),
                scenario: m.get("scenario").unwrap(),
                seed: m.get_u64("seed").unwrap_or(11),
                threshold: m.get_f64("threshold").unwrap_or(0.5),
            },
            format,
        )
        .map(|_| ()),
        "fleet" => coordinator::cmd_fleet(
            &FleetQuery {
                apps: m.get("apps").unwrap(),
                scale: m.get_f64("scale").unwrap_or(1000.0),
                catalog: m.get("catalog").unwrap(),
                pricing: m.get("pricing").unwrap(),
                max_machines: m.get_usize("max-machines").unwrap_or(16),
                fairness: m.get("fairness").unwrap(),
                scenario: m.get("scenario").unwrap(),
                seed: m.get_u64("seed").unwrap_or(1),
            },
            format,
        )
        .map(|_| ()),
        "run" => coordinator::cmd_run(
            m.get("app").unwrap(),
            m.get_f64("scale").unwrap_or(1000.0),
            m.get_u64("seed").unwrap_or(1),
            format,
        )
        .map(|_| ()),
        "bounds" => coordinator::cmd_bounds(
            m.get("app").unwrap(),
            m.get_usize("machines").unwrap_or(12),
            format,
        )
        .map(|_| ()),
        "experiment" => coordinator::cmd_experiment(
            m.get("id").unwrap(),
            m.get_u64("seed").unwrap_or(1),
            format,
        ),
        "apps" => {
            coordinator::cmd_apps(format);
            Ok(())
        }
        "serve" => coordinator::cmd_serve(
            &ServeQuery {
                queries: m
                    .get("queries")
                    .ok_or_else(|| anyhow::anyhow!("--queries <file> is required"))?,
                profiles: m.get("profiles").unwrap_or(""),
                save_profiles: m.get("save-profiles").unwrap_or(""),
                shards: m.get_usize("shards").unwrap_or(8),
                threads: m.get_usize("threads").unwrap_or(0),
                max_machines: m.get_usize("max-machines").unwrap_or(12),
            },
            format,
        )
        .map(|_| ()),
        "synth" => coordinator::cmd_synth(
            &SynthQuery {
                preset: m.get("preset").unwrap(),
                seed: m.get_u64("seed").unwrap_or(1),
                count: m.get_usize("count").unwrap_or(8),
                scale: m.get_f64("scale").unwrap_or(1000.0),
                catalog: m.get("catalog").unwrap(),
                pricing: m.get("pricing").unwrap(),
                max_machines: m.get_usize("max-machines").unwrap_or(12),
                check: m.has("check"),
            },
            format,
        )
        .map(|_| ()),
        _ => unreachable!(),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = app();
    let (cmd, m) = match cli.parse(&argv) {
        Ok(v) => v,
        Err(CliError::Help(h)) => {
            println!("{h}");
            return;
        }
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let format_name = m.get("format").unwrap();
    let Some(format) = OutputFormat::by_name(format_name) else {
        eprintln!("error: unknown output format '{format_name}' (text|json)");
        std::process::exit(2);
    };
    if let Err(e) = dispatch(cmd, &m, format) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
