//! RDD lineage DAGs and the merged-application DAG of §3.2 / Fig. 2.
//!
//! An application is a sequence of jobs, each triggered by an action whose
//! lineage walks parent RDDs back to cached roots or DFS blocks. Merging
//! all job DAGs yields one DAG of transformations in which the number of
//! child branches of a dataset equals the number of times it is computed —
//! and, absent caching, a dataset on the path of `k` later actions is
//! recomputed `k - 1` extra times. This module reproduces those counts
//! (unit test `fig2_lr_counts` replays the Logistic Regression example).

use std::collections::BTreeMap;

/// Transformation kinds we distinguish (cost modelling only needs whether a
/// shuffle boundary is crossed; the rest is labelling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transform {
    /// Narrow (map, filter, ...): no shuffle boundary.
    Narrow,
    /// Wide (reduceByKey, join, ...): shuffle boundary -> new stage.
    Wide,
    /// Read from the distributed file system.
    Source,
}

/// One dataset (RDD) node in the merged DAG.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub id: usize,
    pub name: String,
    pub transform: Transform,
    pub parents: Vec<usize>,
    /// Marked `.cache()` by the application author.
    pub cached: bool,
}

/// An action (job trigger) rooted at a dataset.
#[derive(Debug, Clone)]
pub struct Action {
    pub id: usize,
    pub name: String,
    pub on: usize,
}

/// The merged application DAG (Fig. 2): all job lineages in one graph.
#[derive(Debug, Clone, Default)]
pub struct AppDag {
    pub datasets: Vec<Dataset>,
    pub actions: Vec<Action>,
}

impl AppDag {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a dataset; returns its id.
    pub fn dataset(&mut self, name: &str, transform: Transform, parents: &[usize]) -> usize {
        for &p in parents {
            assert!(p < self.datasets.len(), "unknown parent {p}");
        }
        let id = self.datasets.len();
        self.datasets.push(Dataset {
            id,
            name: name.to_string(),
            transform,
            parents: parents.to_vec(),
            cached: false,
        });
        id
    }

    pub fn source(&mut self, name: &str) -> usize {
        self.dataset(name, Transform::Source, &[])
    }

    /// Mark a dataset as cached.
    pub fn cache(&mut self, id: usize) {
        self.datasets[id].cached = true;
    }

    /// Add an action on a dataset; returns its id.
    pub fn action(&mut self, name: &str, on: usize) -> usize {
        assert!(on < self.datasets.len());
        let id = self.actions.len();
        self.actions.push(Action { id, name: name.to_string(), on });
        id
    }

    pub fn cached_datasets(&self) -> Vec<usize> {
        self.datasets.iter().filter(|d| d.cached).map(|d| d.id).collect()
    }

    /// Child-branch count per dataset in the merged DAG: edges from child
    /// datasets plus actions rooted at the dataset. Equals the number of
    /// times the dataset is *computed* when nothing is cached (§3.2).
    pub fn branch_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.datasets.len()];
        for d in &self.datasets {
            for &p in &d.parents {
                counts[p] += 1;
            }
        }
        for a in &self.actions {
            counts[a.on] += 1;
        }
        counts
    }

    /// Number of times each dataset is computed when executing all actions
    /// in order with NO caching at all: each action's lineage recomputes
    /// every ancestor once per path reaching it (depth-first traversal of
    /// §3.2). With a DAG this is the number of (action, path) pairs.
    pub fn compute_counts_uncached(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.datasets.len()];
        for a in &self.actions {
            self.count_paths(a.on, &mut counts);
        }
        counts
    }

    fn count_paths(&self, node: usize, counts: &mut [usize]) {
        counts[node] += 1;
        let parents = self.datasets[node].parents.clone();
        for p in parents {
            self.count_paths(p, counts);
        }
    }

    /// Number of times each dataset is computed when the `cached` datasets
    /// are pinned in memory after first computation (eviction-free): the
    /// traversal stops at already-cached datasets.
    pub fn compute_counts_cached(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.datasets.len()];
        let mut materialized = vec![false; self.datasets.len()];
        for a in &self.actions {
            self.count_with_cache(a.on, &mut counts, &mut materialized);
        }
        counts
    }

    fn count_with_cache(&self, node: usize, counts: &mut [usize], mat: &mut [bool]) {
        if mat[node] {
            return; // served from cache
        }
        counts[node] += 1;
        let ds = &self.datasets[node];
        let parents = ds.parents.clone();
        for p in parents {
            self.count_with_cache(p, counts, mat);
        }
        if ds.cached {
            mat[node] = true;
        }
    }

    /// Extra computations avoided by caching: Σ (uncached - cached) counts.
    pub fn recomputations_saved(&self) -> usize {
        let u = self.compute_counts_uncached();
        let c = self.compute_counts_cached();
        u.iter().zip(&c).map(|(a, b)| a - b).sum()
    }

    /// Number of shuffle boundaries (wide transforms) on the lineage of an
    /// action — proxy for its stage count.
    pub fn stages_of_action(&self, action: usize) -> usize {
        let mut wide = 0usize;
        let mut stack = vec![self.actions[action].on];
        let mut seen = vec![false; self.datasets.len()];
        while let Some(n) = stack.pop() {
            if seen[n] {
                continue;
            }
            seen[n] = true;
            if self.datasets[n].transform == Transform::Wide {
                wide += 1;
            }
            stack.extend(self.datasets[n].parents.iter().copied());
        }
        wide + 1
    }

    /// Simple cycle check (a lineage must be a DAG by construction; this
    /// guards hand-built graphs in tests/config).
    pub fn is_acyclic(&self) -> bool {
        // Kahn's algorithm over parent edges
        let n = self.datasets.len();
        let mut indeg = vec![0usize; n];
        let mut children: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for d in &self.datasets {
            indeg[d.id] = d.parents.len();
            for &p in &d.parents {
                children.entry(p).or_default().push(d.id);
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(x) = queue.pop() {
            seen += 1;
            for &c in children.get(&x).map(|v| v.as_slice()).unwrap_or(&[]) {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        seen == n
    }
}

/// Build the Logistic Regression merged DAG of Fig. 2: a root D0, a cached
/// D1, a chain D2..D11 where D2 and D11 feed several of the 8 actions.
///
/// The figure's headline counts: D1 and D2 have 8 and 6 child branches;
/// without caching D0, D1, D2, D11 are recomputed 7, 7, 5, 3 *extra* times
/// (i.e. computed 8, 8, 6, 4 times).
pub fn fig2_logistic_regression() -> AppDag {
    let mut g = AppDag::new();
    let d0 = g.source("D0");
    let d1 = g.dataset("D1", Transform::Narrow, &[d0]);
    let d2 = g.dataset("D2", Transform::Narrow, &[d1]);
    // action_0 reads D1 directly; action_7 reads D1 through a side branch
    g.action("action_0", d1);
    // two branch heads directly under D2 (actions 1 and 2)
    let h1 = g.dataset("D3", Transform::Narrow, &[d2]);
    let h2 = g.dataset("D4", Transform::Narrow, &[d2]);
    g.action("action_1", h1);
    g.action("action_2", h2);
    // D11 under D2, reached by four downstream actions (computed 4x)
    let d11 = g.dataset("D11", Transform::Narrow, &[d2]);
    for i in 0..4 {
        let b = g.dataset(&format!("D{}", 12 + i), Transform::Narrow, &[d11]);
        g.action(&format!("action_{}", 3 + i), b);
    }
    // action_7: the model-summary branch off D1 itself
    let tail = g.dataset("D16", Transform::Narrow, &[d1]);
    g.action("action_7", tail);
    g.cache(d1);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_lr_counts() {
        let g = fig2_logistic_regression();
        assert!(g.is_acyclic());
        assert_eq!(g.actions.len(), 8, "LR has 8 actions in Fig. 2");
        let uncached = g.compute_counts_uncached();
        // computed = paper's "recomputed k times" + the first computation
        assert_eq!(uncached[0], 8, "D0 computed 8x (7 recomputations)");
        assert_eq!(uncached[1], 8, "D1 computed 8x (7 recomputations)");
        assert_eq!(uncached[2], 6, "D2 computed 6x (5 recomputations)");
        let d11 = g.datasets.iter().find(|d| d.name == "D11").unwrap().id;
        assert_eq!(uncached[d11], 4, "D11 computed 4x (3 recomputations)");
        // D1's child branches: D2 + D16 + action_0 = 3 graph branches;
        // its 8 computations come from the 8 (action, path) pairs above.
        assert_eq!(g.branch_counts()[1], 3);
    }

    #[test]
    fn caching_d1_stops_upstream_recomputation() {
        let g = fig2_logistic_regression();
        let cached = g.compute_counts_cached();
        assert_eq!(cached[0], 1, "D0 computed once");
        assert_eq!(cached[1], 1, "D1 computed once, then cache-served");
        // D2 still recomputed per downstream action (it is not cached)
        assert_eq!(cached[2], 6);
        assert!(g.recomputations_saved() >= 14);
    }

    #[test]
    fn stages_follow_wide_transforms() {
        let mut g = AppDag::new();
        let s = g.source("in");
        let m = g.dataset("map", Transform::Narrow, &[s]);
        let r = g.dataset("reduce", Transform::Wide, &[m]);
        let j = g.dataset("join", Transform::Wide, &[r, m]);
        let a = g.action("collect", j);
        assert_eq!(g.stages_of_action(a), 3);
    }

    #[test]
    fn cached_datasets_listed() {
        let mut g = AppDag::new();
        let s = g.source("in");
        let d = g.dataset("feat", Transform::Narrow, &[s]);
        g.cache(d);
        assert_eq!(g.cached_datasets(), vec![d]);
    }

    #[test]
    fn diamond_counts_paths_not_nodes() {
        // action on top of a diamond: the shared root is reached twice
        let mut g = AppDag::new();
        let root = g.source("r");
        let l = g.dataset("l", Transform::Narrow, &[root]);
        let r = g.dataset("r2", Transform::Narrow, &[root]);
        let top = g.dataset("t", Transform::Narrow, &[l, r]);
        g.action("a", top);
        let u = g.compute_counts_uncached();
        assert_eq!(u[root], 2);
        assert_eq!(u[top], 1);
    }

    #[test]
    fn empty_dag_is_acyclic() {
        assert!(AppDag::new().is_acyclic());
    }
}
