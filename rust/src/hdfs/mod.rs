//! HDFS-like block store + the paper's two sampling strategies (§4.2).
//!
//! Original input data is fragmented into fixed-size blocks. Sample runs
//! shrink the data either by selecting few whole blocks (**Block-n**, cheap:
//! a metadata operation on the DFS) or by re-chunking into smaller blocks
//! (**Block-s**, costly: a full preparation pass over the sample bytes).
//! Blink keeps the number of tasks proportional to the data scale by fixing
//! the block size, so the parallelism level — which influences measured
//! dataset sizes — is preserved across scales.

use crate::util::units::Mb;

/// Default DFS block size (Hadoop default: 64 or 128 MB).
pub const DEFAULT_BLOCK_MB: Mb = 64.0;

/// One stored block.
#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    pub id: usize,
    pub size_mb: Mb,
}

/// A file in the distributed file system, fragmented into blocks.
#[derive(Debug, Clone)]
pub struct DfsFile {
    pub name: String,
    pub blocks: Vec<Block>,
}

impl DfsFile {
    /// Fragment `total_mb` of data into blocks of `block_mb` (last block
    /// holds the remainder).
    pub fn ingest(name: &str, total_mb: Mb, block_mb: Mb) -> DfsFile {
        assert!(total_mb > 0.0 && block_mb > 0.0);
        let full = (total_mb / block_mb).floor() as usize;
        let rem = total_mb - full as f64 * block_mb;
        let mut blocks: Vec<Block> = (0..full)
            .map(|id| Block { id, size_mb: block_mb })
            .collect();
        if rem > 1e-9 {
            blocks.push(Block { id: full, size_mb: rem });
        }
        DfsFile { name: name.to_string(), blocks }
    }

    pub fn total_mb(&self) -> Mb {
        self.blocks.iter().map(|b| b.size_mb).sum()
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }
}

/// Which sampling strategy produced a sample (determines its preparation
/// cost and whether it is feasible at all).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SampleApproach {
    /// Select `n` existing blocks — metadata-only, negligible cost.
    BlockN,
    /// Re-chunk the data into smaller blocks — pays a preparation pass.
    BlockS,
}

impl std::fmt::Display for SampleApproach {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SampleApproach::BlockN => write!(f, "Block-n"),
            SampleApproach::BlockS => write!(f, "Block-s"),
        }
    }
}

/// A sample dataset carved out of a [`DfsFile`].
#[derive(Debug, Clone)]
pub struct Sample {
    pub approach: SampleApproach,
    /// Fraction of the original data (e.g. 0.001 = 0.1 %).
    pub fraction: f64,
    pub size_mb: Mb,
    /// Number of blocks == number of input tasks in the sample run.
    pub num_blocks: usize,
    /// Extra one-off preparation cost in seconds (Block-s only).
    pub prep_cost_s: f64,
}

/// Sampling planner: decides Block-n vs Block-s per §4.2 and carves samples.
#[derive(Debug, Clone)]
pub struct Sampler {
    /// Block-s preparation throughput (MB/s of sample data written).
    pub prep_mb_per_s: f64,
    /// Minimum number of whole blocks required to use Block-n.
    pub min_blocks_for_block_n: usize,
}

impl Default for Sampler {
    fn default() -> Self {
        // preparation writes the sample once through the DFS; two whole
        // blocks are enough to call it a Block-n selection (the paper's
        // 2K-block inputs sample 2 blocks at 0.1 %)
        Sampler { prep_mb_per_s: 40.0, min_blocks_for_block_n: 2 }
    }
}

impl Sampler {
    /// Choose the approach for a file: Block-n whenever the file has enough
    /// blocks that `fraction` still selects whole blocks, else Block-s.
    pub fn choose(&self, file: &DfsFile, fraction: f64) -> SampleApproach {
        let picked = (file.num_blocks() as f64 * fraction).floor() as usize;
        if picked >= self.min_blocks_for_block_n {
            SampleApproach::BlockN
        } else {
            SampleApproach::BlockS
        }
    }

    /// Carve a sample using an explicitly chosen approach (workload models
    /// can force Block-s when whole-block selection is not applicable).
    pub fn sample_with(&self, file: &DfsFile, fraction: f64, approach: SampleApproach) -> Sample {
        assert!(fraction > 0.0 && fraction <= 1.0);
        let size_mb = file.total_mb() * fraction;
        match approach {
            SampleApproach::BlockN => {
                let n = ((file.num_blocks() as f64 * fraction).floor() as usize).max(1);
                Sample { approach, fraction, size_mb, num_blocks: n, prep_cost_s: 0.0 }
            }
            SampleApproach::BlockS => {
                let n = ((file.num_blocks() as f64 * fraction).ceil() as usize).max(1);
                Sample {
                    approach,
                    fraction,
                    size_mb,
                    num_blocks: n,
                    prep_cost_s: size_mb / self.prep_mb_per_s,
                }
            }
        }
    }

    /// Carve a sample of `fraction` of the file.
    ///
    /// Block-n keeps the original block size (tasks stay proportional to the
    /// scale). Block-s re-chunks the sample into the same *count* of blocks
    /// the equivalent Block-n sample would have had, preserving the
    /// task-per-byte ratio, but pays the preparation pass.
    pub fn sample(&self, file: &DfsFile, fraction: f64) -> Sample {
        self.sample_with(file, fraction, self.choose(file, fraction))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn ingest_fragments_with_remainder() {
        let f = DfsFile::ingest("in", 200.0, 64.0);
        assert_eq!(f.num_blocks(), 4);
        assert!((f.total_mb() - 200.0).abs() < 1e-9);
        assert!((f.blocks[3].size_mb - 8.0).abs() < 1e-9);
    }

    #[test]
    fn ingest_exact_multiple_has_no_stub_block() {
        let f = DfsFile::ingest("in", 128.0, 64.0);
        assert_eq!(f.num_blocks(), 2);
    }

    #[test]
    fn block_n_chosen_for_many_blocks() {
        // 1 TB at 64 MB blocks = 16K blocks; 0.1 % -> 16 blocks (paper §4.2)
        let f = DfsFile::ingest("big", 1024.0 * 1024.0, 64.0);
        let s = Sampler::default().sample(&f, 0.001);
        assert_eq!(s.approach, SampleApproach::BlockN);
        assert_eq!(s.num_blocks, 16);
        assert_eq!(s.prep_cost_s, 0.0);
    }

    #[test]
    fn block_s_chosen_for_small_files_and_costs() {
        // GBT-like: 30.6 MB in 100 tiny blocks; 0.1 % can't select whole
        // 64 MB-grade blocks -> Block-s with a preparation cost
        let f = DfsFile::ingest("gbt", 30.6, 0.306);
        let sampler = Sampler { min_blocks_for_block_n: 4, ..Default::default() };
        let s = sampler.sample(&f, 0.001);
        assert_eq!(s.approach, SampleApproach::BlockS);
        assert!(s.prep_cost_s > 0.0);
        assert!(s.num_blocks >= 1);
    }

    #[test]
    fn tasks_proportional_to_scale() {
        // 16K blocks of 64 MB: 0.1/0.2/0.3 % select 16/32/48 blocks (§4.2)
        let f = DfsFile::ingest("svm", 16_000.0 * 64.0, 64.0);
        let sampler = Sampler::default();
        let n1 = sampler.sample(&f, 0.001).num_blocks;
        let n2 = sampler.sample(&f, 0.002).num_blocks;
        let n3 = sampler.sample(&f, 0.003).num_blocks;
        assert_eq!((n1 * 2, n1 * 3), (n2, n3)); // 16, 32, 48 per the paper
    }

    #[test]
    fn property_sample_size_and_blocks_sane() {
        prop::check(
            &prop::Config { cases: 128, seed: 0xd1f5, max_size: 64 },
            |rng: &mut Rng, size| {
                let total = rng.range(10.0, 1e6) * (size.max(1) as f64 / 64.0 + 0.1);
                let block = rng.range(1.0, 128.0);
                let frac = rng.range(0.0005, 0.9);
                (DfsFile::ingest("f", total, block), frac)
            },
            |(file, frac)| {
                let s = Sampler::default().sample(file, *frac);
                if s.num_blocks == 0 {
                    return Err("no blocks".into());
                }
                if s.num_blocks > file.num_blocks() + 1 {
                    return Err("more sample blocks than source".into());
                }
                if s.size_mb > file.total_mb() {
                    return Err("sample bigger than file".into());
                }
                if s.approach == SampleApproach::BlockN && s.prep_cost_s != 0.0 {
                    return Err("block-n must be free".into());
                }
                Ok(())
            },
        );
    }
}
