//! Experiment drivers: one function per paper table/figure.
//!
//! Each driver returns a plain data struct (asserted on by integration
//! tests) and has a `print_*` companion that emits the same rows/series
//! the paper reports. `benches/` and the `blink` CLI both call these.
//! See DESIGN.md §5 for the experiment-to-module index.

pub mod report;

use crate::blink::{Advisor, FitBackend, RustFit, Scales, DEFAULT_SCALES};
use crate::ernest::ErnestModel;
use crate::memory::EvictionPolicy;
use crate::metrics::RunSummary;
use crate::sim::{simulate, ClusterSpec, MachineSpec, SimOptions, SimResult};
use crate::util::par;
use crate::util::stats;
use crate::workloads::{all_apps, app_by_name, AppModel, FULL_SCALE};

pub const MAX_MACHINES: usize = 12;

/// Simulate one actual run.
pub fn actual_run(app: &AppModel, scale: f64, machines: usize, seed: u64) -> RunSummary {
    let res = actual_run_full(app, scale, machines, seed);
    RunSummary::from_log(&res.log)
}

pub fn actual_run_full(app: &AppModel, scale: f64, machines: usize, seed: u64) -> SimResult {
    simulate(
        &app.profile(scale),
        &ClusterSpec::workers(machines),
        SimOptions { policy: EvictionPolicy::Lru, seed, compute: None, detailed_log: false },
    )
    .expect("paper testbed clusters are valid")
}

/// Sampling scales per app for the enlarged-scale study (§6.4: GBT and ALS
/// get extended sampling) — the advisor's [`Scales::Paper`] policy.
pub fn sampling_scales(app: &AppModel) -> Vec<f64> {
    Scales::Paper.for_app(app)
}

// ======================================================================
// Table 1
// ======================================================================

/// One application's Table-1 block.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub app: String,
    pub approach: String,
    pub input_gb: f64,
    pub blocks: usize,
    pub sample_cost_machine_min: f64,
    /// (time_min, cost_machine_min, eviction_free) per cluster size 1..=12.
    pub runs: Vec<(f64, f64, bool)>,
    /// Blink's recommendation (the bold number).
    pub blink_pick: usize,
    /// First eviction-free size (the first green cell).
    pub optimal: usize,
}

impl Table1Row {
    pub fn costs(&self) -> Vec<f64> {
        self.runs.iter().map(|r| r.1).collect()
    }

    pub fn pick_cost(&self) -> f64 {
        self.runs[self.blink_pick - 1].1
    }
}

/// Whether a simulated run was eviction-free AND fully cached (a paper
/// "green cell").
fn eviction_free(s: &RunSummary, res: &SimResult) -> bool {
    s.evictions == 0 && (res.cached_fraction_after_load - 1.0).abs() < 1e-9
}

/// Run one Table-1 block (all cluster sizes at one scale).
pub fn table1_row(
    app: &AppModel,
    scale: f64,
    sampling: &[f64],
    backend: &mut dyn FitBackend,
    seed: u64,
) -> Table1Row {
    let mut advisor = Advisor::builder().scales(sampling).build(backend);
    let d = advisor.profile(app).recommend(scale, &MachineSpec::worker_node());

    // each cluster size simulates under its own seed (`seed + n`), so the
    // parallel sweep is bit-identical to the old serial loop
    let runs: Vec<(f64, f64, bool)> = par::sweep_range(1, MAX_MACHINES, |n| {
        let res = actual_run_full(app, scale, n, seed + n as u64);
        let s = RunSummary::from_log(&res.log);
        (s.duration_s / 60.0, s.cost_machine_s / 60.0, eviction_free(&s, &res))
    });
    let optimal = runs.iter().position(|r| r.2).map_or(MAX_MACHINES, |i| i + 1);
    Table1Row {
        app: app.name.to_string(),
        approach: app
            .sample_approach(&crate::hdfs::Sampler::default(), 0.001)
            .to_string(),
        input_gb: app.input_mb(scale) / 1024.0,
        blocks: app.parallelism(scale),
        sample_cost_machine_min: d.sample_cost_machine_s / 60.0,
        runs,
        blink_pick: d.machines,
        optimal,
    }
}

/// The full Table 1: all apps at 100 % and at their enlarged scales.
pub struct Table1 {
    pub at_100: Vec<Table1Row>,
    pub enlarged: Vec<Table1Row>,
}

pub fn table1(seed: u64) -> Table1 {
    let mut at_100 = Vec::new();
    let mut enlarged = Vec::new();
    for app in all_apps() {
        // 100 %: the paper's standard 3 sample runs for every app
        let mut b = RustFit::default();
        at_100.push(table1_row(&app, FULL_SCALE, &DEFAULT_SCALES, &mut b, seed));
        // enlarged: GBT/ALS get their extended sampling (§6.4 exception)
        let mut b = RustFit::default();
        enlarged.push(table1_row(
            &app,
            app.enlarged_scale,
            &sampling_scales(&app),
            &mut b,
            seed + 7777,
        ));
    }
    Table1 { at_100, enlarged }
}

/// Top half only (the 100 % block) — cheap enough for debug-mode tests.
pub fn table1_at_100(seed: u64) -> Vec<Table1Row> {
    all_apps()
        .iter()
        .map(|app| {
            let mut b = RustFit::default();
            table1_row(app, FULL_SCALE, &DEFAULT_SCALES, &mut b, seed)
        })
        .collect()
}

// ======================================================================
// Figure 1 — svm time/cost vs cluster size, with Ernest's prediction
// ======================================================================

#[derive(Debug, Clone)]
pub struct Fig1 {
    /// (machines, time_min, cost_machine_min, eviction_free)
    pub series: Vec<(usize, f64, f64, bool)>,
    pub ernest_time_min: Vec<f64>,
    pub ernest_pick: usize,
    pub optimal: usize,
}

pub fn fig1(seed: u64) -> Fig1 {
    let app = app_by_name("svm").unwrap();
    let series: Vec<(usize, f64, f64, bool)> = par::sweep_range(1, MAX_MACHINES, |n| {
        let res = actual_run_full(&app, FULL_SCALE, n, seed + n as u64);
        let s = RunSummary::from_log(&res.log);
        (n, s.duration_s / 60.0, s.cost_machine_s / 60.0, eviction_free(&s, &res))
    });
    let optimal = series.iter().position(|r| r.3).map_or(MAX_MACHINES, |i| i + 1);
    let ernest = ErnestModel::train(&app, MAX_MACHINES, seed);
    let ernest_time_min = (1..=MAX_MACHINES)
        .map(|n| ernest.predict_time_s(n) / 60.0)
        .collect();
    Fig1 {
        series,
        ernest_time_min,
        ernest_pick: ernest.cheapest_cluster(MAX_MACHINES),
        optimal,
    }
}

// ======================================================================
// Figure 4 — repeated short runs: size constant, time noisy
// ======================================================================

#[derive(Debug, Clone)]
pub struct Fig4Scale {
    pub scale: f64,
    pub times_s: Vec<f64>,
    pub sizes_mb: Vec<f64>,
}

/// 10 runs each on three small data scales (the paper used 738 MB–2.2 GB,
/// i.e. scales ~12/25/37 of svm) on a single machine.
pub fn fig4(seed: u64) -> Vec<Fig4Scale> {
    let app = app_by_name("svm").unwrap();
    [12.0, 25.0, 37.0]
        .iter()
        .map(|&scale| {
            let (times, sizes) = par::sweep_range(0, 9, |run| {
                let res = simulate(
                    &app.profile(scale),
                    &ClusterSpec::workers(1),
                    SimOptions {
                        policy: EvictionPolicy::Lru,
                        seed: seed + run as u64,
                        compute: None,
                        detailed_log: false,
                    },
                )
                .expect("single-machine cluster is valid");
                let s = RunSummary::from_log(&res.log);
                (s.duration_s, s.total_cached_mb())
            })
            .into_iter()
            .unzip();
            Fig4Scale { scale, times_s: times, sizes_mb: sizes }
        })
        .collect()
}

// ======================================================================
// Figure 6 — Blink cost vs average and worst
// ======================================================================

#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub app: String,
    /// Blink total cost (sample runs + actual run at its pick).
    pub blink_cost: f64,
    pub avg_cost: f64,
    pub worst_cost: f64,
}

pub fn fig6(table: &Table1) -> Vec<Fig6Row> {
    table
        .at_100
        .iter()
        .map(|row| {
            let costs = row.costs();
            Fig6Row {
                app: row.app.clone(),
                blink_cost: row.pick_cost() + row.sample_cost_machine_min,
                avg_cost: stats::mean(&costs),
                worst_cost: stats::max(&costs),
            }
        })
        .collect()
}

/// The paper's two headline ratios (52.6 % and 25.1 %).
pub fn fig6_ratios(rows: &[Fig6Row]) -> (f64, f64) {
    let vs_avg: Vec<f64> = rows.iter().map(|r| r.blink_cost / r.avg_cost).collect();
    let vs_worst: Vec<f64> = rows.iter().map(|r| r.blink_cost / r.worst_cost).collect();
    (stats::mean(&vs_avg), stats::mean(&vs_worst))
}

// ======================================================================
// Figure 7 — size prediction error per app
// ======================================================================

#[derive(Debug, Clone)]
pub struct Fig7Row {
    pub app: String,
    pub predicted_mb: f64,
    pub actual_mb: f64,
    pub error: f64,
}

pub fn fig7() -> Vec<Fig7Row> {
    all_apps()
        .iter()
        .map(|app| {
            let mut backend = RustFit::default();
            let mut advisor = Advisor::builder().scales(&DEFAULT_SCALES).build(&mut backend);
            let d = advisor.profile(app).recommend(FULL_SCALE, &MachineSpec::worker_node());
            let actual = app.total_true_cached_mb(FULL_SCALE);
            Fig7Row {
                app: app.name.to_string(),
                predicted_mb: d.predicted_cached_mb,
                actual_mb: actual,
                error: stats::rel_err(d.predicted_cached_mb, actual),
            }
        })
        .collect()
}

// ======================================================================
// Figures 8 & 9 — GBT: more sample runs buy accuracy
// ======================================================================

#[derive(Debug, Clone)]
pub struct Fig8Point {
    pub num_samples: usize,
    pub sample_cost_machine_min: f64,
    pub accuracy: f64,
    /// Model cross-validation relative error (Fig. 9's 53.9 % -> 28.5 %).
    pub cv_rel_err: f64,
}

pub fn fig8() -> Vec<Fig8Point> {
    let app = app_by_name("gbt").unwrap();
    let actual = app.total_true_cached_mb(FULL_SCALE);
    (3..=10)
        .map(|k| {
            let scales: Vec<f64> = (1..=k).map(|s| s as f64).collect();
            let mut backend = RustFit::default();
            let mut advisor = Advisor::builder().scales(&scales).build(&mut backend);
            let profile = advisor.profile(&app);
            let d = profile.recommend(FULL_SCALE, &MachineSpec::worker_node());
            let cv = profile
                .models
                .as_ref()
                .map(|(s, _)| s.worst_cv_rel_err())
                .unwrap_or(0.0);
            Fig8Point {
                num_samples: k,
                sample_cost_machine_min: d.sample_cost_machine_s / 60.0,
                accuracy: 1.0 - stats::rel_err(d.predicted_cached_mb, actual),
                cv_rel_err: cv,
            }
        })
        .collect()
}

/// Fig. 9's raw series: measured cached size per sample scale.
pub fn fig9_sizes() -> Vec<(f64, f64)> {
    let app = app_by_name("gbt").unwrap();
    (1..=10)
        .map(|s| (s as f64, app.measured_cached_mb(0, s as f64)))
        .collect()
}

// ======================================================================
// Figure 10 — sample-run cost vs optimal actual cost; Ernest comparison
// ======================================================================

#[derive(Debug, Clone)]
pub struct Fig10Row {
    pub app: String,
    pub approach: String,
    /// sample cost / optimal actual cost.
    pub overhead: f64,
}

pub struct Fig10 {
    pub rows: Vec<Fig10Row>,
    /// Ernest's sampling cost over Blink's, for svm (paper: 16.4x).
    pub ernest_over_blink: f64,
}

pub fn fig10(table: &Table1, seed: u64) -> Fig10 {
    let rows = table
        .at_100
        .iter()
        .map(|row| {
            let optimal_cost = row.runs[row.optimal - 1].1;
            Fig10Row {
                app: row.app.clone(),
                approach: row.approach.clone(),
                overhead: row.sample_cost_machine_min / optimal_cost,
            }
        })
        .collect();
    let svm = app_by_name("svm").unwrap();
    let ernest = ErnestModel::train(&svm, MAX_MACHINES, seed);
    let blink_cost = table
        .at_100
        .iter()
        .find(|r| r.app == "svm")
        .unwrap()
        .sample_cost_machine_min;
    Fig10 {
        rows,
        ernest_over_blink: ernest.training_cost_machine_s / 60.0 / blink_cost,
    }
}

// ======================================================================
// Figure 11 — KM task skew on 7 machines at 200 %
// ======================================================================

#[derive(Debug, Clone)]
pub struct Fig11 {
    pub tasks_per_machine: Vec<usize>,
    pub evictions_per_machine: Vec<usize>,
    pub blink_pick: usize,
    pub true_optimal: usize,
    pub pick_cost: f64,
    pub optimal_cost: f64,
}

pub fn fig11(seed: u64) -> Fig11 {
    let app = app_by_name("km").unwrap();
    let scale = app.enlarged_scale; // 200 %
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().scales(&DEFAULT_SCALES).build(&mut backend);
    let d = advisor.profile(&app).recommend(scale, &MachineSpec::worker_node());

    let res = actual_run_full(&app, scale, d.machines, seed);
    let s = RunSummary::from_log(&res.log);

    // the true cost-optimum: sweep a few sizes above the pick (fanned out,
    // folded in ascending order so ties resolve like the serial loop)
    let mut best = (d.machines, s.cost_machine_s / 60.0);
    let costs = par::sweep_range(d.machines + 1, MAX_MACHINES, |n| {
        (n, actual_run(&app, scale, n, seed + n as u64).cost_machine_s / 60.0)
    });
    for (n, cost) in costs {
        if cost < best.1 {
            best = (n, cost);
        }
    }
    Fig11 {
        tasks_per_machine: res.iter_tasks_per_machine.clone(),
        evictions_per_machine: res.evictions_per_machine.clone(),
        blink_pick: d.machines,
        true_optimal: best.0,
        pick_cost: s.cost_machine_s / 60.0,
        optimal_cost: best.1,
    }
}

// ======================================================================
// Table 2 — cluster bounds at 12 machines
// ======================================================================

#[derive(Debug, Clone)]
pub struct Table2Row {
    pub app: String,
    pub predicted_scale: f64,
    /// (relative offset, eviction_free) for -5 %..+5 % around prediction.
    pub probes: Vec<(f64, bool)>,
    /// True eviction-free boundary found by the simulator.
    pub true_boundary: f64,
}

pub fn table2(seed: u64) -> Vec<Table2Row> {
    table2_impl(seed, true)
}

/// Bounds-only variant (no simulation probes) for cheap test assertions.
pub fn table2_bounds_only(seed: u64) -> Vec<Table2Row> {
    table2_impl(seed, false)
}

fn table2_impl(seed: u64, with_probes: bool) -> Vec<Table2Row> {
    let machine = MachineSpec::worker_node();
    all_apps()
        .iter()
        .filter(|a| a.name != "km") // excluded per §6.5 (see Fig. 11)
        .map(|app| {
            // one trained profile answers the Table-2 inverse query — the
            // same pipeline `blink bounds` uses, no hand-rolled training
            let mut b = RustFit::default();
            let mut advisor = Advisor::builder().build(&mut b);
            let profile = advisor.profile(app);
            assert!(!profile.no_cached_data(), "{} caches data", app.name);
            let predicted = profile.max_scale(&machine, 12);

            let offsets = [-0.05, -0.04, -0.03, -0.02, -0.01, 0.0, 0.01, 0.02, 0.03, 0.04, 0.05];
            let probes = if with_probes {
                offsets
                    .iter()
                    .map(|&off| {
                        let scale = predicted * (1.0 + off);
                        // eviction-free status is decided by materialization
                        // + the first execution-memory claim; probing with a
                        // single iteration keeps huge scales affordable
                        let mut profile = app.profile(scale);
                        profile.iterations = 1;
                        let res = simulate(
                            &profile,
                            &ClusterSpec::workers(12),
                            SimOptions {
                                policy: EvictionPolicy::Lru,
                                seed,
                                compute: None,
                                detailed_log: false,
                            },
                        )
                        .expect("12-worker cluster is valid");
                        let s = RunSummary::from_log(&res.log);
                        (off, eviction_free(&s, &res))
                    })
                    .collect()
            } else {
                Vec::new()
            };
            // true boundary via the true laws (selector-style condition)
            let true_boundary = {
                let m = machine.unified_mb();
                let r = machine.storage_floor_mb();
                // solve cached(s)/12 = m - min(m-r, exec(s)/12) by bisection
                let fits = |s: f64| {
                    let exec_pm = (m - r).min(app.exec_mem_mb(s) / 12.0);
                    app.total_true_cached_mb(s) / 12.0 < m - exec_pm
                };
                let mut lo = 0.0;
                let mut hi = predicted.max(1.0);
                while fits(hi) {
                    lo = hi;
                    hi *= 2.0;
                }
                for _ in 0..64 {
                    let mid = 0.5 * (lo + hi);
                    if fits(mid) {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                lo
            };
            Table2Row {
                app: app.name.to_string(),
                predicted_scale: predicted,
                probes,
                true_boundary,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_sizes_constant_times_noisy() {
        for sc in fig4(11) {
            let (first, rest) = sc.sizes_mb.split_first().unwrap();
            assert!(rest.iter().all(|s| (s - first).abs() < 1e-9), "sizes vary");
            assert!(stats::cv(&sc.times_s) > 0.001, "times should be noisy");
        }
    }

    #[test]
    fn fig9_series_has_10_points() {
        let pts = fig9_sizes();
        assert_eq!(pts.len(), 10);
        assert!(pts.iter().all(|p| p.1 > 0.0));
    }

    #[test]
    fn table2_boundaries_within_5pct() {
        for row in table2_bounds_only(5) {
            let err = (row.predicted_scale - row.true_boundary).abs() / row.true_boundary;
            assert!(err < 0.05, "{}: predicted {} vs true {}", row.app, row.predicted_scale, row.true_boundary);
        }
    }
}

// ======================================================================
// Section 4 — the inline experiments motivating efficient sample runs
// ======================================================================

/// §4.2: same data, 10 vs 1000 tasks — parallelism influences both the
/// run time and the measured cached size.
#[derive(Debug, Clone)]
pub struct Sec4Parallelism {
    pub tasks_low: usize,
    pub tasks_high: usize,
    pub time_low_s: f64,
    pub time_high_s: f64,
    pub size_low_mb: f64,
    pub size_high_mb: f64,
}

pub fn sec4_parallelism(seed: u64) -> Sec4Parallelism {
    let app = app_by_name("svm").unwrap();
    let scale = 20.0; // ~1.2 GB input, the paper's demo size
    let run = |parallelism: usize, seed: u64| {
        let mut p = app.profile_with_parallelism(scale, 0.0, parallelism);
        // on the sample node each task pays scheduling + shuffle-cleanup
        p.task_overhead_s = 0.02;
        let res = simulate(
            &p,
            &ClusterSpec::workers(1),
            SimOptions { policy: EvictionPolicy::Lru, seed, compute: None, detailed_log: false },
        )
        .expect("single-machine cluster is valid");
        let s = RunSummary::from_log(&res.log);
        (s.duration_s, s.total_cached_mb())
    };
    let (time_low_s, size_low_mb) = run(10, seed);
    let (time_high_s, size_high_mb) = run(1000, seed);
    Sec4Parallelism {
        tasks_low: 10,
        tasks_high: 1000,
        time_low_s,
        time_high_s,
        size_low_mb,
        size_high_mb,
    }
}

/// §4.3: the same sample run on a single machine vs the full 12-machine
/// cluster — sampling on the cluster costs far more (paper: 13.9x).
#[derive(Debug, Clone)]
pub struct Sec4Cluster {
    pub cost_single: f64,
    pub cost_cluster: f64,
}

pub fn sec4_single_vs_cluster(seed: u64) -> Sec4Cluster {
    let app = app_by_name("svm").unwrap();
    let profile = app.profile(20.0); // ~1.2 GB input
    let cost = |n: usize| {
        let res = simulate(
            &profile,
            &ClusterSpec::workers(n),
            SimOptions { policy: EvictionPolicy::Lru, seed, compute: None, detailed_log: false },
        )
        .expect("worker cluster is valid");
        RunSummary::from_log(&res.log).cost_machine_s
    };
    Sec4Cluster { cost_single: cost(1), cost_cluster: cost(12) }
}
