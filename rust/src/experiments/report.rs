//! ASCII renderers: print each experiment the way the paper lays it out,
//! plus the fleet planner's ranked/Pareto report.

use super::*;
use crate::blink::{Plan, RiskAdjustedPick};
use crate::sim::InstanceCatalog;
use crate::util::units::{fmt_mb_signed, fmt_pct, fmt_secs};

fn hr(width: usize) -> String {
    "-".repeat(width)
}

pub fn print_table1(t: &Table1) {
    println!("TABLE 1 — overview of evaluated applications");
    for (title, rows) in [("100 % data scale", &t.at_100), ("enlarged data scale", &t.enlarged)] {
        println!("\n[{title}]");
        print!("{:<22}", "#Machines");
        for r in rows {
            print!("{:>14}", r.app.to_uppercase());
        }
        println!();
        print!("{:<22}", "sample cost (m-min)");
        for r in rows {
            print!("{:>14.1}", r.sample_cost_machine_min);
        }
        println!();
        print!("{:<22}", "approach");
        for r in rows {
            print!("{:>14}", r.approach);
        }
        println!();
        print!("{:<22}", "input size (GB)");
        for r in rows {
            print!("{:>14.2}", r.input_gb);
        }
        println!();
        println!("{}", hr(22 + rows.len() * 14));
        for n in 1..=MAX_MACHINES {
            print!("{:<22}", format!("n={n}  time|cost"));
            for r in rows {
                let (time, cost, free) = r.runs[n - 1];
                let mark = if r.blink_pick == n {
                    "*"
                } else if free {
                    "+"
                } else {
                    " "
                };
                print!("{:>13}{}", format!("{time:.1}|{cost:.1}"), mark);
            }
            println!();
        }
        print!("{:<22}", "BLINK pick");
        for r in rows {
            print!("{:>14}", r.blink_pick);
        }
        println!();
        print!("{:<22}", "first eviction-free");
        for r in rows {
            print!("{:>14}", r.optimal);
        }
        println!("\n  (* = BLINK's pick, + = eviction-free cell)");
    }
}

pub fn print_fig1(f: &Fig1) {
    println!("FIGURE 1 — svm: time & cost vs cluster size (areas A/B/C)");
    println!("{:>4} {:>12} {:>16} {:>14} {:>10}", "n", "time (min)", "cost (m-min)", "ernest (min)", "cached");
    for (i, (n, time, cost, free)) in f.series.iter().enumerate() {
        println!(
            "{:>4} {:>12.1} {:>16.1} {:>14.1} {:>10}",
            n,
            time,
            cost,
            f.ernest_time_min[i],
            if *free { "full" } else { "partial" }
        );
    }
    println!("area C (optimal) = {} machines; Ernest would pick {}", f.optimal, f.ernest_pick);
}

pub fn print_fig4(scales: &[Fig4Scale]) {
    println!("FIGURE 4 — 10 short runs x 3 scales (svm, 1 machine)");
    for sc in scales {
        println!(
            "scale {:>5.0}: cached size {:>8.1} MB (constant: {}), time mean {:>6.1}s cv {}",
            sc.scale,
            sc.sizes_mb[0],
            sc.sizes_mb.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9),
            crate::util::stats::mean(&sc.times_s),
            fmt_pct(crate::util::stats::cv(&sc.times_s)),
        );
    }
}

pub fn print_fig6(rows: &[Fig6Row]) {
    println!("FIGURE 6 — BLINK cost vs average/worst actual-run cost");
    println!("{:>6} {:>16} {:>14} {:>14} {:>9} {:>9}", "app", "blink (m-min)", "avg", "worst", "vs avg", "vs worst");
    for r in rows {
        println!(
            "{:>6} {:>16.1} {:>14.1} {:>14.1} {:>9} {:>9}",
            r.app,
            r.blink_cost,
            r.avg_cost,
            r.worst_cost,
            fmt_pct(r.blink_cost / r.avg_cost),
            fmt_pct(r.blink_cost / r.worst_cost),
        );
    }
    let (a, w) = fig6_ratios(rows);
    println!("mean: {} of average cost, {} of worst cost (paper: 52.6 % / 25.1 %)", fmt_pct(a), fmt_pct(w));
}

pub fn print_fig7(rows: &[Fig7Row]) {
    println!("FIGURE 7 — prediction error of cached dataset sizes");
    println!("{:>6} {:>14} {:>14} {:>8}", "app", "predicted MB", "actual MB", "error");
    let mut errs = Vec::new();
    for r in rows {
        println!("{:>6} {:>14.1} {:>14.1} {:>8}", r.app, r.predicted_mb, r.actual_mb, fmt_pct(r.error));
        errs.push(r.error);
    }
    println!("average error {} (paper: 7.4 %)", fmt_pct(crate::util::stats::mean(&errs)));
}

pub fn print_fig8(points: &[Fig8Point]) {
    println!("FIGURE 8 — GBT: sample cost & prediction accuracy vs #samples");
    println!("{:>9} {:>18} {:>10} {:>10}", "#samples", "cost (m-min)", "accuracy", "cv err");
    for p in points {
        println!(
            "{:>9} {:>18.2} {:>10} {:>10}",
            p.num_samples,
            p.sample_cost_machine_min,
            fmt_pct(p.accuracy),
            fmt_pct(p.cv_rel_err)
        );
    }
}

pub fn print_fig9(sizes: &[(f64, f64)]) {
    println!("FIGURE 9 — GBT cached dataset size during sample runs");
    for (s, mb) in sizes {
        println!("scale {:>4.0} (0.{:.0} %): {:>8.1} KB", s, s, mb * 1024.0);
    }
}

pub fn print_fig10(f: &Fig10) {
    println!("FIGURE 10 — cost of sample runs vs optimal actual runs");
    println!("{:>6} {:>10} {:>10}", "app", "approach", "overhead");
    let mut all = Vec::new();
    let mut by_approach: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for r in &f.rows {
        println!("{:>6} {:>10} {:>10}", r.app, r.approach, fmt_pct(r.overhead));
        all.push(r.overhead);
        by_approach.entry(r.approach.as_str()).or_default().push(r.overhead);
    }
    println!("average {} (paper: 8.1 %)", fmt_pct(crate::util::stats::mean(&all)));
    for (a, v) in by_approach {
        println!("  {a}: avg {}", fmt_pct(crate::util::stats::mean(&v)));
    }
    println!("Ernest sampling cost = {:.1}x Blink's (paper: 16.4x)", f.ernest_over_blink);
}

pub fn print_fig11(f: &Fig11) {
    println!("FIGURE 11 — KM at 200 %: task distribution on {} machines", f.blink_pick);
    println!("{:>8} {:>7} {:>10}", "machine", "tasks", "evictions");
    for (i, (t, e)) in f
        .tasks_per_machine
        .iter()
        .zip(&f.evictions_per_machine)
        .enumerate()
    {
        println!("{:>8} {:>7} {:>10}", i + 1, t, e);
    }
    println!(
        "BLINK picked {} ({:.1} m-min) but the true optimum is {} ({:.1} m-min) — skew-driven evictions",
        f.blink_pick, f.pick_cost, f.true_optimal, f.optimal_cost
    );
}

pub fn print_table2(rows: &[Table2Row]) {
    println!("TABLE 2 — cluster bounds at 12 machines (✓ = eviction-free)");
    print!("{:<12}", "scale\\app");
    for r in rows {
        print!("{:>7}", r.app.to_uppercase());
    }
    println!();
    let offsets = [-0.05, -0.04, -0.03, -0.02, -0.01, 0.0, 0.01, 0.02, 0.03, 0.04, 0.05];
    for (oi, off) in offsets.iter().enumerate() {
        let label = if *off == 0.0 {
            "Predicted".to_string()
        } else {
            format!("{:+.0} %", off * 100.0)
        };
        print!("{label:<12}");
        for r in rows {
            print!("{:>7}", if r.probes[oi].1 { "✓" } else { "x" });
        }
        println!();
    }
    for r in rows {
        let err = (r.predicted_scale - r.true_boundary) / r.true_boundary;
        println!(
            "{:>6}: predicted max scale {:>9.1} vs true boundary {:>9.1} ({} error)",
            r.app,
            r.predicted_scale,
            r.true_boundary,
            fmt_pct(err.abs())
        );
    }
}

/// The `blink advise` report: ranked per-type picks, then the time/cost
/// Pareto front over the whole (type × count) grid.
pub fn print_plan(plan: &Plan, catalog: &InstanceCatalog, pricing: &str) {
    println!("\nPLAN — catalog '{}' ({} types), pricing '{}'", catalog.name, catalog.instances.len(), pricing);
    println!(
        "{:>4} {:<12} {:>4} {:>4}..{:<4} {:>10} {:>12} {:>14} {:>6}",
        "rank", "instance", "n", "min", "max", "time", "cost", "headroom", "free"
    );
    for (i, pick) in plan.ranked.iter().enumerate() {
        let c = &pick.candidate;
        let s = &pick.selection;
        let headroom = if s.saturated {
            format!("-{} !", crate::util::units::fmt_mb(s.cache_deficit_mb()))
        } else {
            fmt_mb_signed(c.headroom_mb)
        };
        println!(
            "{:>4} {:<12} {:>4} {:>4}..{:<4} {:>10} {:>12.2} {:>14} {:>6}",
            i + 1,
            c.instance,
            c.machines,
            s.machines_min,
            s.machines_max,
            fmt_secs(c.predicted_time_s),
            c.predicted_cost,
            headroom,
            if c.eviction_free { "yes" } else { "NO" },
        );
    }
    if plan.pareto.iter().all(|c| c.eviction_free) {
        println!("pareto front (time vs cost, eviction-free candidates):");
    } else {
        println!("pareto front (time vs cost — NO candidate fits eviction-free; full grid):");
    }
    for c in &plan.pareto {
        println!(
            "  {:<12} x{:<3} {:>10}  cost {:>10.2}",
            c.instance,
            c.machines,
            fmt_secs(c.predicted_time_s),
            c.predicted_cost
        );
    }
    if let Some(best) = plan.best() {
        println!(
            "-> recommend {} x{} ({}, cost {:.2}){}",
            best.candidate.instance,
            best.candidate.machines,
            fmt_secs(best.candidate.predicted_time_s),
            best.candidate.predicted_cost,
            if best.candidate.eviction_free {
                ""
            } else {
                "  — WARNING: cluster bound hit on every type; run will evict"
            }
        );
    }
}

/// Risk cross-validation table: the planner's analytic picks realized by
/// event-driven engine runs under a disturbance scenario.
pub fn print_risk(risks: &[RiskAdjustedPick], scenario: &str, pricing: &str) {
    println!(
        "\nRISK — top picks cross-validated by engine runs (scenario '{scenario}', pricing '{pricing}')"
    );
    if risks.is_empty() {
        println!("  (no pick could be validated)");
        return;
    }
    println!(
        "{:>4} {:<12} {:>4} {:>12} {:>14} {:>10} {:>6}",
        "rank", "instance", "n", "time", "realized", "vs quote", "lost"
    );
    for (i, r) in risks.iter().enumerate() {
        if r.completed_runs == 0 {
            println!(
                "{:>4} {:<12} {:>4} {:>12} {:>14} {:>10} {:>6}",
                i + 1,
                r.pick.candidate.instance,
                r.pick.candidate.machines,
                "COLLAPSED",
                "inf",
                "-",
                r.machines_lost,
            );
            continue;
        }
        println!(
            "{:>4} {:<12} {:>4} {:>12} {:>14.4} {:>+9.1}% {:>6.1}",
            i + 1,
            r.pick.candidate.instance,
            r.pick.candidate.machines,
            fmt_secs(r.realized_time_s),
            r.realized_cost,
            (r.cost_inflation - 1.0) * 100.0,
            r.machines_lost,
        );
    }
}

pub fn print_sec4(p: &Sec4Parallelism, c: &Sec4Cluster) {
    println!("SECTION 4.2 — parallelism during sample runs (svm, ~1.2 GB)");
    println!(
        "  {} tasks:   {:>8}  cached {:>8.1} MB",
        p.tasks_low,
        crate::util::units::fmt_secs(p.time_low_s),
        p.size_low_mb
    );
    println!(
        "  {} tasks: {:>8}  cached {:>8.1} MB",
        p.tasks_high,
        crate::util::units::fmt_secs(p.time_high_s),
        p.size_high_mb
    );
    println!(
        "  (paper: 41 s vs 3.5 min; 728.9 MB vs 747.8 MB — parallelism\n   changes both, so Blink keeps tasks proportional to the scale)"
    );
    println!("\nSECTION 4.3 — sample run on 1 vs 12 machines (svm, ~1.2 GB)");
    println!(
        "  single machine: {:>8.1} machine-s   cluster: {:>8.1} machine-s  ({:.1}x, paper: 13.9x)",
        c.cost_single,
        c.cost_cluster,
        c.cost_cluster / c.cost_single
    );
}
