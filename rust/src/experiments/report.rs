//! Experiment renderers: the text printers lay each table/figure out the
//! way the paper does; the `json_*` companions encode the same driver
//! structs via [`crate::util::json`] for `blink experiment --format json`.

use std::fmt::Write as _;

use super::*;
use crate::blink::report::{render_plan_text, render_risk_text};
use crate::blink::{Plan, RiskAdjustedPick};
use crate::sim::InstanceCatalog;
use crate::util::json::Json;
use crate::util::units::fmt_pct;

fn hr(width: usize) -> String {
    "-".repeat(width)
}

/// Table 1 as a string — byte-identical to what [`print_table1`] emits
/// (including the trailing newline). The golden-snapshot tests freeze
/// this rendering so refactors cannot silently drift the reproduction.
pub fn render_table1(t: &Table1) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "TABLE 1 — overview of evaluated applications");
    for (title, rows) in [("100 % data scale", &t.at_100), ("enlarged data scale", &t.enlarged)] {
        let _ = writeln!(out, "\n[{title}]");
        let _ = write!(out, "{:<22}", "#Machines");
        for r in rows {
            let _ = write!(out, "{:>14}", r.app.to_uppercase());
        }
        let _ = writeln!(out);
        let _ = write!(out, "{:<22}", "sample cost (m-min)");
        for r in rows {
            let _ = write!(out, "{:>14.1}", r.sample_cost_machine_min);
        }
        let _ = writeln!(out);
        let _ = write!(out, "{:<22}", "approach");
        for r in rows {
            let _ = write!(out, "{:>14}", r.approach);
        }
        let _ = writeln!(out);
        let _ = write!(out, "{:<22}", "input size (GB)");
        for r in rows {
            let _ = write!(out, "{:>14.2}", r.input_gb);
        }
        let _ = writeln!(out);
        let _ = writeln!(out, "{}", hr(22 + rows.len() * 14));
        for n in 1..=MAX_MACHINES {
            let _ = write!(out, "{:<22}", format!("n={n}  time|cost"));
            for r in rows {
                let (time, cost, free) = r.runs[n - 1];
                let mark = if r.blink_pick == n {
                    "*"
                } else if free {
                    "+"
                } else {
                    " "
                };
                let _ = write!(out, "{:>13}{}", format!("{time:.1}|{cost:.1}"), mark);
            }
            let _ = writeln!(out);
        }
        let _ = write!(out, "{:<22}", "BLINK pick");
        for r in rows {
            let _ = write!(out, "{:>14}", r.blink_pick);
        }
        let _ = writeln!(out);
        let _ = write!(out, "{:<22}", "first eviction-free");
        for r in rows {
            let _ = write!(out, "{:>14}", r.optimal);
        }
        let _ = writeln!(out, "\n  (* = BLINK's pick, + = eviction-free cell)");
    }
    out
}

pub fn print_table1(t: &Table1) {
    print!("{}", render_table1(t));
}

pub fn print_fig1(f: &Fig1) {
    println!("FIGURE 1 — svm: time & cost vs cluster size (areas A/B/C)");
    println!("{:>4} {:>12} {:>16} {:>14} {:>10}", "n", "time (min)", "cost (m-min)", "ernest (min)", "cached");
    for (i, (n, time, cost, free)) in f.series.iter().enumerate() {
        println!(
            "{:>4} {:>12.1} {:>16.1} {:>14.1} {:>10}",
            n,
            time,
            cost,
            f.ernest_time_min[i],
            if *free { "full" } else { "partial" }
        );
    }
    println!("area C (optimal) = {} machines; Ernest would pick {}", f.optimal, f.ernest_pick);
}

pub fn print_fig4(scales: &[Fig4Scale]) {
    println!("FIGURE 4 — 10 short runs x 3 scales (svm, 1 machine)");
    for sc in scales {
        println!(
            "scale {:>5.0}: cached size {:>8.1} MB (constant: {}), time mean {:>6.1}s cv {}",
            sc.scale,
            sc.sizes_mb[0],
            sc.sizes_mb.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9),
            crate::util::stats::mean(&sc.times_s),
            fmt_pct(crate::util::stats::cv(&sc.times_s)),
        );
    }
}

pub fn print_fig6(rows: &[Fig6Row]) {
    println!("FIGURE 6 — BLINK cost vs average/worst actual-run cost");
    println!("{:>6} {:>16} {:>14} {:>14} {:>9} {:>9}", "app", "blink (m-min)", "avg", "worst", "vs avg", "vs worst");
    for r in rows {
        println!(
            "{:>6} {:>16.1} {:>14.1} {:>14.1} {:>9} {:>9}",
            r.app,
            r.blink_cost,
            r.avg_cost,
            r.worst_cost,
            fmt_pct(r.blink_cost / r.avg_cost),
            fmt_pct(r.blink_cost / r.worst_cost),
        );
    }
    let (a, w) = fig6_ratios(rows);
    println!("mean: {} of average cost, {} of worst cost (paper: 52.6 % / 25.1 %)", fmt_pct(a), fmt_pct(w));
}

pub fn print_fig7(rows: &[Fig7Row]) {
    println!("FIGURE 7 — prediction error of cached dataset sizes");
    println!("{:>6} {:>14} {:>14} {:>8}", "app", "predicted MB", "actual MB", "error");
    let mut errs = Vec::new();
    for r in rows {
        println!("{:>6} {:>14.1} {:>14.1} {:>8}", r.app, r.predicted_mb, r.actual_mb, fmt_pct(r.error));
        errs.push(r.error);
    }
    println!("average error {} (paper: 7.4 %)", fmt_pct(crate::util::stats::mean(&errs)));
}

pub fn print_fig8(points: &[Fig8Point]) {
    println!("FIGURE 8 — GBT: sample cost & prediction accuracy vs #samples");
    println!("{:>9} {:>18} {:>10} {:>10}", "#samples", "cost (m-min)", "accuracy", "cv err");
    for p in points {
        println!(
            "{:>9} {:>18.2} {:>10} {:>10}",
            p.num_samples,
            p.sample_cost_machine_min,
            fmt_pct(p.accuracy),
            fmt_pct(p.cv_rel_err)
        );
    }
}

pub fn print_fig9(sizes: &[(f64, f64)]) {
    println!("FIGURE 9 — GBT cached dataset size during sample runs");
    for (s, mb) in sizes {
        println!("scale {:>4.0} (0.{:.0} %): {:>8.1} KB", s, s, mb * 1024.0);
    }
}

pub fn print_fig10(f: &Fig10) {
    println!("FIGURE 10 — cost of sample runs vs optimal actual runs");
    println!("{:>6} {:>10} {:>10}", "app", "approach", "overhead");
    let mut all = Vec::new();
    let mut by_approach: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for r in &f.rows {
        println!("{:>6} {:>10} {:>10}", r.app, r.approach, fmt_pct(r.overhead));
        all.push(r.overhead);
        by_approach.entry(r.approach.as_str()).or_default().push(r.overhead);
    }
    println!("average {} (paper: 8.1 %)", fmt_pct(crate::util::stats::mean(&all)));
    for (a, v) in by_approach {
        println!("  {a}: avg {}", fmt_pct(crate::util::stats::mean(&v)));
    }
    println!("Ernest sampling cost = {:.1}x Blink's (paper: 16.4x)", f.ernest_over_blink);
}

pub fn print_fig11(f: &Fig11) {
    println!("FIGURE 11 — KM at 200 %: task distribution on {} machines", f.blink_pick);
    println!("{:>8} {:>7} {:>10}", "machine", "tasks", "evictions");
    for (i, (t, e)) in f
        .tasks_per_machine
        .iter()
        .zip(&f.evictions_per_machine)
        .enumerate()
    {
        println!("{:>8} {:>7} {:>10}", i + 1, t, e);
    }
    println!(
        "BLINK picked {} ({:.1} m-min) but the true optimum is {} ({:.1} m-min) — skew-driven evictions",
        f.blink_pick, f.pick_cost, f.true_optimal, f.optimal_cost
    );
}

/// Table 2 as a string — byte-identical to what [`print_table2`] emits
/// (frozen by the golden-snapshot tests, like [`render_table1`]).
pub fn render_table2(rows: &[Table2Row]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "TABLE 2 — cluster bounds at 12 machines (✓ = eviction-free)");
    let _ = write!(out, "{:<12}", "scale\\app");
    for r in rows {
        let _ = write!(out, "{:>7}", r.app.to_uppercase());
    }
    let _ = writeln!(out);
    let offsets = [-0.05, -0.04, -0.03, -0.02, -0.01, 0.0, 0.01, 0.02, 0.03, 0.04, 0.05];
    for (oi, off) in offsets.iter().enumerate() {
        let label = if *off == 0.0 {
            "Predicted".to_string()
        } else {
            format!("{:+.0} %", off * 100.0)
        };
        let _ = write!(out, "{label:<12}");
        for r in rows {
            let _ = write!(out, "{:>7}", if r.probes[oi].1 { "✓" } else { "x" });
        }
        let _ = writeln!(out);
    }
    for r in rows {
        let err = (r.predicted_scale - r.true_boundary) / r.true_boundary;
        let _ = writeln!(
            out,
            "{:>6}: predicted max scale {:>9.1} vs true boundary {:>9.1} ({} error)",
            r.app,
            r.predicted_scale,
            r.true_boundary,
            fmt_pct(err.abs())
        );
    }
    out
}

pub fn print_table2(rows: &[Table2Row]) {
    print!("{}", render_table2(rows));
}

/// The `blink advise` report: ranked per-type picks, then the time/cost
/// Pareto front over the whole (type × count) grid. Thin wrapper over
/// [`render_plan_text`] for callers that print straight to stdout.
pub fn print_plan(plan: &Plan, catalog: &InstanceCatalog, pricing: &str) {
    println!("{}", render_plan_text(plan, &catalog.name, catalog.instances.len(), pricing));
}

/// Risk cross-validation table: the planner's analytic picks realized by
/// event-driven engine runs under a disturbance scenario. Thin wrapper
/// over [`render_risk_text`].
pub fn print_risk(risks: &[RiskAdjustedPick], scenario: &str, pricing: &str) {
    println!("{}", render_risk_text(risks, scenario, pricing));
}

// ======================================================================
// JSON encodings (blink experiment --format json)
// ======================================================================

fn json_table1_row(r: &Table1Row) -> Json {
    Json::obj(vec![
        ("app", r.app.as_str().into()),
        ("approach", r.approach.as_str().into()),
        ("input_gb", r.input_gb.into()),
        ("blocks", r.blocks.into()),
        ("sample_cost_machine_min", r.sample_cost_machine_min.into()),
        (
            "runs",
            Json::Arr(
                r.runs
                    .iter()
                    .enumerate()
                    .map(|(i, (time, cost, free))| {
                        Json::obj(vec![
                            ("machines", (i + 1).into()),
                            ("time_min", (*time).into()),
                            ("cost_machine_min", (*cost).into()),
                            ("eviction_free", (*free).into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("blink_pick", r.blink_pick.into()),
        ("first_eviction_free", r.optimal.into()),
    ])
}

pub fn json_table1(t: &Table1) -> Json {
    Json::obj(vec![
        ("at_100", Json::Arr(t.at_100.iter().map(json_table1_row).collect())),
        ("enlarged", Json::Arr(t.enlarged.iter().map(json_table1_row).collect())),
    ])
}

pub fn json_table2(rows: &[Table2Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("app", r.app.as_str().into()),
                    ("predicted_scale", r.predicted_scale.into()),
                    ("true_boundary", r.true_boundary.into()),
                    (
                        "probes",
                        Json::Arr(
                            r.probes
                                .iter()
                                .map(|(off, free)| {
                                    Json::obj(vec![
                                        ("offset", (*off).into()),
                                        ("eviction_free", (*free).into()),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

pub fn json_fig1(f: &Fig1) -> Json {
    Json::obj(vec![
        (
            "series",
            Json::Arr(
                f.series
                    .iter()
                    .zip(&f.ernest_time_min)
                    .map(|((n, time, cost, free), ernest)| {
                        Json::obj(vec![
                            ("machines", (*n).into()),
                            ("time_min", (*time).into()),
                            ("cost_machine_min", (*cost).into()),
                            ("eviction_free", (*free).into()),
                            ("ernest_time_min", (*ernest).into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("ernest_pick", f.ernest_pick.into()),
        ("optimal", f.optimal.into()),
    ])
}

pub fn json_fig4(scales: &[Fig4Scale]) -> Json {
    Json::Arr(
        scales
            .iter()
            .map(|sc| {
                Json::obj(vec![
                    ("scale", sc.scale.into()),
                    ("times_s", sc.times_s.clone().into()),
                    ("sizes_mb", sc.sizes_mb.clone().into()),
                ])
            })
            .collect(),
    )
}

pub fn json_fig6(rows: &[Fig6Row]) -> Json {
    let (vs_avg, vs_worst) = fig6_ratios(rows);
    Json::obj(vec![
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("app", r.app.as_str().into()),
                            ("blink_cost", r.blink_cost.into()),
                            ("avg_cost", r.avg_cost.into()),
                            ("worst_cost", r.worst_cost.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("mean_vs_avg", vs_avg.into()),
        ("mean_vs_worst", vs_worst.into()),
    ])
}

pub fn json_fig7(rows: &[Fig7Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("app", r.app.as_str().into()),
                    ("predicted_mb", r.predicted_mb.into()),
                    ("actual_mb", r.actual_mb.into()),
                    ("error", r.error.into()),
                ])
            })
            .collect(),
    )
}

pub fn json_fig8(points: &[Fig8Point]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("num_samples", p.num_samples.into()),
                    ("sample_cost_machine_min", p.sample_cost_machine_min.into()),
                    ("accuracy", p.accuracy.into()),
                    ("cv_rel_err", p.cv_rel_err.into()),
                ])
            })
            .collect(),
    )
}

pub fn json_fig9(sizes: &[(f64, f64)]) -> Json {
    Json::Arr(
        sizes
            .iter()
            .map(|(s, mb)| {
                Json::obj(vec![("scale", (*s).into()), ("cached_mb", (*mb).into())])
            })
            .collect(),
    )
}

pub fn json_fig10(f: &Fig10) -> Json {
    Json::obj(vec![
        (
            "rows",
            Json::Arr(
                f.rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("app", r.app.as_str().into()),
                            ("approach", r.approach.as_str().into()),
                            ("overhead", r.overhead.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("ernest_over_blink", f.ernest_over_blink.into()),
    ])
}

pub fn json_fig11(f: &Fig11) -> Json {
    Json::obj(vec![
        ("tasks_per_machine", f.tasks_per_machine.clone().into()),
        ("evictions_per_machine", f.evictions_per_machine.clone().into()),
        ("blink_pick", f.blink_pick.into()),
        ("true_optimal", f.true_optimal.into()),
        ("pick_cost", f.pick_cost.into()),
        ("optimal_cost", f.optimal_cost.into()),
    ])
}

pub fn json_sec4(p: &Sec4Parallelism, c: &Sec4Cluster) -> Json {
    Json::obj(vec![
        (
            "parallelism",
            Json::obj(vec![
                ("tasks_low", p.tasks_low.into()),
                ("tasks_high", p.tasks_high.into()),
                ("time_low_s", p.time_low_s.into()),
                ("time_high_s", p.time_high_s.into()),
                ("size_low_mb", p.size_low_mb.into()),
                ("size_high_mb", p.size_high_mb.into()),
            ]),
        ),
        (
            "single_vs_cluster",
            Json::obj(vec![
                ("cost_single", c.cost_single.into()),
                ("cost_cluster", c.cost_cluster.into()),
            ]),
        ),
    ])
}

pub fn print_sec4(p: &Sec4Parallelism, c: &Sec4Cluster) {
    println!("SECTION 4.2 — parallelism during sample runs (svm, ~1.2 GB)");
    println!(
        "  {} tasks:   {:>8}  cached {:>8.1} MB",
        p.tasks_low,
        crate::util::units::fmt_secs(p.time_low_s),
        p.size_low_mb
    );
    println!(
        "  {} tasks: {:>8}  cached {:>8.1} MB",
        p.tasks_high,
        crate::util::units::fmt_secs(p.time_high_s),
        p.size_high_mb
    );
    println!(
        "  (paper: 41 s vs 3.5 min; 728.9 MB vs 747.8 MB — parallelism\n   changes both, so Blink keeps tasks proportional to the scale)"
    );
    println!("\nSECTION 4.3 — sample run on 1 vs 12 machines (svm, ~1.2 GB)");
    println!(
        "  single machine: {:>8.1} machine-s   cluster: {:>8.1} machine-s  ({:.1}x, paper: 13.9x)",
        c.cost_single,
        c.cost_cluster,
        c.cost_cluster / c.cost_single
    );
}
