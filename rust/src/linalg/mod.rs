//! Dense small-matrix least squares, the pure-Rust twin of the Pallas
//! `linfit` kernel.
//!
//! Blink's predictors fit tiny models (<= 16 points, <= 4 features). The
//! production hot path dispatches those fits as one batched HLO executable
//! (see `runtime::linfit`); this module provides (a) the same algorithm in
//! pure Rust as the fallback when artifacts are absent, and (b) the oracle
//! the integration tests compare the PJRT path against.

/// Ordinary least squares via normal equations + Gaussian elimination with
/// partial pivoting. `x` is row-major [n][k]. Returns theta[k].
/// Returns None if the system is singular.
pub fn ols(x: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    weighted_ols(x, y, &vec![1.0; y.len()])
}

/// Weighted OLS; rows with weight 0 are excluded (used for CV folds).
pub fn weighted_ols(x: &[Vec<f64>], y: &[f64], w: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), w.len());
    let n = x.len();
    if n == 0 {
        return None;
    }
    let k = x[0].len();
    // G = X^T W X, b = X^T W y
    let mut g = vec![vec![0.0; k]; k];
    let mut b = vec![0.0; k];
    for i in 0..n {
        for a in 0..k {
            let xa = x[i][a] * w[i];
            b[a] += xa * y[i];
            for c in 0..k {
                g[a][c] += xa * x[i][c];
            }
        }
    }
    solve(&mut g, &mut b)
}

/// Solve G theta = b in place (partial pivoting). None if singular.
fn solve(g: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let k = b.len();
    for col in 0..k {
        // pivot
        let (piv, pmax) = (col..k)
            .map(|r| (r, g[r][col].abs()))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        if pmax < 1e-12 {
            return None;
        }
        g.swap(col, piv);
        b.swap(col, piv);
        let d = g[col][col];
        for r in 0..k {
            if r == col {
                continue;
            }
            let f = g[r][col] / d;
            for c in col..k {
                g[r][c] -= f * g[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    Some((0..k).map(|i| b[i] / g[i][i]).collect())
}

/// Non-negative least squares by FISTA (accelerated projected gradient) on
/// the normal equations — the exact algorithm of the Pallas `linfit`
/// kernel (and the same KKT point scipy's bounded `curve_fit` converges to
/// on these tiny convex problems). Acceleration matters for the
/// ill-conditioned quadratic/log feature families in the model zoo.
pub fn nnls(x: &[Vec<f64>], y: &[f64], w: &[f64], iters: usize) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let k = if n == 0 { 0 } else { x[0].len() };
    let mut g = vec![vec![0.0; k]; k];
    let mut b = vec![0.0; k];
    for i in 0..n {
        for a in 0..k {
            let xa = x[i][a] * w[i];
            b[a] += xa * y[i];
            for c in 0..k {
                g[a][c] += xa * x[i][c];
            }
        }
    }
    let lip = g
        .iter()
        .map(|row| row.iter().map(|v| v.abs()).sum::<f64>())
        .fold(0.0f64, f64::max);
    let eta = 1.0 / lip.max(1e-12);
    let mut theta = vec![0.0; k];
    let mut momentum = theta.clone(); // FISTA's extrapolated point
    let mut t = 1.0f64;
    let mut grad = vec![0.0; k];
    for _ in 0..iters {
        for a in 0..k {
            grad[a] = -b[a];
            for c in 0..k {
                grad[a] += g[a][c] * momentum[c];
            }
        }
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let beta = (t - 1.0) / t_next;
        for a in 0..k {
            let next = (momentum[a] - eta * grad[a]).max(0.0);
            momentum[a] = next + beta * (next - theta[a]);
            theta[a] = next;
        }
        t = t_next;
    }
    theta
}

/// Residual RMSE of a fitted model over rows with weight > 0.
pub fn residual_rmse(x: &[Vec<f64>], y: &[f64], w: &[f64], theta: &[f64]) -> f64 {
    let mut se = 0.0;
    let mut n = 0.0;
    for i in 0..x.len() {
        if w[i] <= 0.0 {
            continue;
        }
        let pred: f64 = x[i].iter().zip(theta).map(|(a, t)| a * t).sum();
        se += w[i] * (pred - y[i]) * (pred - y[i]);
        n += w[i];
    }
    (se / n.max(1.0)).sqrt()
}

/// Predict a single row.
pub fn predict(row: &[f64], theta: &[f64]) -> f64 {
    row.iter().zip(theta).map(|(a, t)| a * t).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn design(xs: &[f64]) -> Vec<Vec<f64>> {
        xs.iter().map(|&s| vec![1.0, s]).collect()
    }

    #[test]
    fn ols_recovers_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = xs.iter().map(|s| 3.0 + 2.0 * s).collect();
        let th = ols(&design(&xs), &y).unwrap();
        assert!((th[0] - 3.0).abs() < 1e-9 && (th[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ols_singular_returns_none() {
        // duplicated feature column -> singular Gram
        let x = vec![vec![1.0, 1.0], vec![2.0, 2.0], vec![3.0, 3.0]];
        assert!(ols(&x, &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn weighted_ols_ignores_zero_weight_rows() {
        let xs = [1.0, 2.0, 3.0, 100.0];
        let mut y: Vec<f64> = xs.iter().map(|s| 1.0 + s).collect();
        y[3] = -999.0; // corrupted row, weight 0
        let th = weighted_ols(&design(&xs), &y, &[1.0, 1.0, 1.0, 0.0]).unwrap();
        assert!((th[0] - 1.0).abs() < 1e-9 && (th[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn nnls_matches_ols_when_solution_positive() {
        let xs = [1.0, 2.0, 3.0, 5.0, 8.0];
        let y: Vec<f64> = xs.iter().map(|s| 0.7 + 1.3 * s).collect();
        let w = vec![1.0; 5];
        let th = nnls(&design(&xs), &y, &w, 5000);
        assert!((th[0] - 0.7).abs() < 1e-3, "{th:?}");
        assert!((th[1] - 1.3).abs() < 1e-3, "{th:?}");
    }

    #[test]
    fn nnls_clamps_negative_intercept_to_zero() {
        // true intercept is negative; bounded fit must return theta0 = 0
        let xs = [1.0, 2.0, 3.0];
        let y: Vec<f64> = xs.iter().map(|s| -5.0 + 2.0 * s).collect();
        let th = nnls(&design(&xs), &y, &[1.0; 3], 5000);
        assert!(th[0].abs() < 1e-6, "{th:?}");
        assert!(th.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn property_nnls_never_negative_and_fits_clean_lines() {
        prop::check(
            &prop::Config { cases: 96, seed: 0x11f17, max_size: 12 },
            |rng: &mut Rng, size| {
                let n = (size.max(2)).min(12);
                let th0 = rng.range(0.0, 5.0);
                let th1 = rng.range(0.1, 4.0);
                let xs: Vec<f64> = (0..n).map(|i| 1.0 + i as f64 + rng.f64()).collect();
                let y: Vec<f64> = xs.iter().map(|s| th0 + th1 * s).collect();
                (xs, y, th0, th1)
            },
            |(xs, y, th0, th1)| {
                let w = vec![1.0; xs.len()];
                let th = nnls(&design(xs), y, &w, 8000);
                if th.iter().any(|&t| t < 0.0) {
                    return Err("negative coefficient".into());
                }
                if (th[1] - th1).abs() > 0.02 * th1.max(1.0) {
                    return Err(format!("slope {th:?} vs ({th0}, {th1})"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rmse_zero_on_perfect_fit() {
        let xs = [1.0, 2.0, 3.0];
        let y: Vec<f64> = xs.iter().map(|s| 1.0 + s).collect();
        let x = design(&xs);
        let rm = residual_rmse(&x, &y, &[1.0; 3], &[1.0, 1.0]);
        assert!(rm < 1e-12);
    }
}
