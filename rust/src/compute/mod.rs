//! RealCompute bridge: run actual AOT-compiled kernels as simulator task
//! bodies.
//!
//! The big Table-1 sweeps use the analytic task-cost model (59 GB of SVM
//! does not fit a laptop), but the end-to-end example must prove the three
//! layers compose: here a Spark "task" really executes the corresponding
//! workload kernel (svm/logreg gradient step, k-means Lloyd step) on
//! synthetic partition data through PJRT, and the simulator consumes the
//! *measured wall-clock* duration. Cached reads run one kernel pass;
//! recomputations replay the lineage `recompute_factor`-times-ish by
//! repeating passes, reproducing the cached-vs-recomputed asymmetry with
//! real compute.

use anyhow::Result;

use crate::runtime::Runtime;
use crate::sim::{TaskCompute, WorkloadProfile};
use crate::util::prng::Rng;

/// Fixed AOT shapes of the workload kernels (python/compile/kernels).
pub const SVM_ROWS: usize = 4096;
pub const SVM_DIM: usize = 64;
pub const KM_ROWS: usize = 4096;
pub const KM_DIM: usize = 16;
pub const KM_K: usize = 8;

/// Synthetic partition data matching one kernel invocation.
pub struct KernelData {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub w: Vec<f32>,
    pub centroids: Vec<f32>,
}

/// Generate deterministic synthetic data for an app's kernel.
pub fn gen_data(app: &str, seed: u64) -> KernelData {
    let mut rng = Rng::new(seed);
    match app {
        "km" => {
            let x = (0..KM_ROWS * KM_DIM).map(|_| rng.normal() as f32).collect();
            let centroids = (0..KM_K * KM_DIM).map(|_| rng.normal() as f32).collect();
            KernelData { x, y: Vec::new(), w: Vec::new(), centroids }
        }
        _ => {
            // svm / lr shapes are identical
            let x: Vec<f32> = (0..SVM_ROWS * SVM_DIM).map(|_| rng.normal() as f32).collect();
            let y: Vec<f32> = (0..SVM_ROWS)
                .map(|_| if rng.f64() < 0.5 { -1.0 } else { 1.0 })
                .collect();
            let w = vec![0.0f32; SVM_DIM];
            KernelData { x, y, w, centroids: Vec::new() }
        }
    }
}

/// Which artifact an app's iteration step runs on.
pub fn kernel_for_app(app: &str) -> &'static str {
    match app {
        "km" => "kmeans_step",
        "lr" | "bayes" => "logreg_step",
        _ => "svm_step",
    }
}

/// TaskCompute backed by the PJRT runtime.
pub struct RealCompute<'a> {
    runtime: &'a mut Runtime,
    data: KernelData,
    app: String,
    /// Kernel passes per recomputation (the lineage-depth analogue).
    pub recompute_passes: usize,
    /// Tasks executed (observability).
    pub tasks_run: usize,
}

impl<'a> RealCompute<'a> {
    pub fn new(runtime: &'a mut Runtime, app: &str, seed: u64) -> RealCompute<'a> {
        RealCompute {
            runtime,
            data: gen_data(app, seed),
            app: app.to_string(),
            recompute_passes: 4,
            tasks_run: 0,
        }
    }

    /// One kernel pass; returns the step's loss/inertia scalar.
    pub fn one_pass(&mut self) -> Result<f32> {
        let name = kernel_for_app(&self.app);
        let exe = self.runtime.get(name)?;
        let outs = match name {
            "kmeans_step" => {
                let o = exe.run_f32(&[&self.data.x, &self.data.centroids])?;
                // feed the updated centroids back in (iterative semantics)
                self.data.centroids.copy_from_slice(&o[0]);
                o
            }
            _ => {
                let o = exe.run_f32(&[&self.data.x, &self.data.y, &self.data.w])?;
                self.data.w.copy_from_slice(&o[0]);
                o
            }
        };
        Ok(*outs[1].first().unwrap_or(&0.0))
    }
}

impl TaskCompute for RealCompute<'_> {
    fn run_task(&mut self, _profile: &WorkloadProfile, cached_read: bool) -> Option<f64> {
        let passes = if cached_read { 1 } else { self.recompute_passes };
        let t0 = std::time::Instant::now();
        for _ in 0..passes {
            if let Err(e) = self.one_pass() {
                eprintln!("RealCompute pass failed ({e:#}); analytic fallback");
                return None;
            }
        }
        self.tasks_run += 1;
        Some(t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_routing() {
        assert_eq!(kernel_for_app("km"), "kmeans_step");
        assert_eq!(kernel_for_app("lr"), "logreg_step");
        assert_eq!(kernel_for_app("svm"), "svm_step");
        assert_eq!(kernel_for_app("rfc"), "svm_step");
    }

    #[test]
    fn synthetic_data_shapes() {
        let d = gen_data("svm", 1);
        assert_eq!(d.x.len(), SVM_ROWS * SVM_DIM);
        assert_eq!(d.y.len(), SVM_ROWS);
        assert!(d.y.iter().all(|&v| v == 1.0 || v == -1.0));
        let k = gen_data("km", 2);
        assert_eq!(k.centroids.len(), KM_K * KM_DIM);
    }

    #[test]
    fn data_deterministic_by_seed() {
        assert_eq!(gen_data("svm", 7).x[..8], gen_data("svm", 7).x[..8]);
        assert_ne!(gen_data("svm", 7).x[..8], gen_data("svm", 8).x[..8]);
    }
}
