//! L3 coordinator: ties Blink, the simulator, the PJRT runtime and the
//! experiment drivers together behind the `blink` CLI.
//!
//! The coordinator chooses the fit backend at startup (PJRT `linfit` when
//! `artifacts/` is present, pure-Rust fallback otherwise), orchestrates
//! the sample-runs -> predict -> select -> actual-run pipeline, and
//! exposes each paper experiment as a subcommand.

use anyhow::{anyhow, Result};

use crate::blink::{planner, Advice, Blink, BlinkDecision, FitBackend, RustFit};
use crate::cost::pricing_by_name;
use crate::experiments::{self, report};
use crate::memory::EvictionPolicy;
use crate::metrics::RunSummary;
use crate::runtime::{artifacts_available, PjrtFit, Runtime};
use crate::sim::{engine, scenario, FleetSpec, InstanceCatalog, MachineSpec, SimOptions};
use crate::util::units::{fmt_mb, fmt_pct, fmt_secs};
use crate::workloads::{app_by_name, AppModel};

/// Which fit backend the coordinator is using.
pub enum Backend {
    Pjrt(Runtime),
    Rust(RustFit),
}

impl Backend {
    /// Prefer the compiled Pallas kernel; fall back to pure Rust.
    pub fn auto() -> Backend {
        if artifacts_available() {
            match Runtime::from_repo_root() {
                Ok(rt) => return Backend::Pjrt(rt),
                Err(e) => eprintln!("PJRT unavailable ({e:#}); using rust-nnls"),
            }
        }
        Backend::Rust(RustFit::default())
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt-linfit",
            Backend::Rust(_) => "rust-nnls",
        }
    }

    /// Run a closure with the backend as a `&mut dyn FitBackend`.
    pub fn with<R>(&mut self, f: impl FnOnce(&mut dyn FitBackend) -> R) -> R {
        match self {
            Backend::Pjrt(rt) => {
                let mut fit = PjrtFit::new(rt);
                f(&mut fit)
            }
            Backend::Rust(fit) => f(fit),
        }
    }
}

fn lookup(app: &str) -> Result<AppModel> {
    app_by_name(app).ok_or_else(|| {
        anyhow!("unknown app '{app}' (choose from als bayes gbt km lr pca rfc svm)")
    })
}

/// `blink decide`: the full pipeline for one app/scale.
pub fn cmd_decide(app: &str, scale: f64, verbose: bool) -> Result<BlinkDecision> {
    let app = lookup(app)?;
    let mut backend = Backend::auto();
    println!("fit backend: {}", backend.name());
    let machine = MachineSpec::worker_node();
    let scales = experiments::sampling_scales(&app);
    let d = backend.with(|b| {
        let mut blink = Blink::new(b);
        blink.decide_with_scales(&app, scale, &machine, &scales)
    });
    println!(
        "app {}  scale {:.0} ({} input)",
        app.name,
        scale,
        fmt_mb(app.input_mb(scale))
    );
    println!(
        "predicted cached {}  exec memory {}",
        fmt_mb(d.predicted_cached_mb),
        fmt_mb(d.predicted_exec_mb)
    );
    if let Some(sel) = &d.selection {
        if sel.saturated {
            // a saturated selection has no headroom — report the deficit
            println!(
                "machines_min {}  machines_max {}  cache deficit/machine {}",
                sel.machines_min,
                sel.machines_max,
                fmt_mb(sel.cache_deficit_mb())
            );
            println!("WARNING: cluster bound hit; run will evict");
        } else {
            println!(
                "machines_min {}  machines_max {}  headroom/machine {}",
                sel.machines_min,
                sel.machines_max,
                fmt_mb(sel.headroom_mb)
            );
        }
    }
    println!(
        "-> recommended cluster size: {} machines (sampling cost {})",
        d.machines,
        fmt_secs(d.sample_cost_machine_s)
    );
    if verbose {
        if let Some((sizes, _)) = &d.predictors {
            for (ds, m) in &sizes.models {
                println!(
                    "  dataset {ds}: {} model, cv err {}",
                    m.kind.name(),
                    fmt_pct(m.cv_rel_err)
                );
            }
        }
    }
    Ok(d)
}

/// `blink advise`: the fleet-aware planner — search an instance catalog
/// for `(type × count)` candidates under a pricing model. With a scenario
/// other than `none`, the top analytic picks are cross-validated against
/// event-driven engine runs under that scenario and re-ranked by realized
/// cost.
pub fn cmd_advise(
    app: &str,
    scale: f64,
    catalog_name: &str,
    pricing_name: &str,
    max_machines: usize,
    scenario_name: &str,
) -> Result<Advice> {
    let app = lookup(app)?;
    let catalog = InstanceCatalog::by_name(catalog_name)
        .ok_or_else(|| anyhow!("unknown catalog '{catalog_name}' (paper|cloud|all)"))?;
    let pricing = pricing_by_name(pricing_name).ok_or_else(|| {
        anyhow!("unknown pricing model '{pricing_name}' (machine-seconds|hourly|per-second|spot)")
    })?;
    let scenario = scenario::by_name(scenario_name).ok_or_else(|| {
        anyhow!("unknown scenario '{scenario_name}' (spot|straggler|failure|autoscale|none)")
    })?;
    if max_machines == 0 {
        return Err(anyhow!("--max-machines must be at least 1"));
    }
    let mut backend = Backend::auto();
    println!("fit backend: {}", backend.name());
    let scales = experiments::sampling_scales(&app);
    let advice = backend.with(|b| {
        let mut blink = Blink::new(b);
        blink.max_machines = max_machines;
        blink.advise_with_scales(&app, scale, &catalog, pricing.as_ref(), &scales)
    });
    println!(
        "app {}  scale {:.0} ({} input)  predicted cached {}  exec {}  sampling cost {}",
        app.name,
        scale,
        fmt_mb(app.input_mb(scale)),
        fmt_mb(advice.predicted_cached_mb),
        fmt_mb(advice.predicted_exec_mb),
        fmt_secs(advice.sample_cost_machine_s),
    );
    report::print_plan(&advice.plan, &catalog, pricing.name());
    if scenario_name != "none" {
        let profile = app.profile(scale);
        let risks = planner::risk_adjusted(
            &profile,
            &advice.plan,
            &catalog,
            pricing.as_ref(),
            scenario.as_ref(),
            &[11, 12, 13],
            3,
        );
        report::print_risk(&risks, scenario.name(), pricing.name());
    }
    Ok(advice)
}

/// `blink simulate`: run one workload through the event-driven engine on
/// a homogeneous fleet of a catalog instance type, under a disturbance
/// scenario, and compare the realized per-machine cost against the naive
/// (undisturbed) quote of the same pricing model.
pub fn cmd_simulate(
    app: &str,
    scale: f64,
    machines: usize,
    instance_name: &str,
    scenario_name: &str,
    pricing_name: &str,
    seed: u64,
) -> Result<RunSummary> {
    let model = lookup(app)?;
    let catalog = InstanceCatalog::all();
    let instance = catalog.get(instance_name).ok_or_else(|| {
        anyhow!("unknown instance type '{instance_name}' (see the paper|cloud catalogs)")
    })?;
    let scenario = scenario::by_name(scenario_name).ok_or_else(|| {
        anyhow!("unknown scenario '{scenario_name}' (spot|straggler|failure|autoscale|none)")
    })?;
    let pricing = pricing_by_name(pricing_name).ok_or_else(|| {
        anyhow!("unknown pricing model '{pricing_name}' (machine-seconds|hourly|per-second|spot)")
    })?;
    let fleet = FleetSpec::homogeneous(instance.clone(), machines)
        .map_err(|e| anyhow!("invalid fleet: {e}"))?;
    let profile = model.profile(scale);
    let opts = |seed: u64| SimOptions {
        policy: EvictionPolicy::Lru,
        seed,
        compute: None,
        detailed_log: false,
    };
    let baseline = engine::run(&profile, &fleet, &scenario::NoDisturbances, opts(seed))
        .map_err(|e| anyhow!("baseline run failed: {e}"))?;
    let disturbed = engine::run(&profile, &fleet, scenario.as_ref(), opts(seed))
        .map_err(|e| anyhow!("scenario run failed: {e}"))?;
    let b = RunSummary::from_log(&baseline.sim.log);
    let s = RunSummary::from_log(&disturbed.sim.log);
    println!(
        "app {}  scale {:.0} ({} input)  fleet {} x {}  scenario '{}'",
        model.name,
        scale,
        fmt_mb(model.input_mb(scale)),
        machines,
        instance.name,
        scenario.name(),
    );
    println!(
        "baseline: {} ({:.1} machine-min), evictions {}, cached after load {}",
        fmt_secs(b.duration_s),
        b.cost_machine_min(),
        b.evictions,
        fmt_pct(baseline.sim.cached_fraction_after_load),
    );
    println!(
        "scenario: {} ({:+.1} %), evictions {}, machines lost {}, joined {}, cached after load {}",
        fmt_secs(s.duration_s),
        (s.duration_s / b.duration_s.max(1e-12) - 1.0) * 100.0,
        s.evictions,
        s.machines_lost,
        s.machines_joined,
        fmt_pct(disturbed.sim.cached_fraction_after_load),
    );
    let naive = pricing.price(instance, machines, b.duration_s);
    let realized = pricing.price_timeline(&disturbed.timeline);
    println!(
        "{} pricing — naive quote {:.4}  realized (per-machine uptime) {:.4}  ({:+.1} %)",
        pricing.name(),
        naive,
        realized,
        (realized / naive.max(1e-12) - 1.0) * 100.0,
    );
    Ok(s)
}

/// `blink run`: decide, then simulate the actual run at the pick.
pub fn cmd_run(app: &str, scale: f64, seed: u64) -> Result<RunSummary> {
    let model = lookup(app)?;
    let d = cmd_decide(app, scale, false)?;
    let s = experiments::actual_run(&model, scale, d.machines, seed);
    println!(
        "actual run: {} on {} machines -> {} ({:.1} machine-min, {} evictions)",
        app,
        d.machines,
        fmt_secs(s.duration_s),
        s.cost_machine_min(),
        s.evictions
    );
    let total = d.sample_cost_machine_s + s.cost_machine_s;
    println!(
        "total cost incl. sampling: {:.1} machine-min (sampling {})",
        total / 60.0,
        fmt_pct(d.sample_cost_machine_s / s.cost_machine_s.max(1e-9))
    );
    Ok(s)
}

/// `blink bounds`: Table-2 style max-scale prediction for one app.
pub fn cmd_bounds(app: &str, machines: usize) -> Result<f64> {
    let model = lookup(app)?;
    let mut backend = Backend::auto();
    let mgr = crate::blink::SampleRunsManager::default();
    let runs = match mgr.run(&model, &experiments::sampling_scales(&model)) {
        crate::blink::SamplingOutcome::Profiled(r) => r,
        crate::blink::SamplingOutcome::NoCachedData { .. } => {
            println!("{app} caches nothing; any scale fits");
            return Ok(f64::INFINITY);
        }
    };
    let (sp, ep) = backend.with(|b| {
        (
            crate::blink::SizePredictor::train(b, &runs),
            crate::blink::ExecMemoryPredictor::train(b, &runs),
        )
    });
    let machine = MachineSpec::worker_node();
    let s = crate::blink::bounds::max_scale(&sp, &ep, &machine, machines, 1e-5);
    println!(
        "{app}: max eviction-free data scale on {machines} machines ~ {s:.1} ({} input)",
        fmt_mb(model.input_mb(s))
    );
    Ok(s)
}

/// `blink experiment --id <id>`: regenerate a paper table/figure.
pub fn cmd_experiment(id: &str, seed: u64) -> Result<()> {
    match id {
        "table1" => report::print_table1(&experiments::table1(seed)),
        "table2" => report::print_table2(&experiments::table2(seed)),
        "fig1" => report::print_fig1(&experiments::fig1(seed)),
        "fig2" => {
            let dag = crate::dag::fig2_logistic_regression();
            let counts = dag.compute_counts_uncached();
            println!("FIGURE 2 — merged LR DAG (computed-times without caching)");
            for d in &dag.datasets {
                println!("  {:<5} computed {}x", d.name, counts[d.id]);
            }
        }
        "fig4" => report::print_fig4(&experiments::fig4(seed)),
        "fig6" => {
            let t = experiments::table1(seed);
            report::print_fig6(&experiments::fig6(&t));
        }
        "fig7" => report::print_fig7(&experiments::fig7()),
        "fig8" => report::print_fig8(&experiments::fig8()),
        "fig9" => report::print_fig9(&experiments::fig9_sizes()),
        "fig10" => {
            let t = experiments::table1(seed);
            report::print_fig10(&experiments::fig10(&t, seed));
        }
        "fig11" => report::print_fig11(&experiments::fig11(seed)),
        "sec4" => report::print_sec4(
            &experiments::sec4_parallelism(seed),
            &experiments::sec4_single_vs_cluster(seed),
        ),
        "all" => {
            for id in [
                "fig1", "fig2", "fig4", "fig7", "fig8", "fig9", "fig11", "sec4", "table1",
                "table2",
            ] {
                cmd_experiment(id, seed)?;
                println!();
            }
            // fig6/fig10 derive from table1; print them from one run
            let t = experiments::table1(seed);
            report::print_fig6(&experiments::fig6(&t));
            println!();
            report::print_fig10(&experiments::fig10(&t, seed));
        }
        other => return Err(anyhow!("unknown experiment '{other}'")),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_auto_never_panics() {
        let mut b = Backend::auto();
        let name = b.with(|f| f.name());
        assert!(name == "pjrt-linfit" || name == "rust-nnls");
    }

    #[test]
    fn lookup_rejects_unknown() {
        assert!(lookup("nope").is_err());
        assert!(lookup("svm").is_ok());
    }

    #[test]
    fn unknown_experiment_is_an_error() {
        assert!(cmd_experiment("fig99", 1).is_err());
    }

    #[test]
    fn advise_rejects_bad_inputs() {
        assert!(cmd_advise("nope", 1000.0, "cloud", "hourly", 12, "none").is_err());
        assert!(cmd_advise("svm", 1000.0, "bogus-catalog", "hourly", 12, "none").is_err());
        assert!(cmd_advise("svm", 1000.0, "cloud", "free-lunch", 12, "none").is_err());
        assert!(cmd_advise("svm", 1000.0, "cloud", "hourly", 0, "none").is_err());
        assert!(cmd_advise("svm", 1000.0, "cloud", "hourly", 12, "meteor").is_err());
    }

    #[test]
    fn simulate_rejects_bad_inputs() {
        assert!(cmd_simulate("nope", 100.0, 4, "gp.xlarge", "spot", "spot", 1).is_err());
        assert!(cmd_simulate("svm", 100.0, 4, "no-such-shape", "spot", "spot", 1).is_err());
        assert!(cmd_simulate("svm", 100.0, 4, "gp.xlarge", "meteor", "spot", 1).is_err());
        assert!(cmd_simulate("svm", 100.0, 4, "gp.xlarge", "spot", "free-lunch", 1).is_err());
        assert!(cmd_simulate("svm", 100.0, 0, "gp.xlarge", "spot", "spot", 1).is_err());
    }
}
