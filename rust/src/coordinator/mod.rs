//! L3 coordinator: ties Blink, the simulator, the PJRT runtime and the
//! experiment drivers together behind the `blink` CLI.
//!
//! The coordinator chooses the fit backend at startup (PJRT `linfit` when
//! `artifacts/` is present, pure-Rust fallback otherwise) and exposes each
//! query as a subcommand. Every `cmd_*` function is a thin
//! parse → query → render shim: it resolves names to domain objects,
//! asks a [`Advisor`] session (or the engine/experiment drivers) for a
//! typed report, prints that report exactly once in the requested
//! [`OutputFormat`], and returns it. Compute paths never print.

use anyhow::{anyhow, Result};

use crate::blink::report::{
    AdaptReport, AppRow, AppsReport, BoundsReport, FleetRealized, FleetReport, FleetTenantRow,
    PlanReport, RecommendReport, RiskSection, RunReport, RunStats, ServeReport, SimulateReport,
    SynthReport, SynthRow,
};
use crate::blink::{
    adaptive, plan_fleet, store, Advisor, FleetPlanInput, OutputFormat, Report, RustFit,
    ValidationSpec,
};
use crate::cost::{pricing_by_name, pricing_names};
use crate::experiments::{self, report};
use crate::hdfs::Sampler;
use crate::memory::EvictionPolicy;
use crate::metrics::RunSummary;
use crate::runtime::{artifacts_available, PjrtFit, Runtime};
use crate::sim::{
    engine, scenario, FleetFairness, FleetSpec, InstanceCatalog, MachineSpec, SimOptions,
    TenantSpec,
};
use crate::testkit;
use crate::util::json::Json;
use crate::workloads::{all_apps, app_by_name, AppModel, SynthConfig};

/// Which fit backend the coordinator is using.
pub enum Backend {
    Pjrt(Runtime),
    Rust(RustFit),
}

impl Backend {
    /// Prefer the compiled Pallas kernel; fall back to pure Rust.
    pub fn auto() -> Backend {
        if artifacts_available() {
            match Runtime::from_repo_root() {
                Ok(rt) => return Backend::Pjrt(rt),
                Err(e) => eprintln!("PJRT unavailable ({e:#}); using rust-nnls"),
            }
        }
        Backend::Rust(RustFit::default())
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt-linfit",
            Backend::Rust(_) => "rust-nnls",
        }
    }

    /// Run a closure with a default-configured advisor session bound to
    /// this backend.
    pub fn with_advisor<R>(&mut self, f: impl FnOnce(&mut Advisor<'_>) -> R) -> R {
        self.with_advisor_built(Advisor::builder(), f)
    }

    /// Same, with a pre-configured builder.
    pub fn with_advisor_built<R>(
        &mut self,
        builder: crate::blink::AdvisorBuilder,
        f: impl FnOnce(&mut Advisor<'_>) -> R,
    ) -> R {
        match self {
            Backend::Pjrt(rt) => {
                let mut fit = PjrtFit::new(rt);
                let mut advisor = builder.build(&mut fit);
                f(&mut advisor)
            }
            Backend::Rust(fit) => {
                let mut advisor = builder.build(fit);
                f(&mut advisor)
            }
        }
    }
}

fn lookup(app: &str) -> Result<AppModel> {
    app_by_name(app).ok_or_else(|| {
        let names: Vec<String> = all_apps().into_iter().map(|a| a.name).collect();
        anyhow!("unknown app '{app}' (choose from {})", names.join(" "))
    })
}

fn lookup_catalog(name: &str) -> Result<InstanceCatalog> {
    InstanceCatalog::by_name(name).ok_or_else(|| {
        anyhow!("unknown catalog '{name}' (choose from {})", InstanceCatalog::names().join(" "))
    })
}

fn lookup_pricing(name: &str) -> Result<Box<dyn crate::cost::PricingModel>> {
    pricing_by_name(name).ok_or_else(|| {
        anyhow!("unknown pricing model '{name}' (choose from {})", pricing_names().join(" "))
    })
}

fn lookup_scenario(name: &str) -> Result<Box<dyn scenario::Scenario>> {
    scenario::by_name(name).ok_or_else(|| {
        anyhow!(
            "unknown scenario '{name}' (choose from {})",
            scenario::scenario_names().join(" ")
        )
    })
}

/// Parse the `--fractions` grid: a comma-separated list of storage
/// fractions, each strictly inside (0, 1). Empty means "don't search the
/// memory split" — every candidate keeps its type's configured fraction.
fn parse_fractions(s: &str) -> Result<Vec<f64>> {
    if s.trim().is_empty() {
        return Ok(Vec::new());
    }
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        let f: f64 = part
            .parse()
            .map_err(|_| anyhow!("invalid storage fraction '{part}' in --fractions '{s}'"))?;
        if !f.is_finite() || f <= 0.0 || f >= 1.0 {
            return Err(anyhow!(
                "storage fraction {f} out of range in --fractions '{s}' (each must be in (0, 1))"
            ));
        }
        out.push(f);
    }
    Ok(out)
}

/// `blink decide`: the §5.4 recommendation for one app/scale.
pub fn cmd_decide(
    app: &str,
    scale: f64,
    verbose: bool,
    format: OutputFormat,
) -> Result<RecommendReport> {
    let app = lookup(app)?;
    let mut backend = Backend::auto();
    let backend_name = backend.name();
    let report = backend.with_advisor(|advisor| {
        let profile = advisor.profile(&app);
        RecommendReport::new(backend_name, &profile, scale, &MachineSpec::worker_node(), verbose)
    });
    println!("{}", report.render(format));
    Ok(report)
}

/// `blink advise`: the fleet-aware planner — search an instance catalog
/// for `(type × count)` candidates under a pricing model. With a scenario
/// other than `none`, the top analytic picks are cross-validated against
/// event-driven engine runs under that scenario and re-ranked by realized
/// cost.
pub fn cmd_advise(
    app: &str,
    scale: f64,
    catalog_name: &str,
    pricing_name: &str,
    max_machines: usize,
    scenario_name: &str,
    fractions: &str,
    format: OutputFormat,
) -> Result<PlanReport> {
    let app = lookup(app)?;
    let catalog = lookup_catalog(catalog_name)?;
    let pricing = lookup_pricing(pricing_name)?;
    let scenario = lookup_scenario(scenario_name)?;
    let fractions = parse_fractions(fractions)?;
    if max_machines == 0 {
        return Err(anyhow!("--max-machines must be at least 1"));
    }
    let mut backend = Backend::auto();
    let backend_name = backend.name();
    let report = backend.with_advisor_built(
        Advisor::builder().max_machines(max_machines),
        |advisor| {
            let profile = advisor.profile(&app);
            let advice = if fractions.is_empty() {
                profile.plan(scale, &catalog, pricing.as_ref())
            } else {
                profile.plan_with_fractions(scale, &catalog, pricing.as_ref(), &fractions)
            };
            let spec =
                ValidationSpec { scenario: scenario.as_ref(), seeds: &[11, 12, 13], top_k: 3 };
            let risk = (scenario_name != "none").then(|| RiskSection {
                scenario: scenario.name().to_string(),
                picks: profile.validate(scale, &advice.plan, &catalog, pricing.as_ref(), &spec),
            });
            PlanReport {
                backend: backend_name.to_string(),
                app: app.name.to_string(),
                scale,
                input_mb: app.input_mb(scale),
                predicted_cached_mb: advice.predicted_cached_mb,
                predicted_exec_mb: advice.predicted_exec_mb,
                sample_cost_machine_s: advice.sample_cost_machine_s,
                plan: advice.plan,
                catalog_name: catalog.name.to_string(),
                catalog_types: catalog.instances.len(),
                pricing: pricing.name().to_string(),
                risk,
            }
        },
    );
    println!("{}", report.render(format));
    Ok(report)
}

/// Parsed-name inputs of `blink simulate` (bundled so the shim stays a
/// readable signature).
pub struct SimulateQuery<'a> {
    pub app: &'a str,
    pub scale: f64,
    pub machines: usize,
    pub instance: &'a str,
    pub scenario: &'a str,
    pub pricing: &'a str,
    pub seed: u64,
}

/// `blink simulate`: run one workload through the event-driven engine on
/// a homogeneous fleet of a catalog instance type, under a disturbance
/// scenario, and compare the realized per-machine cost against the naive
/// (undisturbed) quote of the same pricing model.
pub fn cmd_simulate(q: &SimulateQuery<'_>, format: OutputFormat) -> Result<SimulateReport> {
    let model = lookup(q.app)?;
    let catalog = InstanceCatalog::all();
    let instance = catalog.get(q.instance).ok_or_else(|| {
        anyhow!("unknown instance type '{}' (see the paper|cloud catalogs)", q.instance)
    })?;
    let scenario = lookup_scenario(q.scenario)?;
    let pricing = lookup_pricing(q.pricing)?;
    let fleet = FleetSpec::homogeneous(instance.clone(), q.machines)
        .map_err(|e| anyhow!("invalid fleet: {e}"))?;
    let profile = model.profile(q.scale);
    let opts = |seed: u64| SimOptions {
        policy: EvictionPolicy::Lru,
        seed,
        compute: None,
        detailed_log: false,
    };
    let baseline = engine::run(&profile, &fleet, &scenario::NoDisturbances, opts(q.seed))
        .map_err(|e| anyhow!("baseline run failed: {e}"))?;
    let disturbed = engine::run(&profile, &fleet, scenario.as_ref(), opts(q.seed))
        .map_err(|e| anyhow!("scenario run failed: {e}"))?;
    let stats = |s: &RunSummary, cached_fraction: f64| RunStats {
        duration_s: s.duration_s,
        cost_machine_min: s.cost_machine_min(),
        evictions: s.evictions,
        machines_lost: s.machines_lost,
        machines_joined: s.machines_joined,
        cached_fraction_after_load: cached_fraction,
    };
    let b = RunSummary::from_log(&baseline.sim.log);
    let s = RunSummary::from_log(&disturbed.sim.log);
    let report = SimulateReport {
        app: model.name.to_string(),
        scale: q.scale,
        input_mb: model.input_mb(q.scale),
        machines: q.machines,
        instance: instance.name.to_string(),
        scenario: scenario.name().to_string(),
        pricing: pricing.name().to_string(),
        naive_quote: pricing.price(instance, q.machines, b.duration_s),
        realized_cost: pricing.price_timeline(&disturbed.timeline),
        baseline: stats(&b, baseline.sim.cached_fraction_after_load),
        disturbed: stats(&s, disturbed.sim.cached_fraction_after_load),
    };
    println!("{}", report.render(format));
    Ok(report)
}

/// `blink run`: recommend, then simulate the actual run at the pick —
/// one advisor query plus one engine run, rendered as a single report.
pub fn cmd_run(app: &str, scale: f64, seed: u64, format: OutputFormat) -> Result<RunReport> {
    let model = lookup(app)?;
    let mut backend = Backend::auto();
    let backend_name = backend.name();
    let decide = backend.with_advisor(|advisor| {
        let profile = advisor.profile(&model);
        RecommendReport::new(backend_name, &profile, scale, &MachineSpec::worker_node(), false)
    });
    let s = experiments::actual_run(&model, scale, decide.recommendation.machines, seed);
    let report = RunReport {
        decide,
        seed,
        duration_s: s.duration_s,
        cost_machine_min: s.cost_machine_min(),
        cost_machine_s: s.cost_machine_s,
        evictions: s.evictions,
    };
    println!("{}", report.render(format));
    Ok(report)
}

/// `blink bounds`: Table-2 style max-scale prediction for one app. The
/// whole pipeline lives in [`TrainedProfile::max_scale`] — the
/// coordinator only resolves names and renders.
///
/// [`TrainedProfile::max_scale`]: crate::blink::TrainedProfile::max_scale
pub fn cmd_bounds(app: &str, machines: usize, format: OutputFormat) -> Result<BoundsReport> {
    let model = lookup(app)?;
    if machines == 0 {
        return Err(anyhow!("--machines must be at least 1"));
    }
    let mut backend = Backend::auto();
    let report = backend.with_advisor(|advisor| {
        let profile = advisor.profile(&model);
        let s = profile.max_scale(&MachineSpec::worker_node(), machines);
        BoundsReport {
            app: model.name.to_string(),
            machines,
            max_scale: s,
            input_mb_at_max: if s.is_finite() { model.input_mb(s) } else { 0.0 },
        }
    });
    println!("{}", report.render(format));
    Ok(report)
}

/// `blink apps`: list the registered workload models.
pub fn cmd_apps(format: OutputFormat) -> AppsReport {
    let sampler = Sampler::default();
    let report = AppsReport {
        rows: all_apps()
            .iter()
            .map(|a| AppRow {
                name: a.name.to_string(),
                input_mb: a.input_mb_full,
                blocks: a.blocks_full,
                iterations: a.iterations,
                cached_mb_at_100: a.total_true_cached_mb(1000.0),
                approach: a.sample_approach(&sampler, 0.001).to_string(),
            })
            .collect(),
    };
    println!("{}", report.render(format));
    report
}

/// Parsed-name inputs of `blink synth`.
pub struct SynthQuery<'a> {
    pub preset: &'a str,
    pub seed: u64,
    pub count: usize,
    pub scale: f64,
    pub catalog: &'a str,
    pub pricing: &'a str,
    pub max_machines: usize,
    /// Cross-check every workload against the testkit's analytic
    /// invariants and report violations (with reproduction seeds).
    pub check: bool,
}

/// `blink synth`: generate seeded synthetic workloads from a preset and
/// run each through the full advisor pipeline — profile (one sampling
/// phase per workload), the §5.4 worker-node recommendation and the
/// catalog planner — optionally asserting the testkit invariants.
pub fn cmd_synth(q: &SynthQuery<'_>, format: OutputFormat) -> Result<SynthReport> {
    let cfg = SynthConfig::by_name(q.preset).ok_or_else(|| {
        anyhow!("unknown preset '{}' (choose from {})", q.preset, SynthConfig::names().join(" "))
    })?;
    let catalog = lookup_catalog(q.catalog)?;
    let pricing = lookup_pricing(q.pricing)?;
    if q.count == 0 {
        return Err(anyhow!("--count must be at least 1"));
    }
    if q.max_machines == 0 {
        return Err(anyhow!("--max-machines must be at least 1"));
    }
    let mut backend = Backend::auto();
    let backend_name = backend.name();
    let report = backend.with_advisor_built(
        Advisor::builder().max_machines(q.max_machines),
        |advisor| {
            let spec =
                testkit::MatrixSpec { max_machines: q.max_machines, ..Default::default() };
            let mut rows = Vec::with_capacity(q.count);
            let mut checks = 0usize;
            let mut violations = Vec::new();
            for (seed, app) in cfg.generate_many(q.seed, q.count) {
                let profile = advisor.profile(&app);
                let rec = profile.recommend(q.scale, &MachineSpec::worker_node());
                let advice = profile.plan(q.scale, &catalog, pricing.as_ref());
                if q.check {
                    // both halves of the invariant catalog, so any CI
                    // violation (analytic or engine-level) reproduces here
                    let (c1, v1) = testkit::check_profile(&app, seed, &profile, &spec);
                    let (c2, v2) = testkit::check_engine(&app, seed, &profile, &spec);
                    checks += c1 + c2;
                    violations.extend(v1.iter().chain(&v2).map(|v| v.to_string()));
                }
                let best = advice.plan.best().expect("catalogs are non-empty");
                rows.push(SynthRow {
                    name: app.name.clone(),
                    seed,
                    datasets: app.cached_laws.len(),
                    input_mb: app.input_mb(q.scale),
                    predicted_cached_mb: advice.predicted_cached_mb,
                    predicted_exec_mb: advice.predicted_exec_mb,
                    sample_cost_machine_s: advice.sample_cost_machine_s,
                    machines: rec.machines,
                    best_instance: best.candidate.instance.clone(),
                    best_machines: best.candidate.machines,
                    best_cost: best.candidate.predicted_cost,
                    eviction_free: best.candidate.eviction_free,
                    no_cached_data: profile.no_cached_data(),
                });
            }
            SynthReport {
                backend: backend_name.to_string(),
                preset: q.preset.to_string(),
                first_seed: q.seed,
                scale: q.scale,
                catalog_name: catalog.name.to_string(),
                catalog_types: catalog.instances.len(),
                pricing: pricing.name().to_string(),
                rows,
                checks,
                violations,
            }
        },
    );
    println!("{}", report.render(format));
    Ok(report)
}

/// Parsed-name inputs of `blink adapt`.
pub struct AdaptQuery<'a> {
    pub app: &'a str,
    pub scale: f64,
    pub catalog: &'a str,
    pub pricing: &'a str,
    pub max_machines: usize,
    pub scenario: &'a str,
    pub seed: u64,
    /// Relative refit divergence that triggers a re-plan.
    pub threshold: f64,
}

/// `blink adapt`: the observe → refit → re-plan → act loop. Profiles the
/// app, launches the catalog plan's best pick through the engine under
/// the scenario, refits the size models from the run's own job-barrier
/// observations, and — past the divergence threshold — re-plans the
/// remaining iterations and enacts a deficit-driven scale-out, adopting
/// it only if the realized cost does not exceed the static run's.
pub fn cmd_adapt(q: &AdaptQuery<'_>, format: OutputFormat) -> Result<AdaptReport> {
    let app = lookup(q.app)?;
    let catalog = lookup_catalog(q.catalog)?;
    let pricing = lookup_pricing(q.pricing)?;
    let scenario = lookup_scenario(q.scenario)?;
    if q.max_machines == 0 {
        return Err(anyhow!("--max-machines must be at least 1"));
    }
    if !q.threshold.is_finite() || q.threshold <= 0.0 {
        return Err(anyhow!("--threshold must be a positive finite number"));
    }
    if !q.scale.is_finite() || q.scale <= 0.0 {
        return Err(anyhow!("--scale must be a positive finite number"));
    }
    let cfg = adaptive::AdaptConfig {
        threshold: q.threshold,
        seed: q.seed,
        ..Default::default()
    };
    let mut backend = Backend::auto();
    let backend_name = backend.name();
    let outcome = backend.with_advisor_built(
        Advisor::builder().max_machines(q.max_machines),
        |advisor| {
            let profile = advisor.profile(&app);
            adaptive::adapt(
                &profile,
                q.scale,
                &catalog,
                pricing.as_ref(),
                scenario.as_ref(),
                &cfg,
            )
        },
    );
    let report = AdaptReport {
        backend: backend_name.to_string(),
        catalog_name: catalog.name.to_string(),
        pricing: pricing.name().to_string(),
        scenario: scenario.name().to_string(),
        threshold: cfg.threshold,
        outcome: outcome.map_err(|e| anyhow!("adaptive run failed: {e}"))?,
    };
    println!("{}", report.render(format));
    Ok(report)
}

/// Parsed-name inputs of `blink fleet`.
pub struct FleetQuery<'a> {
    /// Comma-separated tenant list: registered app names or
    /// `synth:<preset>:<seed>` generator specs.
    pub apps: &'a str,
    pub scale: f64,
    pub catalog: &'a str,
    pub pricing: &'a str,
    pub max_machines: usize,
    /// Shared-store arbitration: `shared-lru` or `reservation-floors`.
    pub fairness: &'a str,
    pub scenario: &'a str,
    pub seed: u64,
}

fn lookup_fairness(name: &str) -> Result<FleetFairness> {
    match name {
        "shared-lru" => Ok(FleetFairness::SharedLru),
        "reservation-floors" => Ok(FleetFairness::ReservationFloors),
        _ => Err(anyhow!(
            "unknown fairness '{name}' (choose from shared-lru reservation-floors)"
        )),
    }
}

/// `blink fleet`: plan N concurrent tenants onto one shared fleet — the
/// §5.4 bound extended with summed working sets ([`plan_fleet`]) — then
/// realize the best pick with the interleaved engine
/// ([`engine::run_fleet`]) under the requested fairness knob and
/// disturbance scenario. One sampling phase per tenant; the realized
/// section prices the shared timeline once for everyone.
pub fn cmd_fleet(q: &FleetQuery<'_>, format: OutputFormat) -> Result<FleetReport> {
    let names: Vec<&str> = q.apps.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if names.is_empty() {
        return Err(anyhow!("--apps needs at least one tenant (comma-separated)"));
    }
    let catalog = lookup_catalog(q.catalog)?;
    let pricing = lookup_pricing(q.pricing)?;
    let fairness = lookup_fairness(q.fairness)?;
    let scenario = lookup_scenario(q.scenario)?;
    if q.max_machines == 0 {
        return Err(anyhow!("--max-machines must be at least 1"));
    }
    if !q.scale.is_finite() || q.scale <= 0.0 {
        return Err(anyhow!("--scale must be a positive finite number"));
    }
    let mut models = Vec::with_capacity(names.len());
    for name in &names {
        models.push(store::resolve_app(name).ok_or_else(|| {
            anyhow!("unknown app '{name}' (registered app or synth:<preset>:<seed>)")
        })?);
    }
    let mut backend = Backend::auto();
    let backend_name = backend.name();
    let report = backend.with_advisor_built(
        Advisor::builder().max_machines(q.max_machines),
        |advisor| -> Result<FleetReport> {
            let trained: Vec<_> = models.iter().map(|m| advisor.profile(m)).collect();
            let workloads: Vec<_> = models.iter().map(|m| m.profile(q.scale)).collect();
            let inputs: Vec<FleetPlanInput<'_>> = models
                .iter()
                .zip(&trained)
                .zip(&workloads)
                .map(|((m, t), w)| FleetPlanInput {
                    name: m.name.clone(),
                    profile: w,
                    cached_total_mb: t.predicted_cached_mb(q.scale),
                    exec_total_mb: t.predicted_exec_mb(q.scale),
                })
                .collect();
            let plan = plan_fleet(&inputs, &catalog, pricing.as_ref(), q.max_machines);
            let realized = match plan.best() {
                Some(best) => {
                    let instance = catalog
                        .get(&best.candidate.instance)
                        .expect("plan candidates come from the catalog")
                        .clone();
                    let fleet = FleetSpec::homogeneous(instance.clone(), best.candidate.machines)
                        .map_err(|e| anyhow!("invalid fleet: {e}"))?;
                    let tenants: Vec<TenantSpec> = models
                        .iter()
                        .zip(&workloads)
                        .map(|(m, w)| TenantSpec { name: m.name.clone(), profile: w.clone() })
                        .collect();
                    let res = engine::run_fleet(
                        &tenants,
                        &fleet,
                        scenario.as_ref(),
                        fairness,
                        SimOptions {
                            policy: EvictionPolicy::Lru,
                            seed: q.seed,
                            compute: None,
                            detailed_log: false,
                        },
                    )
                    .map_err(|e| anyhow!("fleet run failed: {e}"))?;
                    Some(FleetRealized {
                        instance: instance.name.to_string(),
                        machines: best.candidate.machines,
                        seed: q.seed,
                        duration_s: res.duration_s,
                        realized_cost: pricing.price_timeline(&res.timeline),
                        fingerprint: res.fingerprint(),
                        tenants: res.tenants,
                    })
                }
                None => None,
            };
            Ok(FleetReport {
                backend: backend_name.to_string(),
                scale: q.scale,
                catalog_name: catalog.name.to_string(),
                catalog_types: catalog.instances.len(),
                pricing: pricing.name().to_string(),
                fairness: q.fairness.to_string(),
                scenario: scenario.name().to_string(),
                rows: models
                    .iter()
                    .zip(&trained)
                    .map(|(m, t)| FleetTenantRow {
                        name: m.name.clone(),
                        predicted_cached_mb: t.predicted_cached_mb(q.scale),
                        predicted_exec_mb: t.predicted_exec_mb(q.scale),
                        sample_cost_machine_s: t.sample_cost_machine_s,
                    })
                    .collect(),
                plan,
                realized,
            })
        },
    )?;
    println!("{}", report.render(format));
    Ok(report)
}

/// Parsed-name inputs of `blink serve`.
pub struct ServeQuery<'a> {
    /// Path to the JSONL query file (one `util::json` doc per line).
    pub queries: &'a str,
    /// Directory of saved profiles to preload ("" = none).
    pub profiles: &'a str,
    /// Directory to write the store's trained profiles into ("" = none).
    pub save_profiles: &'a str,
    pub shards: usize,
    /// Worker threads for the batch (0 = sized from the host, 1 = serial).
    pub threads: usize,
    pub max_machines: usize,
}

/// Keep only filename-safe characters of an app name.
fn safe_file_stem(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
        .collect()
}

/// `blink serve`: answer a JSONL batch of `recommend`/`plan`/`max_scale`
/// queries from a sharded concurrent [`store::ProfileStore`] — thousands
/// of apps profiled once (or preloaded from disk), every query answered
/// lock-free on the read path. The per-query answers mirror the
/// `--format json` contract of the corresponding subcommands; a malformed
/// line yields a per-query error doc, never a process abort. A preloaded
/// profile whose fingerprint does not match the live app definition is
/// rejected up front with a typed error.
pub fn cmd_serve(q: &ServeQuery<'_>, format: OutputFormat) -> Result<ServeReport> {
    if q.shards == 0 {
        return Err(anyhow!("--shards must be at least 1"));
    }
    if q.max_machines == 0 {
        return Err(anyhow!("--max-machines must be at least 1"));
    }
    let input = std::fs::read_to_string(q.queries)
        .map_err(|e| anyhow!("read queries file '{}': {e}", q.queries))?;
    let profile_store =
        store::ProfileStore::builder().shards(q.shards).max_machines(q.max_machines).build();
    if !q.profiles.is_empty() {
        let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(q.profiles)
            .map_err(|e| anyhow!("read profiles dir '{}': {e}", q.profiles))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "json"))
            .collect();
        paths.sort();
        for path in paths {
            // the file names its app; the live definition is the referee
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("read profile '{}': {e}", path.display()))?;
            let doc = crate::util::json::parse(&text)
                .map_err(|e| anyhow!("profile '{}': {e}", path.display()))?;
            let name = doc
                .path(&["fingerprint", "app"])
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("profile '{}': no fingerprint.app", path.display()))?;
            let live = store::resolve_app(name)
                .ok_or_else(|| anyhow!("profile '{}': unknown app '{name}'", path.display()))?;
            let profile = store::load_profile(&path, &live)
                .map_err(|e| anyhow!("profile '{}': {e}", path.display()))?;
            profile_store.insert(profile).map_err(|e| anyhow!("profile intake: {e}"))?;
        }
    }
    let started = std::time::Instant::now();
    let outcomes = store::serve_batch(&profile_store, &input, q.threads);
    let elapsed_s = started.elapsed().as_secs_f64();
    if !q.save_profiles.is_empty() {
        std::fs::create_dir_all(q.save_profiles)
            .map_err(|e| anyhow!("create save dir '{}': {e}", q.save_profiles))?;
        for profile in profile_store.profiles() {
            let mut h = std::collections::hash_map::DefaultHasher::new();
            use std::hash::{Hash, Hasher};
            for s in &profile.scales {
                s.to_bits().hash(&mut h);
            }
            let file = format!("{}-{:08x}.json", safe_file_stem(&profile.app.name), h.finish());
            let path = std::path::Path::new(q.save_profiles).join(file);
            store::save_profile(&profile, &path).map_err(|e| anyhow!("{e}"))?;
        }
    }
    let ok = outcomes.iter().filter(|o| o.ok).count();
    let report = ServeReport {
        backend: profile_store.backend_name().to_string(),
        queries: outcomes.len(),
        ok,
        errors: outcomes.len() - ok,
        profiles: profile_store.len(),
        sampling_phases: profile_store.sampling_phases(),
        shards: profile_store.shard_count(),
        threads: q.threads,
        elapsed_s,
        results: outcomes.into_iter().map(|o| o.doc).collect(),
    };
    println!("{}", report.render(format));
    Ok(report)
}

/// `blink experiment --id <id>`: regenerate a paper table/figure.
pub fn cmd_experiment(id: &str, seed: u64, format: OutputFormat) -> Result<()> {
    match format {
        OutputFormat::Text => cmd_experiment_text(id, seed),
        OutputFormat::Json => {
            let j = experiment_json(id, seed)?;
            println!("{}", Json::obj(vec![("experiment", id.into()), ("data", j)]).pretty());
            Ok(())
        }
    }
}

/// Figure 2's data: computed-times per dataset of the merged LR DAG.
fn fig2_counts() -> Vec<(String, usize)> {
    let dag = crate::dag::fig2_logistic_regression();
    let counts = dag.compute_counts_uncached();
    dag.datasets.iter().map(|d| (d.name.clone(), counts[d.id])).collect()
}

fn cmd_experiment_text(id: &str, seed: u64) -> Result<()> {
    match id {
        "table1" => report::print_table1(&experiments::table1(seed)),
        "table2" => report::print_table2(&experiments::table2(seed)),
        "fig1" => report::print_fig1(&experiments::fig1(seed)),
        "fig2" => {
            println!("FIGURE 2 — merged LR DAG (computed-times without caching)");
            for (name, count) in fig2_counts() {
                println!("  {name:<5} computed {count}x");
            }
        }
        "fig4" => report::print_fig4(&experiments::fig4(seed)),
        "fig6" => {
            let t = experiments::table1(seed);
            report::print_fig6(&experiments::fig6(&t));
        }
        "fig7" => report::print_fig7(&experiments::fig7()),
        "fig8" => report::print_fig8(&experiments::fig8()),
        "fig9" => report::print_fig9(&experiments::fig9_sizes()),
        "fig10" => {
            let t = experiments::table1(seed);
            report::print_fig10(&experiments::fig10(&t, seed));
        }
        "fig11" => report::print_fig11(&experiments::fig11(seed)),
        "sec4" => report::print_sec4(
            &experiments::sec4_parallelism(seed),
            &experiments::sec4_single_vs_cluster(seed),
        ),
        "all" => {
            for id in [
                "fig1", "fig2", "fig4", "fig7", "fig8", "fig9", "fig11", "sec4", "table1",
                "table2",
            ] {
                cmd_experiment_text(id, seed)?;
                println!();
            }
            // fig6/fig10 derive from table1; print them from one run
            let t = experiments::table1(seed);
            report::print_fig6(&experiments::fig6(&t));
            println!();
            report::print_fig10(&experiments::fig10(&t, seed));
        }
        other => return Err(anyhow!("unknown experiment '{other}'")),
    }
    Ok(())
}

/// The machine rendering of one experiment (same drivers as the text
/// path; `util::json`-parsable by construction).
fn experiment_json(id: &str, seed: u64) -> Result<Json> {
    Ok(match id {
        "table1" => report::json_table1(&experiments::table1(seed)),
        "table2" => report::json_table2(&experiments::table2(seed)),
        "fig1" => report::json_fig1(&experiments::fig1(seed)),
        "fig2" => Json::obj(vec![(
            "datasets",
            Json::Arr(
                fig2_counts()
                    .into_iter()
                    .map(|(name, count)| {
                        Json::obj(vec![("name", name.into()), ("computed", count.into())])
                    })
                    .collect(),
            ),
        )]),
        "fig4" => report::json_fig4(&experiments::fig4(seed)),
        "fig6" => report::json_fig6(&experiments::fig6(&experiments::table1(seed))),
        "fig7" => report::json_fig7(&experiments::fig7()),
        "fig8" => report::json_fig8(&experiments::fig8()),
        "fig9" => report::json_fig9(&experiments::fig9_sizes()),
        "fig10" => {
            let t = experiments::table1(seed);
            report::json_fig10(&experiments::fig10(&t, seed))
        }
        "fig11" => report::json_fig11(&experiments::fig11(seed)),
        "sec4" => report::json_sec4(
            &experiments::sec4_parallelism(seed),
            &experiments::sec4_single_vs_cluster(seed),
        ),
        "all" => {
            let mut entries: Vec<(&str, Json)> = Vec::new();
            for id in ["fig1", "fig2", "fig4", "fig7", "fig8", "fig9", "fig11", "sec4"] {
                entries.push((id, experiment_json(id, seed)?));
            }
            // table1 and its derived figures share one run, as in text mode
            let t = experiments::table1(seed);
            entries.push(("table1", report::json_table1(&t)));
            entries.push(("table2", report::json_table2(&experiments::table2(seed))));
            entries.push(("fig6", report::json_fig6(&experiments::fig6(&t))));
            entries.push(("fig10", report::json_fig10(&experiments::fig10(&t, seed))));
            Json::obj(entries)
        }
        other => return Err(anyhow!("unknown experiment '{other}'")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const F: OutputFormat = OutputFormat::Text;

    #[test]
    fn backend_auto_never_panics() {
        let mut b = Backend::auto();
        let name = b.with_advisor(|a| a.backend_name());
        assert!(name == "pjrt-linfit" || name == "rust-nnls");
    }

    #[test]
    fn lookup_rejects_unknown_and_lists_all_registered_apps() {
        assert!(lookup("svm").is_ok());
        let err = lookup("nope").unwrap_err().to_string();
        for app in all_apps() {
            assert!(err.contains(&app.name), "error must list '{}': {err}", app.name);
        }
    }

    #[test]
    fn unknown_experiment_is_an_error_in_both_formats() {
        assert!(cmd_experiment("fig99", 1, OutputFormat::Text).is_err());
        assert!(cmd_experiment("fig99", 1, OutputFormat::Json).is_err());
    }

    #[test]
    fn advise_rejects_bad_inputs() {
        let advise = |app, catalog, pricing, max, scenario, fractions| {
            cmd_advise(app, 1000.0, catalog, pricing, max, scenario, fractions, F)
        };
        assert!(advise("nope", "cloud", "hourly", 12, "none", "").is_err());
        assert!(advise("svm", "bogus-catalog", "hourly", 12, "none", "").is_err());
        assert!(advise("svm", "cloud", "free-lunch", 12, "none", "").is_err());
        assert!(advise("svm", "cloud", "hourly", 0, "none", "").is_err());
        assert!(advise("svm", "cloud", "hourly", 12, "meteor", "").is_err());
        // malformed or out-of-range fraction grids
        assert!(advise("svm", "cloud", "hourly", 12, "none", "0.3,nope").is_err());
        assert!(advise("svm", "cloud", "hourly", 12, "none", "0.0").is_err());
        assert!(advise("svm", "cloud", "hourly", 12, "none", "1.5").is_err());
    }

    #[test]
    fn unknown_catalog_and_pricing_errors_list_the_valid_names() {
        let err = lookup_catalog("bogus-catalog").unwrap_err().to_string();
        for name in InstanceCatalog::names() {
            assert!(err.contains(name), "catalog error must list '{name}': {err}");
        }
        let err = lookup_pricing("free-lunch").unwrap_err().to_string();
        for name in pricing_names() {
            assert!(err.contains(name), "pricing error must list '{name}': {err}");
        }
    }

    #[test]
    fn unknown_scenario_error_lists_every_valid_name() {
        let err = lookup_scenario("meteor").unwrap_err().to_string();
        for name in scenario::scenario_names() {
            assert!(err.contains(name), "scenario error must list '{name}': {err}");
        }
    }

    #[test]
    fn adapt_rejects_bad_inputs() {
        let q = |app, catalog, pricing, max_machines, scenario| AdaptQuery {
            app,
            scale: 100.0,
            catalog,
            pricing,
            max_machines,
            scenario,
            seed: 1,
            threshold: 0.5,
        };
        assert!(cmd_adapt(&q("nope", "cloud", "hourly", 12, "none"), F).is_err());
        assert!(cmd_adapt(&q("svm", "bogus-catalog", "hourly", 12, "none"), F).is_err());
        assert!(cmd_adapt(&q("svm", "cloud", "free-lunch", 12, "none"), F).is_err());
        assert!(cmd_adapt(&q("svm", "cloud", "hourly", 0, "none"), F).is_err());
        assert!(cmd_adapt(&q("svm", "cloud", "hourly", 12, "meteor"), F).is_err());
        for bad in [f64::NAN, f64::INFINITY, 0.0, -0.5] {
            let mut query = q("svm", "cloud", "hourly", 12, "none");
            query.threshold = bad;
            assert!(cmd_adapt(&query, F).is_err(), "threshold {bad}");
        }
        let mut query = q("svm", "cloud", "hourly", 12, "none");
        query.scale = -1.0;
        assert!(cmd_adapt(&query, F).is_err());
    }

    #[test]
    fn fleet_rejects_bad_inputs() {
        let q = |apps, catalog, pricing, max_machines, fairness, scenario| FleetQuery {
            apps,
            scale: 100.0,
            catalog,
            pricing,
            max_machines,
            fairness,
            scenario,
            seed: 1,
        };
        let base =
            |apps| q(apps, "paper", "machine-seconds", 12, "shared-lru", "none");
        assert!(cmd_fleet(&base(""), F).is_err());
        assert!(cmd_fleet(&base(" , ,"), F).is_err());
        assert!(cmd_fleet(&base("svm,nope"), F).is_err());
        assert!(cmd_fleet(&base("svm,synth:meteor:1"), F).is_err());
        assert!(cmd_fleet(&q("svm,km", "bogus-catalog", "machine-seconds", 12, "shared-lru", "none"), F).is_err());
        assert!(cmd_fleet(&q("svm,km", "paper", "free-lunch", 12, "shared-lru", "none"), F).is_err());
        assert!(cmd_fleet(&q("svm,km", "paper", "machine-seconds", 0, "shared-lru", "none"), F).is_err());
        assert!(cmd_fleet(&q("svm,km", "paper", "machine-seconds", 12, "communism", "none"), F).is_err());
        assert!(cmd_fleet(&q("svm,km", "paper", "machine-seconds", 12, "shared-lru", "meteor"), F).is_err());
        let mut query = base("svm,km");
        query.scale = -1.0;
        assert!(cmd_fleet(&query, F).is_err());
        // the fairness error lists both knobs
        let err = cmd_fleet(&q("svm", "paper", "machine-seconds", 12, "communism", "none"), F)
            .unwrap_err()
            .to_string();
        assert!(err.contains("shared-lru") && err.contains("reservation-floors"), "{err}");
    }

    #[test]
    fn fractions_parse_roundtrips_and_rejects_garbage() {
        assert_eq!(parse_fractions("").unwrap(), Vec::<f64>::new());
        assert_eq!(parse_fractions("  ").unwrap(), Vec::<f64>::new());
        assert_eq!(parse_fractions("0.3,0.5, 0.7").unwrap(), vec![0.3, 0.5, 0.7]);
        assert!(parse_fractions("0.3,,0.5").is_err());
        assert!(parse_fractions("nan").is_err());
        assert!(parse_fractions("-0.2").is_err());
        assert!(parse_fractions("1").is_err());
    }

    #[test]
    fn simulate_rejects_bad_inputs() {
        let q = |app, machines, instance, scenario, pricing| SimulateQuery {
            app,
            scale: 100.0,
            machines,
            instance,
            scenario,
            pricing,
            seed: 1,
        };
        assert!(cmd_simulate(&q("nope", 4, "gp.xlarge", "spot", "spot"), F).is_err());
        assert!(cmd_simulate(&q("svm", 4, "no-such-shape", "spot", "spot"), F).is_err());
        assert!(cmd_simulate(&q("svm", 4, "gp.xlarge", "meteor", "spot"), F).is_err());
        assert!(cmd_simulate(&q("svm", 4, "gp.xlarge", "spot", "free-lunch"), F).is_err());
        assert!(cmd_simulate(&q("svm", 0, "gp.xlarge", "spot", "spot"), F).is_err());
    }

    #[test]
    fn bounds_rejects_zero_machines() {
        assert!(cmd_bounds("svm", 0, F).is_err());
    }

    #[test]
    fn serve_rejects_bad_inputs() {
        let q = |queries, shards, max_machines| ServeQuery {
            queries,
            profiles: "",
            save_profiles: "",
            shards,
            threads: 1,
            max_machines,
        };
        assert!(cmd_serve(&q("/no/such/queries.jsonl", 8, 12), F).is_err());
        assert!(cmd_serve(&q("/no/such/queries.jsonl", 0, 12), F).is_err());
        assert!(cmd_serve(&q("/no/such/queries.jsonl", 8, 0), F).is_err());
    }

    #[test]
    fn synth_rejects_bad_inputs() {
        let q = |preset, count, catalog, pricing, max_machines| SynthQuery {
            preset,
            seed: 1,
            count,
            scale: 100.0,
            catalog,
            pricing,
            max_machines,
            check: false,
        };
        assert!(cmd_synth(&q("meteor", 2, "paper", "hourly", 12), F).is_err());
        assert!(cmd_synth(&q("smoke", 0, "paper", "hourly", 12), F).is_err());
        assert!(cmd_synth(&q("smoke", 2, "bogus-catalog", "hourly", 12), F).is_err());
        assert!(cmd_synth(&q("smoke", 2, "paper", "free-lunch", 12), F).is_err());
        assert!(cmd_synth(&q("smoke", 2, "paper", "hourly", 0), F).is_err());
        // the preset error lists every valid preset name
        let err = cmd_synth(&q("meteor", 2, "paper", "hourly", 12), F).unwrap_err().to_string();
        for name in SynthConfig::names() {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }
}
