//! Pluggable pricing of runs (the cost layer).
//!
//! The paper accounts cost in *machine-seconds* (`duration × machines`);
//! that stays the default and is what [`crate::metrics::RunSummary`]
//! reports. Production deployments price the same run differently —
//! per-instance-hour with a billing granularity, spot discounts — so the
//! planner ([`crate::blink::planner`]) takes any [`PricingModel`] and
//! prices each `(instance type × count)` candidate through it. The paper's
//! Table 1/2 numbers always go through [`MachineSeconds`], keeping the
//! reproduction bit-identical.

use crate::metrics::RunSummary;
use crate::sim::{FleetTimeline, InstanceType};

/// Prices a run of `machines` nodes of one instance type for a duration.
/// `Sync` because pricing models are stateless lookup tables and the
/// planner shares one reference across its parallel validation sweep.
pub trait PricingModel: Sync {
    fn name(&self) -> &'static str;

    /// Cost of keeping `machines` nodes of `instance` busy `duration_s`
    /// seconds. Unit depends on the model (machine-seconds or currency).
    fn price(&self, instance: &InstanceType, machines: usize, duration_s: f64) -> f64;

    /// Price an analyzed run, assuming `instance` nodes executed it.
    fn price_run(&self, instance: &InstanceType, summary: &RunSummary) -> f64 {
        self.price(instance, summary.machines, summary.duration_s)
    }

    /// Price a *realized* per-machine timeline from an engine run: each
    /// uptime segment bills its own instance type for its own span. This
    /// is what makes disturbances cost something — a preempted spot
    /// machine stops billing at reclaim time, but the recompute recovery
    /// stretches every survivor's segment, so the realized total exceeds
    /// the naive `machines × undisturbed-duration` quote.
    fn price_timeline(&self, timeline: &FleetTimeline) -> f64 {
        timeline
            .entries
            .iter()
            .map(|e| self.price(&e.instance, 1, e.up_to_s - e.up_from_s))
            .sum()
    }
}

/// The paper's accounting: `duration_s × machines`, type-blind.
pub struct MachineSeconds;

impl MachineSeconds {
    /// The raw accounting shared with [`crate::metrics`] (kept as a free
    /// method so the metrics layer needs no `InstanceType`).
    pub fn machine_seconds(&self, machines: usize, duration_s: f64) -> f64 {
        duration_s * machines as f64
    }
}

impl PricingModel for MachineSeconds {
    fn name(&self) -> &'static str {
        "machine-seconds"
    }

    fn price(&self, _instance: &InstanceType, machines: usize, duration_s: f64) -> f64 {
        self.machine_seconds(machines, duration_s)
    }
}

/// On-demand pricing: each instance bills `price_per_hour`, rounded up to
/// a billing granularity (classic clouds billed whole hours; modern ones
/// bill per second with a minimum).
pub struct PerInstanceHour {
    /// Billing quantum in seconds; `<= 0` means exact (no rounding).
    pub billing_granularity_s: f64,
}

impl PerInstanceHour {
    pub fn hourly() -> PerInstanceHour {
        PerInstanceHour { billing_granularity_s: 3600.0 }
    }

    pub fn per_second() -> PerInstanceHour {
        PerInstanceHour { billing_granularity_s: 1.0 }
    }

    fn billed_seconds(&self, duration_s: f64) -> f64 {
        let d = duration_s.max(0.0);
        if self.billing_granularity_s <= 0.0 {
            return d;
        }
        (d / self.billing_granularity_s).ceil() * self.billing_granularity_s
    }
}

impl PricingModel for PerInstanceHour {
    fn name(&self) -> &'static str {
        if self.billing_granularity_s >= 3600.0 {
            "hourly"
        } else {
            "per-second"
        }
    }

    fn price(&self, instance: &InstanceType, machines: usize, duration_s: f64) -> f64 {
        self.billed_seconds(duration_s) / 3600.0 * instance.price_per_hour * machines as f64
    }
}

/// Spot/preemptible pricing: an on-demand model discounted by a factor.
pub struct SpotDiscount {
    pub base: PerInstanceHour,
    /// Fraction knocked off the on-demand price (0.7 = pay 30 %).
    pub discount: f64,
}

impl SpotDiscount {
    pub fn typical() -> SpotDiscount {
        SpotDiscount { base: PerInstanceHour::per_second(), discount: 0.7 }
    }
}

impl PricingModel for SpotDiscount {
    fn name(&self) -> &'static str {
        "spot"
    }

    fn price(&self, instance: &InstanceType, machines: usize, duration_s: f64) -> f64 {
        self.base.price(instance, machines, duration_s) * (1.0 - self.discount)
    }
}

/// Look a pricing model up by CLI name.
pub fn pricing_by_name(name: &str) -> Option<Box<dyn PricingModel>> {
    match name {
        "machine-seconds" => Some(Box::new(MachineSeconds)),
        "hourly" => Some(Box::new(PerInstanceHour::hourly())),
        "per-second" => Some(Box::new(PerInstanceHour::per_second())),
        "spot" => Some(Box::new(SpotDiscount::typical())),
        _ => None,
    }
}

/// Every name [`pricing_by_name`] accepts, for CLI help and error text.
pub fn pricing_names() -> &'static [&'static str] {
    &["machine-seconds", "hourly", "per-second", "spot"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{Event, EventLog};

    fn worker() -> InstanceType {
        InstanceType::paper_worker()
    }

    #[test]
    fn machine_seconds_matches_legacy_accounting() {
        // the inline rule this layer replaced: duration_s * machines
        let p = MachineSeconds;
        assert_eq!(p.price(&worker(), 2, 90.0), 180.0);
        assert_eq!(p.machine_seconds(12, 10.0), 120.0);
    }

    #[test]
    fn summary_cost_field_agrees_with_pricing_model() {
        let mut log = EventLog::new();
        log.push(Event::AppStart { app: "svm".into(), machines: 3, data_scale: 1.0 });
        log.push(Event::AppEnd { duration_s: 60.0 });
        let s = RunSummary::from_log(&log);
        assert_eq!(s.cost_machine_s, MachineSeconds.price_run(&worker(), &s));
        assert_eq!(s.cost_machine_s, 180.0);
    }

    #[test]
    fn hourly_rounds_up_to_billing_granularity() {
        let p = PerInstanceHour::hourly();
        // 10 minutes bills a whole hour per instance
        let cost = p.price(&worker(), 4, 600.0);
        assert!((cost - 4.0 * worker().price_per_hour).abs() < 1e-12);
        // 61 minutes bills two hours
        let cost = p.price(&worker(), 1, 3660.0);
        assert!((cost - 2.0 * worker().price_per_hour).abs() < 1e-12);
    }

    #[test]
    fn per_second_billing_is_proportional() {
        let p = PerInstanceHour::per_second();
        let one = p.price(&worker(), 1, 1800.0);
        let two = p.price(&worker(), 1, 3600.0);
        assert!((two - 2.0 * one).abs() < 1e-12);
        assert!((two - worker().price_per_hour).abs() < 1e-12);
    }

    #[test]
    fn spot_discounts_on_demand() {
        let spot = SpotDiscount::typical();
        let od = PerInstanceHour::per_second();
        let full = od.price(&worker(), 5, 1234.0);
        let disc = spot.price(&worker(), 5, 1234.0);
        assert!((disc - full * 0.3).abs() < 1e-12);
    }

    #[test]
    fn pricing_lookup_roundtrips_names() {
        // the advise report prints name(); it must identify the exact model
        for name in pricing_names() {
            assert_eq!(pricing_by_name(name).unwrap().name(), *name);
        }
        assert!(pricing_by_name("free-lunch").is_none());
    }

    #[test]
    fn timeline_pricing_bills_per_machine_uptime() {
        use crate::sim::TimelineEntry;
        let entry = |machine: usize, from: f64, to: f64| TimelineEntry {
            machine,
            instance: worker(),
            up_from_s: from,
            up_to_s: to,
        };
        // 2 machines for the whole 100 s, one reclaimed at 40 s
        let timeline = FleetTimeline {
            duration_s: 100.0,
            entries: vec![entry(0, 0.0, 100.0), entry(1, 0.0, 100.0), entry(2, 0.0, 40.0)],
        };
        let ms = MachineSeconds.price_timeline(&timeline);
        assert!((ms - 240.0).abs() < 1e-9, "{ms}");
        assert!((timeline.machine_seconds() - 240.0).abs() < 1e-9);
        // per-second billing is proportional to the same uptime
        let per_s = PerInstanceHour::per_second().price_timeline(&timeline);
        let expect = worker().price_per_hour * 240.0 / 3600.0;
        assert!((per_s - expect).abs() < 1e-9, "{per_s} vs {expect}");
        // an undisturbed timeline equals the classic n × duration quote
        let flat = FleetTimeline {
            duration_s: 100.0,
            entries: vec![entry(0, 0.0, 100.0), entry(1, 0.0, 100.0)],
        };
        assert!(
            (MachineSeconds.price_timeline(&flat) - MachineSeconds.price(&worker(), 2, 100.0))
                .abs()
                < 1e-9
        );
        // a restart splits one machine into two billed segments
        let restarted = FleetTimeline {
            duration_s: 100.0,
            entries: vec![entry(0, 0.0, 30.0), entry(0, 50.0, 100.0)],
        };
        assert!((MachineSeconds.price_timeline(&restarted) - 80.0).abs() < 1e-9);
    }

    #[test]
    fn zero_duration_costs_nothing_everywhere() {
        for name in ["machine-seconds", "hourly", "per-second", "spot"] {
            let p = pricing_by_name(name).unwrap();
            assert_eq!(p.price(&worker(), 8, 0.0), 0.0, "{name}");
        }
    }
}
