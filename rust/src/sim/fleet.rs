//! Fleet specifications and typed simulation errors.
//!
//! The paper's testbed is homogeneous — [`super::ClusterSpec`] is "n copies
//! of one `MachineSpec`". A [`FleetSpec`] generalizes that to a list of
//! [`InstanceGroup`]s, each a count of one named [`InstanceType`] — the
//! shape a cloud deployment actually provisions (e.g. 4 on-demand
//! `gp.xlarge` + 8 spot `cpu.xlarge`). The event-driven engine
//! ([`super::engine`]) schedules over whatever mix a fleet declares, and
//! the per-machine realized timeline it emits is priced per instance type
//! by [`crate::cost::PricingModel::price_timeline`].
//!
//! Validation happens at construction: zero-count, zero-core, zero-memory
//! or zero-bandwidth groups are a typed [`SimError`], not a mid-run panic.

use super::cluster::{ClusterSpec, InstanceType};

/// Typed error for simulator entry points (replaces the historical
/// `assert!(machines > 0)` panic).
#[derive(Debug, Clone, PartialEq)]
pub enum SimError {
    /// The fleet declares no machines at all.
    EmptyFleet,
    /// An instance group with `count == 0`.
    ZeroCount { instance: String },
    /// An instance type with no task slots.
    ZeroCores { instance: String },
    /// An instance type whose unified memory region is empty.
    NoMemory { instance: String },
    /// `storage_fraction` places the protected floor outside `[0, M]`.
    BadStorageFloor { instance: String },
    /// Disk or network bandwidth is not positive (task durations and
    /// shuffle costs divide by them).
    NoBandwidth { instance: String },
    /// A disturbance scenario removed every machine mid-run.
    AllMachinesLost { at_s: f64 },
    /// A scenario scheduled a disturbance at a NaN/infinite time. Rejected
    /// at intake: a non-finite deadline sorts after every finite one, so it
    /// would silently starve the event queue instead of ever firing.
    NonFiniteEventTime { scenario: String, at_s: f64 },
    /// A scenario was configured with a horizon fraction outside `[0, 1]`
    /// (or NaN). Rejected at intake before any disturbance is scheduled:
    /// a fraction past the horizon silently schedules nothing, a negative
    /// or NaN one schedules nonsense times.
    BadScheduleFraction { scenario: String, at_frac: f64 },
    /// A multi-tenant entry point was handed an empty tenant list. There
    /// is no sensible degenerate run (no logs, no stats), so intake
    /// rejects it the same way `EmptyFleet` rejects a machine-less fleet.
    NoTenants,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::EmptyFleet => write!(f, "fleet declares no machines"),
            SimError::ZeroCount { instance } => {
                write!(f, "instance group '{instance}' has count 0")
            }
            SimError::ZeroCores { instance } => {
                write!(f, "instance type '{instance}' has no cores")
            }
            SimError::NoMemory { instance } => {
                write!(f, "instance type '{instance}' has an empty unified memory region")
            }
            SimError::BadStorageFloor { instance } => {
                write!(f, "instance type '{instance}' has a storage floor outside [0, M]")
            }
            SimError::NoBandwidth { instance } => {
                write!(f, "instance type '{instance}' has non-positive disk/net bandwidth")
            }
            SimError::AllMachinesLost { at_s } => {
                write!(f, "scenario removed every machine by t={at_s:.1}s")
            }
            SimError::NonFiniteEventTime { scenario, at_s } => {
                write!(f, "scenario '{scenario}' scheduled a disturbance at non-finite t={at_s}")
            }
            SimError::BadScheduleFraction { scenario, at_frac } => {
                write!(
                    f,
                    "scenario '{scenario}' has a horizon fraction outside [0, 1]: {at_frac}"
                )
            }
            SimError::NoTenants => write!(f, "fleet run declares no tenants"),
        }
    }
}

impl std::error::Error for SimError {}

/// `count` machines of one instance type.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceGroup {
    pub instance: InstanceType,
    pub count: usize,
}

/// A (possibly heterogeneous) set of machines: the generalization of
/// [`ClusterSpec`] the engine runs on.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub groups: Vec<InstanceGroup>,
}

impl FleetSpec {
    /// Build a validated fleet.
    pub fn new(groups: Vec<InstanceGroup>) -> Result<FleetSpec, SimError> {
        let fleet = FleetSpec { groups };
        fleet.validate()?;
        Ok(fleet)
    }

    /// A single-type fleet (`count` × `instance`).
    pub fn homogeneous(instance: InstanceType, count: usize) -> Result<FleetSpec, SimError> {
        FleetSpec::new(vec![InstanceGroup { instance, count }])
    }

    /// The legacy path: a [`ClusterSpec`] as an unpriced single-type fleet.
    /// `price_per_hour` is 0 because a bare `MachineSpec` carries no price;
    /// the paper reproduction prices in machine-seconds, which never reads
    /// it.
    pub fn from_cluster(cluster: &ClusterSpec) -> Result<FleetSpec, SimError> {
        FleetSpec::homogeneous(
            InstanceType {
                name: "cluster".into(),
                spec: cluster.machine.clone(),
                price_per_hour: 0.0,
            },
            cluster.machines,
        )
    }

    /// Total machine count across groups.
    pub fn machines(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// Total task slots across groups.
    pub fn slots(&self) -> usize {
        self.groups.iter().map(|g| g.count * g.instance.spec.cores).sum()
    }

    /// Check every group for the degeneracies that used to panic mid-run.
    pub fn validate(&self) -> Result<(), SimError> {
        if self.machines() == 0 {
            return Err(SimError::EmptyFleet);
        }
        for g in &self.groups {
            let name = g.instance.name.to_string();
            if g.count == 0 {
                return Err(SimError::ZeroCount { instance: name });
            }
            let spec = &g.instance.spec;
            if spec.cores == 0 {
                return Err(SimError::ZeroCores { instance: name });
            }
            let m = spec.unified_mb();
            if m <= 0.0 {
                return Err(SimError::NoMemory { instance: name });
            }
            let r = spec.storage_floor_mb();
            if !(0.0..=m).contains(&r) {
                return Err(SimError::BadStorageFloor { instance: name });
            }
            if spec.disk_mb_s <= 0.0 || spec.net_mb_s <= 0.0 {
                return Err(SimError::NoBandwidth { instance: name });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MachineSpec;

    #[test]
    fn valid_fleets_pass() {
        let f = FleetSpec::homogeneous(InstanceType::paper_worker(), 4).unwrap();
        assert_eq!(f.machines(), 4);
        assert_eq!(f.slots(), 16);
        let mixed = FleetSpec::new(vec![
            InstanceGroup { instance: InstanceType::paper_worker(), count: 2 },
            InstanceGroup { instance: InstanceType::paper_sample(), count: 3 },
        ])
        .unwrap();
        assert_eq!(mixed.machines(), 5);
    }

    #[test]
    fn empty_and_zero_count_fleets_rejected() {
        assert_eq!(FleetSpec::new(vec![]).unwrap_err(), SimError::EmptyFleet);
        let e = FleetSpec::homogeneous(InstanceType::paper_worker(), 0).unwrap_err();
        assert!(matches!(e, SimError::ZeroCount { .. }));
    }

    #[test]
    fn degenerate_instance_types_rejected_at_construction() {
        let mut zero_cores = InstanceType::paper_worker();
        zero_cores.spec.cores = 0;
        assert!(matches!(
            FleetSpec::homogeneous(zero_cores, 2).unwrap_err(),
            SimError::ZeroCores { .. }
        ));

        let mut no_mem = InstanceType::paper_worker();
        no_mem.spec.heap_mb = 100.0; // below the 300 MB reserved overhead
        assert!(matches!(
            FleetSpec::homogeneous(no_mem, 2).unwrap_err(),
            SimError::NoMemory { .. }
        ));

        let mut bad_floor = InstanceType::paper_worker();
        bad_floor.spec.storage_fraction = 1.5;
        assert!(matches!(
            FleetSpec::homogeneous(bad_floor, 2).unwrap_err(),
            SimError::BadStorageFloor { .. }
        ));

        let mut no_disk = InstanceType::paper_worker();
        no_disk.spec.disk_mb_s = 0.0;
        assert!(matches!(
            FleetSpec::homogeneous(no_disk, 2).unwrap_err(),
            SimError::NoBandwidth { .. }
        ));
    }

    #[test]
    fn from_cluster_preserves_spec_and_count() {
        let c = ClusterSpec::workers(7);
        let f = FleetSpec::from_cluster(&c).unwrap();
        assert_eq!(f.machines(), 7);
        assert_eq!(f.groups[0].instance.spec, MachineSpec::worker_node());
        assert!(FleetSpec::from_cluster(&ClusterSpec::workers(0)).is_err());
    }

    #[test]
    fn errors_display_the_offending_instance() {
        let mut z = InstanceType::paper_worker();
        z.spec.cores = 0;
        let e = FleetSpec::homogeneous(z, 1).unwrap_err();
        assert!(e.to_string().contains("i5-worker"), "{e}");
    }
}
