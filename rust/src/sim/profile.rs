//! The simulator's executable workload description.
//!
//! A [`WorkloadProfile`] is what one *run* of an application at one data
//! scale looks like to the cluster: input size, task parallelism, which
//! datasets get cached and how big they truly are (physics) vs. how big the
//! listener reports them (measurement), iteration count and cost
//! coefficients. [`crate::workloads`] generates profiles from per-app
//! models; the simulator and the Blink coordinator only see this struct.

use crate::util::units::Mb;

/// One dataset the application marks `.cache()`.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedData {
    /// Dataset id in the application DAG.
    pub id: usize,
    /// Physical deserialized size — what occupies executor storage memory.
    pub true_total_mb: Mb,
    /// What the SparkListener reports (includes the small-sample
    /// measurement quirks of §6.2 / Fig. 9; equals `true_total_mb` at
    /// non-tiny scales).
    pub measured_total_mb: Mb,
}

/// Everything the simulator needs to execute one run.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadProfile {
    pub name: String,
    /// Data scale in the paper's units: 1 = 0.1 % of original, 1000 = 100 %.
    pub scale: f64,
    /// Input bytes read from DFS in job 0.
    pub input_mb: Mb,
    /// Tasks per stage (== partitions of the cached datasets).
    pub parallelism: usize,
    pub cached: Vec<CachedData>,
    /// Number of iterative actions after materialization.
    pub iterations: usize,
    /// Compute seconds per MB of (re)computed partition data.
    pub compute_s_per_mb: f64,
    /// How much faster a cached read is than recomputation (paper: ~97x).
    pub cached_speedup: f64,
    /// Lineage-depth multiplier for recomputation vs first computation.
    pub recompute_factor: f64,
    /// Serial (driver) seconds per job — the Amdahl term.
    pub serial_s: f64,
    /// Bytes shuffled per iteration (scales the Area-B network term).
    pub shuffle_mb: Mb,
    /// Total execution memory the application claims across the cluster.
    pub exec_mem_total_mb: Mb,
    /// Fixed per-task overhead (scheduling/dispatch), seconds.
    pub task_overhead_s: f64,
    /// Log-space sigma of task-duration noise (the Fig. 4 time variance).
    pub task_time_sigma: f64,
    /// One-off Block-s sample preparation cost, seconds (0 for Block-n).
    pub sample_prep_s: f64,
}

impl WorkloadProfile {
    pub fn total_cached_true_mb(&self) -> Mb {
        self.cached.iter().map(|c| c.true_total_mb).sum()
    }

    pub fn total_cached_measured_mb(&self) -> Mb {
        self.cached.iter().map(|c| c.measured_total_mb).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_sum_across_datasets() {
        let p = WorkloadProfile {
            name: "x".into(),
            scale: 1.0,
            input_mb: 10.0,
            parallelism: 2,
            cached: vec![
                CachedData { id: 0, true_total_mb: 5.0, measured_total_mb: 5.5 },
                CachedData { id: 1, true_total_mb: 3.0, measured_total_mb: 2.5 },
            ],
            iterations: 1,
            compute_s_per_mb: 0.0,
            cached_speedup: 97.0,
            recompute_factor: 1.0,
            serial_s: 0.0,
            shuffle_mb: 0.0,
            exec_mem_total_mb: 0.0,
            task_overhead_s: 0.0,
            task_time_sigma: 0.0,
            sample_prep_s: 0.0,
        };
        assert_eq!(p.total_cached_true_mb(), 8.0);
        assert_eq!(p.total_cached_measured_mb(), 8.0);
    }
}
