//! Cluster and machine specifications: the paper's testbed (§6) plus a
//! named, priced instance catalog for the fleet-aware cost planner.
//!
//! The paper fixes one machine type and lets Blink choose only the count.
//! [`InstanceType`] attaches a name and an hourly price to a
//! [`MachineSpec`], and [`InstanceCatalog`] groups the types a deployment
//! may choose from — the paper's two testbed nodes (`paper`) or a
//! cloud-style menu of general/compute/memory/storage-optimized shapes
//! (`cloud`). [`crate::blink::planner`] searches (type × count) over a
//! catalog; the original constructors ([`ClusterSpec::workers`],
//! [`ClusterSpec::single_sample_node`]) stay as thin wrappers so every
//! paper-reproduction call site is untouched.
//!
//! Beyond the hand-written menus, [`InstanceCatalog::generate`] builds a
//! seeded cloud-scale catalog — hundreds of types across four families and
//! successive hardware generations with coherent core/memory/price scaling
//! — so the planner can be stressed at the search-space sizes Crispy-style
//! allocation assistants face (`--catalog generated:<seed>:<n>`).

use crate::util::prng::Rng;
use crate::util::units::Mb;

/// One machine/instance type. Defaults model the paper's two node types.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineSpec {
    /// Task slots (paper: 4-core i5 workers / 4-thread i3 sample node).
    pub cores: usize,
    /// Executor JVM heap, MB.
    pub heap_mb: Mb,
    /// `spark.memory.fraction` — unified region share of (heap - 300 MB).
    pub memory_fraction: f64,
    /// `spark.memory.storageFraction` — protected storage share R/M.
    pub storage_fraction: f64,
    /// Sequential DFS read bandwidth, MB/s.
    pub disk_mb_s: f64,
    /// Per-link network bandwidth, MB/s (1 GBit/s LAN ~ 117 MB/s).
    pub net_mb_s: f64,
    /// Coordination overhead added per machine per job (YARN negotiation,
    /// barrier synchronization) — the linear Area-B term.
    pub coord_s_per_machine: f64,
}

/// Reserved JVM overhead Spark subtracts before splitting memory.
pub const RESERVED_MB: Mb = 300.0;

impl MachineSpec {
    /// The paper's 12-node actual-run worker: i5, 16 GB RAM, 1 TB disk.
    /// 12 GB executor heap leaves room for OS + HDFS daemons.
    pub fn worker_node() -> MachineSpec {
        MachineSpec {
            cores: 4,
            heap_mb: 12.0 * 1024.0,
            memory_fraction: 0.6,
            storage_fraction: 0.5,
            disk_mb_s: 120.0,
            net_mb_s: 117.0,
            coord_s_per_machine: 0.12,
        }
    }

    /// The paper's sample-run node: i3-2370M, 3.8 GB RAM, 388 GB disk.
    pub fn sample_node() -> MachineSpec {
        MachineSpec {
            cores: 4,
            heap_mb: 3.0 * 1024.0,
            memory_fraction: 0.6,
            storage_fraction: 0.5,
            disk_mb_s: 90.0,
            net_mb_s: 117.0,
            coord_s_per_machine: 0.12,
        }
    }

    /// Unified region M = (heap - reserved) * memory.fraction (§3.3).
    pub fn unified_mb(&self) -> Mb {
        (self.heap_mb - RESERVED_MB) * self.memory_fraction
    }

    /// Protected storage floor R = M * storageFraction.
    pub fn storage_floor_mb(&self) -> Mb {
        self.unified_mb() * self.storage_fraction
    }
}

/// A named, priced machine shape — one row of an [`InstanceCatalog`].
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceType {
    pub name: String,
    pub spec: MachineSpec,
    /// On-demand price per instance-hour (the paper's testbed nodes carry
    /// an amortized hardware+power figure so both catalogs price the same
    /// way).
    pub price_per_hour: f64,
}

impl InstanceType {
    /// The paper's i5 worker node, priced at amortized ownership cost.
    pub fn paper_worker() -> InstanceType {
        InstanceType {
            name: "i5-worker".into(),
            spec: MachineSpec::worker_node(),
            price_per_hour: 0.10,
        }
    }

    /// The paper's i3 sample node.
    pub fn paper_sample() -> InstanceType {
        InstanceType {
            name: "i3-sample".into(),
            spec: MachineSpec::sample_node(),
            price_per_hour: 0.05,
        }
    }

    /// A homogeneous cluster of `machines` nodes of this type.
    pub fn cluster(&self, machines: usize) -> ClusterSpec {
        ClusterSpec { machines, machine: self.spec.clone() }
    }
}

fn cloud_spec(cores: usize, ram_gb: f64, disk_mb_s: f64, net_mb_s: f64) -> MachineSpec {
    MachineSpec {
        cores,
        // cloud images keep ~25 % of RAM for OS + daemons, as the paper's
        // worker does (12 GB executor heap out of 16 GB)
        heap_mb: ram_gb * 0.75 * 1024.0,
        memory_fraction: 0.6,
        storage_fraction: 0.5,
        disk_mb_s,
        net_mb_s,
        coord_s_per_machine: 0.12,
    }
}

/// A named set of instance types the planner may choose from.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceCatalog {
    pub name: String,
    pub instances: Vec<InstanceType>,
}

/// The four generated-catalog families: (name prefix, RAM GB per core,
/// baseline disk MB/s, price per core-hour). Prices follow the hand-written
/// cloud menu's per-core rates so the generated catalog is a superset in
/// spirit, not a different economy.
const GENERATED_FAMILIES: [(&str, f64, f64, f64); 4] = [
    ("gp", 4.0, 200.0, 0.048),
    ("cpu", 2.0, 180.0, 0.0425),
    ("mem", 8.0, 200.0, 0.063),
    ("io", 8.0, 450.0, 0.078),
];

const GENERATED_SIZES: [&str; 4] = ["xlarge", "2xlarge", "4xlarge", "8xlarge"];

impl InstanceCatalog {
    /// The paper's testbed: the two node types of §6.
    pub fn paper() -> InstanceCatalog {
        InstanceCatalog {
            name: "paper".into(),
            instances: vec![InstanceType::paper_worker(), InstanceType::paper_sample()],
        }
    }

    /// A cloud-style menu: general, compute-, memory- and storage-optimized
    /// shapes with plausible on-demand prices.
    pub fn cloud() -> InstanceCatalog {
        InstanceCatalog {
            name: "cloud".into(),
            instances: vec![
                InstanceType {
                    name: "gp.xlarge".into(), // general purpose, 4 vCPU / 16 GB
                    spec: cloud_spec(4, 16.0, 200.0, 300.0),
                    price_per_hour: 0.192,
                },
                InstanceType {
                    name: "cpu.xlarge".into(), // compute optimized, 4 vCPU / 8 GB
                    spec: cloud_spec(4, 8.0, 180.0, 300.0),
                    price_per_hour: 0.170,
                },
                InstanceType {
                    name: "mem.xlarge".into(), // memory optimized, 4 vCPU / 32 GB
                    spec: cloud_spec(4, 32.0, 200.0, 300.0),
                    price_per_hour: 0.252,
                },
                InstanceType {
                    name: "mem.2xlarge".into(), // memory optimized, 8 vCPU / 64 GB
                    spec: cloud_spec(8, 64.0, 250.0, 600.0),
                    price_per_hour: 0.504,
                },
                InstanceType {
                    name: "io.xlarge".into(), // storage optimized, 4 vCPU / 32 GB, NVMe
                    spec: cloud_spec(4, 32.0, 450.0, 300.0),
                    price_per_hour: 0.312,
                },
            ],
        }
    }

    /// Union of every known hand-written catalog.
    pub fn all() -> InstanceCatalog {
        let mut instances = InstanceCatalog::paper().instances;
        instances.extend(InstanceCatalog::cloud().instances);
        InstanceCatalog { name: "all".into(), instances }
    }

    /// A one-type catalog (the planner degenerates to §5.4 on it).
    pub fn single(instance: InstanceType) -> InstanceCatalog {
        InstanceCatalog { name: "single".into(), instances: vec![instance] }
    }

    /// A seeded, deterministic cloud-scale catalog of `n` instance types.
    ///
    /// Types are enumerated structurally — family (gp/cpu/mem/io) × size
    /// (xlarge..8xlarge, 4..32 cores) × hardware generation — so names are
    /// unique for any `n` and the shape grid is coherent: RAM scales with
    /// cores at a per-family GB/core ratio, disk/network bandwidth grow
    /// with size and generation, and the hourly price is per-core family
    /// pricing with a small generational discount. The seed drives only
    /// bounded jitter (price ±3 %, storage fraction in [0.4, 0.6]) via the
    /// same forked-PRNG idiom as `workloads::synth`: the same
    /// `(seed, n)` always yields byte-identical catalogs, and catalogs for
    /// the same seed agree on their common prefix.
    pub fn generate(seed: u64, n: usize) -> InstanceCatalog {
        let mut rng = Rng::new(seed).fork("catalog");
        let mut instances = Vec::with_capacity(n);
        for i in 0..n {
            let (family, ram_gb_per_core, disk_base, price_per_core) =
                GENERATED_FAMILIES[i % GENERATED_FAMILIES.len()];
            let size_idx = (i / GENERATED_FAMILIES.len()) % GENERATED_SIZES.len();
            let generation = i / (GENERATED_FAMILIES.len() * GENERATED_SIZES.len()) + 1;
            let cores = 4usize << size_idx;
            let gen_speedup = 1.0 + 0.05 * (generation - 1) as f64;
            let disk_mb_s = disk_base * (1.0 + 0.5 * size_idx as f64) * gen_speedup;
            let net_mb_s = 75.0 * cores as f64 * gen_speedup;
            let mut spec = cloud_spec(cores, cores as f64 * ram_gb_per_core, disk_mb_s, net_mb_s);
            // newer generations trade a slice of protected storage for
            // execution room — this is what makes the storage fraction a
            // dimension worth searching, and it keeps R strictly below M
            spec.storage_fraction = rng.range(0.4, 0.6);
            let discount = (1.0 - 0.02 * (generation - 1) as f64).max(0.5);
            let price_per_hour = cores as f64 * price_per_core * discount * rng.range(0.97, 1.03);
            instances.push(InstanceType {
                name: format!("{family}{generation}.{}", GENERATED_SIZES[size_idx]),
                spec,
                price_per_hour,
            });
        }
        InstanceCatalog { name: format!("generated:{seed}:{n}"), instances }
    }

    /// The valid `by_name` spellings, for CLI error messages.
    pub fn names() -> &'static [&'static str] {
        &["paper", "cloud", "all", "generated:<seed>:<n>"]
    }

    /// Look a catalog up by CLI name. `generated:<seed>:<n>` builds a
    /// seeded catalog of `n` types via [`InstanceCatalog::generate`].
    pub fn by_name(name: &str) -> Option<InstanceCatalog> {
        match name {
            "paper" => Some(InstanceCatalog::paper()),
            "cloud" => Some(InstanceCatalog::cloud()),
            "all" => Some(InstanceCatalog::all()),
            _ => {
                let rest = name.strip_prefix("generated:")?;
                let (seed, count) = rest.split_once(':')?;
                let seed: u64 = seed.parse().ok()?;
                let count: usize = count.parse().ok()?;
                if count == 0 {
                    return None;
                }
                Some(InstanceCatalog::generate(seed, count))
            }
        }
    }

    /// Look an instance type up by name.
    pub fn get(&self, name: &str) -> Option<&InstanceType> {
        self.instances.iter().find(|i| i.name == name)
    }
}

/// A homogeneous cluster (the paper's "instance size" axis: Blink fixes the
/// machine type and selects only the count).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub machines: usize,
    pub machine: MachineSpec,
}

impl ClusterSpec {
    /// The paper's actual-run cluster: `machines` i5 worker nodes.
    pub fn workers(machines: usize) -> ClusterSpec {
        InstanceType::paper_worker().cluster(machines)
    }

    /// The paper's sampling setup: one i3 node.
    pub fn single_sample_node() -> ClusterSpec {
        InstanceType::paper_sample().cluster(1)
    }

    /// Total caching capacity when execution uses nothing (n x M).
    pub fn max_cache_mb(&self) -> Mb {
        self.machines as f64 * self.machine.unified_mb()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_memory_regions() {
        let m = MachineSpec::worker_node();
        // (12288 - 300) * 0.6 = 7192.8, R = half of that
        assert!((m.unified_mb() - 7192.8).abs() < 1e-9);
        assert!((m.storage_floor_mb() - 3596.4).abs() < 1e-9);
    }

    #[test]
    fn sample_node_is_smaller() {
        let s = MachineSpec::sample_node();
        let w = MachineSpec::worker_node();
        assert!(s.unified_mb() < w.unified_mb());
        assert!(s.unified_mb() > 1000.0, "still fits tiny samples");
    }

    #[test]
    fn cluster_capacity_scales_linearly() {
        let c1 = ClusterSpec::workers(1);
        let c12 = ClusterSpec::workers(12);
        assert!((c12.max_cache_mb() - 12.0 * c1.max_cache_mb()).abs() < 1e-6);
    }

    #[test]
    fn thin_constructors_match_paper_specs() {
        // the planner refactor must not perturb the paper testbed
        assert_eq!(ClusterSpec::workers(12).machine, MachineSpec::worker_node());
        let s = ClusterSpec::single_sample_node();
        assert_eq!(s.machines, 1);
        assert_eq!(s.machine, MachineSpec::sample_node());
    }

    #[test]
    fn catalogs_are_named_priced_and_distinct() {
        let paper = InstanceCatalog::paper();
        assert_eq!(paper.instances.len(), 2);
        let cloud = InstanceCatalog::cloud();
        assert!(cloud.instances.len() >= 4, "cloud catalog needs >= 4 types");
        let all = InstanceCatalog::all();
        assert_eq!(all.instances.len(), paper.instances.len() + cloud.instances.len());
        let mut names: Vec<&str> = all.instances.iter().map(|i| i.name.as_str()).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), before, "instance names must be unique");
        for i in &all.instances {
            assert!(i.price_per_hour > 0.0, "{}", i.name);
            assert!(i.spec.unified_mb() > 0.0, "{}", i.name);
        }
    }

    #[test]
    fn catalog_lookup() {
        assert_eq!(InstanceCatalog::by_name("cloud").unwrap().name, "cloud");
        assert!(InstanceCatalog::by_name("nope").is_none());
        let cloud = InstanceCatalog::cloud();
        assert!(cloud.get("mem.xlarge").is_some());
        assert!(cloud.get("i5-worker").is_none());
        assert_eq!(InstanceCatalog::paper().get("i5-worker").unwrap().spec, MachineSpec::worker_node());
    }

    #[test]
    fn generated_catalog_is_deterministic_and_parsable() {
        let a = InstanceCatalog::generate(42, 64);
        let b = InstanceCatalog::generate(42, 64);
        assert_eq!(a, b, "same (seed, n) must be byte-identical");
        assert_eq!(a.name, "generated:42:64");
        assert_eq!(a.instances.len(), 64);
        // prefix property: growing n extends, never reshuffles
        let small = InstanceCatalog::generate(42, 16);
        assert_eq!(&a.instances[..16], &small.instances[..]);
        // a different seed moves prices but not the structural grid
        let c = InstanceCatalog::generate(43, 64);
        assert_eq!(
            a.instances.iter().map(|i| i.name.as_str()).collect::<Vec<_>>(),
            c.instances.iter().map(|i| i.name.as_str()).collect::<Vec<_>>(),
        );
        let moved =
            a.instances.iter().zip(&c.instances).any(|(x, y)| x.price_per_hour != y.price_per_hour);
        assert!(moved, "a different seed must move prices");
        // CLI spelling round-trips
        let via_cli = InstanceCatalog::by_name("generated:42:64").unwrap();
        assert_eq!(via_cli, a);
        assert!(InstanceCatalog::by_name("generated:42:0").is_none());
        assert!(InstanceCatalog::by_name("generated:42").is_none());
        assert!(InstanceCatalog::by_name("generated:x:8").is_none());
    }

    #[test]
    fn generated_families_scale_coherently() {
        let cat = InstanceCatalog::generate(7, 512);
        let gp1 = cat.get("gp1.xlarge").unwrap();
        let gp1_big = cat.get("gp1.8xlarge").unwrap();
        assert_eq!(gp1.spec.cores, 4);
        assert_eq!(gp1_big.spec.cores, 32);
        // RAM and price scale with cores within a family/generation
        assert!(gp1_big.spec.heap_mb > 7.0 * gp1.spec.heap_mb);
        assert!(gp1_big.price_per_hour > 6.0 * gp1.price_per_hour);
        // memory-optimized shapes hold more cache per core than compute
        let mem = cat.get("mem1.xlarge").unwrap();
        let cpu = cat.get("cpu1.xlarge").unwrap();
        assert!(mem.spec.unified_mb() > 2.0 * cpu.spec.unified_mb());
        // later generations are no pricier than generation 1
        let gp9 = cat.get("gp9.xlarge").unwrap();
        assert!(gp9.price_per_hour < gp1.price_per_hour * 1.05);
    }

    #[test]
    fn property_generated_types_are_unique_finite_and_memory_sound() {
        use crate::util::prng::Rng;
        use crate::util::prop;
        prop::check(
            &prop::Config { cases: 48, seed: 0xca7a10, max_size: 64 },
            |rng: &mut Rng, _size| (rng.below(1 << 20) as u64, rng.below(512) as usize + 1),
            |&(seed, n)| {
                let cat = InstanceCatalog::generate(seed, n);
                if cat.instances.len() != n {
                    return Err(format!("seed {seed}: {} types, wanted {n}", cat.instances.len()));
                }
                let mut names: Vec<&str> =
                    cat.instances.iter().map(|i| i.name.as_str()).collect();
                names.sort_unstable();
                names.dedup();
                if names.len() != n {
                    return Err(format!("seed {seed}: duplicate instance names"));
                }
                for i in &cat.instances {
                    if !(i.price_per_hour.is_finite() && i.price_per_hour > 0.0) {
                        return Err(format!(
                            "seed {seed}: {} price {} not finite-positive",
                            i.name, i.price_per_hour
                        ));
                    }
                    let (m, r) = (i.spec.unified_mb(), i.spec.storage_floor_mb());
                    if !(m.is_finite() && m > 0.0 && r.is_finite() && r > 0.0) {
                        return Err(format!("seed {seed}: {} degenerate memory", i.name));
                    }
                    if r > m {
                        return Err(format!(
                            "seed {seed}: {} storage floor {r} exceeds unified {m}",
                            i.name
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn memory_optimized_types_hold_more_cache_per_node() {
        let cloud = InstanceCatalog::cloud();
        let gp = cloud.get("gp.xlarge").unwrap();
        let mem = cloud.get("mem.xlarge").unwrap();
        assert!(mem.spec.unified_mb() > 1.9 * gp.spec.unified_mb());
        assert!(mem.price_per_hour > gp.price_per_hour, "capacity costs money");
    }
}
