//! Cluster and machine specifications (the paper's testbed, §6).

use crate::util::units::Mb;

/// One machine/instance type. Defaults model the paper's two node types.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineSpec {
    /// Task slots (paper: 4-core i5 workers / 4-thread i3 sample node).
    pub cores: usize,
    /// Executor JVM heap, MB.
    pub heap_mb: Mb,
    /// `spark.memory.fraction` — unified region share of (heap - 300 MB).
    pub memory_fraction: f64,
    /// `spark.memory.storageFraction` — protected storage share R/M.
    pub storage_fraction: f64,
    /// Sequential DFS read bandwidth, MB/s.
    pub disk_mb_s: f64,
    /// Per-link network bandwidth, MB/s (1 GBit/s LAN ~ 117 MB/s).
    pub net_mb_s: f64,
    /// Coordination overhead added per machine per job (YARN negotiation,
    /// barrier synchronization) — the linear Area-B term.
    pub coord_s_per_machine: f64,
}

/// Reserved JVM overhead Spark subtracts before splitting memory.
pub const RESERVED_MB: Mb = 300.0;

impl MachineSpec {
    /// The paper's 12-node actual-run worker: i5, 16 GB RAM, 1 TB disk.
    /// 12 GB executor heap leaves room for OS + HDFS daemons.
    pub fn worker_node() -> MachineSpec {
        MachineSpec {
            cores: 4,
            heap_mb: 12.0 * 1024.0,
            memory_fraction: 0.6,
            storage_fraction: 0.5,
            disk_mb_s: 120.0,
            net_mb_s: 117.0,
            coord_s_per_machine: 0.12,
        }
    }

    /// The paper's sample-run node: i3-2370M, 3.8 GB RAM, 388 GB disk.
    pub fn sample_node() -> MachineSpec {
        MachineSpec {
            cores: 4,
            heap_mb: 3.0 * 1024.0,
            memory_fraction: 0.6,
            storage_fraction: 0.5,
            disk_mb_s: 90.0,
            net_mb_s: 117.0,
            coord_s_per_machine: 0.12,
        }
    }

    /// Unified region M = (heap - reserved) * memory.fraction (§3.3).
    pub fn unified_mb(&self) -> Mb {
        (self.heap_mb - RESERVED_MB) * self.memory_fraction
    }

    /// Protected storage floor R = M * storageFraction.
    pub fn storage_floor_mb(&self) -> Mb {
        self.unified_mb() * self.storage_fraction
    }
}

/// A homogeneous cluster (the paper's "instance size" axis: Blink fixes the
/// machine type and selects only the count).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub machines: usize,
    pub machine: MachineSpec,
}

impl ClusterSpec {
    pub fn workers(machines: usize) -> ClusterSpec {
        ClusterSpec { machines, machine: MachineSpec::worker_node() }
    }

    pub fn single_sample_node() -> ClusterSpec {
        ClusterSpec { machines: 1, machine: MachineSpec::sample_node() }
    }

    /// Total caching capacity when execution uses nothing (n x M).
    pub fn max_cache_mb(&self) -> Mb {
        self.machines as f64 * self.machine.unified_mb()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_memory_regions() {
        let m = MachineSpec::worker_node();
        // (12288 - 300) * 0.6 = 7192.8, R = half of that
        assert!((m.unified_mb() - 7192.8).abs() < 1e-9);
        assert!((m.storage_floor_mb() - 3596.4).abs() < 1e-9);
    }

    #[test]
    fn sample_node_is_smaller() {
        let s = MachineSpec::sample_node();
        let w = MachineSpec::worker_node();
        assert!(s.unified_mb() < w.unified_mb());
        assert!(s.unified_mb() > 1000.0, "still fits tiny samples");
    }

    #[test]
    fn cluster_capacity_scales_linearly() {
        let c1 = ClusterSpec::workers(1);
        let c12 = ClusterSpec::workers(12);
        assert!((c12.max_cache_mb() - 12.0 * c1.max_cache_mb()).abs() < 1e-6);
    }
}
