//! Discrete-event Spark-like cluster simulator.
//!
//! This is the substrate the paper runs on top of (their 12-node private
//! cluster): machines with task slots, sequential jobs (one per action) of
//! parallel tasks, list scheduling with cache locality, per-machine unified
//! memory ([`crate::memory`]), shuffle + serial (Amdahl) costs that grow
//! with cluster size, and log-normal task-duration noise. It reproduces the
//! exact mechanisms the paper reasons about:
//!
//! * **Area A** — when aggregate storage cannot hold the cached dataset,
//!   the uncached fraction of partitions is recomputed in every iteration
//!   (a recomputing task runs ~the paper's 97x longer than a cached read);
//! * **Area B** — more machines shrink the parallel part but not the
//!   serial part, and add coordination/shuffle overhead per machine;
//! * **task skew** — durations are noisy, so machines finish waves at
//!   different times and greedy scheduling over-assigns tasks to fast
//!   machines; with a thin caching margin this evicts partitions
//!   (the KM +150 % case of Fig. 11).
//!
//! The simulator emits a [`crate::metrics::EventLog`] — the same interface
//! a SparkListener gives the real Blink.

pub mod cluster;
pub mod profile;

pub use cluster::{ClusterSpec, InstanceCatalog, InstanceType, MachineSpec};
pub use profile::{CachedData, WorkloadProfile};

use crate::memory::{EvictionPolicy, PartitionKey, UnifiedMemory};
use crate::metrics::{Event, EventLog};
use crate::util::prng::Rng;

/// Pluggable task-body executor. The analytic model is the default; the
/// RealCompute bridge (examples/end_to_end.rs) substitutes wall-clock
/// measurements of the AOT-compiled kernels via PJRT.
pub trait TaskCompute {
    /// Returns the measured duration (seconds) of one task body, or `None`
    /// to fall back to the analytic duration for this task.
    fn run_task(&mut self, profile: &WorkloadProfile, cached_read: bool) -> Option<f64>;
}

/// Always use the analytic model.
pub struct AnalyticCompute;

impl TaskCompute for AnalyticCompute {
    fn run_task(&mut self, _p: &WorkloadProfile, _cached: bool) -> Option<f64> {
        None
    }
}

/// Simulation options.
pub struct SimOptions<'a> {
    pub policy: EvictionPolicy,
    pub seed: u64,
    pub compute: Option<&'a mut dyn TaskCompute>,
    /// Emit per-task TaskEnd / per-partition BlockUpdate events. Sample
    /// runs need them (the listener-log contract); multi-million-task
    /// sweeps set this to false and get one aggregate BlockUpdate per
    /// dataset instead.
    pub detailed_log: bool,
}

impl Default for SimOptions<'_> {
    fn default() -> Self {
        SimOptions { policy: EvictionPolicy::Lru, seed: 0, compute: None, detailed_log: true }
    }
}

/// Per-machine simulation state.
struct Machine {
    /// Next-free time per core slot (seconds).
    slots: Vec<f64>,
    mem: UnifiedMemory,
    tasks_run: usize,
    evictions: usize,
}

/// Outcome of a simulated run: the listener log plus placement diagnostics
/// used by Fig. 11.
pub struct SimResult {
    pub log: EventLog,
    /// Tasks executed per machine in iteration jobs (Fig. 11 histogram).
    pub iter_tasks_per_machine: Vec<usize>,
    /// Evictions per machine.
    pub evictions_per_machine: Vec<usize>,
    /// Fraction of the primary cached dataset resident after job 0.
    pub cached_fraction_after_load: f64,
}

/// Simulate one application run.
///
/// Jobs are sequential: job 0 materializes (and caches) the datasets from
/// DFS input; jobs `1..=iterations` are the iterative actions, each reading
/// every partition of the cached dataset(s) — from cache where resident,
/// by recomputation otherwise (recomputed partitions try to re-cache).
pub fn simulate(
    profile: &WorkloadProfile,
    cluster: &ClusterSpec,
    opts: SimOptions<'_>,
) -> SimResult {
    let n = cluster.machines;
    assert!(n > 0, "cluster needs at least one machine");
    let mut rng = Rng::new(opts.seed ^ 0x5117_c0de);
    let mut compute = opts.compute;
    let detailed = opts.detailed_log;
    let mut cached_reads_total = 0usize;
    let mut tasks_total = 0usize;
    let mut log = EventLog::new();
    log.push(Event::AppStart {
        app: profile.name.clone(),
        machines: n,
        data_scale: profile.scale,
    });

    let mut machines: Vec<Machine> = (0..n)
        .map(|_| Machine {
            slots: vec![0.0; cluster.machine.cores],
            mem: UnifiedMemory::new(
                cluster.machine.unified_mb(),
                cluster.machine.storage_floor_mb(),
                opts.policy,
            ),
            tasks_run: 0,
            evictions: 0,
        })
        .collect();

    // Block-s sample preparation happens before the app starts.
    let mut now = profile.sample_prep_s;
    for m in &mut machines {
        for s in &mut m.slots {
            *s = now;
        }
    }

    let parts = profile.parallelism.max(1);
    // partition -> machine currently caching it (per dataset)
    let mut location: Vec<Vec<Option<usize>>> =
        profile.cached.iter().map(|_| vec![None; parts]).collect();

    let exec_per_machine = profile.exec_mem_total_mb / n as f64;

    // ---------------------------------------------------------- job 0 ----
    // Materialize: read input, compute, cache each partition where it ran.
    let input_per_task = profile.input_mb / parts as f64;
    for p in 0..parts {
        let (mi, si) = earliest_slot(&machines);
        let base = input_per_task / cluster.machine.disk_mb_s
            + input_per_task * profile.compute_s_per_mb
            + profile.task_overhead_s;
        let dur = task_duration(base, profile, false, &mut rng, &mut compute);
        let start = machines[mi].slots[si];
        machines[mi].slots[si] = start + dur;
        machines[mi].tasks_run += 1;
        tasks_total += 1;
        if detailed {
            log.push(Event::TaskEnd {
                stage: 0,
                task: p,
                machine: mi,
                duration_s: dur,
                cached_read: false,
            });
        }
        for (di, ds) in profile.cached.iter().enumerate() {
            let true_part = ds.true_total_mb / parts as f64;
            let measured_part = ds.measured_total_mb / parts as f64;
            let stored = machines[mi].mem.insert(
                PartitionKey { dataset: ds.id, index: p },
                true_part,
                profile.iterations + 1,
                1,
            );
            for key in machines[mi].mem.drain_evicted() {
                machines[mi].evictions += 1;
                log.push(Event::Eviction { machine: mi });
                mark_evicted(&mut location, profile, key);
            }
            if stored {
                location[di][p] = Some(mi);
            }
            if detailed {
                log.push(Event::BlockUpdate {
                    dataset: ds.id,
                    partition: p,
                    size_mb: measured_part,
                    stored,
                });
            }
        }
    }
    now = barrier(&mut machines, now);
    now += profile.serial_s + shuffle_s(profile, cluster);
    set_all_slots(&mut machines, now);

    let cached_fraction_after_load = if profile.cached.is_empty() {
        0.0
    } else {
        location[0].iter().filter(|l| l.is_some()).count() as f64 / parts as f64
    };

    // ------------------------------------------------- iteration jobs ----
    let mut iter_tasks = vec![0usize; n];
    for job in 1..=profile.iterations {
        // Execution memory is claimed at the start of each action; with a
        // thin margin this is what evicts over-cached machines (Fig. 11).
        for (mi, m) in machines.iter_mut().enumerate() {
            m.mem.claim_execution(exec_per_machine);
            for key in m.mem.drain_evicted() {
                m.evictions += 1;
                log.push(Event::Eviction { machine: mi });
                mark_evicted(&mut location, profile, key);
            }
        }

        for p in 0..parts {
            // a task reads the corresponding partition of every cached
            // dataset; locality pins it to the machine caching dataset 0
            let pinned = profile.cached.first().and_then(|_| location[0][p]);
            let (mi, si) = match pinned {
                Some(m) => (m, earliest_slot_on(&machines[m])),
                None => earliest_slot(&machines),
            };
            let cached_read = pinned.is_some();
            let part_input = profile.input_mb / parts as f64;
            let base = if cached_read {
                let part_cached: f64 = profile
                    .cached
                    .iter()
                    .map(|d| d.true_total_mb / parts as f64)
                    .sum();
                part_cached * profile.compute_s_per_mb / profile.cached_speedup
                    + profile.task_overhead_s
            } else {
                // recompute the lineage: re-read input + recompute
                part_input / cluster.machine.disk_mb_s
                    + part_input * profile.compute_s_per_mb * profile.recompute_factor
                    + profile.task_overhead_s
            };
            let dur = task_duration(base, profile, cached_read, &mut rng, &mut compute);
            let start = machines[mi].slots[si];
            machines[mi].slots[si] = start + dur;
            machines[mi].tasks_run += 1;
            iter_tasks[mi] += 1;
            tasks_total += 1;
            if cached_read {
                cached_reads_total += 1;
            }
            if detailed {
                log.push(Event::TaskEnd {
                    stage: job,
                    task: p,
                    machine: mi,
                    duration_s: dur,
                    cached_read,
                });
            }
            if cached_read {
                for ds in &profile.cached {
                    machines[mi].mem.touch(PartitionKey { dataset: ds.id, index: p });
                }
            } else {
                // Spark re-caches a recomputed partition where it ran
                for (di, ds) in profile.cached.iter().enumerate() {
                    let true_part = ds.true_total_mb / parts as f64;
                    let stored = machines[mi].mem.insert(
                        PartitionKey { dataset: ds.id, index: p },
                        true_part,
                        profile.iterations - job + 1,
                        1,
                    );
                    for key in machines[mi].mem.drain_evicted() {
                        machines[mi].evictions += 1;
                        log.push(Event::Eviction { machine: mi });
                        mark_evicted(&mut location, profile, key);
                    }
                    if stored {
                        location[di][p] = Some(mi);
                    }
                }
            }
        }
        let job_start = now;
        now = barrier(&mut machines, now);
        now += profile.serial_s + shuffle_s(profile, cluster);
        set_all_slots(&mut machines, now);
        log.push(Event::JobEnd { job, duration_s: now - job_start });
    }

    if !detailed {
        // one aggregate BlockUpdate per dataset: currently-resident bytes
        // in measured units (what a listener's final snapshot would show)
        for (di, ds) in profile.cached.iter().enumerate() {
            let resident = location[di].iter().filter(|l| l.is_some()).count();
            let measured_part = ds.measured_total_mb / parts as f64;
            log.push(Event::BlockUpdate {
                dataset: ds.id,
                partition: 0,
                size_mb: measured_part * resident as f64,
                stored: resident > 0,
            });
        }
    }
    for (mi, m) in machines.iter().enumerate() {
        log.push(Event::ExecMemory { machine: mi, peak_mb: m.mem.exec_used_mb() });
    }
    let _ = (tasks_total, cached_reads_total);
    log.push(Event::AppEnd { duration_s: now });

    SimResult {
        log,
        iter_tasks_per_machine: iter_tasks,
        evictions_per_machine: machines.iter().map(|m| m.evictions).collect(),
        cached_fraction_after_load,
    }
}

fn mark_evicted(
    location: &mut [Vec<Option<usize>>],
    profile: &WorkloadProfile,
    key: PartitionKey,
) {
    for (di, ds) in profile.cached.iter().enumerate() {
        if ds.id == key.dataset {
            if let Some(slot) = location[di].get_mut(key.index) {
                *slot = None;
            }
        }
    }
}

fn task_duration(
    base_s: f64,
    profile: &WorkloadProfile,
    cached_read: bool,
    rng: &mut Rng,
    compute: &mut Option<&mut dyn TaskCompute>,
) -> f64 {
    if let Some(c) = compute.as_deref_mut() {
        if let Some(measured) = c.run_task(profile, cached_read) {
            return measured;
        }
    }
    rng.lognormal(base_s, profile.task_time_sigma).max(1e-6)
}

/// (machine, slot) with the earliest free time; ties take the lowest index,
/// which matches Spark's deterministic executor ordering.
fn earliest_slot(machines: &[Machine]) -> (usize, usize) {
    let mut best = (0usize, 0usize, f64::INFINITY);
    for (mi, m) in machines.iter().enumerate() {
        for (si, &t) in m.slots.iter().enumerate() {
            if t < best.2 {
                best = (mi, si, t);
            }
        }
    }
    (best.0, best.1)
}

fn earliest_slot_on(m: &Machine) -> usize {
    let mut best = (0usize, f64::INFINITY);
    for (si, &t) in m.slots.iter().enumerate() {
        if t < best.1 {
            best = (si, t);
        }
    }
    best.0
}

/// Advance the barrier: all slots drain, return the max finish time.
fn barrier(machines: &mut [Machine], now: f64) -> f64 {
    machines
        .iter()
        .flat_map(|m| m.slots.iter().copied())
        .fold(now, f64::max)
}

fn set_all_slots(machines: &mut [Machine], t: f64) {
    for m in machines {
        for s in &mut m.slots {
            *s = t;
        }
    }
}

/// Per-iteration shuffle + coordination cost (the Area-B terms): each
/// machine exchanges `(n-1)/n` of its shuffle share over the network whose
/// aggregate bandwidth scales with `n`, plus a per-machine coordination
/// overhead (YARN negotiation, straggler barrier).
pub fn shuffle_s(profile: &WorkloadProfile, cluster: &ClusterSpec) -> f64 {
    let n = cluster.machines as f64;
    if cluster.machines == 1 {
        return 0.0;
    }
    let net = profile.shuffle_mb * (n - 1.0) / n / (cluster.machine.net_mb_s * n);
    net + cluster.machine.coord_s_per_machine * n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunSummary;

    fn tiny_profile(cached_mb: f64, iters: usize, parallelism: usize) -> WorkloadProfile {
        WorkloadProfile {
            name: "toy".into(),
            scale: 1000.0,
            input_mb: 1000.0,
            parallelism,
            cached: vec![CachedData {
                id: 0,
                true_total_mb: cached_mb,
                measured_total_mb: cached_mb,
            }],
            iterations: iters,
            compute_s_per_mb: 0.01,
            cached_speedup: 97.0,
            recompute_factor: 1.0,
            serial_s: 1.0,
            shuffle_mb: 100.0,
            exec_mem_total_mb: 500.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.1,
            sample_prep_s: 0.0,
        }
    }

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec { machines: n, machine: MachineSpec::worker_node() }
    }

    #[test]
    fn fully_cached_run_has_no_evictions_and_fast_iterations() {
        let p = tiny_profile(2000.0, 5, 32);
        let res = simulate(&p, &cluster(2), SimOptions::default());
        let s = RunSummary::from_log(&res.log);
        assert_eq!(s.evictions, 0);
        assert!((res.cached_fraction_after_load - 1.0).abs() < 1e-9);
        // every iteration task was a cached read
        assert_eq!(s.cached_reads, 5 * 32);
    }

    #[test]
    fn under_provisioned_cluster_recomputes() {
        // one worker stores ~6.9 GB; ask for 30 GB of cache
        let p = tiny_profile(30_000.0, 3, 64);
        let res = simulate(&p, &cluster(1), SimOptions::default());
        let s = RunSummary::from_log(&res.log);
        assert!(res.cached_fraction_after_load < 0.5);
        assert!(s.cached_reads < 3 * 64);
        // and it is slower than a fully-provisioned cluster per unit work
        let res_big = simulate(&p, &cluster(8), SimOptions::default());
        let s_big = RunSummary::from_log(&res_big.log);
        assert!(s.duration_s > s_big.duration_s * 2.0);
    }

    #[test]
    fn cost_has_area_a_and_area_b() {
        // calibrated so ~3 machines fit the cache, with recomputation
        // expensive enough that under-provisioning clearly hurts
        let mut p = tiny_profile(18_000.0, 10, 128);
        p.compute_s_per_mb = 0.05;
        p.recompute_factor = 5.0;
        let costs: Vec<f64> = (1..=10)
            .map(|n| {
                let r = simulate(&p, &cluster(n), SimOptions::default());
                RunSummary::from_log(&r.log).cost_machine_s
            })
            .collect();
        let opt = crate::util::stats::argmin(&costs).unwrap() + 1;
        assert!(opt > 1, "area A exists: 1 machine is not optimal ({costs:?})");
        assert!(opt < 10, "area B exists: biggest cluster is not optimal ({costs:?})");
        // cost rises toward the area-B end
        assert!(costs[9] > costs[opt - 1]);
    }

    #[test]
    fn time_decreases_with_machines_when_cached() {
        // compute-heavy enough that parallelism beats coordination overhead
        let mut p = tiny_profile(3000.0, 5, 96);
        p.compute_s_per_mb = 0.2;
        let t2 = RunSummary::from_log(&simulate(&p, &cluster(2), SimOptions::default()).log)
            .duration_s;
        let t8 = RunSummary::from_log(&simulate(&p, &cluster(8), SimOptions::default()).log)
            .duration_s;
        assert!(t8 < t2, "t8={t8} t2={t2}");
    }

    #[test]
    fn deterministic_given_seed_and_sizes_stable_across_seeds() {
        let p = tiny_profile(2000.0, 4, 32);
        let a = simulate(&p, &cluster(2), SimOptions { seed: 1, ..Default::default() });
        let b = simulate(&p, &cluster(2), SimOptions { seed: 1, ..Default::default() });
        let c = simulate(&p, &cluster(2), SimOptions { seed: 2, ..Default::default() });
        let (sa, sb, sc) = (
            RunSummary::from_log(&a.log),
            RunSummary::from_log(&b.log),
            RunSummary::from_log(&c.log),
        );
        assert_eq!(sa.duration_s, sb.duration_s, "same seed, same run");
        assert_ne!(sa.duration_s, sc.duration_s, "time varies across runs");
        // the paper's Fig. 4: cached dataset size does NOT vary across runs
        assert_eq!(sa.cached_sizes_mb, sc.cached_sizes_mb);
    }

    #[test]
    fn sample_prep_cost_shifts_clock() {
        let mut p = tiny_profile(100.0, 1, 4);
        let base = RunSummary::from_log(&simulate(&p, &cluster(1), SimOptions::default()).log)
            .duration_s;
        p.sample_prep_s = 42.0;
        let with = RunSummary::from_log(&simulate(&p, &cluster(1), SimOptions::default()).log)
            .duration_s;
        assert!((with - base - 42.0).abs() < 1e-9);
    }

    #[test]
    fn skew_with_thin_margin_causes_evictions() {
        // Fig. 11 mechanism (the KM +150 % case): 100 partitions on 7
        // machines. All partitions cache during materialization (15 fit in
        // the full unified region), but once execution memory is claimed
        // the storage limit drops below 15 partitions -> machines that the
        // skewed schedule over-assigned evict their surplus.
        let mut p = tiny_profile(46_000.0, 6, 100); // partition = 460 MB
        p.task_time_sigma = 0.4;
        p.exec_mem_total_mb = 7.0 * 492.8;
        let res = simulate(&p, &cluster(7), SimOptions { seed: 3, ..Default::default() });
        let total_evictions: usize = res.evictions_per_machine.iter().sum();
        assert!(total_evictions > 0, "thin margin + skew must evict");
        let max_tasks = *res.iter_tasks_per_machine.iter().max().unwrap();
        let min_tasks = *res.iter_tasks_per_machine.iter().min().unwrap();
        assert!(max_tasks > min_tasks, "scheduler skew exists");
    }

    #[test]
    fn no_cached_dataset_runs_without_block_updates() {
        let mut p = tiny_profile(0.0, 2, 8);
        p.cached.clear();
        let res = simulate(&p, &cluster(1), SimOptions::default());
        let s = RunSummary::from_log(&res.log);
        assert_eq!(s.total_cached_mb(), 0.0);
        assert_eq!(s.evictions, 0);
    }
}
