//! Discrete-event Spark-like cluster simulator.
//!
//! This is the substrate the paper runs on top of (their 12-node private
//! cluster): machines with task slots, sequential jobs (one per action) of
//! parallel tasks, list scheduling with cache locality, per-machine unified
//! memory ([`crate::memory`]), shuffle + serial (Amdahl) costs that grow
//! with cluster size, and log-normal task-duration noise. It reproduces the
//! exact mechanisms the paper reasons about:
//!
//! * **Area A** — when aggregate storage cannot hold the cached dataset,
//!   the uncached fraction of partitions is recomputed in every iteration
//!   (a recomputing task runs ~the paper's 97x longer than a cached read);
//! * **Area B** — more machines shrink the parallel part but not the
//!   serial part, and add coordination/shuffle overhead per machine;
//! * **task skew** — durations are noisy, so machines finish waves at
//!   different times and greedy scheduling over-assigns tasks to fast
//!   machines; with a thin caching margin this evicts partitions
//!   (the KM +150 % case of Fig. 11).
//!
//! The simulator emits a [`crate::metrics::EventLog`] — the same interface
//! a SparkListener gives the real Blink.
//!
//! The execution core lives in [`engine`]: an event-driven scheduler over
//! heterogeneous [`FleetSpec`]s with pluggable disturbance [`scenario`]s
//! (spot preemption, stragglers, failure + restart, step autoscaling).
//! [`simulate`] is the legacy single-type entry point — a thin wrapper
//! over the engine with [`scenario::NoDisturbances`], byte-identical to
//! the pre-engine serial code (property-tested), so every paper experiment
//! is untouched.

pub mod cluster;
pub mod engine;
pub mod fleet;
pub mod profile;
pub mod scenario;

pub use cluster::{ClusterSpec, InstanceCatalog, InstanceType, MachineSpec};
pub use engine::{
    run_fleet, EngineResult, FleetFairness, FleetRunResult, FleetTimeline, IterationObservation,
    TenantRunStats, TenantSpec, TimelineEntry,
};
pub use fleet::{FleetSpec, InstanceGroup, SimError};
pub use profile::{CachedData, WorkloadProfile};
pub use scenario::{scenario_names, Disturbance, DisturbanceKind, Scenario};

use crate::memory::EvictionPolicy;
use crate::metrics::EventLog;

/// Pluggable task-body executor. The analytic model is the default; the
/// RealCompute bridge (examples/end_to_end.rs) substitutes wall-clock
/// measurements of the AOT-compiled kernels via PJRT.
pub trait TaskCompute {
    /// Returns the measured duration (seconds) of one task body, or `None`
    /// to fall back to the analytic duration for this task.
    fn run_task(&mut self, profile: &WorkloadProfile, cached_read: bool) -> Option<f64>;
}

/// Always use the analytic model.
pub struct AnalyticCompute;

impl TaskCompute for AnalyticCompute {
    fn run_task(&mut self, _p: &WorkloadProfile, _cached: bool) -> Option<f64> {
        None
    }
}

/// Simulation options.
pub struct SimOptions<'a> {
    pub policy: EvictionPolicy,
    pub seed: u64,
    pub compute: Option<&'a mut dyn TaskCompute>,
    /// Emit per-task TaskEnd / per-partition BlockUpdate events. Sample
    /// runs need them (the listener-log contract); multi-million-task
    /// sweeps set this to false and get one aggregate BlockUpdate per
    /// dataset instead.
    pub detailed_log: bool,
}

impl Default for SimOptions<'_> {
    fn default() -> Self {
        SimOptions { policy: EvictionPolicy::Lru, seed: 0, compute: None, detailed_log: true }
    }
}

/// Outcome of a simulated run: the listener log plus placement diagnostics
/// used by Fig. 11.
pub struct SimResult {
    pub log: EventLog,
    /// Tasks executed per machine in iteration jobs (Fig. 11 histogram).
    pub iter_tasks_per_machine: Vec<usize>,
    /// Evictions per machine.
    pub evictions_per_machine: Vec<usize>,
    /// Fraction of the primary cached dataset resident after job 0.
    pub cached_fraction_after_load: f64,
}

/// Simulate one application run on a homogeneous cluster (the legacy
/// paper-reproduction entry point).
///
/// Jobs are sequential: job 0 materializes (and caches) the datasets from
/// DFS input; jobs `1..=iterations` are the iterative actions, each reading
/// every partition of the cached dataset(s) — from cache where resident,
/// by recomputation otherwise (recomputed partitions try to re-cache).
///
/// This is a thin wrapper over [`engine::run`] with
/// [`scenario::NoDisturbances`]; the event log is byte-identical to the
/// pre-engine serial simulator. Degenerate clusters (zero machines) are a
/// typed [`SimError`], not a panic.
pub fn simulate(
    profile: &WorkloadProfile,
    cluster: &ClusterSpec,
    opts: SimOptions<'_>,
) -> Result<SimResult, SimError> {
    let fleet = FleetSpec::from_cluster(cluster)?;
    engine::run(profile, &fleet, &scenario::NoDisturbances, opts).map(|r| r.sim)
}

/// The Area-B overhead formula shared by every caller (the single-type
/// [`shuffle_s`], the engine's fleet aggregation, and the horizon anchor):
/// `(n-1)/n` of the shuffle volume over the aggregate network bandwidth,
/// plus the summed coordination overhead. One definition, so a model tweak
/// cannot silently diverge between the analytic and executed paths.
pub(crate) fn shuffle_overhead_s(shuffle_mb: f64, n: f64, agg_net_mb_s: f64, coord_s: f64) -> f64 {
    let net = shuffle_mb * (n - 1.0) / n / agg_net_mb_s;
    net + coord_s
}

/// Per-iteration shuffle + coordination cost (the Area-B terms): each
/// machine exchanges `(n-1)/n` of its shuffle share over the network whose
/// aggregate bandwidth scales with `n`, plus a per-machine coordination
/// overhead (YARN negotiation, straggler barrier).
pub fn shuffle_s(profile: &WorkloadProfile, cluster: &ClusterSpec) -> f64 {
    let n = cluster.machines as f64;
    if cluster.machines == 1 {
        return 0.0;
    }
    shuffle_overhead_s(
        profile.shuffle_mb,
        n,
        cluster.machine.net_mb_s * n,
        cluster.machine.coord_s_per_machine * n,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunSummary;

    fn tiny_profile(cached_mb: f64, iters: usize, parallelism: usize) -> WorkloadProfile {
        WorkloadProfile {
            name: "toy".into(),
            scale: 1000.0,
            input_mb: 1000.0,
            parallelism,
            cached: vec![CachedData {
                id: 0,
                true_total_mb: cached_mb,
                measured_total_mb: cached_mb,
            }],
            iterations: iters,
            compute_s_per_mb: 0.01,
            cached_speedup: 97.0,
            recompute_factor: 1.0,
            serial_s: 1.0,
            shuffle_mb: 100.0,
            exec_mem_total_mb: 500.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.1,
            sample_prep_s: 0.0,
        }
    }

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec { machines: n, machine: MachineSpec::worker_node() }
    }

    #[test]
    fn fully_cached_run_has_no_evictions_and_fast_iterations() {
        let p = tiny_profile(2000.0, 5, 32);
        let res = simulate(&p, &cluster(2), SimOptions::default()).unwrap();
        let s = RunSummary::from_log(&res.log);
        assert_eq!(s.evictions, 0);
        assert!((res.cached_fraction_after_load - 1.0).abs() < 1e-9);
        // every iteration task was a cached read
        assert_eq!(s.cached_reads, 5 * 32);
    }

    #[test]
    fn under_provisioned_cluster_recomputes() {
        // one worker stores ~6.9 GB; ask for 30 GB of cache
        let p = tiny_profile(30_000.0, 3, 64);
        let res = simulate(&p, &cluster(1), SimOptions::default()).unwrap();
        let s = RunSummary::from_log(&res.log);
        assert!(res.cached_fraction_after_load < 0.5);
        assert!(s.cached_reads < 3 * 64);
        // and it is slower than a fully-provisioned cluster per unit work
        let res_big = simulate(&p, &cluster(8), SimOptions::default()).unwrap();
        let s_big = RunSummary::from_log(&res_big.log);
        assert!(s.duration_s > s_big.duration_s * 2.0);
    }

    #[test]
    fn zero_machine_cluster_is_a_typed_error_not_a_panic() {
        let p = tiny_profile(100.0, 1, 4);
        let err = simulate(&p, &cluster(0), SimOptions::default()).unwrap_err();
        assert!(matches!(err, SimError::ZeroCount { .. }), "{err}");
    }

    #[test]
    fn cost_has_area_a_and_area_b() {
        // calibrated so ~3 machines fit the cache, with recomputation
        // expensive enough that under-provisioning clearly hurts
        let mut p = tiny_profile(18_000.0, 10, 128);
        p.compute_s_per_mb = 0.05;
        p.recompute_factor = 5.0;
        let costs: Vec<f64> = (1..=10)
            .map(|n| {
                let r = simulate(&p, &cluster(n), SimOptions::default()).unwrap();
                RunSummary::from_log(&r.log).cost_machine_s
            })
            .collect();
        let opt = crate::util::stats::argmin(&costs).unwrap() + 1;
        assert!(opt > 1, "area A exists: 1 machine is not optimal ({costs:?})");
        assert!(opt < 10, "area B exists: biggest cluster is not optimal ({costs:?})");
        // cost rises toward the area-B end
        assert!(costs[9] > costs[opt - 1]);
    }

    #[test]
    fn time_decreases_with_machines_when_cached() {
        // compute-heavy enough that parallelism beats coordination overhead
        let mut p = tiny_profile(3000.0, 5, 96);
        p.compute_s_per_mb = 0.2;
        let t2 =
            RunSummary::from_log(&simulate(&p, &cluster(2), SimOptions::default()).unwrap().log)
                .duration_s;
        let t8 =
            RunSummary::from_log(&simulate(&p, &cluster(8), SimOptions::default()).unwrap().log)
                .duration_s;
        assert!(t8 < t2, "t8={t8} t2={t2}");
    }

    #[test]
    fn deterministic_given_seed_and_sizes_stable_across_seeds() {
        let p = tiny_profile(2000.0, 4, 32);
        let a = simulate(&p, &cluster(2), SimOptions { seed: 1, ..Default::default() }).unwrap();
        let b = simulate(&p, &cluster(2), SimOptions { seed: 1, ..Default::default() }).unwrap();
        let c = simulate(&p, &cluster(2), SimOptions { seed: 2, ..Default::default() }).unwrap();
        let (sa, sb, sc) = (
            RunSummary::from_log(&a.log),
            RunSummary::from_log(&b.log),
            RunSummary::from_log(&c.log),
        );
        assert_eq!(sa.duration_s, sb.duration_s, "same seed, same run");
        assert_ne!(sa.duration_s, sc.duration_s, "time varies across runs");
        // the paper's Fig. 4: cached dataset size does NOT vary across runs
        assert_eq!(sa.cached_sizes_mb, sc.cached_sizes_mb);
    }

    #[test]
    fn sample_prep_cost_shifts_clock() {
        let mut p = tiny_profile(100.0, 1, 4);
        let base =
            RunSummary::from_log(&simulate(&p, &cluster(1), SimOptions::default()).unwrap().log)
                .duration_s;
        p.sample_prep_s = 42.0;
        let with =
            RunSummary::from_log(&simulate(&p, &cluster(1), SimOptions::default()).unwrap().log)
                .duration_s;
        assert!((with - base - 42.0).abs() < 1e-9);
    }

    #[test]
    fn skew_with_thin_margin_causes_evictions() {
        // Fig. 11 mechanism (the KM +150 % case): 100 partitions on 7
        // machines. All partitions cache during materialization (15 fit in
        // the full unified region), but once execution memory is claimed
        // the storage limit drops below 15 partitions -> machines that the
        // skewed schedule over-assigned evict their surplus.
        let mut p = tiny_profile(46_000.0, 6, 100); // partition = 460 MB
        p.task_time_sigma = 0.4;
        p.exec_mem_total_mb = 7.0 * 492.8;
        let res = simulate(&p, &cluster(7), SimOptions { seed: 3, ..Default::default() }).unwrap();
        let total_evictions: usize = res.evictions_per_machine.iter().sum();
        assert!(total_evictions > 0, "thin margin + skew must evict");
        let max_tasks = *res.iter_tasks_per_machine.iter().max().unwrap();
        let min_tasks = *res.iter_tasks_per_machine.iter().min().unwrap();
        assert!(max_tasks > min_tasks, "scheduler skew exists");
    }

    #[test]
    fn no_cached_dataset_runs_without_block_updates() {
        let mut p = tiny_profile(0.0, 2, 8);
        p.cached.clear();
        let res = simulate(&p, &cluster(1), SimOptions::default()).unwrap();
        let s = RunSummary::from_log(&res.log);
        assert_eq!(s.total_cached_mb(), 0.0);
        assert_eq!(s.evictions, 0);
    }
}
