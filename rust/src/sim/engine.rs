//! Event-driven simulation engine over heterogeneous fleets.
//!
//! This is the refactored core of the old monolithic `simulate()` loop:
//! an explicit time-ordered event queue of scenario disturbances and
//! machine-lifecycle events, drained against the scheduling frontier as
//! per-machine [`MachineState`]s execute jobs. The legacy
//! [`super::simulate`] is now a thin wrapper over [`run`] with
//! [`super::scenario::NoDisturbances`], and is byte-identical (event log
//! JSONL) to the pre-refactor serial code — property-tested in
//! `rust/tests/engine_equivalence.rs` — so the paper reproduction never
//! moves.
//!
//! What the engine adds over the legacy loop:
//!
//! * **heterogeneous fleets** — a [`FleetSpec`] of mixed
//!   [`InstanceType`] groups; task durations and shuffle/coordination
//!   overheads use the spec of the machine a task actually runs on;
//! * **disturbances** — spot preemption (cached partitions and in-flight
//!   tasks lost, survivors recompute via the existing Area-A lineage
//!   path), straggler slowdown windows, machine failure with restart, and
//!   step autoscaling; lost/joined machines emit
//!   [`Event::MachineLost`]/[`Event::MachineJoined`];
//! * **realized timelines** — per-machine uptime segments
//!   ([`FleetTimeline`]) so [`crate::cost::PricingModel::price_timeline`]
//!   can price what actually ran (a preempted spot fleet bills fewer
//!   machine-seconds but stretches the run — the realized cost the naive
//!   `SpotDiscount` quote ignores).
//!
//! ## In-flight semantics
//!
//! Task events are journaled per job and flushed at the job barrier.
//! When a machine is lost at time `t`, journaled tasks of that machine
//! whose finish time exceeds `t` are *rewound* — their events and
//! counters are undone and their partitions re-enter the job's work
//! queue, to be re-executed on survivors (as a recompute, since the lost
//! machine's cache went with it); a retry never starts before the loss
//! that caused it. Tasks that finished before `t` keep their events;
//! their cached partitions are still dropped, so later iterations
//! recompute them — exactly the lineage recovery a Spark driver performs
//! after an executor loss.
//!
//! One deliberate approximation: within a job, tasks are assigned in
//! partition order (the legacy greedy list scheduler — required for
//! byte-identity with the pre-engine simulator), not in simulated-time
//! order. Disturbances are drained against each candidate task's start
//! time, so a disturbance can be applied "before" a lower-start task of
//! a higher partition index is scheduled. Tasks of one job are logically
//! concurrent, so this only shifts which in-flight tasks a loss rewinds;
//! job barriers and all cross-job effects remain time-consistent.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

use super::cluster::InstanceType;
use super::fleet::{FleetSpec, SimError};
use super::profile::WorkloadProfile;
use super::scenario::{DisturbanceKind, Scenario, ScenarioCtx};
use super::{SimOptions, SimResult, TaskCompute};
use crate::memory::{EvictionPolicy, PartitionKey, UnifiedMemory};
use crate::metrics::{Event, EventLog};
use crate::util::prng::Rng;
use crate::util::units::Mb;

/// One machine's live state: slot clocks, unified memory, lifecycle.
pub struct MachineState {
    pub spec: super::MachineSpec,
    pub instance: InstanceType,
    /// Index into the engine's group table (for overhead aggregation).
    group: usize,
    pub alive: bool,
    /// Next-free time per core slot (seconds).
    slots: Vec<f64>,
    mem: UnifiedMemory,
    tasks_run: usize,
    iter_tasks: usize,
    evictions: usize,
    /// Start of the current uptime segment.
    up_from_s: f64,
    /// Closed uptime segments (machine losses close them).
    segments: Vec<(f64, f64)>,
    slow_factor: f64,
    /// Straggler window: tasks starting in `[slow_from, slow_until)` run
    /// `slow_factor`× slower.
    slow_from: f64,
    slow_until: f64,
    /// Standing cross-job execution pressure (MB) claimed by co-resident
    /// tenants ([`DisturbanceKind::Pressure`]): added to every execution
    /// claim from the disturbance on. 0 for every other scenario, which
    /// keeps their claims byte-identical to the pre-contention engine.
    pressure_mb: Mb,
}

impl MachineState {
    fn slowdown_at(&self, start: f64) -> f64 {
        if start >= self.slow_from && start < self.slow_until {
            self.slow_factor
        } else {
            1.0
        }
    }
}

impl MachineState {
    fn new(instance: &InstanceType, group: usize, policy: EvictionPolicy, at_s: f64) -> Self {
        MachineState {
            spec: instance.spec.clone(),
            instance: instance.clone(),
            group,
            alive: true,
            slots: vec![at_s; instance.spec.cores],
            mem: UnifiedMemory::new(
                instance.spec.unified_mb(),
                instance.spec.storage_floor_mb(),
                policy,
            ),
            tasks_run: 0,
            iter_tasks: 0,
            evictions: 0,
            up_from_s: at_s,
            segments: Vec::new(),
            slow_factor: 1.0,
            slow_from: f64::INFINITY,
            slow_until: f64::NEG_INFINITY,
            pressure_mb: 0.0,
        }
    }
}

/// One machine's realized uptime interval.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineEntry {
    pub machine: usize,
    pub instance: InstanceType,
    pub up_from_s: f64,
    pub up_to_s: f64,
}

/// The realized per-machine timeline of a run — what actually got billed.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetTimeline {
    pub duration_s: f64,
    pub entries: Vec<TimelineEntry>,
}

impl FleetTimeline {
    /// Total realized uptime across machines (the paper's accounting unit).
    pub fn machine_seconds(&self) -> f64 {
        self.entries.iter().map(|e| e.up_to_s - e.up_from_s).sum()
    }
}

/// One job-boundary snapshot of observed cached-dataset residency — the
/// engine's observation hook for `blink::adaptive`. Sizes are in the
/// *measured* units a listener would report (what the sample-run fits were
/// trained on), so the adaptive loop can fold them straight into the
/// [`crate::blink::SizePredictor`] models without unit conversion.
#[derive(Debug, Clone, PartialEq)]
pub struct IterationObservation {
    /// Job index (0 = the materialization job, 1..=iterations after).
    pub job: usize,
    /// Simulated time of the job barrier the snapshot was taken at.
    pub at_s: f64,
    /// `(dataset id, resident partitions, observed resident MB)` per
    /// cached dataset, in dataset declaration order. Carrying the
    /// partition count lets a consumer estimate the *full* dataset size
    /// (`resident_mb / resident_parts × parallelism`) from the observation
    /// alone, the way a listener extrapolates from the blocks it has seen.
    pub cached: Vec<(usize, usize, f64)>,
}

/// Outcome of an engine run: the legacy [`SimResult`] plus the realized
/// timeline the cost layer prices and the per-job observation journal
/// the adaptive loop refits from.
pub struct EngineResult {
    pub sim: SimResult,
    pub timeline: FleetTimeline,
    /// Cached-size snapshot at every job barrier (empty only for
    /// workloads that cache nothing). One entry per job, job order.
    pub observations: Vec<IterationObservation>,
}

// ---------------------------------------------------------------------
// event queue
// ---------------------------------------------------------------------

enum QueuedKind {
    Disturb(DisturbanceKind),
    /// Internal: a failed machine coming back (scheduled by `Fail`).
    Rejoin { machine: usize },
}

struct QueueItem {
    at_s: f64,
    seq: u64,
    kind: QueuedKind,
}

// Min-ordering on `(at_s, seq)` via `Reverse` in the heap below. `total_cmp`
// gives a total order on `f64`, but non-finite times are rejected at intake
// (`run` returns [`SimError::NonFiniteEventTime`]) because a NaN deadline
// would sort after every finite time and silently starve the queue.
impl Ord for QueueItem {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at_s.total_cmp(&other.at_s).then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for QueueItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for QueueItem {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for QueueItem {}

/// Time-ordered queue of pending engine events: a binary min-heap keyed on
/// `(at_s, seq)`. Replaces the historical scanned-`Vec` whose `pop_due` was
/// O(n) per call (O(n²) per drained queue); the heap keeps the same
/// deterministic `(at_s, seq)` order at O(log n) per operation, which is
/// what lets dense disturbance schedules (large spot fleets, autoscale
/// storms) stay off the profile.
struct EventQueue {
    heap: BinaryHeap<Reverse<QueueItem>>,
    seq: u64,
}

impl EventQueue {
    fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    fn push(&mut self, at_s: f64, kind: QueuedKind) {
        debug_assert!(at_s.is_finite(), "event time must be finite (guarded at intake)");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(QueueItem { at_s, seq, kind }));
    }

    /// Remove and return the earliest item due at or before `t`, if any.
    /// The heap minimum is the globally earliest `(at_s, seq)`, so if it is
    /// not due nothing is — identical semantics to the old full scan.
    fn pop_due(&mut self, t: f64) -> Option<QueueItem> {
        match self.heap.peek() {
            Some(Reverse(item)) if item.at_s <= t => self.heap.pop().map(|r| r.0),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------
// per-job journal
// ---------------------------------------------------------------------

/// Journal of a job in flight. Flushed to the log at the job barrier in
/// assignment order (identical to the legacy push order); task entries of
/// a lost machine can be rewound before the flush.
///
/// Task events live in one flat arena (`Vec<Event>`) shared by every task
/// of the job; each entry holds its contiguous `Range` into it. This
/// replaced the per-task `Vec<Event>` buffers (and the spare-buffer pool
/// that recycled them): a job now costs one arena grow instead of one
/// allocation per task, the hot spot `BENCH_hotpaths.json` tracks under
/// `engine/arena-svm-100pct-4-machines-detailed`. A rewound task's range
/// is simply never flushed; the garbage is reclaimed when the arena
/// clears at the barrier.
enum JournalEntry {
    Task {
        part: usize,
        machine: usize,
        end_s: f64,
        iteration: bool,
        evictions: usize,
        events: std::ops::Range<usize>,
    },
    Marker(Event),
}

/// Drain the journal into the log in assignment order, copying each live
/// task's event range out of the arena (task events carry no heap data),
/// then reset the arena for the next job. Ranges of rewound tasks are
/// skipped because their entries are gone from the journal.
fn flush_journal(log: &mut EventLog, journal: &mut Vec<JournalEntry>, arena: &mut Vec<Event>) {
    for entry in journal.drain(..) {
        match entry {
            JournalEntry::Task { events, .. } => {
                for e in arena[events].iter().cloned() {
                    log.push(e);
                }
            }
            JournalEntry::Marker(e) => log.push(e),
        }
    }
    arena.clear();
}

// ---------------------------------------------------------------------
// scheduling helpers (the legacy free functions, fleet-aware)
// ---------------------------------------------------------------------

/// (machine, slot) with the earliest free time among live machines; ties
/// take the lowest index (Spark's deterministic executor ordering).
/// `None` when every machine is gone.
fn earliest_slot(machines: &[MachineState]) -> Option<(usize, usize)> {
    let mut best = (0usize, 0usize, f64::INFINITY);
    let mut found = false;
    for (mi, m) in machines.iter().enumerate() {
        if !m.alive {
            continue;
        }
        for (si, &t) in m.slots.iter().enumerate() {
            if t < best.2 {
                best = (mi, si, t);
            }
            found = true;
        }
    }
    if found {
        Some((best.0, best.1))
    } else {
        None
    }
}

fn earliest_slot_on(m: &MachineState) -> usize {
    let mut best = (0usize, f64::INFINITY);
    for (si, &t) in m.slots.iter().enumerate() {
        if t < best.1 {
            best = (si, t);
        }
    }
    best.0
}

/// Advance the barrier: all live slots drain, return the max finish time.
fn barrier(machines: &[MachineState], now: f64) -> f64 {
    machines
        .iter()
        .filter(|m| m.alive)
        .flat_map(|m| m.slots.iter().copied())
        .fold(now, f64::max)
}

fn set_all_slots(machines: &mut [MachineState], t: f64) {
    for m in machines.iter_mut().filter(|m| m.alive) {
        for s in &mut m.slots {
            *s = t;
        }
    }
}

/// Per-iteration shuffle + coordination cost over the live fleet: the
/// fleet generalization of [`super::shuffle_s`]. Aggregates per group
/// (`count × value`) so a homogeneous fleet computes bit-identical values
/// to the legacy single-spec formula.
fn fleet_overhead_s(
    profile: &WorkloadProfile,
    machines: &[MachineState],
    groups: &[InstanceType],
) -> f64 {
    let mut per_group = vec![0usize; groups.len()];
    let mut n = 0usize;
    for m in machines {
        if m.alive {
            per_group[m.group] += 1;
            n += 1;
        }
    }
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    let mut agg_net = 0.0;
    let mut coord = 0.0;
    for (g, &c) in groups.iter().zip(&per_group) {
        if c == 0 {
            continue;
        }
        agg_net += g.spec.net_mb_s * c as f64;
        coord += g.spec.coord_s_per_machine * c as f64;
    }
    super::shuffle_overhead_s(profile.shuffle_mb, nf, agg_net, coord)
}

fn mark_evicted(
    location: &mut [Vec<Option<usize>>],
    profile: &WorkloadProfile,
    key: PartitionKey,
) {
    for (di, ds) in profile.cached.iter().enumerate() {
        if ds.id == key.dataset {
            if let Some(slot) = location[di].get_mut(key.index) {
                *slot = None;
            }
        }
    }
}

fn task_duration(
    base_s: f64,
    profile: &WorkloadProfile,
    cached_read: bool,
    rng: &mut Rng,
    compute: &mut Option<&mut dyn TaskCompute>,
) -> f64 {
    if let Some(c) = compute.as_deref_mut() {
        if let Some(measured) = c.run_task(profile, cached_read) {
            return measured;
        }
    }
    rng.lognormal(base_s, profile.task_time_sigma).max(1e-6)
}

/// Deterministic closed-form runtime anchor for the undisturbed run (no
/// noise, no disturbances): wave scheduling over the fleet's slots with a
/// capacity-based residency guess. Scenarios use it to place "a third of
/// the way in" style disturbances without a pilot run; it is an anchor,
/// not a prediction.
pub fn horizon_s(profile: &WorkloadProfile, fleet: &FleetSpec) -> f64 {
    let parts = profile.parallelism.max(1) as f64;
    let n = fleet.machines().max(1) as f64;
    let slots = fleet.slots().max(1) as f64;
    let waves = (parts / slots).ceil();
    let disk: f64 = fleet
        .groups
        .iter()
        .map(|g| g.instance.spec.disk_mb_s * g.count as f64)
        .sum::<f64>()
        / n;
    let input_pp = profile.input_mb / parts;
    let t_load = input_pp / disk + input_pp * profile.compute_s_per_mb + profile.task_overhead_s;

    let capacity: f64 = fleet
        .groups
        .iter()
        .map(|g| g.instance.spec.unified_mb() * g.count as f64)
        .sum();
    let cached_total: f64 = profile.cached.iter().map(|d| d.true_total_mb).sum();
    let resident = if cached_total <= 0.0 { 1.0 } else { (capacity / cached_total).min(1.0) };
    let cached_pp = cached_total / parts;
    let t_cached =
        cached_pp * profile.compute_s_per_mb / profile.cached_speedup + profile.task_overhead_s;
    let t_recompute = input_pp / disk
        + input_pp * profile.compute_s_per_mb * profile.recompute_factor
        + profile.task_overhead_s;
    let t_task = resident * t_cached + (1.0 - resident) * t_recompute;

    let agg_net: f64 = fleet
        .groups
        .iter()
        .map(|g| g.instance.spec.net_mb_s * g.count as f64)
        .sum();
    let coord: f64 = fleet
        .groups
        .iter()
        .map(|g| g.instance.spec.coord_s_per_machine * g.count as f64)
        .sum();
    let per_job = if n <= 1.0 {
        profile.serial_s
    } else {
        profile.serial_s + super::shuffle_overhead_s(profile.shuffle_mb, n, agg_net, coord)
    };

    profile.sample_prep_s
        + waves * t_load
        + per_job
        + profile.iterations as f64 * (waves * t_task + per_job)
}

// ---------------------------------------------------------------------
// disturbance application
// ---------------------------------------------------------------------

/// A machine leaves at `at_s`: close its uptime segment, drop its cached
/// store (the `memory` layer releases everything at once), clear partition
/// locations, and rewind its in-flight journal entries back into the job's
/// work queue. Returns whether any state changed (`false` for a machine
/// that is already gone), so the caller can skip rescanning the frontier.
#[allow(clippy::too_many_arguments)]
fn lose_machine(
    mi: usize,
    at_s: f64,
    machines: &mut [MachineState],
    location: &mut [Vec<Option<usize>>],
    journal: &mut Vec<JournalEntry>,
    pending: &mut VecDeque<usize>,
    not_before: &mut [f64],
) -> bool {
    if !machines[mi].alive {
        return false;
    }
    // a loss cannot predate the machine's current uptime segment
    let at_s = at_s.max(machines[mi].up_from_s);
    let cached_mb_lost: Mb = {
        let m = &mut machines[mi];
        m.alive = false;
        m.segments.push((m.up_from_s, at_s));
        let lost = m.mem.cached_mb();
        let _ = m.mem.release_all();
        lost
    };
    for ds in location.iter_mut() {
        for slot in ds.iter_mut() {
            if *slot == Some(mi) {
                *slot = None;
            }
        }
    }
    let mut inflight = 0usize;
    let mut kept = Vec::with_capacity(journal.len());
    for entry in journal.drain(..) {
        match entry {
            JournalEntry::Task {
                part,
                machine,
                end_s,
                iteration,
                evictions: entry_evictions,
                ..
            } if machine == mi && end_s > at_s => {
                inflight += 1;
                let m = &mut machines[mi];
                m.tasks_run -= 1;
                if iteration {
                    m.iter_tasks -= 1;
                }
                m.evictions -= entry_evictions;
                // the retry cannot start before the loss that caused it
                not_before[part] = at_s;
                pending.push_back(part);
            }
            other => kept.push(other),
        }
    }
    *journal = kept;
    journal.push(JournalEntry::Marker(Event::MachineLost {
        machine: mi,
        time_s: at_s,
        cached_mb_lost,
        inflight_tasks: inflight,
    }));
    true
}

/// Apply one queued event. Returns whether any scheduling-visible state
/// changed: `false` for no-op events (a preempt of an out-of-range or
/// already-dead machine, a slowdown on a dead machine, a degenerate
/// scale-out), which lets the dispatch loops keep their computed frontier
/// slot instead of rescanning every machine.
#[allow(clippy::too_many_arguments)]
fn apply_item(
    item: QueueItem,
    machines: &mut Vec<MachineState>,
    groups: &mut Vec<InstanceType>,
    profile: &WorkloadProfile,
    location: &mut [Vec<Option<usize>>],
    journal: &mut Vec<JournalEntry>,
    pending: &mut VecDeque<usize>,
    not_before: &mut [f64],
    queue: &mut EventQueue,
    policy: EvictionPolicy,
    exec_pm: Mb,
    now: f64,
) -> bool {
    // a join can only take effect at the scheduling frontier: a machine
    // (re)appearing during the inter-job serial window must not run tasks
    // of the next job before that job starts
    let join_s = item.at_s.max(now);
    match item.kind {
        QueuedKind::Disturb(DisturbanceKind::Preempt { machine }) => {
            machine < machines.len()
                && lose_machine(
                    machine, item.at_s, machines, location, journal, pending, not_before,
                )
        }
        QueuedKind::Disturb(DisturbanceKind::Fail { machine, restart_delay_s }) => {
            if machine < machines.len() && machines[machine].alive {
                lose_machine(machine, item.at_s, machines, location, journal, pending, not_before);
                queue.push(item.at_s + restart_delay_s, QueuedKind::Rejoin { machine });
                true
            } else {
                false
            }
        }
        QueuedKind::Disturb(DisturbanceKind::Slowdown { machine, factor, duration_s }) => {
            match machines.get_mut(machine) {
                Some(m) if m.alive => {
                    m.slow_factor = factor;
                    m.slow_from = item.at_s;
                    m.slow_until = item.at_s + duration_s;
                    true
                }
                _ => false,
            }
        }
        QueuedKind::Disturb(DisturbanceKind::ScaleOut { instance, count }) => {
            // degenerate requests are ignored, not panicked on — and a
            // zero-count scale-out must be rejected *before* mutating
            // `groups`: the old `count.max(1)` validation let `count == 0`
            // through, pushing an empty `InstanceGroup` into the group
            // table (and its type into every later overhead aggregation)
            if count == 0 || FleetSpec::homogeneous(instance.clone(), count).is_err() {
                return false;
            }
            let group = groups.len();
            groups.push(instance.clone());
            for _ in 0..count {
                let idx = machines.len();
                let mut m = MachineState::new(&instance, group, policy, join_s);
                if exec_pm > 0.0 {
                    m.mem.claim_execution(exec_pm);
                }
                machines.push(m);
                journal.push(JournalEntry::Marker(Event::MachineJoined {
                    machine: idx,
                    time_s: join_s,
                }));
            }
            true
        }
        QueuedKind::Disturb(DisturbanceKind::Pressure { machine, claim_mb }) => {
            match machines.get_mut(machine) {
                Some(m) if m.alive && claim_mb > 0.0 => {
                    m.pressure_mb = claim_mb;
                    // the squeeze takes effect immediately: re-claim the
                    // current execution share plus the co-tenant pressure,
                    // evicting whatever no longer fits the shrunk storage
                    // region (journaled so a later rewind stays coherent)
                    m.mem.claim_execution(exec_pm + claim_mb);
                    for key in m.mem.drain_evicted() {
                        m.evictions += 1;
                        journal.push(JournalEntry::Marker(Event::Eviction { machine }));
                        mark_evicted(location, profile, key);
                    }
                    // even with nothing evicted the claim shifts every
                    // later task's cache admission, so this always counts
                    // as a state change
                    true
                }
                _ => false,
            }
        }
        QueuedKind::Rejoin { machine } => {
            let m = &mut machines[machine];
            m.alive = true;
            m.up_from_s = join_s;
            m.mem = UnifiedMemory::new(m.spec.unified_mb(), m.spec.storage_floor_mb(), policy);
            if exec_pm + m.pressure_mb > 0.0 {
                // a restarted machine rejoins into the same contention
                // environment it left: the co-tenant pressure persists
                m.mem.claim_execution(exec_pm + m.pressure_mb);
            }
            for s in &mut m.slots {
                *s = join_s;
            }
            m.slow_factor = 1.0;
            m.slow_from = f64::INFINITY;
            m.slow_until = f64::NEG_INFINITY;
            journal.push(JournalEntry::Marker(Event::MachineJoined {
                machine,
                time_s: join_s,
            }));
            true
        }
    }
}

// ---------------------------------------------------------------------
// the engine run
// ---------------------------------------------------------------------

/// Simulate one application run on `fleet` under `scenario`.
///
/// With [`super::scenario::NoDisturbances`] this produces the exact event
/// log the legacy serial simulator produced (the legacy `simulate()` is a
/// wrapper over this function).
pub fn run(
    profile: &WorkloadProfile,
    fleet: &FleetSpec,
    scenario: &dyn Scenario,
    opts: SimOptions<'_>,
) -> Result<EngineResult, SimError> {
    fleet.validate()?;
    scenario.validate()?;
    let policy = opts.policy;
    let mut rng = Rng::new(opts.seed ^ 0x5117_c0de);
    let mut compute = opts.compute;
    let detailed = opts.detailed_log;

    let mut groups: Vec<InstanceType> = fleet.groups.iter().map(|g| g.instance.clone()).collect();
    let mut machines: Vec<MachineState> = Vec::with_capacity(fleet.machines());
    for (gi, g) in fleet.groups.iter().enumerate() {
        for _ in 0..g.count {
            machines.push(MachineState::new(&g.instance, gi, policy, 0.0));
        }
    }
    let n0 = machines.len();

    let mut log = EventLog::new();
    log.push(Event::AppStart {
        app: profile.name.clone(),
        machines: n0,
        data_scale: profile.scale,
    });

    let mut queue = EventQueue::new();
    let horizon = horizon_s(profile, fleet);
    for d in scenario.schedule(&ScenarioCtx { fleet, profile, horizon_s: horizon }) {
        // NaN/infinite deadlines would sort after every finite time and
        // silently starve the queue (the run would simply never see the
        // disturbance, or hang fast-forwarding to it) — reject them as a
        // typed error at intake instead
        if !d.at_s.is_finite() {
            return Err(SimError::NonFiniteEventTime {
                scenario: scenario.name().to_string(),
                at_s: d.at_s,
            });
        }
        if let DisturbanceKind::Fail { restart_delay_s, .. } = d.kind {
            // the restart schedules a second queue push at `at_s + delay`
            if !restart_delay_s.is_finite() || !(d.at_s + restart_delay_s).is_finite() {
                return Err(SimError::NonFiniteEventTime {
                    scenario: scenario.name().to_string(),
                    at_s: d.at_s + restart_delay_s,
                });
            }
        }
        queue.push(d.at_s, QueuedKind::Disturb(d.kind));
    }

    // Block-s sample preparation happens before the app starts.
    let mut now = profile.sample_prep_s;
    for m in &mut machines {
        for s in &mut m.slots {
            *s = now;
        }
    }

    let parts = profile.parallelism.max(1);
    // partition -> machine currently caching it (per dataset)
    let mut location: Vec<Vec<Option<usize>>> =
        profile.cached.iter().map(|_| vec![None; parts]).collect();
    // per-machine execution share of the current iteration job (0 before
    // job 1; rejoining/scaling machines claim it on arrival)
    let mut exec_pm: Mb = 0.0;
    // earliest restart time per partition within the current job: a task
    // rewound by a machine loss at time t must not re-run before t, even
    // on a survivor whose slot idled earlier (causality of the retry)
    let mut not_before: Vec<f64> = vec![0.0; parts];
    // work list, journal and the task-event arena are allocated once and
    // recycled across every job of the run: the journal drains at each
    // barrier and the arena clears with it, so steady state allocates
    // nothing per task
    let mut pending: VecDeque<usize> = VecDeque::with_capacity(parts);
    let mut journal: Vec<JournalEntry> = Vec::new();
    let mut arena: Vec<Event> = Vec::new();

    // ---------------------------------------------------------- job 0 ----
    // Materialize: read input, compute, cache each partition where it ran.
    let input_per_task = profile.input_mb / parts as f64;
    {
        pending.extend(0..parts);
        loop {
            while let Some(p) = pending.pop_front() {
                loop {
                    let Some((mi, si)) = earliest_slot(&machines) else {
                        // every machine is down; fast-forward to the next
                        // queued lifecycle event — a restart or scale-out
                        // may revive the fleet before this is fatal
                        match queue.pop_due(f64::INFINITY) {
                            Some(item) => {
                                apply_item(
                                    item,
                                    &mut machines,
                                    &mut groups,
                                    profile,
                                    &mut location,
                                    &mut journal,
                                    &mut pending,
                                    &mut not_before,
                                    &mut queue,
                                    policy,
                                    exec_pm,
                                    now,
                                );
                                continue;
                            }
                            None => return Err(SimError::AllMachinesLost { at_s: now }),
                        }
                    };
                    let start = machines[mi].slots[si].max(not_before[p]);
                    // drain due no-op events without rescanning the
                    // frontier — the slot stays valid until one changes
                    // scheduling-visible state
                    let mut changed = false;
                    while !changed {
                        let Some(item) = queue.pop_due(start) else { break };
                        changed = apply_item(
                            item,
                            &mut machines,
                            &mut groups,
                            profile,
                            &mut location,
                            &mut journal,
                            &mut pending,
                            &mut not_before,
                            &mut queue,
                            policy,
                            exec_pm,
                            now,
                        );
                    }
                    if changed {
                        continue;
                    }
                    let base = input_per_task / machines[mi].spec.disk_mb_s
                        + input_per_task * profile.compute_s_per_mb
                        + profile.task_overhead_s;
                    let dur = task_duration(base, profile, false, &mut rng, &mut compute)
                        * machines[mi].slowdown_at(start);
                    machines[mi].slots[si] = start + dur;
                    machines[mi].tasks_run += 1;
                    let events_from = arena.len();
                    let mut entry_evictions = 0usize;
                    if detailed {
                        arena.push(Event::TaskEnd {
                            stage: 0,
                            task: p,
                            machine: mi,
                            duration_s: dur,
                            cached_read: false,
                        });
                    }
                    for (di, ds) in profile.cached.iter().enumerate() {
                        let true_part = ds.true_total_mb / parts as f64;
                        let measured_part = ds.measured_total_mb / parts as f64;
                        let stored = machines[mi].mem.insert(
                            PartitionKey { dataset: ds.id, index: p },
                            true_part,
                            profile.iterations + 1,
                            1,
                        );
                        for key in machines[mi].mem.drain_evicted() {
                            machines[mi].evictions += 1;
                            entry_evictions += 1;
                            arena.push(Event::Eviction { machine: mi });
                            mark_evicted(&mut location, profile, key);
                        }
                        if stored {
                            location[di][p] = Some(mi);
                        }
                        if detailed {
                            arena.push(Event::BlockUpdate {
                                dataset: ds.id,
                                partition: p,
                                size_mb: measured_part,
                                stored,
                            });
                        }
                    }
                    journal.push(JournalEntry::Task {
                        part: p,
                        machine: mi,
                        end_s: start + dur,
                        iteration: false,
                        evictions: entry_evictions,
                        events: events_from..arena.len(),
                    });
                    break;
                }
            }
            let b = barrier(&machines, now);
            let mut changed = false;
            while !changed {
                let Some(item) = queue.pop_due(b) else { break };
                changed = apply_item(
                    item,
                    &mut machines,
                    &mut groups,
                    profile,
                    &mut location,
                    &mut journal,
                    &mut pending,
                    &mut not_before,
                    &mut queue,
                    policy,
                    exec_pm,
                    now,
                );
            }
            if changed {
                continue;
            }
            now = b;
            break;
        }
        flush_journal(&mut log, &mut journal, &mut arena);
    }
    now += profile.serial_s + fleet_overhead_s(profile, &machines, &groups);
    set_all_slots(&mut machines, now);

    let cached_fraction_after_load = if profile.cached.is_empty() {
        0.0
    } else {
        location[0].iter().filter(|l| l.is_some()).count() as f64 / parts as f64
    };

    // Job-boundary snapshot of observed residency, in measured units —
    // the same arithmetic as the aggregate BlockUpdate emitted at the end
    // of a non-detailed run, taken at every barrier for the adaptive loop.
    let snapshot = |location: &[Vec<Option<usize>>], job: usize, at_s: f64| IterationObservation {
        job,
        at_s,
        cached: profile
            .cached
            .iter()
            .enumerate()
            .map(|(di, ds)| {
                let resident = location[di].iter().filter(|l| l.is_some()).count();
                (ds.id, resident, ds.measured_total_mb / parts as f64 * resident as f64)
            })
            .collect(),
    };
    let mut observations: Vec<IterationObservation> =
        Vec::with_capacity(profile.iterations + 1);
    observations.push(snapshot(&location, 0, now));

    // ------------------------------------------------- iteration jobs ----
    for job in 1..=profile.iterations {
        pending.clear();
        pending.extend(0..parts);
        // losses/joins between jobs take effect before the exec claim
        while let Some(item) = queue.pop_due(now) {
            apply_item(
                item,
                &mut machines,
                &mut groups,
                profile,
                &mut location,
                &mut journal,
                &mut pending,
                &mut not_before,
                &mut queue,
                policy,
                exec_pm,
                now,
            );
        }
        flush_journal(&mut log, &mut journal, &mut arena);
        // the between-jobs drain only produces markers (the journal was
        // empty, so nothing could rewind); start the job from a clean
        // work list and retry-floor
        pending.clear();
        pending.extend(0..parts);
        for nb in &mut not_before {
            *nb = 0.0;
        }

        // Every machine may be down transiently (failure awaiting its
        // restart): fast-forward through queued lifecycle events before
        // declaring the fleet dead.
        let mut alive_n = machines.iter().filter(|m| m.alive).count();
        while alive_n == 0 {
            let Some(item) = queue.pop_due(f64::INFINITY) else {
                return Err(SimError::AllMachinesLost { at_s: now });
            };
            apply_item(
                item,
                &mut machines,
                &mut groups,
                profile,
                &mut location,
                &mut journal,
                &mut pending,
                &mut not_before,
                &mut queue,
                policy,
                exec_pm,
                now,
            );
            alive_n = machines.iter().filter(|m| m.alive).count();
        }
        flush_journal(&mut log, &mut journal, &mut arena);

        // Execution memory is claimed at the start of each action; with a
        // thin margin this is what evicts over-cached machines (Fig. 11).
        // Co-tenant pressure (the contention scenario) rides on top of the
        // job's own share — zero everywhere else, so undisturbed claims
        // are bit-identical to the pre-contention engine.
        exec_pm = profile.exec_mem_total_mb / alive_n as f64;
        for (mi, m) in machines.iter_mut().enumerate() {
            if !m.alive {
                continue;
            }
            m.mem.claim_execution(exec_pm + m.pressure_mb);
            for key in m.mem.drain_evicted() {
                m.evictions += 1;
                log.push(Event::Eviction { machine: mi });
                mark_evicted(&mut location, profile, key);
            }
        }

        loop {
            while let Some(p) = pending.pop_front() {
                loop {
                    // a task reads the corresponding partition of every
                    // cached dataset; locality pins it to the machine
                    // caching dataset 0
                    let pinned = profile.cached.first().and_then(|_| location[0][p]);
                    let (mi, si) = match pinned {
                        Some(m) => (m, earliest_slot_on(&machines[m])),
                        None => match earliest_slot(&machines) {
                            Some(s) => s,
                            None => {
                                // all machines down: fast-forward to the
                                // next lifecycle event or give up
                                match queue.pop_due(f64::INFINITY) {
                                    Some(item) => {
                                        apply_item(
                                            item,
                                            &mut machines,
                                            &mut groups,
                                            profile,
                                            &mut location,
                                            &mut journal,
                                            &mut pending,
                                            &mut not_before,
                                            &mut queue,
                                            policy,
                                            exec_pm,
                                            now,
                                        );
                                        continue;
                                    }
                                    None => {
                                        return Err(SimError::AllMachinesLost { at_s: now })
                                    }
                                }
                            }
                        },
                    };
                    let start = machines[mi].slots[si].max(not_before[p]);
                    // as in job 0: only a state-changing event invalidates
                    // the computed slot (or the pinned machine's liveness)
                    let mut changed = false;
                    while !changed {
                        let Some(item) = queue.pop_due(start) else { break };
                        changed = apply_item(
                            item,
                            &mut machines,
                            &mut groups,
                            profile,
                            &mut location,
                            &mut journal,
                            &mut pending,
                            &mut not_before,
                            &mut queue,
                            policy,
                            exec_pm,
                            now,
                        );
                    }
                    if changed {
                        continue;
                    }
                    let cached_read = pinned.is_some();
                    let part_input = profile.input_mb / parts as f64;
                    let base = if cached_read {
                        let part_cached: f64 = profile
                            .cached
                            .iter()
                            .map(|d| d.true_total_mb / parts as f64)
                            .sum();
                        part_cached * profile.compute_s_per_mb / profile.cached_speedup
                            + profile.task_overhead_s
                    } else {
                        // recompute the lineage: re-read input + recompute
                        part_input / machines[mi].spec.disk_mb_s
                            + part_input * profile.compute_s_per_mb * profile.recompute_factor
                            + profile.task_overhead_s
                    };
                    let dur = task_duration(base, profile, cached_read, &mut rng, &mut compute)
                        * machines[mi].slowdown_at(start);
                    machines[mi].slots[si] = start + dur;
                    machines[mi].tasks_run += 1;
                    machines[mi].iter_tasks += 1;
                    let events_from = arena.len();
                    let mut entry_evictions = 0usize;
                    if detailed {
                        arena.push(Event::TaskEnd {
                            stage: job,
                            task: p,
                            machine: mi,
                            duration_s: dur,
                            cached_read,
                        });
                    }
                    if cached_read {
                        for ds in &profile.cached {
                            machines[mi].mem.touch(PartitionKey { dataset: ds.id, index: p });
                        }
                    } else {
                        // Spark re-caches a recomputed partition where it ran
                        for (di, ds) in profile.cached.iter().enumerate() {
                            let true_part = ds.true_total_mb / parts as f64;
                            let stored = machines[mi].mem.insert(
                                PartitionKey { dataset: ds.id, index: p },
                                true_part,
                                profile.iterations - job + 1,
                                1,
                            );
                            for key in machines[mi].mem.drain_evicted() {
                                machines[mi].evictions += 1;
                                entry_evictions += 1;
                                arena.push(Event::Eviction { machine: mi });
                                mark_evicted(&mut location, profile, key);
                            }
                            if stored {
                                location[di][p] = Some(mi);
                            }
                        }
                    }
                    journal.push(JournalEntry::Task {
                        part: p,
                        machine: mi,
                        end_s: start + dur,
                        iteration: true,
                        evictions: entry_evictions,
                        events: events_from..arena.len(),
                    });
                    break;
                }
            }
            let b = barrier(&machines, now);
            let mut changed = false;
            while !changed {
                let Some(item) = queue.pop_due(b) else { break };
                changed = apply_item(
                    item,
                    &mut machines,
                    &mut groups,
                    profile,
                    &mut location,
                    &mut journal,
                    &mut pending,
                    &mut not_before,
                    &mut queue,
                    policy,
                    exec_pm,
                    now,
                );
            }
            if changed {
                continue;
            }
            break;
        }
        flush_journal(&mut log, &mut journal, &mut arena);
        let job_start = now;
        now = barrier(&machines, now);
        now += profile.serial_s + fleet_overhead_s(profile, &machines, &groups);
        set_all_slots(&mut machines, now);
        log.push(Event::JobEnd { job, duration_s: now - job_start });
        observations.push(snapshot(&location, job, now));
    }

    if !detailed {
        // one aggregate BlockUpdate per dataset: currently-resident bytes
        // in measured units (what a listener's final snapshot would show)
        for (di, ds) in profile.cached.iter().enumerate() {
            let resident = location[di].iter().filter(|l| l.is_some()).count();
            let measured_part = ds.measured_total_mb / parts as f64;
            log.push(Event::BlockUpdate {
                dataset: ds.id,
                partition: 0,
                size_mb: measured_part * resident as f64,
                stored: resident > 0,
            });
        }
    }
    for (mi, m) in machines.iter().enumerate() {
        log.push(Event::ExecMemory { machine: mi, peak_mb: m.mem.exec_used_mb() });
    }
    log.push(Event::AppEnd { duration_s: now });

    let mut timeline = FleetTimeline { duration_s: now, entries: Vec::new() };
    for (mi, m) in machines.iter().enumerate() {
        for &(from, to) in &m.segments {
            timeline.entries.push(TimelineEntry {
                machine: mi,
                instance: m.instance.clone(),
                up_from_s: from,
                up_to_s: to,
            });
        }
        if m.alive {
            timeline.entries.push(TimelineEntry {
                machine: mi,
                instance: m.instance.clone(),
                up_from_s: m.up_from_s,
                up_to_s: now,
            });
        }
    }

    let sim = SimResult {
        log,
        iter_tasks_per_machine: machines.iter().map(|m| m.iter_tasks).collect(),
        evictions_per_machine: machines.iter().map(|m| m.evictions).collect(),
        cached_fraction_after_load,
    };
    Ok(EngineResult { sim, timeline, observations })
}

// ---------------------------------------------------------------------
// multi-tenant fleet runs
// ---------------------------------------------------------------------

/// Dataset-id stride separating tenants in the shared store. Tenant `t`'s
/// local dataset `d` lives under global id `t * TENANT_STRIDE + d`, so one
/// [`UnifiedMemory`] per machine arbitrates every tenant's blocks while
/// ownership stays decodable from the key alone (`id / TENANT_STRIDE`).
/// Per-tenant event logs always use *local* ids — each log is the same
/// self-contained listener trace a single-tenant run emits.
const TENANT_STRIDE: usize = 1 << 24;

/// One application sharing the fleet: a display name plus its workload.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub profile: WorkloadProfile,
}

/// How the shared store arbitrates across tenants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetFairness {
    /// One global LRU order: any tenant's insert may evict any other
    /// tenant's coldest block (the Spark default on a shared cluster).
    SharedLru,
    /// Each of the N tenants is guaranteed `R / N` of every machine's
    /// protected storage floor: a foreign insert may only evict a
    /// tenant's blocks while that tenant holds *more* than its floor.
    /// A tenant's own inserts still displace its own older blocks.
    ReservationFloors,
}

/// Per-tenant outcome of a fleet run.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantRunStats {
    pub name: String,
    /// Jobs completed (materialization + iterations).
    pub jobs: usize,
    /// Cache evictions charged to this tenant's blocks (whoever's insert
    /// or claim triggered them).
    pub evictions: usize,
    /// This tenant's cached MB dropped by machine losses.
    pub cached_mb_lost: Mb,
    /// Barrier time of the tenant's last job (its makespan on the shared
    /// fleet, including time spent waiting behind co-tenants).
    pub finish_s: f64,
    /// Fraction of dataset-0 partitions resident after the tenant's
    /// materialization job — the same Fig. 5 metric the single-tenant
    /// [`SimResult`] reports.
    pub cached_fraction_after_load: f64,
}

/// Outcome of [`run_fleet`]: one listener log per tenant (local dataset
/// ids, self-contained), per-tenant stats, and the shared realized
/// timeline the cost layer prices once for everyone.
pub struct FleetRunResult {
    pub logs: Vec<EventLog>,
    pub tenants: Vec<TenantRunStats>,
    pub timeline: FleetTimeline,
    /// Fleet makespan (the last tenant's finish).
    pub duration_s: f64,
}

impl FleetRunResult {
    /// Order-sensitive digest of the whole run: FNV-1a over every
    /// tenant's log bytes plus its stats (f64s by bit pattern) plus the
    /// timeline shape. Two runs agree byte-for-byte iff their
    /// fingerprints match — what the `check_fleet` thread-matrix
    /// invariant compares.
    pub fn fingerprint(&self) -> String {
        fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
            h
        }
        use std::fmt::Write;
        let mut s = String::new();
        for (log, t) in self.logs.iter().zip(&self.tenants) {
            let digest = fnv(0xcbf2_9ce4_8422_2325, log.to_jsonl().as_bytes());
            let _ = write!(
                s,
                "{}|{}|{}|{:x}|{:x}|{:x}|{:016x}#",
                t.name,
                t.jobs,
                t.evictions,
                t.cached_mb_lost.to_bits(),
                t.finish_s.to_bits(),
                t.cached_fraction_after_load.to_bits(),
                digest,
            );
        }
        let _ = write!(s, "{:x}|{}", self.duration_s.to_bits(), self.timeline.entries.len());
        s
    }
}

/// Drop evicted shared-store keys out of every owner's location map and
/// charge the eviction to the owner's stats and log.
fn fleet_drain_evictions(
    mi: usize,
    machines: &mut [MachineState],
    tenants: &[TenantSpec],
    locations: &mut [Vec<Vec<Option<usize>>>],
    stats: &mut [TenantRunStats],
    logs: &mut [EventLog],
) {
    for key in machines[mi].mem.drain_evicted() {
        let owner = key.dataset / TENANT_STRIDE;
        if owner >= tenants.len() {
            continue;
        }
        stats[owner].evictions += 1;
        logs[owner].push(Event::Eviction { machine: mi });
        let local = key.dataset % TENANT_STRIDE;
        for (di, ds) in tenants[owner].profile.cached.iter().enumerate() {
            if ds.id == local {
                if let Some(slot) = locations[owner][di].get_mut(key.index) {
                    *slot = None;
                }
            }
        }
    }
}

/// Insert one block into the shared store under the fleet's fairness
/// policy. `ReservationFloors` guards each co-tenant's `R / N` share:
/// a foreign block is evictable only while its owner sits above the
/// floor, so contention cannot starve a tenant below its reservation.
fn fleet_insert(
    m: &mut MachineState,
    key: PartitionKey,
    size_mb: Mb,
    ref_count: usize,
    tenant: usize,
    n_tenants: usize,
    fairness: FleetFairness,
) -> bool {
    match fairness {
        FleetFairness::SharedLru => m.mem.insert(key, size_mb, ref_count, 1),
        FleetFairness::ReservationFloors => {
            let floor = m.mem.r_mb / n_tenants as f64;
            let mut usage = vec![0.0f64; n_tenants];
            for (d, _parts, mb) in m.mem.dataset_usage() {
                let o = d / TENANT_STRIDE;
                if o < n_tenants {
                    usage[o] += mb;
                }
            }
            m.mem.insert_guarded(key, size_mb, ref_count, 1, &|d| {
                let o = d / TENANT_STRIDE;
                o == tenant || o >= n_tenants || usage[o] > floor
            })
        }
    }
}

/// A machine leaves a multi-tenant fleet at `at_s`: close its uptime
/// segment, release the shared store, and attribute the per-dataset
/// losses ([`crate::memory::DatasetLoss`]) back to their owning tenants —
/// every tenant's log records a [`Event::MachineLost`] carrying *its own*
/// lost bytes, so a tenant whose protected dataset lost blocks is
/// notified even when the loss was triggered by a co-tenant's scenario.
fn fleet_lose(
    mi: usize,
    at_s: f64,
    machines: &mut [MachineState],
    tenants: &[TenantSpec],
    locations: &mut [Vec<Vec<Option<usize>>>],
    stats: &mut [TenantRunStats],
    logs: &mut [EventLog],
) -> bool {
    if !machines[mi].alive {
        return false;
    }
    let at_s = at_s.max(machines[mi].up_from_s);
    let losses = {
        let m = &mut machines[mi];
        m.alive = false;
        m.segments.push((m.up_from_s, at_s));
        m.mem.release_all()
    };
    let mut lost_mb = vec![0.0f64; tenants.len()];
    for l in &losses {
        let owner = l.dataset / TENANT_STRIDE;
        if owner < lost_mb.len() {
            lost_mb[owner] += l.lost_mb;
        }
    }
    for t in 0..tenants.len() {
        for ds in locations[t].iter_mut() {
            for slot in ds.iter_mut() {
                if *slot == Some(mi) {
                    *slot = None;
                }
            }
        }
        stats[t].cached_mb_lost += lost_mb[t];
        logs[t].push(Event::MachineLost {
            machine: mi,
            time_s: at_s,
            cached_mb_lost: lost_mb[t],
            inflight_tasks: 0,
        });
    }
    true
}

/// Apply one queued event to a multi-tenant fleet. Fleet runs drain
/// lifecycle events at job boundaries only (no mid-job rewind — see
/// [`run_fleet`]), so there is no journal: markers go straight to every
/// affected tenant's log. Returns whether scheduling-visible state
/// changed, mirroring [`apply_item`].
#[allow(clippy::too_many_arguments)]
fn fleet_apply(
    item: QueueItem,
    machines: &mut Vec<MachineState>,
    groups: &mut Vec<InstanceType>,
    tenants: &[TenantSpec],
    locations: &mut [Vec<Vec<Option<usize>>>],
    stats: &mut [TenantRunStats],
    logs: &mut [EventLog],
    queue: &mut EventQueue,
    policy: EvictionPolicy,
    now: f64,
) -> bool {
    let join_s = item.at_s.max(now);
    match item.kind {
        QueuedKind::Disturb(DisturbanceKind::Preempt { machine }) => {
            machine < machines.len()
                && fleet_lose(machine, item.at_s, machines, tenants, locations, stats, logs)
        }
        QueuedKind::Disturb(DisturbanceKind::Fail { machine, restart_delay_s }) => {
            if machine < machines.len() && machines[machine].alive {
                fleet_lose(machine, item.at_s, machines, tenants, locations, stats, logs);
                queue.push(item.at_s + restart_delay_s, QueuedKind::Rejoin { machine });
                true
            } else {
                false
            }
        }
        QueuedKind::Disturb(DisturbanceKind::Slowdown { machine, factor, duration_s }) => {
            match machines.get_mut(machine) {
                Some(m) if m.alive => {
                    m.slow_factor = factor;
                    m.slow_from = item.at_s;
                    m.slow_until = item.at_s + duration_s;
                    true
                }
                _ => false,
            }
        }
        QueuedKind::Disturb(DisturbanceKind::ScaleOut { instance, count }) => {
            if count == 0 || FleetSpec::homogeneous(instance.clone(), count).is_err() {
                return false;
            }
            let group = groups.len();
            groups.push(instance.clone());
            for _ in 0..count {
                let idx = machines.len();
                // no execution claim on arrival: the next job's claim
                // loop sizes the running tenant's share over the new
                // alive count
                machines.push(MachineState::new(&instance, group, policy, join_s));
                for log in logs.iter_mut() {
                    log.push(Event::MachineJoined { machine: idx, time_s: join_s });
                }
            }
            true
        }
        QueuedKind::Disturb(DisturbanceKind::Pressure { machine, claim_mb }) => {
            if machine >= machines.len() || !machines[machine].alive || claim_mb <= 0.0 {
                return false;
            }
            // ride on top of whatever the running tenant currently
            // claims; evictions hit whichever tenants lose blocks
            let cur = machines[machine].mem.exec_used_mb();
            machines[machine].pressure_mb = claim_mb;
            machines[machine].mem.claim_execution(cur + claim_mb);
            fleet_drain_evictions(machine, machines, tenants, locations, stats, logs);
            true
        }
        QueuedKind::Rejoin { machine } => {
            let m = &mut machines[machine];
            m.alive = true;
            m.up_from_s = join_s;
            m.mem = UnifiedMemory::new(m.spec.unified_mb(), m.spec.storage_floor_mb(), policy);
            if m.pressure_mb > 0.0 {
                // the pressure environment persists across a restart
                m.mem.claim_execution(m.pressure_mb);
            }
            for s in &mut m.slots {
                *s = join_s;
            }
            m.slow_factor = 1.0;
            m.slow_from = f64::INFINITY;
            m.slow_until = f64::NEG_INFINITY;
            for log in logs.iter_mut() {
                log.push(Event::MachineJoined { machine, time_s: join_s });
            }
            true
        }
    }
}

/// Interleave N tenants' job streams on one shared fleet.
///
/// Jobs are the interleaving grain: tenants' jobs serialize on the fleet
/// in FIFO order of readiness, merged by the key
/// `(ready_s, tenant, seq)` — earliest-ready job first, ties broken by
/// tenant index, then by the tenant's own job order. The key is a total
/// order over every remaining job (`total_cmp` on the time, integers
/// after), and nothing in the loop reads wall-clock or address-order
/// state, so replays are byte-deterministic: same tenants + fleet +
/// scenario + seed ⇒ identical logs, on any thread count.
///
/// Differences from the single-tenant [`run`], by construction:
///
/// * **one tenant delegates** — `run_fleet(&[t], ..)` calls [`run`] and
///   wraps its result, so the degenerate fleet is byte-identical to the
///   single-tenant engine (the `check_fleet` invariant);
/// * **job-boundary disturbances** — lifecycle events apply between
///   jobs, not between tasks, so there is no in-flight rewind. Coarser
///   than [`run`], but time-consistent at every barrier the tenants
///   actually share;
/// * **shared store** — every machine's [`UnifiedMemory`] holds all
///   tenants' blocks under [`TENANT_STRIDE`]d keys, arbitrated by the
///   [`FleetFairness`] knob; evictions and machine-loss bytes are
///   attributed to the owning tenant;
/// * **no `ExecMemory` events** — the per-machine execution peak is a
///   fleet-wide quantity that belongs to no single tenant's log.
///
/// The scenario is scheduled once against the *summed* horizon of all
/// tenants (jobs serialize, so the run is roughly the tenants' horizons
/// laid end to end); profile-derived scenarios see tenant 0's profile.
pub fn run_fleet(
    tenants: &[TenantSpec],
    fleet: &FleetSpec,
    scenario: &dyn Scenario,
    fairness: FleetFairness,
    opts: SimOptions<'_>,
) -> Result<FleetRunResult, SimError> {
    let Some(first) = tenants.first() else {
        return Err(SimError::NoTenants);
    };
    if tenants.len() == 1 {
        // degenerate fleet: exactly the single-tenant engine (fairness
        // is moot with one tenant)
        let res = run(&first.profile, fleet, scenario, opts)?;
        let evictions = res.sim.evictions_per_machine.iter().sum();
        let cached_mb_lost = res
            .sim
            .log
            .events
            .iter()
            .map(|e| match e {
                Event::MachineLost { cached_mb_lost, .. } => *cached_mb_lost,
                _ => 0.0,
            })
            .sum();
        let stats = TenantRunStats {
            name: first.name.clone(),
            jobs: first.profile.iterations + 1,
            evictions,
            cached_mb_lost,
            finish_s: res.timeline.duration_s,
            cached_fraction_after_load: res.sim.cached_fraction_after_load,
        };
        return Ok(FleetRunResult {
            duration_s: res.timeline.duration_s,
            logs: vec![res.sim.log],
            tenants: vec![stats],
            timeline: res.timeline,
        });
    }

    fleet.validate()?;
    scenario.validate()?;
    debug_assert!(
        tenants.iter().all(|t| t.profile.cached.iter().all(|d| d.id < TENANT_STRIDE)),
        "dataset ids must fit below the tenant stride"
    );
    let n = tenants.len();
    let policy = opts.policy;
    let mut rng = Rng::new(opts.seed ^ 0xf1ee_7c0d);
    let mut compute = opts.compute;
    let detailed = opts.detailed_log;

    let mut groups: Vec<InstanceType> = fleet.groups.iter().map(|g| g.instance.clone()).collect();
    let mut machines: Vec<MachineState> = Vec::with_capacity(fleet.machines());
    for (gi, g) in fleet.groups.iter().enumerate() {
        for _ in 0..g.count {
            machines.push(MachineState::new(&g.instance, gi, policy, 0.0));
        }
    }
    let n0 = machines.len();

    let mut logs: Vec<EventLog> = tenants
        .iter()
        .map(|t| {
            let mut log = EventLog::new();
            log.push(Event::AppStart {
                app: t.profile.name.clone(),
                machines: n0,
                data_scale: t.profile.scale,
            });
            log
        })
        .collect();
    let mut stats: Vec<TenantRunStats> = tenants
        .iter()
        .map(|t| TenantRunStats {
            name: t.name.clone(),
            jobs: 0,
            evictions: 0,
            cached_mb_lost: 0.0,
            finish_s: 0.0,
            cached_fraction_after_load: 0.0,
        })
        .collect();

    let mut queue = EventQueue::new();
    let horizon: f64 = tenants.iter().map(|t| horizon_s(&t.profile, fleet)).sum();
    for d in scenario.schedule(&ScenarioCtx { fleet, profile: &first.profile, horizon_s: horizon })
    {
        if !d.at_s.is_finite() {
            return Err(SimError::NonFiniteEventTime {
                scenario: scenario.name().to_string(),
                at_s: d.at_s,
            });
        }
        if let DisturbanceKind::Fail { restart_delay_s, .. } = d.kind {
            if !restart_delay_s.is_finite() || !(d.at_s + restart_delay_s).is_finite() {
                return Err(SimError::NonFiniteEventTime {
                    scenario: scenario.name().to_string(),
                    at_s: d.at_s + restart_delay_s,
                });
            }
        }
        queue.push(d.at_s, QueuedKind::Disturb(d.kind));
    }

    // per-tenant partition locations, local dataset order as in `run`
    let mut locations: Vec<Vec<Vec<Option<usize>>>> = tenants
        .iter()
        .map(|t| {
            let parts = t.profile.parallelism.max(1);
            t.profile.cached.iter().map(|_| vec![None; parts]).collect()
        })
        .collect();

    // merged job stream: next job index and earliest start per tenant
    let mut next_job: Vec<usize> = vec![0; n];
    let mut ready_s: Vec<f64> = tenants.iter().map(|t| t.profile.sample_prep_s).collect();
    let mut fleet_now = 0.0f64;

    loop {
        // pick the next job by the merge key (ready_s, tenant, seq)
        let mut pick: Option<(f64, usize, usize)> = None;
        for t in 0..n {
            if next_job[t] > tenants[t].profile.iterations {
                continue;
            }
            let key = (ready_s[t], t, next_job[t]);
            let better = match pick {
                None => true,
                Some(cur) => match key.0.total_cmp(&cur.0) {
                    Ordering::Less => true,
                    Ordering::Equal => (key.1, key.2) < (cur.1, cur.2),
                    Ordering::Greater => false,
                },
            };
            if better {
                pick = Some(key);
            }
        }
        let Some((ready, t, job)) = pick else { break };
        let prof = &tenants[t].profile;
        let parts = prof.parallelism.max(1);
        let job_start = fleet_now.max(ready);

        // job-boundary drain: lifecycle events due by the job's start
        // apply now; with every machine down, fast-forward to a revival
        while let Some(item) = queue.pop_due(job_start) {
            fleet_apply(
                item, &mut machines, &mut groups, tenants, &mut locations, &mut stats,
                &mut logs, &mut queue, policy, job_start,
            );
        }
        while machines.iter().filter(|m| m.alive).count() == 0 {
            let Some(item) = queue.pop_due(f64::INFINITY) else {
                return Err(SimError::AllMachinesLost { at_s: job_start });
            };
            fleet_apply(
                item, &mut machines, &mut groups, tenants, &mut locations, &mut stats,
                &mut logs, &mut queue, policy, job_start,
            );
        }
        let alive_n = machines.iter().filter(|m| m.alive).count();

        // raise (never rewind) slots to the job start: machines revived
        // by the fast-forward join later than `job_start` and keep their
        // later clocks
        for m in machines.iter_mut().filter(|m| m.alive) {
            for s in &mut m.slots {
                *s = s.max(job_start);
            }
        }

        // the running tenant's execution share replaces the previous
        // tenant's (jobs serialize); standing co-tenant pressure rides on
        // top, as in the single-tenant claim
        let exec_pm: Mb =
            if job == 0 { 0.0 } else { prof.exec_mem_total_mb / alive_n as f64 };
        for mi in 0..machines.len() {
            if !machines[mi].alive {
                continue;
            }
            let claim = exec_pm + machines[mi].pressure_mb;
            machines[mi].mem.claim_execution(claim);
            fleet_drain_evictions(mi, &mut machines, tenants, &mut locations, &mut stats, &mut logs);
        }

        if job == 0 {
            // materialize: read input, cache each partition where it ran
            let input_per_task = prof.input_mb / parts as f64;
            for p in 0..parts {
                let (mi, si) = earliest_slot(&machines).expect("a live machine exists");
                let start = machines[mi].slots[si];
                let base = input_per_task / machines[mi].spec.disk_mb_s
                    + input_per_task * prof.compute_s_per_mb
                    + prof.task_overhead_s;
                let dur = task_duration(base, prof, false, &mut rng, &mut compute)
                    * machines[mi].slowdown_at(start);
                machines[mi].slots[si] = start + dur;
                machines[mi].tasks_run += 1;
                if detailed {
                    logs[t].push(Event::TaskEnd {
                        stage: 0,
                        task: p,
                        machine: mi,
                        duration_s: dur,
                        cached_read: false,
                    });
                }
                for (di, ds) in prof.cached.iter().enumerate() {
                    let true_part = ds.true_total_mb / parts as f64;
                    let measured_part = ds.measured_total_mb / parts as f64;
                    let gkey =
                        PartitionKey { dataset: t * TENANT_STRIDE + ds.id, index: p };
                    let stored = fleet_insert(
                        &mut machines[mi],
                        gkey,
                        true_part,
                        prof.iterations + 1,
                        t,
                        n,
                        fairness,
                    );
                    fleet_drain_evictions(
                        mi, &mut machines, tenants, &mut locations, &mut stats, &mut logs,
                    );
                    if stored {
                        locations[t][di][p] = Some(mi);
                    }
                    if detailed {
                        logs[t].push(Event::BlockUpdate {
                            dataset: ds.id,
                            partition: p,
                            size_mb: measured_part,
                            stored,
                        });
                    }
                }
            }
        } else {
            for p in 0..parts {
                // locality pins the task to the machine caching dataset 0
                let pinned = prof.cached.first().and_then(|_| locations[t][0][p]);
                let (mi, si) = match pinned {
                    Some(m) => (m, earliest_slot_on(&machines[m])),
                    None => earliest_slot(&machines).expect("a live machine exists"),
                };
                let start = machines[mi].slots[si];
                let cached_read = pinned.is_some();
                let part_input = prof.input_mb / parts as f64;
                let base = if cached_read {
                    let part_cached: f64 =
                        prof.cached.iter().map(|d| d.true_total_mb / parts as f64).sum();
                    part_cached * prof.compute_s_per_mb / prof.cached_speedup
                        + prof.task_overhead_s
                } else {
                    part_input / machines[mi].spec.disk_mb_s
                        + part_input * prof.compute_s_per_mb * prof.recompute_factor
                        + prof.task_overhead_s
                };
                let dur = task_duration(base, prof, cached_read, &mut rng, &mut compute)
                    * machines[mi].slowdown_at(start);
                machines[mi].slots[si] = start + dur;
                machines[mi].tasks_run += 1;
                machines[mi].iter_tasks += 1;
                if detailed {
                    logs[t].push(Event::TaskEnd {
                        stage: job,
                        task: p,
                        machine: mi,
                        duration_s: dur,
                        cached_read,
                    });
                }
                if cached_read {
                    for ds in &prof.cached {
                        machines[mi]
                            .mem
                            .touch(PartitionKey { dataset: t * TENANT_STRIDE + ds.id, index: p });
                    }
                } else {
                    // re-cache the recomputed partition where it ran
                    for (di, ds) in prof.cached.iter().enumerate() {
                        let true_part = ds.true_total_mb / parts as f64;
                        let gkey =
                            PartitionKey { dataset: t * TENANT_STRIDE + ds.id, index: p };
                        let stored = fleet_insert(
                            &mut machines[mi],
                            gkey,
                            true_part,
                            prof.iterations - job + 1,
                            t,
                            n,
                            fairness,
                        );
                        fleet_drain_evictions(
                            mi, &mut machines, tenants, &mut locations, &mut stats, &mut logs,
                        );
                        if stored {
                            locations[t][di][p] = Some(mi);
                        }
                    }
                }
            }
        }

        let b = barrier(&machines, job_start);
        let end = b + prof.serial_s + fleet_overhead_s(prof, &machines, &groups);
        if job == 0 {
            stats[t].cached_fraction_after_load = if prof.cached.is_empty() {
                0.0
            } else {
                locations[t][0].iter().filter(|l| l.is_some()).count() as f64 / parts as f64
            };
        } else {
            logs[t].push(Event::JobEnd { job, duration_s: end - job_start });
        }
        stats[t].jobs += 1;
        stats[t].finish_s = end;
        next_job[t] += 1;
        ready_s[t] = end;
        fleet_now = end;
    }

    // per-tenant epilogue: final aggregate residency for non-detailed
    // runs, then AppEnd at the tenant's own finish time
    for t in 0..n {
        let prof = &tenants[t].profile;
        let parts = prof.parallelism.max(1);
        if !detailed {
            for (di, ds) in prof.cached.iter().enumerate() {
                let resident = locations[t][di].iter().filter(|l| l.is_some()).count();
                let measured_part = ds.measured_total_mb / parts as f64;
                logs[t].push(Event::BlockUpdate {
                    dataset: ds.id,
                    partition: 0,
                    size_mb: measured_part * resident as f64,
                    stored: resident > 0,
                });
            }
        }
        logs[t].push(Event::AppEnd { duration_s: stats[t].finish_s });
    }

    let mut timeline = FleetTimeline { duration_s: fleet_now, entries: Vec::new() };
    for (mi, m) in machines.iter().enumerate() {
        for &(from, to) in &m.segments {
            timeline.entries.push(TimelineEntry {
                machine: mi,
                instance: m.instance.clone(),
                up_from_s: from,
                up_to_s: to,
            });
        }
        if m.alive {
            timeline.entries.push(TimelineEntry {
                machine: mi,
                instance: m.instance.clone(),
                up_from_s: m.up_from_s,
                up_to_s: fleet_now,
            });
        }
    }

    Ok(FleetRunResult { duration_s: fleet_now, logs, tenants: stats, timeline })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunSummary;
    use crate::sim::scenario::{
        FailureRestart, NoDisturbances, SpotPreemption, StepAutoscale, StragglerSlowdown,
    };
    use crate::sim::{CachedData, ClusterSpec, InstanceCatalog};

    fn toy_profile(cached_mb: f64, iters: usize, parallelism: usize) -> WorkloadProfile {
        WorkloadProfile {
            name: "toy".into(),
            scale: 1000.0,
            input_mb: 1000.0,
            parallelism,
            cached: vec![CachedData {
                id: 0,
                true_total_mb: cached_mb,
                measured_total_mb: cached_mb,
            }],
            iterations: iters,
            compute_s_per_mb: 0.01,
            cached_speedup: 97.0,
            recompute_factor: 1.0,
            serial_s: 1.0,
            shuffle_mb: 100.0,
            exec_mem_total_mb: 500.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.1,
            sample_prep_s: 0.0,
        }
    }

    fn worker_fleet(n: usize) -> FleetSpec {
        FleetSpec::homogeneous(InstanceType::paper_worker(), n).unwrap()
    }

    fn opts(seed: u64) -> SimOptions<'static> {
        SimOptions { seed, ..Default::default() }
    }

    #[test]
    fn engine_none_matches_legacy_wrapper() {
        let p = toy_profile(2000.0, 5, 32);
        let via_engine = run(&p, &worker_fleet(3), &NoDisturbances, opts(7)).unwrap().sim;
        let via_wrapper = crate::sim::simulate(&p, &ClusterSpec::workers(3), opts(7)).unwrap();
        assert_eq!(via_engine.log.to_jsonl(), via_wrapper.log.to_jsonl());
        assert_eq!(via_engine.iter_tasks_per_machine, via_wrapper.iter_tasks_per_machine);
        assert_eq!(via_engine.evictions_per_machine, via_wrapper.evictions_per_machine);
    }

    #[test]
    fn undisturbed_timeline_is_n_by_duration() {
        let p = toy_profile(2000.0, 4, 32);
        let res = run(&p, &worker_fleet(4), &NoDisturbances, opts(1)).unwrap();
        let s = RunSummary::from_log(&res.sim.log);
        assert_eq!(res.timeline.entries.len(), 4);
        assert!((res.timeline.machine_seconds() - 4.0 * s.duration_s).abs() < 1e-9);
        assert_eq!(res.timeline.duration_s, s.duration_s);
    }

    #[test]
    fn heterogeneous_fleet_runs_and_uses_all_machines() {
        let fleet = FleetSpec::new(vec![
            super::super::fleet::InstanceGroup {
                instance: InstanceType::paper_worker(),
                count: 2,
            },
            super::super::fleet::InstanceGroup {
                instance: InstanceType::paper_sample(),
                count: 2,
            },
        ])
        .unwrap();
        let p = toy_profile(3000.0, 4, 64);
        let res = run(&p, &fleet, &NoDisturbances, opts(3)).unwrap();
        let s = RunSummary::from_log(&res.sim.log);
        assert_eq!(s.machines, 4);
        assert_eq!(s.tasks, 64 * 5);
        assert_eq!(res.sim.iter_tasks_per_machine.len(), 4);
        assert!(res.sim.iter_tasks_per_machine.iter().all(|&t| t > 0));
    }

    #[test]
    fn spot_preemption_loses_cache_and_stretches_the_run() {
        // 24 GB cached just fits 4 workers; after the reclaim the 3
        // survivors cannot hold it, so the remaining iterations pay the
        // Area-A recompute penalty — the stretch the naive quote misses
        let mut p = toy_profile(24_000.0, 8, 64);
        p.recompute_factor = 5.0;
        let fleet = worker_fleet(4);
        let base = run(&p, &fleet, &NoDisturbances, opts(5)).unwrap();
        let spot = run(&p, &fleet, &SpotPreemption::default(), opts(5)).unwrap();
        let bs = RunSummary::from_log(&base.sim.log);
        let ss = RunSummary::from_log(&spot.sim.log);
        assert!(ss.machines_lost >= 1, "a machine must be reclaimed");
        assert!(ss.duration_s > bs.duration_s, "losing cache costs time");
        assert!(ss.cached_reads < bs.cached_reads, "survivors recompute");
        let lost_event = spot.sim.log.events.iter().any(|e| {
            matches!(e, Event::MachineLost { cached_mb_lost, .. } if *cached_mb_lost > 0.0)
        });
        assert!(lost_event, "the reclaimed machine held cached partitions");
        // the realized timeline bills the lost machine only until reclaim
        assert!(
            spot.timeline.machine_seconds() < 4.0 * ss.duration_s,
            "lost machine must not bill to the end"
        );
    }

    #[test]
    fn failure_restart_rejoins_with_empty_memory() {
        let p = toy_profile(4000.0, 8, 64);
        let res = run(&p, &worker_fleet(3), &FailureRestart::default(), opts(2)).unwrap();
        let s = RunSummary::from_log(&res.sim.log);
        assert_eq!(s.machines_lost, 1);
        assert_eq!(s.machines_joined, 1);
        // the restarted machine contributes two uptime segments
        let segs_of_0 = res.timeline.entries.iter().filter(|e| e.machine == 0).count();
        assert_eq!(segs_of_0, 2);
    }

    #[test]
    fn failure_on_a_single_machine_fleet_waits_for_the_restart() {
        // all machines transiently down is NOT AllMachinesLost: the engine
        // fast-forwards to the queued restart instead of erroring
        let p = toy_profile(1000.0, 4, 16);
        let res = run(&p, &worker_fleet(1), &FailureRestart::default(), opts(9)).unwrap();
        let s = RunSummary::from_log(&res.sim.log);
        assert_eq!(s.machines_lost, 1);
        assert_eq!(s.machines_joined, 1);
        assert_eq!(s.tasks, 16 * 5, "the run completes after the restart");
    }

    #[test]
    fn straggler_slows_the_run() {
        let mut p = toy_profile(2000.0, 6, 64);
        p.task_time_sigma = 0.0; // isolate the slowdown effect
        let fleet = worker_fleet(2);
        let base = run(&p, &fleet, &NoDisturbances, opts(1)).unwrap();
        let slow = run(
            &p,
            &fleet,
            &StragglerSlowdown { factor: 8.0, ..Default::default() },
            opts(1),
        )
        .unwrap();
        let bt = RunSummary::from_log(&base.sim.log).duration_s;
        let st = RunSummary::from_log(&slow.sim.log).duration_s;
        assert!(st > bt, "straggler {st} vs baseline {bt}");
    }

    #[test]
    fn autoscale_joins_machines_mid_run() {
        let p = toy_profile(2000.0, 8, 64);
        let res = run(&p, &worker_fleet(2), &StepAutoscale::default(), opts(4)).unwrap();
        let s = RunSummary::from_log(&res.sim.log);
        assert_eq!(s.machines, 2, "AppStart reports the initial fleet");
        assert_eq!(s.machines_joined, 2, "the fleet doubled");
        assert_eq!(res.sim.iter_tasks_per_machine.len(), 4);
        // joined machines start their timeline at the scale-out, not at 0
        let joined: Vec<_> = res.timeline.entries.iter().filter(|e| e.machine >= 2).collect();
        assert_eq!(joined.len(), 2);
        assert!(joined.iter().all(|e| e.up_from_s > 0.0));
    }

    #[test]
    fn preempting_every_machine_is_a_typed_error() {
        let p = toy_profile(2000.0, 4, 32);
        struct KillAll;
        impl Scenario for KillAll {
            fn name(&self) -> &'static str {
                "kill-all"
            }
            fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<super::super::scenario::Disturbance> {
                (0..ctx.fleet.machines())
                    .map(|m| super::super::scenario::Disturbance {
                        at_s: 0.0,
                        kind: DisturbanceKind::Preempt { machine: m },
                    })
                    .collect()
            }
        }
        let err = run(&p, &worker_fleet(2), &KillAll, opts(1)).unwrap_err();
        assert!(matches!(err, SimError::AllMachinesLost { .. }));
    }

    #[test]
    fn cloud_shape_spot_run_recovers_cached_reads_after_loss() {
        // preempt 1 of 4 gp.xlarge nodes; survivors can hold the whole
        // dataset, so after a recompute wave the cached reads resume
        let catalog = InstanceCatalog::cloud();
        let gp = catalog.get("gp.xlarge").unwrap().clone();
        let fleet = FleetSpec::homogeneous(gp, 4).unwrap();
        let p = toy_profile(9000.0, 10, 64); // fits on 3 survivors
        let res = run(
            &p,
            &fleet,
            &SpotPreemption { victims: 1, ..Default::default() },
            opts(6),
        )
        .unwrap();
        let s = RunSummary::from_log(&res.sim.log);
        assert_eq!(s.machines_lost, 1);
        // the last iteration job reads everything from cache again
        let last_stage = p.iterations;
        let (mut cached, mut total) = (0usize, 0usize);
        for e in &res.sim.log.events {
            if let Event::TaskEnd { stage, cached_read, .. } = e {
                if *stage == last_stage {
                    total += 1;
                    if *cached_read {
                        cached += 1;
                    }
                }
            }
        }
        assert_eq!(total, 64);
        assert_eq!(cached, 64, "recompute recovery must re-cache on survivors");
    }

    #[test]
    fn horizon_is_positive_and_scales_down_with_slots() {
        let p = toy_profile(2000.0, 10, 256);
        let small = horizon_s(&p, &worker_fleet(2));
        let big = horizon_s(&p, &worker_fleet(8));
        assert!(small > 0.0 && big > 0.0);
        assert!(big < small, "more slots, shorter horizon anchor");
    }

    #[test]
    fn heap_queue_pops_by_time_then_insertion_order() {
        // the heap-backed queue must keep the scanned-Vec semantics: due
        // items come out ordered by (at_s, insertion seq), never by heap
        // internals
        let mut q = EventQueue::new();
        q.push(5.0, QueuedKind::Rejoin { machine: 5 });
        q.push(1.0, QueuedKind::Disturb(DisturbanceKind::Preempt { machine: 0 }));
        q.push(1.0, QueuedKind::Rejoin { machine: 1 });
        q.push(3.0, QueuedKind::Rejoin { machine: 3 });
        assert!(q.pop_due(0.5).is_none(), "nothing due before t=1");
        let a = q.pop_due(10.0).unwrap();
        let b = q.pop_due(10.0).unwrap();
        assert_eq!((a.at_s, b.at_s), (1.0, 1.0));
        assert!(a.seq < b.seq, "ties break by insertion order");
        assert!(matches!(a.kind, QueuedKind::Disturb(_)), "first pushed pops first");
        assert_eq!(q.pop_due(10.0).unwrap().at_s, 3.0);
        assert_eq!(q.pop_due(10.0).unwrap().at_s, 5.0);
        assert!(q.pop_due(f64::INFINITY).is_none());
    }

    #[test]
    fn no_op_disturbances_leave_the_run_byte_identical() {
        // the dispatch loops keep their computed frontier slot across
        // no-op events (out-of-range preempt, slowdown of a machine that
        // does not exist); the run must match an undisturbed one exactly
        struct NoOps;
        impl super::super::scenario::Scenario for NoOps {
            fn name(&self) -> &'static str {
                "no-ops"
            }
            fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<super::super::scenario::Disturbance> {
                let d = |at_s, kind| super::super::scenario::Disturbance { at_s, kind };
                vec![
                    d(0.0, DisturbanceKind::Preempt { machine: 99 }),
                    d(
                        ctx.horizon_s * 0.1,
                        DisturbanceKind::Slowdown { machine: 99, factor: 4.0, duration_s: 10.0 },
                    ),
                    d(ctx.horizon_s * 0.2, DisturbanceKind::Preempt { machine: 99 }),
                ]
            }
        }
        let p = toy_profile(2000.0, 4, 32);
        let disturbed = run(&p, &worker_fleet(3), &NoOps, opts(9)).unwrap();
        let base = run(&p, &worker_fleet(3), &NoDisturbances, opts(9)).unwrap();
        assert_eq!(disturbed.sim.log.to_jsonl(), base.sim.log.to_jsonl());
        assert_eq!(disturbed.timeline, base.timeline);
    }

    #[test]
    fn scale_out_with_zero_count_is_rejected_before_mutating_the_fleet() {
        // regression: the old code validated with `count.max(1)` but
        // spawned with `count`, pushing an empty InstanceGroup into the
        // fleet state and the realized timeline
        struct ZeroScaleOut;
        impl super::super::scenario::Scenario for ZeroScaleOut {
            fn name(&self) -> &'static str {
                "zero-scale-out"
            }
            fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<super::super::scenario::Disturbance> {
                vec![super::super::scenario::Disturbance {
                    at_s: ctx.horizon_s * 0.2,
                    kind: DisturbanceKind::ScaleOut {
                        instance: InstanceType::paper_worker(),
                        count: 0,
                    },
                }]
            }
        }
        let p = toy_profile(2000.0, 4, 32);
        let disturbed = run(&p, &worker_fleet(3), &ZeroScaleOut, opts(9)).unwrap();
        let base = run(&p, &worker_fleet(3), &NoDisturbances, opts(9)).unwrap();
        assert_eq!(disturbed.timeline, base.timeline, "zero-count join must be a no-op");
        assert_eq!(disturbed.sim.log.to_jsonl(), base.sim.log.to_jsonl());
    }

    // ------------------------------------------------ multi-tenant fleet ----

    #[test]
    fn single_tenant_fleet_degenerates_to_run_byte_for_byte() {
        let p = toy_profile(2000.0, 4, 32);
        let tenant = TenantSpec { name: "solo".into(), profile: p.clone() };
        let single = run(&p, &worker_fleet(3), &NoDisturbances, opts(7)).unwrap();
        let fleet = run_fleet(
            &[tenant],
            &worker_fleet(3),
            &NoDisturbances,
            FleetFairness::SharedLru,
            opts(7),
        )
        .unwrap();
        assert_eq!(fleet.logs.len(), 1);
        assert_eq!(fleet.logs[0].to_jsonl(), single.sim.log.to_jsonl());
        assert_eq!(fleet.timeline, single.timeline);
        assert_eq!(fleet.tenants[0].jobs, p.iterations + 1);
        assert_eq!(
            fleet.tenants[0].cached_fraction_after_load,
            single.sim.cached_fraction_after_load
        );
        assert_eq!(
            run_fleet(&[], &worker_fleet(3), &NoDisturbances, FleetFairness::SharedLru, opts(7))
                .unwrap_err(),
            SimError::NoTenants
        );
    }

    #[test]
    fn fleet_interleave_is_deterministic_and_every_log_self_contained() {
        let tenants = vec![
            TenantSpec { name: "a".into(), profile: toy_profile(1500.0, 3, 16) },
            TenantSpec { name: "b".into(), profile: toy_profile(2500.0, 2, 24) },
            TenantSpec { name: "c".into(), profile: toy_profile(500.0, 4, 8) },
        ];
        let fleet = worker_fleet(3);
        let r1 = run_fleet(&tenants, &fleet, &NoDisturbances, FleetFairness::SharedLru, opts(11))
            .unwrap();
        let r2 = run_fleet(&tenants, &fleet, &NoDisturbances, FleetFairness::SharedLru, opts(11))
            .unwrap();
        assert_eq!(r1.fingerprint(), r2.fingerprint(), "same inputs replay byte-identically");
        for (i, log) in r1.logs.iter().enumerate() {
            assert_eq!(log.to_jsonl(), r2.logs[i].to_jsonl());
        }
        assert_eq!(r1.logs.len(), 3);
        for (log, (st, t)) in r1.logs.iter().zip(r1.tenants.iter().zip(&tenants)) {
            // each tenant's log is the same self-contained listener trace
            // a single-tenant run emits: AppStart first, AppEnd last, one
            // JobEnd per iteration
            assert!(matches!(log.events.first(), Some(Event::AppStart { .. })));
            assert!(matches!(
                log.events.last(),
                Some(Event::AppEnd { duration_s }) if *duration_s == st.finish_s
            ));
            let job_ends =
                log.events.iter().filter(|e| matches!(e, Event::JobEnd { .. })).count();
            assert_eq!(job_ends, t.profile.iterations);
            assert_eq!(st.jobs, t.profile.iterations + 1);
        }
        // jobs serialize: the fleet makespan is the last tenant's finish
        let max_finish = r1.tenants.iter().map(|t| t.finish_s).fold(0.0, f64::max);
        assert_eq!(r1.duration_s, max_finish);
        // a different seed perturbs task noise, hence the fingerprint
        let r3 = run_fleet(&tenants, &fleet, &NoDisturbances, FleetFairness::SharedLru, opts(12))
            .unwrap();
        assert_ne!(r1.fingerprint(), r3.fingerprint());
    }

    #[test]
    fn reservation_floors_shield_a_small_tenant_from_a_big_neighbor() {
        // "small" (500 MB/machine) sits well below its R/2 reservation
        // (~1.8 GB/machine on the paper worker); "big" (8 GB/machine
        // demanded) overflows the shared store. Under shared LRU the big
        // tenant's inserts evict the small tenant's older blocks; under
        // reservation floors the shielded predicate refuses those victims
        // and the big tenant's surplus inserts fail instead.
        let tenants = vec![
            TenantSpec { name: "small".into(), profile: toy_profile(1000.0, 2, 8) },
            TenantSpec { name: "big".into(), profile: toy_profile(16000.0, 2, 8) },
        ];
        let fleet = worker_fleet(2);
        let shared =
            run_fleet(&tenants, &fleet, &NoDisturbances, FleetFairness::SharedLru, opts(3))
                .unwrap();
        let floors =
            run_fleet(&tenants, &fleet, &NoDisturbances, FleetFairness::ReservationFloors, opts(3))
                .unwrap();
        assert!(
            shared.tenants[0].evictions > 0,
            "shared LRU lets the big tenant steal the small tenant's blocks"
        );
        assert_eq!(
            floors.tenants[0].evictions, 0,
            "a tenant below its reservation floor is untouchable"
        );
        // no machines were lost in either run
        assert_eq!(shared.tenants[0].cached_mb_lost, 0.0);
        assert_eq!(floors.tenants[0].cached_mb_lost, 0.0);
    }

    #[test]
    fn contention_scenario_squeezes_a_fleet_run_deterministically() {
        use crate::sim::scenario::Contention;
        // 7 GB cached per tenant over 3 workers fits untouched, but the
        // contention squeeze (0.8 of the stealable region) drops the
        // storage limit below residency and forces evictions
        let tenants = vec![
            TenantSpec { name: "a".into(), profile: toy_profile(7000.0, 3, 16) },
            TenantSpec { name: "b".into(), profile: toy_profile(7000.0, 3, 16) },
        ];
        let fleet = worker_fleet(3);
        let base = run_fleet(&tenants, &fleet, &NoDisturbances, FleetFairness::SharedLru, opts(5))
            .unwrap();
        let squeezed =
            run_fleet(&tenants, &fleet, &Contention::default(), FleetFairness::SharedLru, opts(5))
                .unwrap();
        let squeezed2 =
            run_fleet(&tenants, &fleet, &Contention::default(), FleetFairness::SharedLru, opts(5))
                .unwrap();
        assert_eq!(squeezed.fingerprint(), squeezed2.fingerprint());
        let base_ev: usize = base.tenants.iter().map(|t| t.evictions).sum();
        let squeezed_ev: usize = squeezed.tenants.iter().map(|t| t.evictions).sum();
        assert!(squeezed_ev > base_ev, "the squeeze must evict ({squeezed_ev} vs {base_ev})");
        assert_ne!(base.fingerprint(), squeezed.fingerprint());
    }

    #[test]
    fn fleet_machine_loss_attributes_bytes_to_owning_tenants() {
        struct LoseOne;
        impl super::super::scenario::Scenario for LoseOne {
            fn name(&self) -> &'static str {
                "lose-one"
            }
            fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<super::super::scenario::Disturbance> {
                vec![super::super::scenario::Disturbance {
                    at_s: ctx.horizon_s * 0.4,
                    kind: DisturbanceKind::Preempt { machine: 0 },
                }]
            }
        }
        let tenants = vec![
            TenantSpec { name: "a".into(), profile: toy_profile(2000.0, 3, 16) },
            TenantSpec { name: "b".into(), profile: toy_profile(3000.0, 3, 16) },
        ];
        let r = run_fleet(&tenants, &worker_fleet(3), &LoseOne, FleetFairness::SharedLru, opts(6))
            .unwrap();
        // every tenant's log records the loss with its own lost bytes,
        // and the stats agree with the log
        for (log, st) in r.logs.iter().zip(&r.tenants) {
            let logged: f64 = log
                .events
                .iter()
                .map(|e| match e {
                    Event::MachineLost { cached_mb_lost, .. } => *cached_mb_lost,
                    _ => 0.0,
                })
                .sum();
            assert_eq!(logged, st.cached_mb_lost);
        }
        let total_lost: f64 = r.tenants.iter().map(|t| t.cached_mb_lost).sum();
        assert!(total_lost > 0.0, "machine 0 held someone's blocks when it died");
        // the realized timeline closed machine 0's segment early
        let m0_up: f64 = r
            .timeline
            .entries
            .iter()
            .filter(|e| e.machine == 0)
            .map(|e| e.up_to_s - e.up_from_s)
            .sum();
        assert!(m0_up < r.duration_s, "machine 0 billed less than the makespan");
    }
}
