//! Pluggable mid-run disturbance scenarios for the event-driven engine.
//!
//! A [`Scenario`] injects time-stamped [`Disturbance`]s into the engine's
//! event queue before the run starts: spot preemptions (the machine leaves
//! for good, its cached partitions and in-flight tasks are lost and
//! survivors recompute), stragglers (a machine slows down for a window),
//! machine failures with restart (leave + rejoin empty), and step
//! autoscaling (new machines join). Disturbance times are anchored to a
//! deterministic closed-form runtime estimate (`horizon_s` in
//! [`ScenarioCtx`]) so "preempt a third of the way in" lands mid-run for
//! any workload/fleet combination without a pilot run.
//!
//! [`NoDisturbances`] is the no-op scenario: the engine under it is
//! byte-identical to the pre-engine serial simulator (property-tested in
//! `rust/tests/engine_equivalence.rs`), which is what keeps the paper's
//! Table 1/2 and figure reproduction untouched.

use super::cluster::InstanceType;
use super::fleet::{FleetSpec, SimError};
use super::profile::WorkloadProfile;

/// What a scenario sees when scheduling its disturbances.
pub struct ScenarioCtx<'a> {
    pub fleet: &'a FleetSpec,
    pub profile: &'a WorkloadProfile,
    /// Deterministic closed-form runtime anchor for the undisturbed run
    /// (no noise, no disturbances) — computed by `engine::horizon_s`.
    pub horizon_s: f64,
}

/// One scheduled disturbance.
#[derive(Debug, Clone)]
pub struct Disturbance {
    /// Simulated time at which the disturbance takes effect.
    pub at_s: f64,
    pub kind: DisturbanceKind,
}

#[derive(Debug, Clone)]
pub enum DisturbanceKind {
    /// Spot reclaim: the machine leaves permanently. Its cached partitions
    /// and in-flight tasks are lost; survivors recompute.
    Preempt { machine: usize },
    /// Crash + restart: leaves like [`DisturbanceKind::Preempt`], rejoins
    /// with empty memory after the delay.
    Fail { machine: usize, restart_delay_s: f64 },
    /// Straggler: tasks starting on the machine within
    /// `[at_s, at_s + duration_s)` run `factor`× slower.
    Slowdown { machine: usize, factor: f64, duration_s: f64 },
    /// Step autoscaling: `count` new machines of `instance` join.
    ScaleOut { instance: InstanceType, count: usize },
    /// Cross-job contention: co-resident tenants claim `claim_mb` of the
    /// machine's unified region as extra execution pressure from this
    /// point on, squeezing the storage region and evicting cached
    /// partitions down to whatever headroom survives.
    Pressure { machine: usize, claim_mb: f64 },
}

/// A disturbance scenario. Implementations are stateless (`&self`) so one
/// scenario value can drive many engine runs (the planner's risk
/// cross-validation reuses it across seeds and candidate fleets).
/// `Sync` because implementations are stateless and the planner's
/// risk-adjusted validation fans engine runs out across threads, sharing
/// one scenario reference per pick.
pub trait Scenario: Sync {
    fn name(&self) -> &'static str;
    /// The disturbances to inject for this fleet/workload.
    fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance>;
    /// Reject malformed scenario configuration before any disturbance is
    /// scheduled. The engine calls this at intake, next to
    /// `FleetSpec::validate` — a bad `at_frac` becomes a typed error
    /// instead of a silently empty (or nonsensical) schedule. The default
    /// accepts everything, so field-free scenarios need not override it.
    fn validate(&self) -> Result<(), SimError> {
        Ok(())
    }
}

/// Check one horizon-fraction field, the shared intake rule for every
/// `at_frac`-style scenario knob: finite and within `[0, 1]`.
fn validate_frac(scenario: &'static str, at_frac: f64) -> Result<(), SimError> {
    if at_frac.is_finite() && (0.0..=1.0).contains(&at_frac) {
        Ok(())
    } else {
        Err(SimError::BadScheduleFraction { scenario: scenario.to_string(), at_frac })
    }
}

/// The no-op scenario (`--scenario none`): the legacy `simulate()` path.
pub struct NoDisturbances;

/// Convenience constructor mirroring `Scenario::none()` in prose.
pub fn none() -> NoDisturbances {
    NoDisturbances
}

impl Scenario for NoDisturbances {
    fn name(&self) -> &'static str {
        "none"
    }

    fn schedule(&self, _ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
        Vec::new()
    }
}

/// Spot reclaim of the highest-indexed machines, staggered around a
/// fraction of the horizon. Deterministic: same fleet + workload → same
/// preemptions (the task-time noise still varies by seed).
pub struct SpotPreemption {
    /// How many machines to reclaim; 0 = auto (a quarter of the fleet,
    /// at least one). Always capped so at least one machine survives.
    pub victims: usize,
    /// First reclaim as a fraction of the horizon.
    pub at_frac: f64,
    /// Gap between successive reclaims, as a fraction of the horizon.
    pub stagger_frac: f64,
}

impl Default for SpotPreemption {
    fn default() -> Self {
        SpotPreemption { victims: 0, at_frac: 0.35, stagger_frac: 0.08 }
    }
}

impl Scenario for SpotPreemption {
    fn name(&self) -> &'static str {
        "spot"
    }

    fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
        let n = ctx.fleet.machines();
        if n <= 1 {
            return Vec::new(); // never reclaim the only machine
        }
        let auto = (n / 4).max(1);
        let victims = if self.victims > 0 { self.victims } else { auto }.min(n - 1);
        (0..victims)
            .map(|i| Disturbance {
                at_s: ctx.horizon_s * (self.at_frac + self.stagger_frac * i as f64),
                kind: DisturbanceKind::Preempt { machine: n - 1 - i },
            })
            .collect()
    }
}

/// One machine runs `factor`× slower for a window of the run.
pub struct StragglerSlowdown {
    pub machine: usize,
    pub factor: f64,
    pub at_frac: f64,
    pub duration_frac: f64,
}

impl Default for StragglerSlowdown {
    fn default() -> Self {
        StragglerSlowdown { machine: 0, factor: 4.0, at_frac: 0.1, duration_frac: 0.6 }
    }
}

impl Scenario for StragglerSlowdown {
    fn name(&self) -> &'static str {
        "straggler"
    }

    fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
        if self.machine >= ctx.fleet.machines() {
            return Vec::new();
        }
        vec![Disturbance {
            at_s: ctx.horizon_s * self.at_frac,
            kind: DisturbanceKind::Slowdown {
                machine: self.machine,
                factor: self.factor,
                duration_s: ctx.horizon_s * self.duration_frac,
            },
        }]
    }
}

/// One machine crashes and rejoins with empty memory after a delay.
pub struct FailureRestart {
    pub machine: usize,
    pub at_frac: f64,
    /// Restart delay as a fraction of the horizon.
    pub restart_frac: f64,
}

impl Default for FailureRestart {
    fn default() -> Self {
        FailureRestart { machine: 0, at_frac: 0.3, restart_frac: 0.15 }
    }
}

impl Scenario for FailureRestart {
    fn name(&self) -> &'static str {
        "failure"
    }

    fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
        if self.machine >= ctx.fleet.machines() {
            return Vec::new();
        }
        vec![Disturbance {
            at_s: ctx.horizon_s * self.at_frac,
            kind: DisturbanceKind::Fail {
                machine: self.machine,
                restart_delay_s: ctx.horizon_s * self.restart_frac,
            },
        }]
    }
}

/// Step autoscaling: more machines of the fleet's first instance type join
/// partway through the run.
pub struct StepAutoscale {
    pub at_frac: f64,
    /// How many machines join; 0 = auto (double the fleet).
    pub add: usize,
}

impl Default for StepAutoscale {
    fn default() -> Self {
        StepAutoscale { at_frac: 0.3, add: 0 }
    }
}

impl Scenario for StepAutoscale {
    fn name(&self) -> &'static str {
        "autoscale"
    }

    fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
        let count = if self.add > 0 { self.add } else { ctx.fleet.machines() };
        vec![Disturbance {
            at_s: ctx.horizon_s * self.at_frac,
            kind: DisturbanceKind::ScaleOut {
                instance: ctx.fleet.groups[0].instance.clone(),
                count,
            },
        }]
    }

    fn validate(&self) -> Result<(), SimError> {
        validate_frac(self.name(), self.at_frac)
    }
}

/// Feedback-driven autoscaling: scale out **only if** the workload's
/// cached working set actually exceeds the fleet's storage capacity, and
/// size the step from that deficit instead of a fixed count.
///
/// This is the controller half of `blink::adaptive`: the adaptive loop
/// observes a live run, refits the size models, and hands the *observed*
/// deficit to this scenario ([`DeficitController::deficit_mb`]) so the
/// engine realizes the corrective scale-out. Standalone (`--scenario
/// deficit`), it derives the deficit from the profile's measured cached
/// sizes vs. the fleet's §5.4 storage floors — a well-provisioned fleet
/// sees no disturbance at all, which is what separates it from
/// [`StepAutoscale`]'s unconditional step.
///
/// The controller also has a surplus arm: when the deficit is negative
/// (the fleet is oversized for the observed working set) and
/// [`DeficitController::remove`] is set, it retires that many machines —
/// highest index first, always leaving at least one — so an over-fit size
/// prediction stops billing for machines the working set never needed.
pub struct DeficitController {
    /// When the correction lands, as a fraction of the horizon.
    pub at_frac: f64,
    /// How many machines join; 0 = auto-size from the deficit.
    pub add: usize,
    /// Machines to retire when the deficit is a surplus (≤ 0): highest
    /// index first, capped so at least one machine survives. 0 keeps the
    /// historical scale-out-only behavior (a surplus schedules nothing).
    pub remove: usize,
    /// The cache deficit driving the controller (MB). `None` = derive
    /// from the profile's measured cached total minus the fleet's
    /// aggregate storage floor.
    pub deficit_mb: Option<f64>,
    /// Absolute decision time (seconds), overriding `at_frac`. The
    /// adaptive loop sets this to the job barrier its divergence check
    /// fired at — a realized time from the observed run, which the
    /// analytic horizon fraction cannot express.
    pub at_s: Option<f64>,
}

impl Default for DeficitController {
    fn default() -> Self {
        DeficitController { at_frac: 0.3, add: 0, remove: 0, deficit_mb: None, at_s: None }
    }
}

impl DeficitController {
    /// The deficit this controller acts on for a given fleet/workload.
    pub fn deficit_for(&self, ctx: &ScenarioCtx<'_>) -> f64 {
        self.deficit_mb.unwrap_or_else(|| {
            let demand: f64 = ctx.profile.cached.iter().map(|d| d.measured_total_mb).sum();
            let capacity: f64 = ctx
                .fleet
                .groups
                .iter()
                .map(|g| g.count as f64 * g.instance.spec.storage_floor_mb())
                .sum();
            demand - capacity
        })
    }
}

impl Scenario for DeficitController {
    fn name(&self) -> &'static str {
        "deficit"
    }

    fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
        let deficit = self.deficit_for(ctx);
        if !deficit.is_finite() {
            return Vec::new();
        }
        if deficit <= 0.0 {
            // the fleet already fits the working set; the surplus arm
            // retires the configured count, highest index first, never
            // emptying the fleet
            let n = ctx.fleet.machines();
            let count = self.remove.min(n.saturating_sub(1));
            let at_s = self.at_s.unwrap_or(ctx.horizon_s * self.at_frac).max(0.0);
            return (0..count)
                .map(|i| Disturbance {
                    at_s,
                    kind: DisturbanceKind::Preempt { machine: n - 1 - i },
                })
                .collect();
        }
        let count = if self.add > 0 {
            self.add
        } else {
            let per_machine = ctx.fleet.groups[0].instance.spec.storage_floor_mb();
            if per_machine <= 0.0 {
                return Vec::new(); // joining machines would add no storage
            }
            (deficit / per_machine).ceil() as usize
        }
        .max(1);
        vec![Disturbance {
            at_s: self.at_s.unwrap_or(ctx.horizon_s * self.at_frac).max(0.0),
            kind: DisturbanceKind::ScaleOut {
                instance: ctx.fleet.groups[0].instance.clone(),
                count,
            },
        }]
    }

    fn validate(&self) -> Result<(), SimError> {
        validate_frac(self.name(), self.at_frac)?;
        if let Some(d) = self.deficit_mb {
            if d.is_nan() {
                return Err(SimError::BadScheduleFraction {
                    scenario: self.name().to_string(),
                    at_frac: d,
                });
            }
        }
        if let Some(t) = self.at_s {
            if !t.is_finite() {
                return Err(SimError::NonFiniteEventTime {
                    scenario: self.name().to_string(),
                    at_s: t,
                });
            }
        }
        Ok(())
    }
}

/// Cross-job eviction pressure: from a fraction of the horizon on, every
/// machine loses `pressure_frac` of its unified region to co-resident
/// tenants' execution claims. This is the single-tenant stand-in for the
/// contention a shared fleet sees under concurrent load (ROADMAP item 5 /
/// the multi-stage caching paper): the run's own execution share is
/// unchanged, but the storage region shrinks, so a working set that fit
/// comfortably starts thrashing mid-run. A fleet whose storage floor
/// still covers the working set after the squeeze sees no evictions —
/// like [`DeficitController`], the signature is conditional on headroom.
pub struct Contention {
    /// When the co-tenants arrive, as a fraction of the horizon.
    pub at_frac: f64,
    /// Fraction of each machine's unified region (beyond the protected
    /// storage floor `R`) claimed by the co-tenants, in `[0, 1]`.
    pub pressure_frac: f64,
}

impl Default for Contention {
    fn default() -> Self {
        Contention { at_frac: 0.35, pressure_frac: 0.8 }
    }
}

impl Scenario for Contention {
    fn name(&self) -> &'static str {
        "contention"
    }

    fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
        let at_s = ctx.horizon_s * self.at_frac;
        let mut ds = Vec::new();
        let mut machine = 0usize;
        for group in &ctx.fleet.groups {
            // execution can claim at most M - R, so the squeeze is sized
            // against the stealable region, never the protected floor
            let spec = &group.instance.spec;
            let stealable = (spec.unified_mb() - spec.storage_floor_mb()).max(0.0);
            let claim_mb = stealable * self.pressure_frac;
            for _ in 0..group.count {
                ds.push(Disturbance {
                    at_s,
                    kind: DisturbanceKind::Pressure { machine, claim_mb },
                });
                machine += 1;
            }
        }
        ds
    }

    fn validate(&self) -> Result<(), SimError> {
        validate_frac(self.name(), self.at_frac)?;
        if self.pressure_frac.is_finite() && (0.0..=1.0).contains(&self.pressure_frac) {
            Ok(())
        } else {
            Err(SimError::BadScheduleFraction {
                scenario: self.name().to_string(),
                at_frac: self.pressure_frac,
            })
        }
    }
}

/// Every CLI-addressable scenario name, the vocabulary of
/// [`by_name`] — error messages enumerate this so an unknown
/// `--scenario` lists every valid spelling.
pub fn scenario_names() -> [&'static str; 7] {
    ["none", "spot", "straggler", "failure", "autoscale", "deficit", "contention"]
}

/// Look a scenario up by CLI name (`blink simulate --scenario ...`).
pub fn by_name(name: &str) -> Option<Box<dyn Scenario>> {
    match name {
        "none" => Some(Box::new(NoDisturbances)),
        "spot" => Some(Box::new(SpotPreemption::default())),
        "straggler" => Some(Box::new(StragglerSlowdown::default())),
        "failure" => Some(Box::new(FailureRestart::default())),
        "autoscale" => Some(Box::new(StepAutoscale::default())),
        "deficit" => Some(Box::new(DeficitController::default())),
        "contention" => Some(Box::new(Contention::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CachedData, InstanceType};

    fn ctx_fixture(machines: usize) -> (FleetSpec, WorkloadProfile) {
        let fleet = FleetSpec::homogeneous(InstanceType::paper_worker(), machines).unwrap();
        let profile = WorkloadProfile {
            name: "toy".into(),
            scale: 1000.0,
            input_mb: 1000.0,
            parallelism: 32,
            cached: vec![CachedData { id: 0, true_total_mb: 500.0, measured_total_mb: 500.0 }],
            iterations: 5,
            compute_s_per_mb: 0.01,
            cached_speedup: 97.0,
            recompute_factor: 1.0,
            serial_s: 1.0,
            shuffle_mb: 100.0,
            exec_mem_total_mb: 500.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.1,
            sample_prep_s: 0.0,
        };
        (fleet, profile)
    }

    #[test]
    fn lookup_covers_every_cli_name() {
        for name in scenario_names() {
            assert_eq!(by_name(name).unwrap().name(), name);
        }
        assert!(by_name("meteor").is_none());
    }

    #[test]
    fn spot_preempts_a_quarter_and_spares_one_machine() {
        let (fleet, profile) = ctx_fixture(8);
        let ctx = ScenarioCtx { fleet: &fleet, profile: &profile, horizon_s: 100.0 };
        let ds = SpotPreemption::default().schedule(&ctx);
        assert_eq!(ds.len(), 2, "8 machines -> 2 victims");
        for d in &ds {
            assert!(d.at_s > 0.0 && d.at_s < 100.0);
            assert!(matches!(d.kind, DisturbanceKind::Preempt { machine } if machine >= 6));
        }
        // a single machine is never reclaimed
        let (solo, profile) = ctx_fixture(1);
        let ctx = ScenarioCtx { fleet: &solo, profile: &profile, horizon_s: 100.0 };
        assert!(SpotPreemption::default().schedule(&ctx).is_empty());
        // explicit victim counts are capped at n-1
        let (fleet, profile) = ctx_fixture(3);
        let ctx = ScenarioCtx { fleet: &fleet, profile: &profile, horizon_s: 100.0 };
        let many = SpotPreemption { victims: 99, ..Default::default() }.schedule(&ctx);
        assert_eq!(many.len(), 2);
    }

    #[test]
    fn none_schedules_nothing() {
        let (fleet, profile) = ctx_fixture(4);
        let ctx = ScenarioCtx { fleet: &fleet, profile: &profile, horizon_s: 50.0 };
        assert!(none().schedule(&ctx).is_empty());
    }

    #[test]
    fn autoscale_doubles_by_default() {
        let (fleet, profile) = ctx_fixture(4);
        let ctx = ScenarioCtx { fleet: &fleet, profile: &profile, horizon_s: 50.0 };
        let ds = StepAutoscale::default().schedule(&ctx);
        assert_eq!(ds.len(), 1);
        assert!(matches!(ds[0].kind, DisturbanceKind::ScaleOut { count: 4, .. }));
    }

    #[test]
    fn out_of_range_machines_schedule_nothing() {
        let (fleet, profile) = ctx_fixture(2);
        let ctx = ScenarioCtx { fleet: &fleet, profile: &profile, horizon_s: 50.0 };
        assert!(StragglerSlowdown { machine: 9, ..Default::default() }.schedule(&ctx).is_empty());
        assert!(FailureRestart { machine: 9, ..Default::default() }.schedule(&ctx).is_empty());
    }

    #[test]
    fn bad_at_frac_is_a_typed_intake_error() {
        for bad in [f64::NAN, f64::INFINITY, -0.1, 1.5] {
            let e = StepAutoscale { at_frac: bad, add: 1 }.validate().unwrap_err();
            assert!(
                matches!(e, SimError::BadScheduleFraction { ref scenario, .. }
                    if scenario == "autoscale"),
                "{bad}: {e}"
            );
            let e = DeficitController { at_frac: bad, ..Default::default() }
                .validate()
                .unwrap_err();
            assert!(matches!(e, SimError::BadScheduleFraction { .. }), "{bad}: {e}");
        }
        // boundary values are fine, as is every default configuration
        assert!(StepAutoscale { at_frac: 0.0, add: 0 }.validate().is_ok());
        assert!(StepAutoscale { at_frac: 1.0, add: 0 }.validate().is_ok());
        for name in scenario_names() {
            assert!(by_name(name).unwrap().validate().is_ok(), "{name}");
        }
    }

    #[test]
    fn contention_squeezes_every_machine_at_one_instant() {
        let (fleet, profile) = ctx_fixture(4);
        let ctx = ScenarioCtx { fleet: &fleet, profile: &profile, horizon_s: 100.0 };
        let ds = Contention::default().schedule(&ctx);
        assert_eq!(ds.len(), 4, "one pressure claim per machine");
        let spec = &fleet.groups[0].instance.spec;
        let want = (spec.unified_mb() - spec.storage_floor_mb()) * 0.8;
        for (i, d) in ds.iter().enumerate() {
            assert_eq!(d.at_s, 35.0);
            let DisturbanceKind::Pressure { machine, claim_mb } = d.kind.clone() else {
                panic!("expected a pressure claim")
            };
            assert_eq!(machine, i);
            assert!((claim_mb - want).abs() < 1e-9, "{claim_mb} vs {want}");
        }
        // the squeeze never touches the protected floor: a full claim is
        // capped at the stealable region M - R
        let full = Contention { pressure_frac: 1.0, ..Default::default() };
        for d in full.schedule(&ctx) {
            let DisturbanceKind::Pressure { claim_mb, .. } = d.kind else { continue };
            assert!(claim_mb <= spec.unified_mb() - spec.storage_floor_mb() + 1e-9);
        }
        // a bad pressure fraction is a typed intake error
        let e = Contention { pressure_frac: 1.5, ..Default::default() }.validate().unwrap_err();
        assert!(matches!(
            e,
            SimError::BadScheduleFraction { ref scenario, .. } if scenario == "contention"
        ));
    }

    #[test]
    fn deficit_controller_acts_only_under_actual_deficit() {
        // 2 paper workers store far less than 5000 MB of cached data ->
        // the controller must scale out, sized from the deficit
        let (fleet, mut profile) = ctx_fixture(2);
        profile.cached[0].measured_total_mb = 5000.0;
        let ctx = ScenarioCtx { fleet: &fleet, profile: &profile, horizon_s: 100.0 };
        let ctl = DeficitController::default();
        assert!(ctl.deficit_for(&ctx) > 0.0);
        let ds = ctl.schedule(&ctx);
        assert_eq!(ds.len(), 1);
        let DisturbanceKind::ScaleOut { count, .. } = &ds[0].kind else {
            panic!("expected a scale-out")
        };
        let floor = fleet.groups[0].instance.spec.storage_floor_mb();
        assert_eq!(*count, (ctl.deficit_for(&ctx) / floor).ceil() as usize);
        // a fleet that already fits the working set sees no disturbance
        let (big, small_profile) = ctx_fixture(8);
        let ctx = ScenarioCtx { fleet: &big, profile: &small_profile, horizon_s: 100.0 };
        assert!(DeficitController::default().schedule(&ctx).is_empty());
        // an explicit observed deficit overrides the derived one
        let forced = DeficitController { deficit_mb: Some(1.0), add: 3, ..Default::default() };
        let ds = forced.schedule(&ctx);
        assert!(matches!(ds[0].kind, DisturbanceKind::ScaleOut { count: 3, .. }));
        // an absolute decision time overrides the horizon fraction
        let timed = DeficitController { at_s: Some(42.5), ..forced };
        assert_eq!(timed.schedule(&ctx)[0].at_s, 42.5);
        let e = DeficitController { at_s: Some(f64::NAN), ..Default::default() }
            .validate()
            .unwrap_err();
        assert!(matches!(e, SimError::NonFiniteEventTime { .. }));
    }

    #[test]
    fn deficit_controller_surplus_arm_retires_highest_machines_first() {
        let (fleet, profile) = ctx_fixture(8);
        let ctx = ScenarioCtx { fleet: &fleet, profile: &profile, horizon_s: 100.0 };
        // a surplus with remove: 0 keeps the historical no-op
        let idle = DeficitController { deficit_mb: Some(-500.0), ..Default::default() };
        assert!(idle.schedule(&ctx).is_empty());
        // retirements leave from the top of the index range at the
        // decision time
        let surplus = DeficitController {
            deficit_mb: Some(-500.0),
            remove: 3,
            at_s: Some(10.0),
            ..Default::default()
        };
        let ds = surplus.schedule(&ctx);
        assert_eq!(ds.len(), 3);
        for (i, d) in ds.iter().enumerate() {
            assert_eq!(d.at_s, 10.0);
            assert!(
                matches!(d.kind, DisturbanceKind::Preempt { machine } if machine == 7 - i),
                "retirement {i} targets the wrong machine: {:?}",
                d.kind
            );
        }
        // a greedy remove is capped so one machine always survives
        let (two, profile) = ctx_fixture(2);
        let ctx = ScenarioCtx { fleet: &two, profile: &profile, horizon_s: 100.0 };
        let greedy = DeficitController {
            deficit_mb: Some(-1.0),
            remove: 99,
            ..Default::default()
        };
        let ds = greedy.schedule(&ctx);
        assert_eq!(ds.len(), 1, "2-machine fleet keeps a survivor");
        assert!(matches!(ds[0].kind, DisturbanceKind::Preempt { machine: 1 }));
        // the scale-out arm is untouched by the remove knob
        let out = DeficitController { deficit_mb: Some(1.0), add: 2, remove: 5, ..Default::default() };
        let ds = out.schedule(&ctx);
        assert_eq!(ds.len(), 1);
        assert!(matches!(ds[0].kind, DisturbanceKind::ScaleOut { count: 2, .. }));
    }
}
