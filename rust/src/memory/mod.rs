//! Spark's unified memory model (§3.3, Fig. 3) at partition granularity.
//!
//! Per executor, storage (caching) and execution share one unified region
//! `M`; a floor `R` of storage is protected from execution pressure. Cached
//! partitions are evicted when cached bytes exceed `M`, or exceed the
//! storage region left after execution claims its share (execution may
//! steal at most `M - R`). Eviction order is pluggable: LRU (Spark's
//! default), plus the DAG-aware baselines the paper compares against —
//! LRC (lowest remaining reference count) and MRD (largest reference
//! distance, i.e. furthest next use).

use std::collections::{BTreeMap, BTreeSet};

use crate::util::units::Mb;

/// Identifies one cached partition: (dataset id, partition index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PartitionKey {
    pub dataset: usize,
    pub index: usize,
}

/// Eviction policy (paper §2: MRD and LRC "rank cached datasets based on
/// their reference distance and reference count, respectively").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    Lru,
    Lrc,
    Mrd,
}

impl std::fmt::Display for EvictionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvictionPolicy::Lru => write!(f, "LRU"),
            EvictionPolicy::Lrc => write!(f, "LRC"),
            EvictionPolicy::Mrd => write!(f, "MRD"),
        }
    }
}

#[derive(Debug, Clone)]
struct CachedPartition {
    size_mb: Mb,
    last_access: u64,
    /// Remaining references of the owning dataset (LRC key).
    ref_count: usize,
    /// Distance (in upcoming actions) to the next reference (MRD key).
    ref_distance: usize,
}

/// Counters the listener scrapes after a run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemoryStats {
    pub evictions: usize,
    pub failed_caches: usize,
    pub cached_mb: Mb,
    pub peak_cached_mb: Mb,
}

/// What one dataset lost when a machine's store was released wholesale
/// ([`UnifiedMemory::release_all`]): the partitions and bytes that
/// vanished with the machine. The fleet runner groups these by tenant to
/// report cross-tenant cache loss instead of one undifferentiated total.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetLoss {
    pub dataset: usize,
    pub partitions: usize,
    pub lost_mb: Mb,
}

/// One executor's unified memory region.
#[derive(Debug, Clone)]
pub struct UnifiedMemory {
    /// Unified region size (storage + execution), MB.
    pub m_mb: Mb,
    /// Protected storage floor, MB (R <= M).
    pub r_mb: Mb,
    policy: EvictionPolicy,
    exec_used_mb: Mb,
    cached: BTreeMap<PartitionKey, CachedPartition>,
    /// Incremental Σ size of `cached` — the insert/evict hot path must not
    /// rescan the map (profiled: full Table-1 sweep was O(tasks x cached)).
    cached_total_mb: Mb,
    /// Per-dataset (partition count, bytes) for O(#datasets) victim checks.
    per_dataset: BTreeMap<usize, (usize, Mb)>,
    /// Recency index (last_access, key) so the LRU victim is O(log n)
    /// instead of a full scan (hot under area-A cache churn). Entries may
    /// be STALE (touch only bumps the partition's own timestamp); the
    /// victim scan lazily repairs them — this keeps `touch`, the most
    /// frequent operation on the fully-cached fast path, free of index
    /// maintenance.
    lru_index: BTreeSet<(u64, PartitionKey)>,
    clock: u64,
    stats: MemoryStats,
    /// Keys evicted since the last `drain_evicted` call (the simulator
    /// consumes these to mark partitions as needing recomputation).
    evicted_log: Vec<PartitionKey>,
}

impl UnifiedMemory {
    pub fn new(m_mb: Mb, r_mb: Mb, policy: EvictionPolicy) -> Self {
        assert!(m_mb > 0.0 && (0.0..=m_mb).contains(&r_mb), "need 0 <= R <= M");
        UnifiedMemory {
            m_mb,
            r_mb,
            policy,
            exec_used_mb: 0.0,
            cached: BTreeMap::new(),
            cached_total_mb: 0.0,
            per_dataset: BTreeMap::new(),
            lru_index: BTreeSet::new(),
            clock: 0,
            stats: MemoryStats::default(),
            evicted_log: Vec::new(),
        }
    }

    /// Take the partitions evicted since the last call.
    pub fn drain_evicted(&mut self) -> Vec<PartitionKey> {
        std::mem::take(&mut self.evicted_log)
    }

    /// Drop every cached partition at once: the machine holding this store
    /// left the fleet (spot reclaim, failure). Unlike eviction this is not
    /// memory pressure — it bypasses the policy and the eviction stats/log
    /// (the engine reports the loss as a `MachineLost` event instead) and
    /// returns per-dataset loss counts so the caller can invalidate
    /// partition locations AND notify every tenant whose protected dataset
    /// lost blocks (a bare key list silently under-reported cross-tenant
    /// loss in the shared fleet store). Sorted by dataset id, so callers
    /// can attribute losses deterministically. Execution-memory accounting
    /// is untouched.
    pub fn release_all(&mut self) -> Vec<DatasetLoss> {
        let losses: Vec<DatasetLoss> = self
            .per_dataset
            .iter()
            .map(|(&dataset, &(partitions, lost_mb))| DatasetLoss { dataset, partitions, lost_mb })
            .collect();
        self.cached.clear();
        self.lru_index.clear();
        self.per_dataset.clear();
        self.cached_total_mb = 0.0;
        self.evicted_log.clear();
        losses
    }

    /// Storage space currently available for caching: execution may claim
    /// at most `M - R`, so storage keeps at least `R` and at most `M`.
    pub fn storage_limit_mb(&self) -> Mb {
        self.m_mb - self.exec_used_mb.min(self.m_mb - self.r_mb)
    }

    pub fn cached_mb(&self) -> Mb {
        self.cached_total_mb
    }

    fn remove_key(&mut self, key: &PartitionKey) {
        if let Some(p) = self.cached.remove(key) {
            self.lru_index.remove(&(p.last_access, *key));
            self.cached_total_mb -= p.size_mb;
            if let Some(e) = self.per_dataset.get_mut(&key.dataset) {
                e.0 -= 1;
                e.1 -= p.size_mb;
                if e.0 == 0 {
                    self.per_dataset.remove(&key.dataset);
                }
            }
        }
    }

    /// Any evictable partition (outside `inserting`, allowed by the
    /// arbitration predicate) present? O(#datasets).
    fn has_victim(&self, inserting: usize, evictable: &dyn Fn(usize) -> bool) -> bool {
        self.per_dataset.keys().any(|&d| d != inserting && evictable(d))
    }

    /// Per-dataset (dataset, partitions, bytes) currently cached, in
    /// dataset-id order. The fleet runner folds these by tenant stride to
    /// arbitrate reservation floors across co-resident tenants.
    pub fn dataset_usage(&self) -> impl Iterator<Item = (usize, usize, Mb)> + '_ {
        self.per_dataset.iter().map(|(&d, &(n, mb))| (d, n, mb))
    }

    pub fn exec_used_mb(&self) -> Mb {
        self.exec_used_mb
    }

    pub fn stats(&self) -> MemoryStats {
        let mut s = self.stats;
        s.cached_mb = self.cached_mb();
        s
    }

    pub fn num_cached(&self) -> usize {
        self.cached.len()
    }

    pub fn contains(&self, key: PartitionKey) -> bool {
        self.cached.contains_key(&key)
    }

    pub fn cached_keys(&self) -> Vec<PartitionKey> {
        self.cached.keys().copied().collect()
    }

    /// Claim execution memory (task working set). Execution never evicts
    /// below `R`, so its claim is clamped at `M - R` plus whatever storage
    /// is unused beyond that — the paper's model lets execution use the
    /// free part of the unified region.
    pub fn claim_execution(&mut self, mb: Mb) -> Mb {
        let granted = mb.min(self.m_mb - self.r_mb);
        self.exec_used_mb = granted;
        // execution pressure can force storage down to its new limit
        self.enforce_limit();
        granted
    }

    pub fn release_execution(&mut self) {
        self.exec_used_mb = 0.0;
    }

    /// Record an access (cache hit path) for recency bookkeeping.
    pub fn touch(&mut self, key: PartitionKey) -> bool {
        self.clock += 1;
        if let Some(p) = self.cached.get_mut(&key) {
            // lazy: the recency index entry becomes stale and is repaired
            // during the next victim scan (if any)
            p.last_access = self.clock;
            p.ref_count = p.ref_count.saturating_sub(1);
            true
        } else {
            false
        }
    }

    /// Update DAG-derived metadata for a dataset (for LRC/MRD).
    pub fn set_dataset_refs(&mut self, dataset: usize, ref_count: usize, ref_distance: usize) {
        for (k, p) in self.cached.iter_mut() {
            if k.dataset == dataset {
                p.ref_count = ref_count;
                p.ref_distance = ref_distance;
            }
        }
    }

    /// Try to cache a partition; evicts per policy if needed. Returns true
    /// if the partition ended up cached.
    pub fn insert(
        &mut self,
        key: PartitionKey,
        size_mb: Mb,
        ref_count: usize,
        ref_distance: usize,
    ) -> bool {
        self.insert_guarded(key, size_mb, ref_count, ref_distance, &|_| true)
    }

    /// [`UnifiedMemory::insert`] with a per-dataset evictability predicate:
    /// a victim is only considered when `evictable(victim.dataset)` holds.
    /// This is the shared-store arbitration hook — under per-tenant
    /// reservation floors the fleet runner passes a predicate that shields
    /// datasets of tenants still at or below their floor, while the plain
    /// `insert` path (always-true predicate) stays byte-identical to the
    /// single-tenant behavior. If every foreign partition is shielded the
    /// insert fails (counted in `failed_caches`) rather than stealing.
    pub fn insert_guarded(
        &mut self,
        key: PartitionKey,
        size_mb: Mb,
        ref_count: usize,
        ref_distance: usize,
        evictable: &dyn Fn(usize) -> bool,
    ) -> bool {
        self.clock += 1;
        let limit = self.storage_limit_mb();
        if size_mb > limit {
            // partition alone exceeds the storage region: never cached
            self.stats.failed_caches += 1;
            return false;
        }
        if self.cached_total_mb + size_mb > limit && !self.has_victim(key.dataset, evictable) {
            // hot path: memory full of our own dataset -> cannot evict
            self.stats.failed_caches += 1;
            return false;
        }
        while self.cached_total_mb + size_mb > limit {
            match self.pick_victim(key.dataset, evictable) {
                Some(victim) => {
                    self.remove_key(&victim);
                    self.stats.evictions += 1;
                    self.evicted_log.push(victim);
                }
                None => {
                    self.stats.failed_caches += 1;
                    return false;
                }
            }
        }
        let prev = self.cached.insert(
            key,
            CachedPartition {
                size_mb,
                last_access: self.clock,
                ref_count,
                ref_distance,
            },
        );
        if let Some(prev) = prev {
            // replacing an existing partition: undo its accounting
            self.lru_index.remove(&(prev.last_access, key));
            self.cached_total_mb -= prev.size_mb;
            if let Some(e) = self.per_dataset.get_mut(&key.dataset) {
                e.0 -= 1;
                e.1 -= prev.size_mb;
            }
        }
        self.lru_index.insert((self.clock, key));
        self.cached_total_mb += size_mb;
        let e = self.per_dataset.entry(key.dataset).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += size_mb;
        self.stats.peak_cached_mb = self.stats.peak_cached_mb.max(self.cached_total_mb);
        true
    }

    /// Drop partitions until back under the storage limit (used when
    /// execution claims memory mid-run).
    fn enforce_limit(&mut self) {
        let limit = self.storage_limit_mb();
        while self.cached_total_mb > limit {
            // under pressure any dataset is fair game
            match self.pick_victim(usize::MAX, &|_| true) {
                Some(v) => {
                    self.remove_key(&v);
                    self.stats.evictions += 1;
                    self.evicted_log.push(v);
                }
                None => break,
            }
        }
    }

    /// Choose a victim. Spark never evicts partitions of the dataset being
    /// written (`inserting`), to avoid thrashing within one RDD; datasets
    /// the arbitration predicate shields are skipped the same way.
    fn pick_victim(
        &mut self,
        inserting: usize,
        evictable: &dyn Fn(usize) -> bool,
    ) -> Option<PartitionKey> {
        match self.policy {
            // LRU: walk the recency index from the front, lazily repairing
            // stale entries and skipping (but keeping) entries of the
            // protected dataset — amortized O(log n) per eviction
            EvictionPolicy::Lru => {
                let mut cursor: Option<(u64, PartitionKey)> = None;
                loop {
                    let next = match cursor {
                        None => self.lru_index.iter().next().copied(),
                        Some(c) => self
                            .lru_index
                            .range((
                                std::ops::Bound::Excluded(c),
                                std::ops::Bound::Unbounded,
                            ))
                            .next()
                            .copied(),
                    };
                    let Some((ts, key)) = next else { return None };
                    match self.cached.get(&key) {
                        None => {
                            // key evicted earlier; drop the stale entry
                            self.lru_index.remove(&(ts, key));
                        }
                        Some(p) if p.last_access != ts => {
                            // touched since indexed; re-file at current time
                            let now = p.last_access;
                            self.lru_index.remove(&(ts, key));
                            self.lru_index.insert((now, key));
                        }
                        Some(_) if key.dataset != inserting && evictable(key.dataset) => {
                            return Some(key)
                        }
                        Some(_) => cursor = Some((ts, key)), // protected: skip
                    }
                }
            }
            EvictionPolicy::Lrc => self
                .cached
                .iter()
                .filter(|(k, _)| k.dataset != inserting && evictable(k.dataset))
                .min_by(|a, b| {
                    (a.1.ref_count, a.1.last_access).cmp(&(b.1.ref_count, b.1.last_access))
                })
                .map(|(k, _)| *k),
            EvictionPolicy::Mrd => self
                .cached
                .iter()
                .filter(|(k, _)| k.dataset != inserting && evictable(k.dataset))
                .max_by(|a, b| {
                    (a.1.ref_distance, std::cmp::Reverse(a.1.last_access))
                        .cmp(&(b.1.ref_distance, std::cmp::Reverse(b.1.last_access)))
                })
                .map(|(k, _)| *k),
        }
    }

    /// Fraction of a dataset's partitions present, given its total count.
    pub fn cached_fraction(&self, dataset: usize, total_partitions: usize) -> f64 {
        if total_partitions == 0 {
            return 0.0;
        }
        let have = self.cached.keys().filter(|k| k.dataset == dataset).count();
        have as f64 / total_partitions as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn key(d: usize, i: usize) -> PartitionKey {
        PartitionKey { dataset: d, index: i }
    }

    #[test]
    fn caches_until_limit_then_evicts_lru() {
        let mut m = UnifiedMemory::new(100.0, 50.0, EvictionPolicy::Lru);
        for i in 0..10 {
            assert!(m.insert(key(0, i), 10.0, 5, 1));
        }
        assert_eq!(m.num_cached(), 10);
        m.touch(key(0, 0)); // partition 0 recently used
        // a second dataset arrives: must evict from dataset 0, oldest first
        assert!(m.insert(key(1, 0), 10.0, 5, 1));
        assert_eq!(m.stats().evictions, 1);
        assert!(m.contains(key(0, 0)), "recently-touched survives");
        assert!(!m.contains(key(0, 1)), "LRU victim evicted");
    }

    #[test]
    fn never_evicts_partitions_of_inserting_dataset() {
        let mut m = UnifiedMemory::new(50.0, 25.0, EvictionPolicy::Lru);
        for i in 0..5 {
            assert!(m.insert(key(7, i), 10.0, 3, 1));
        }
        // 6th partition of the same dataset cannot displace its siblings
        assert!(!m.insert(key(7, 5), 10.0, 3, 1));
        assert_eq!(m.stats().failed_caches, 1);
        assert_eq!(m.num_cached(), 5);
    }

    #[test]
    fn execution_claims_shrink_storage_but_respect_r() {
        let mut m = UnifiedMemory::new(100.0, 40.0, EvictionPolicy::Lru);
        for i in 0..10 {
            m.insert(key(0, i), 10.0, 2, 1);
        }
        assert_eq!(m.cached_mb(), 100.0);
        let granted = m.claim_execution(80.0);
        assert_eq!(granted, 60.0, "execution capped at M - R");
        assert_eq!(m.storage_limit_mb(), 40.0);
        assert!(m.cached_mb() <= 40.0, "storage forced down to R");
        assert!(m.stats().evictions >= 6);
        m.release_execution();
        assert_eq!(m.storage_limit_mb(), 100.0);
    }

    #[test]
    fn oversized_partition_is_never_cached() {
        let mut m = UnifiedMemory::new(100.0, 50.0, EvictionPolicy::Lru);
        assert!(!m.insert(key(0, 0), 150.0, 1, 1));
        assert_eq!(m.num_cached(), 0);
    }

    #[test]
    fn lrc_evicts_lowest_refcount() {
        let mut m = UnifiedMemory::new(30.0, 15.0, EvictionPolicy::Lrc);
        m.insert(key(0, 0), 10.0, 8, 1); // many refs left
        m.insert(key(1, 0), 10.0, 1, 1); // one ref left
        m.insert(key(2, 0), 10.0, 4, 1);
        assert!(m.insert(key(3, 0), 10.0, 5, 1));
        assert!(!m.contains(key(1, 0)), "lowest ref count evicted");
        assert!(m.contains(key(0, 0)));
    }

    #[test]
    fn mrd_evicts_furthest_next_use() {
        let mut m = UnifiedMemory::new(30.0, 15.0, EvictionPolicy::Mrd);
        m.insert(key(0, 0), 10.0, 5, 2);
        m.insert(key(1, 0), 10.0, 5, 9); // used furthest in the future
        m.insert(key(2, 0), 10.0, 5, 1);
        assert!(m.insert(key(3, 0), 10.0, 5, 3));
        assert!(!m.contains(key(1, 0)), "largest ref distance evicted");
    }

    #[test]
    fn cached_fraction_tracks_partitions() {
        let mut m = UnifiedMemory::new(100.0, 50.0, EvictionPolicy::Lru);
        for i in 0..5 {
            m.insert(key(3, i), 10.0, 2, 1);
        }
        assert_eq!(m.cached_fraction(3, 10), 0.5);
        assert_eq!(m.cached_fraction(9, 10), 0.0);
        assert_eq!(m.cached_fraction(3, 0), 0.0);
    }

    #[test]
    fn release_all_empties_the_store_without_counting_evictions() {
        let mut m = UnifiedMemory::new(100.0, 50.0, EvictionPolicy::Lru);
        for i in 0..8 {
            assert!(m.insert(key(1, i), 10.0, 3, 1));
        }
        for i in 0..2 {
            assert!(m.insert(key(4, i), 5.0, 3, 1));
        }
        // 90 MB cached: an execution claim of 10 leaves the limit at
        // exactly the cached total, so nothing is evicted before the loss
        m.claim_execution(10.0);
        let before = m.stats();
        let losses = m.release_all();
        // every tenant learns exactly what its protected dataset lost,
        // attributed per dataset in id order — not one aggregate number
        assert_eq!(
            losses,
            vec![
                DatasetLoss { dataset: 1, partitions: 8, lost_mb: 80.0 },
                DatasetLoss { dataset: 4, partitions: 2, lost_mb: 10.0 },
            ]
        );
        assert_eq!(m.num_cached(), 0);
        assert_eq!(m.cached_mb(), 0.0);
        assert_eq!(m.stats().evictions, before.evictions, "loss is not eviction");
        assert_eq!(m.exec_used_mb(), 10.0, "execution accounting untouched");
        assert!(m.drain_evicted().is_empty(), "no stale eviction log entries");
        // an already-empty store reports no losses
        assert!(m.release_all().is_empty());
        // the store keeps working after a release
        assert!(m.insert(key(2, 0), 10.0, 3, 1));
        assert!(m.contains(key(2, 0)));
    }

    #[test]
    fn guarded_insert_shields_datasets_the_predicate_protects() {
        let mut m = UnifiedMemory::new(100.0, 50.0, EvictionPolicy::Lru);
        for i in 0..5 {
            assert!(m.insert(key(0, i), 10.0, 3, 1)); // tenant A, 50 MB
        }
        for i in 0..5 {
            assert!(m.insert(key(1, i), 10.0, 3, 1)); // tenant B, 50 MB
        }
        // full store; dataset 0 is shielded -> the victim must come from
        // dataset 1 even though dataset 0 holds the LRU-oldest partitions
        assert!(m.insert_guarded(key(2, 0), 10.0, 3, 1, &|d| d != 0));
        assert_eq!(m.num_cached(), 10);
        assert!((0..5).all(|i| m.contains(key(0, i))), "shielded dataset intact");
        assert!(!m.contains(key(1, 0)), "oldest unshielded partition evicted");
        // when every foreign dataset is shielded the insert fails instead
        // of stealing, and nothing is evicted
        let before = m.stats();
        assert!(!m.insert_guarded(key(3, 0), 10.0, 3, 1, &|_| false));
        assert_eq!(m.stats().evictions, before.evictions);
        assert_eq!(m.stats().failed_caches, before.failed_caches + 1);
        // the always-true predicate is plain insert, byte for byte
        assert!(m.insert_guarded(key(2, 1), 10.0, 3, 1, &|_| true));
    }

    #[test]
    fn dataset_usage_reports_per_dataset_partitions_and_bytes() {
        let mut m = UnifiedMemory::new(100.0, 50.0, EvictionPolicy::Lru);
        for i in 0..3 {
            m.insert(key(7, i), 10.0, 2, 1);
        }
        m.insert(key(2, 0), 5.0, 2, 1);
        let usage: Vec<(usize, usize, Mb)> = m.dataset_usage().collect();
        assert_eq!(usage, vec![(2, 1, 5.0), (7, 3, 30.0)], "dataset-id order");
    }

    #[test]
    fn property_cached_never_exceeds_storage_limit() {
        prop::check(
            &prop::Config { cases: 160, seed: 0x3e3, max_size: 48 },
            |rng: &mut Rng, size| {
                let m_mb = rng.range(50.0, 500.0);
                let r_mb = rng.range(0.0, m_mb);
                let policy = match rng.below(3) {
                    0 => EvictionPolicy::Lru,
                    1 => EvictionPolicy::Lrc,
                    _ => EvictionPolicy::Mrd,
                };
                let ops: Vec<(usize, usize, f64, f64)> = (0..size)
                    .map(|_| {
                        (
                            rng.below(4),
                            rng.below(32),
                            rng.range(1.0, 80.0),
                            rng.range(0.0, m_mb * 1.2),
                        )
                    })
                    .collect();
                (m_mb, r_mb, policy, ops)
            },
            |(m_mb, r_mb, policy, ops)| {
                let mut m = UnifiedMemory::new(*m_mb, *r_mb, *policy);
                for (i, (ds, idx, sz, exec)) in ops.iter().enumerate() {
                    if i % 5 == 4 {
                        m.claim_execution(*exec);
                    } else {
                        m.insert(key(*ds, *idx), *sz, 3, 2);
                    }
                    let limit = m.storage_limit_mb();
                    if m.cached_mb() > limit + 1e-9 {
                        return Err(format!(
                            "cached {} exceeds limit {} (M={m_mb}, R={r_mb})",
                            m.cached_mb(),
                            limit
                        ));
                    }
                    if m.storage_limit_mb() < *r_mb - 1e-9 {
                        return Err("storage floor R violated".into());
                    }
                }
                Ok(())
            },
        );
    }
}
