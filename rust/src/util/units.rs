//! Size / time formatting and parsing used by reports and the CLI.

/// Megabytes, the library's canonical size unit (the paper reports MB/GB).
pub type Mb = f64;

pub const MB_PER_GB: f64 = 1024.0;

pub fn gb(v: f64) -> Mb {
    v * MB_PER_GB
}

/// Human-readable size: "512.0 KB", "1.5 GB", ...
pub fn fmt_mb(mb: Mb) -> String {
    if mb < 0.0009765625 {
        format!("{:.0} B", mb * 1024.0 * 1024.0)
    } else if mb < 1.0 {
        format!("{:.1} KB", mb * 1024.0)
    } else if mb < 1024.0 {
        format!("{mb:.1} MB")
    } else {
        format!("{:.1} GB", mb / 1024.0)
    }
}

/// Human-readable duration from seconds: "45 s", "3.5 min", "2.1 h".
pub fn fmt_secs(s: f64) -> String {
    if s < 60.0 {
        format!("{s:.1} s")
    } else if s < 3600.0 {
        format!("{:.1} min", s / 60.0)
    } else {
        format!("{:.2} h", s / 3600.0)
    }
}

/// Parse "64mb", "1.5gb", "300kb" (case-insensitive) into MB.
pub fn parse_mb(text: &str) -> Option<Mb> {
    let t = text.trim().to_lowercase();
    let (num, mult) = if let Some(n) = t.strip_suffix("gb") {
        (n, 1024.0)
    } else if let Some(n) = t.strip_suffix("mb") {
        (n, 1.0)
    } else if let Some(n) = t.strip_suffix("kb") {
        (n, 1.0 / 1024.0)
    } else {
        (t.as_str(), 1.0)
    };
    num.trim().parse::<f64>().ok().map(|v| v * mult)
}

/// Signed human-readable size: headrooms/deficits render as "1.5 GB" or
/// "-512.0 MB" instead of a nonsensical negative unit split.
pub fn fmt_mb_signed(mb: Mb) -> String {
    if mb < 0.0 {
        format!("-{}", fmt_mb(-mb))
    } else {
        fmt_mb(mb)
    }
}

/// Percentage with one decimal: "4.6 %".
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1} %", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_sizes() {
        assert_eq!(fmt_mb(0.5), "512.0 KB");
        assert_eq!(fmt_mb(59.6 * 1024.0), "59.6 GB");
        assert_eq!(fmt_mb(30.6), "30.6 MB");
    }

    #[test]
    fn formats_signed_sizes() {
        assert_eq!(fmt_mb_signed(30.6), "30.6 MB");
        assert_eq!(fmt_mb_signed(-30.6), "-30.6 MB");
        assert_eq!(fmt_mb_signed(-2048.0), "-2.0 GB");
    }

    #[test]
    fn formats_times() {
        assert_eq!(fmt_secs(41.0), "41.0 s");
        assert_eq!(fmt_secs(210.0), "3.5 min");
        assert_eq!(fmt_secs(7560.0), "2.10 h");
    }

    #[test]
    fn parses_sizes() {
        assert_eq!(parse_mb("64mb"), Some(64.0));
        assert_eq!(parse_mb("1.5 GB"), Some(1536.0));
        assert_eq!(parse_mb("512kb"), Some(0.5));
        assert_eq!(parse_mb("128"), Some(128.0));
        assert_eq!(parse_mb("x"), None);
    }
}
