//! Minimal JSON: a value model, a recursive-descent parser and a printer.
//!
//! Covers the full JSON grammar (RFC 8259) minus `\u` surrogate pairs being
//! validated pairwise — sufficient for listener logs, the AOT manifest and
//! experiment reports. No external dependencies.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Path lookup: `j.path(&["entries", "linfit", "file"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        keys.iter().try_fold(self, |j, k| j.get(k))
    }

    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for ParseError {}

/// Maximum container nesting the parser accepts. The parser is recursive,
/// so without a bound an adversarial `[[[[…` document overflows the stack
/// (an abort, not an `Err`). 128 levels is far beyond any listener log or
/// experiment report while keeping worst-case stack use trivial.
pub const MAX_DEPTH: usize = 128;

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting, bounded by [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode multi-byte utf8 starting at pos-1
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn enter(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn pretty_output_reparses() {
        let v = Json::obj(vec![
            ("name", "blink".into()),
            ("sizes", vec![1.5f64, 2.0, 3.25].into()),
            ("nested", Json::obj(vec![("ok", true.into())])),
        ]);
        assert_eq!(parse(&v.pretty()).unwrap(), v);
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"\\q\""] {
            assert!(parse(src).is_err(), "{src}");
        }
    }

    #[test]
    fn truncated_and_malformed_docs_error_cleanly() {
        let full = r#"{"a": [1, 2.5, {"b": "x\ny", "c": [true, null]}], "d": -1e3}"#;
        assert!(parse(full).is_ok());
        // every strict prefix must be a clean Err, never a panic
        for end in 0..full.len() {
            if !full.is_char_boundary(end) {
                continue;
            }
            assert!(parse(&full[..end]).is_err(), "prefix of len {end} parsed");
        }
        for src in ["\"\\u12\"", "\"\\u\"", "\"\\", "-", "[", "[{", "{\"k\":", "nul", "falsy"] {
            assert!(parse(src).is_err(), "{src:?}");
        }
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        // far past MAX_DEPTH: without the bound this aborts the process
        let deep = "[".repeat(100_000);
        let err = parse(&deep).unwrap_err();
        assert!(err.message.contains("nesting too deep"), "{err}");
        let deep_obj = "{\"k\":".repeat(100_000);
        assert!(parse(&deep_obj).is_err());
        // at the bound itself both sides behave as documented
        let ok = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(parse(&ok).is_ok(), "exactly MAX_DEPTH levels must parse");
        let over = format!("{}{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(parse(&over).is_err());
        // ...and siblings do not accumulate depth
        let wide = format!("[{}1]", "[1],".repeat(1000));
        assert!(parse(&wide).is_ok());
    }

    #[test]
    fn unicode_strings() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn deterministic_object_order() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }
}
