//! A tiny declarative command-line parser (offline stand-in for `clap`).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches
//! and auto-generated help. Only what the `blink` binary needs.

use std::collections::BTreeMap;

/// One `--name <value>` option (or boolean switch when `takes_value=false`).
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

impl Opt {
    pub fn value(name: &'static str, help: &'static str) -> Self {
        Opt { name, help, takes_value: true, default: None }
    }

    pub fn with_default(
        name: &'static str,
        help: &'static str,
        default: &'static str,
    ) -> Self {
        Opt { name, help, takes_value: true, default: Some(default) }
    }

    pub fn switch(name: &'static str, help: &'static str) -> Self {
        Opt { name, help, takes_value: false, default: None }
    }
}

/// Parsed option values for one subcommand invocation.
#[derive(Debug, Clone, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name)?.parse().ok()
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name)?.parse().ok()
    }

    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name)?.parse().ok()
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// A subcommand with its option set.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

/// Application = name + subcommands + options every subcommand accepts.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
    /// Global options (e.g. `--format`), valid after any subcommand.
    pub globals: Vec<Opt>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum CliError {
    Help(String),
    Unknown(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Help(h) => write!(f, "{h}"),
            CliError::Unknown(m) => write!(f, "error: {m}"),
        }
    }
}
impl std::error::Error for CliError {}

impl App {
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n",
            self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<16} {}\n", c.name, c.about));
        }
        if !self.globals.is_empty() {
            s.push_str("\nGLOBAL OPTIONS (any command):\n");
            for o in &self.globals {
                Self::opt_help(&mut s, o);
            }
        }
        s.push_str("\nRun '<command> --help' for command options.\n");
        s
    }

    fn opt_help(s: &mut String, o: &Opt) {
        let meta = if o.takes_value { " <value>" } else { "" };
        let def = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{:<14} {}{}\n", o.name, meta, o.help, def));
    }

    fn command_help(&self, c: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, c.name, c.about);
        for o in c.opts.iter().chain(&self.globals) {
            Self::opt_help(&mut s, o);
        }
        s
    }

    /// Parse argv (without the program name). Returns (command, matches).
    pub fn parse(&self, argv: &[String]) -> Result<(&Command, Matches), CliError> {
        let Some(cmd_name) = argv.first() else {
            return Err(CliError::Help(self.help()));
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(CliError::Help(self.help()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError::Unknown(format!("unknown command '{cmd_name}'")))?;

        let mut m = Matches::default();
        for o in cmd.opts.iter().chain(&self.globals) {
            if let Some(d) = o.default {
                m.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError::Help(self.command_help(cmd)));
            }
            let Some(body) = arg.strip_prefix("--") else {
                return Err(CliError::Unknown(format!("unexpected argument '{arg}'")));
            };
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let opt = cmd
                .opts
                .iter()
                .chain(&self.globals)
                .find(|o| o.name == name)
                .ok_or_else(|| CliError::Unknown(format!("unknown option '--{name}'")))?;
            if opt.takes_value {
                let v = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| CliError::Unknown(format!("--{name} needs a value")))?
                    }
                };
                m.values.insert(name.to_string(), v);
            } else {
                if inline.is_some() {
                    return Err(CliError::Unknown(format!("--{name} takes no value")));
                }
                m.switches.push(name.to_string());
            }
            i += 1;
        }
        Ok((cmd, m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "blink",
            about: "test",
            commands: vec![Command {
                name: "run",
                about: "run stuff",
                opts: vec![
                    Opt::with_default("app", "application", "svm"),
                    Opt::value("scale", "data scale"),
                    Opt::switch("verbose", "more output"),
                ],
            }],
            globals: vec![Opt::with_default("format", "output format", "text")],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_defaults_switches() {
        let a = app();
        let (c, m) = a
            .parse(&argv(&["run", "--scale=2.5", "--verbose"]))
            .unwrap();
        assert_eq!(c.name, "run");
        assert_eq!(m.get("app"), Some("svm"));
        assert_eq!(m.get_f64("scale"), Some(2.5));
        assert!(m.has("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = app();
        let (_, m) = a.parse(&argv(&["run", "--scale", "42"])).unwrap();
        assert_eq!(m.get_usize("scale"), Some(42));
        assert_eq!(m.get_u64("scale"), Some(42));
        assert_eq!(m.get_u64("app"), None, "non-numeric value");
    }

    #[test]
    fn space_separated_value() {
        let a = app();
        let (_, m) = a.parse(&argv(&["run", "--app", "km"])).unwrap();
        assert_eq!(m.get("app"), Some("km"));
    }

    #[test]
    fn global_options_work_on_every_command() {
        let a = app();
        // default applies without mention
        let (_, m) = a.parse(&argv(&["run"])).unwrap();
        assert_eq!(m.get("format"), Some("text"));
        // explicit value in both syntaxes
        let (_, m) = a.parse(&argv(&["run", "--format", "json"])).unwrap();
        assert_eq!(m.get("format"), Some("json"));
        let (_, m) = a.parse(&argv(&["run", "--format=json", "--app", "km"])).unwrap();
        assert_eq!(m.get("format"), Some("json"));
        assert_eq!(m.get("app"), Some("km"));
        // globals are listed in both help texts
        let Err(CliError::Help(h)) = a.parse(&argv(&["run", "--help"])) else { panic!() };
        assert!(h.contains("--format"));
        let Err(CliError::Help(h)) = a.parse(&argv(&[])) else { panic!() };
        assert!(h.contains("--format"));
    }

    #[test]
    fn errors() {
        let a = app();
        assert!(matches!(a.parse(&argv(&[])), Err(CliError::Help(_))));
        assert!(matches!(a.parse(&argv(&["nope"])), Err(CliError::Unknown(_))));
        assert!(matches!(
            a.parse(&argv(&["run", "--bogus"])),
            Err(CliError::Unknown(_))
        ));
        assert!(matches!(
            a.parse(&argv(&["run", "--scale"])),
            Err(CliError::Unknown(_))
        ));
        assert!(matches!(
            a.parse(&argv(&["run", "--help"])),
            Err(CliError::Help(_))
        ));
    }
}
