//! Deterministic PRNG + distributions (offline stand-in for `rand`).
//!
//! Xoshiro256** seeded through SplitMix64, plus the draws the simulator
//! needs: uniform, normal (Box–Muller), log-normal and Zipf. Everything is
//! reproducible from a single `u64` seed; streams can be forked per
//! component (`fork`) so adding draws in one subsystem never perturbs
//! another (important for the paper's "size is deterministic, time is
//! noisy" experiments, Fig. 4).

/// SplitMix64 — used for seeding and hash-like stateless randomness.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless hash of a string + salt to a unit-interval f64.
/// Used for *deterministic* per-(app, scale) measurement quirks that must
/// be identical across repeated runs (Fig. 4) yet vary across scales.
pub fn hash_unit(name: &str, salt: u64) -> f64 {
    let mut h = 0xcbf29ce484222325u64 ^ salt;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    (splitmix64(h) >> 11) as f64 / (1u64 << 53) as f64
}

/// Xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut s = [0u64; 4];
        let mut x = seed;
        for v in s.iter_mut() {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            *v = splitmix64(x);
        }
        Rng { s }
    }

    /// Derive an independent stream for a named component.
    pub fn fork(&self, label: &str) -> Rng {
        let mut h = self.s[0] ^ self.s[2];
        for b in label.bytes() {
            h = splitmix64(h ^ b as u64);
        }
        Rng::new(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/σ.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal such that the *median* is `median` and sigma is the
    /// log-space σ — the shape of task-duration noise in data systems.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (rejection-free
    /// inverse-CDF over precomputable harmonic weights is overkill for the
    /// small n used here; linear scan of cumulative weights).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let total: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.f64() * total;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let root = Rng::new(7);
        let mut a = root.fork("tasks");
        let mut b = root.fork("sizes");
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
        // re-fork reproduces
        let mut a2 = root.fork("tasks");
        assert_eq!(av[0], a2.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(3);
        let n = 30_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(10.0, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[n / 2];
        assert!((med - 10.0).abs() / 10.0 < 0.05, "{med}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > 0);
    }

    #[test]
    fn hash_unit_is_stable_and_spread() {
        let a = hash_unit("svm", 1);
        assert_eq!(a, hash_unit("svm", 1));
        assert_ne!(a, hash_unit("svm", 2));
        assert_ne!(a, hash_unit("km", 1));
        assert!((0.0..1.0).contains(&a));
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..32).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
        assert_ne!(v, (0..32).collect::<Vec<_>>());
    }
}
