//! Offline-image substrates.
//!
//! This build environment resolves only the `xla` crate (and `anyhow`) from
//! the vendored registry — no serde / rand / clap / criterion / proptest.
//! Rather than stubbing those roles out, this module implements the small
//! slices of them the project needs (see DESIGN.md §2, substitution table):
//!
//! * [`json`]  — minimal JSON value model, parser and pretty-printer, used
//!   for listener logs, the artifacts manifest and experiment reports.
//! * [`prng`]  — SplitMix64 / Xoshiro256** PRNGs plus the distributions the
//!   simulator draws from (uniform, normal, log-normal, zipf).
//! * [`stats`] — mean / variance / percentile / RMSE helpers.
//! * [`cli`]   — a tiny declarative flag parser for the `blink` binary.
//! * [`par`]   — deterministic scoped-thread sweeps (a rayon stand-in for
//!   the experiment drivers' per-cluster-size fan-out).
//! * [`prop`]  — a miniature property-testing harness (seeded generators +
//!   failure reporting) standing in for proptest on coordinator invariants.
//! * [`bench`] — a criterion-like micro-benchmark runner (warmup, fixed
//!   sample count, mean/σ/min reporting) used by `benches/hotpaths.rs`.
//! * [`units`] — MB/GB/duration formatting used by every report.

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod units;
