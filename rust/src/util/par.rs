//! Deterministic parallel sweeps over an index range.
//!
//! The experiment drivers evaluate many independent `(cluster size, seed)`
//! simulations; [`sweep_range`] fans them out over a bounded pool of scoped
//! threads (`std::thread::scope`, no dependencies) and returns results in
//! index order. Every simulation derives its RNG from the index, so the
//! parallel sweep is *bit-identical* to [`sweep_range_serial`] — asserted by
//! unit and integration tests, and the reason the drivers may use either
//! path interchangeably.
//!
//! The pool is sized by `std::thread::available_parallelism` (capped at the
//! range length), with workers pulling indices from a shared atomic counter.
//! The historical one-OS-thread-per-index spawn made a large sweep — e.g. a
//! Crispy-sized catalog of hundreds of instance types — exhaust thread
//! limits; the bounded pool keeps the same ordered, bit-identical contract
//! at any range size.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `f(i)` for every `i` in `lo..=hi` on a bounded pool of scoped
/// threads; results are returned in index order. `f` must be pure per index
/// (it receives no shared mutable state), which is what makes the sweep
/// deterministic: each index's result is computed independently and placed
/// by index, so scheduling order cannot leak into the output.
pub fn sweep_range<T, F>(lo: usize, hi: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    sweep_range_with(workers, lo, hi, f)
}

/// [`sweep_range`] with an explicit worker count instead of
/// `available_parallelism` — the serve loop exposes it as `--threads` so
/// throughput can be measured at fixed pool sizes. `workers == 0` means
/// "auto" (same as [`sweep_range`]); `workers == 1` still runs on one
/// spawned worker, which is what makes the output contract trivially
/// identical at every pool size: results are placed by index, never by
/// completion order.
pub fn sweep_range_with<T, F>(workers: usize, lo: usize, hi: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if hi < lo {
        return Vec::new();
    }
    let n = hi - lo + 1;
    let workers = if workers == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        workers
    }
    .min(n);
    let mut out: Vec<Option<T>> = Vec::new();
    out.resize_with(n, || None);
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let f = &f;
                let next = &next;
                scope.spawn(move || {
                    let mut done: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        done.push((i, f(lo + i)));
                    }
                    done
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("sweep worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|v| v.expect("sweep worker filled its slot")).collect()
}

/// The reference serial implementation of [`sweep_range`].
pub fn sweep_range_serial<T, F>(lo: usize, hi: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T,
{
    if hi < lo {
        return Vec::new();
    }
    (lo..=hi).map(f).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_and_complete() {
        let v = sweep_range(3, 10, |i| i * i);
        assert_eq!(v, vec![9, 16, 25, 36, 49, 64, 81, 100]);
    }

    #[test]
    fn empty_range() {
        let v: Vec<usize> = sweep_range(5, 4, |i| i);
        assert!(v.is_empty());
        let v: Vec<usize> = sweep_range_serial(5, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn identical_to_serial_for_seeded_work() {
        // a seed-dependent computation, like the experiment sweeps
        let work = |i: usize| {
            let mut rng = crate::util::prng::Rng::new(1000 + i as u64);
            (0..100).map(|_| rng.f64()).sum::<f64>()
        };
        assert_eq!(sweep_range(1, 16, work), sweep_range_serial(1, 16, work));
    }

    #[test]
    fn single_element() {
        assert_eq!(sweep_range(7, 7, |i| i + 1), vec![8]);
    }

    #[test]
    fn explicit_worker_counts_agree_with_serial() {
        let work = |i: usize| {
            let mut rng = crate::util::prng::Rng::new(42 + i as u64);
            (0..32).map(|_| rng.f64()).sum::<f64>()
        };
        let reference = sweep_range_serial(0, 63, work);
        for workers in [0, 1, 2, 3, 8, 64, 200] {
            assert_eq!(sweep_range_with(workers, 0, 63, work), reference, "workers={workers}");
        }
    }

    #[test]
    fn large_range_stays_bounded_ordered_and_identical_to_serial() {
        // regression for the unbounded spawn: 10_000 indices used to mean
        // 10_000 OS threads; the pool must complete this with a handful,
        // index-ordered and bit-identical to the serial path
        let work = |i: usize| {
            let mut rng = crate::util::prng::Rng::new(i as u64);
            rng.f64() + i as f64
        };
        let par = sweep_range(0, 9_999, work);
        let ser = sweep_range_serial(0, 9_999, work);
        assert_eq!(par.len(), 10_000);
        assert_eq!(par, ser);
        assert!(par.windows(2).all(|w| w[1] > w[0]), "index order preserved");
    }
}
