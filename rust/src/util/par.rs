//! Deterministic parallel sweeps over an index range.
//!
//! The experiment drivers evaluate many independent `(cluster size, seed)`
//! simulations; [`sweep_range`] fans them out over scoped threads
//! (`std::thread::scope`, no dependencies) and returns results in index
//! order. Every simulation derives its RNG from the index, so the parallel
//! sweep is *bit-identical* to [`sweep_range_serial`] — asserted by unit
//! and integration tests, and the reason the drivers may use either path
//! interchangeably.

/// Run `f(i)` for every `i` in `lo..=hi` on scoped threads; results are
/// returned in index order. `f` must be pure per index (it receives no
/// shared mutable state), which is what makes the sweep deterministic.
pub fn sweep_range<T, F>(lo: usize, hi: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if hi < lo {
        return Vec::new();
    }
    let mut out: Vec<Option<T>> = Vec::new();
    out.resize_with(hi - lo + 1, || None);
    std::thread::scope(|scope| {
        for (i, slot) in out.iter_mut().enumerate() {
            let f = &f;
            // the scope joins every handle on exit; no need to keep them
            let _ = scope.spawn(move || {
                *slot = Some(f(lo + i));
            });
        }
    });
    out.into_iter().map(|v| v.expect("sweep worker filled its slot")).collect()
}

/// The reference serial implementation of [`sweep_range`].
pub fn sweep_range_serial<T, F>(lo: usize, hi: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T,
{
    if hi < lo {
        return Vec::new();
    }
    (lo..=hi).map(f).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_and_complete() {
        let v = sweep_range(3, 10, |i| i * i);
        assert_eq!(v, vec![9, 16, 25, 36, 49, 64, 81, 100]);
    }

    #[test]
    fn empty_range() {
        let v: Vec<usize> = sweep_range(5, 4, |i| i);
        assert!(v.is_empty());
        let v: Vec<usize> = sweep_range_serial(5, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn identical_to_serial_for_seeded_work() {
        // a seed-dependent computation, like the experiment sweeps
        let work = |i: usize| {
            let mut rng = crate::util::prng::Rng::new(1000 + i as u64);
            (0..100).map(|_| rng.f64()).sum::<f64>()
        };
        assert_eq!(sweep_range(1, 16, work), sweep_range_serial(1, 16, work));
    }

    #[test]
    fn single_element() {
        assert_eq!(sweep_range(7, 7, |i| i + 1), vec![8]);
    }
}
