//! Criterion-like micro-benchmark runner (offline stand-in for `criterion`).
//!
//! Fixed-iteration-count timing with warmup, reporting mean / σ / min per
//! iteration. `benches/*.rs` are `harness = false` binaries built on this.

use std::hint::black_box;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        super::stats::mean(&self.samples)
    }

    pub fn std_s(&self) -> f64 {
        super::stats::stddev(&self.samples)
    }

    pub fn min_s(&self) -> f64 {
        super::stats::min(&self.samples)
    }

    pub fn report(&self) -> String {
        let m = self.mean_s();
        let unit = |s: f64| {
            if s < 1e-6 {
                format!("{:.1} ns", s * 1e9)
            } else if s < 1e-3 {
                format!("{:.2} µs", s * 1e6)
            } else if s < 1.0 {
                format!("{:.2} ms", s * 1e3)
            } else {
                format!("{:.3} s", s)
            }
        };
        format!(
            "{:<44} mean {:>10}  σ {:>10}  min {:>10}  ({} samples)",
            self.name,
            unit(m),
            unit(self.std_s()),
            unit(self.min_s()),
            self.samples.len()
        )
    }
}

/// Benchmark runner with warmup.
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_count: usize,
    pub iters_per_sample: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            sample_count: 10,
            iters_per_sample: 1,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, sample_count: 5, iters_per_sample: 1, results: Vec::new() }
    }

    /// Time `f`, which must return a value (black-boxed to defeat DCE).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / self.iters_per_sample as f64);
        }
        let m = Measurement { name: name.to_string(), samples };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::quick();
        let m = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.mean_s() > 0.0);
        assert_eq!(m.samples.len(), 5);
    }

    #[test]
    fn report_formats_units() {
        let m = Measurement { name: "x".into(), samples: vec![2e-6, 2e-6] };
        assert!(m.report().contains("µs"));
        let m = Measurement { name: "x".into(), samples: vec![2.0, 2.0] };
        assert!(m.report().contains(" s"));
    }
}
