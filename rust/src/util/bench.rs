//! Criterion-like micro-benchmark runner (offline stand-in for `criterion`).
//!
//! Fixed-iteration-count timing with warmup, reporting median / mean / σ /
//! min per iteration. `benches/*.rs` are `harness = false` binaries built
//! on this. Two env knobs make the harness machine-recordable:
//!
//! * `BLINK_BENCH_SMOKE=1` — switch to the quick profile (fewer samples;
//!   what the CI smoke job runs);
//! * `BLINK_BENCH_JSON=<path>` — after the run, write every measurement as
//!   a deterministic JSON report (the `BENCH_*.json` schema below), which
//!   is how the committed `BENCH_hotpaths.json` baseline is produced.
//!
//! ## `BENCH_*.json` schema (version 1)
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "bench": "hotpaths",
//!   "mode": "full" | "smoke",
//!   "entries": {
//!     "<name>": {"median_s": .., "mean_s": .., "std_s": .., "min_s": ..,
//!                "samples": ..}
//!   }
//! }
//! ```
//!
//! Committed baselines may carry extra advisory keys (e.g. `before` /
//! `deltas` for recorded speedups); the harness never emits or reads them.

use std::hint::black_box;
use std::time::Instant;

use super::json::Json;

/// Version stamp of the emitted `BENCH_*.json` layout; CI's schema-drift
/// check compares it against the committed baseline.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl Measurement {
    /// Median seconds per iteration — the headline number (robust to a
    /// stray slow sample, unlike the mean).
    pub fn median_s(&self) -> f64 {
        super::stats::percentile(&self.samples, 50.0)
    }

    pub fn mean_s(&self) -> f64 {
        super::stats::mean(&self.samples)
    }

    pub fn std_s(&self) -> f64 {
        super::stats::stddev(&self.samples)
    }

    pub fn min_s(&self) -> f64 {
        super::stats::min(&self.samples)
    }

    /// The entry object under `entries.<name>` in the JSON report.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("median_s", Json::Num(self.median_s())),
            ("mean_s", Json::Num(self.mean_s())),
            ("std_s", Json::Num(self.std_s())),
            ("min_s", Json::Num(self.min_s())),
            ("samples", Json::Num(self.samples.len() as f64)),
        ])
    }

    pub fn report(&self) -> String {
        let unit = |s: f64| {
            if s < 1e-6 {
                format!("{:.1} ns", s * 1e9)
            } else if s < 1e-3 {
                format!("{:.2} µs", s * 1e6)
            } else if s < 1.0 {
                format!("{:.2} ms", s * 1e3)
            } else {
                format!("{:.3} s", s)
            }
        };
        format!(
            "{:<44} median {:>10}  mean {:>10}  σ {:>10}  min {:>10}  ({} samples)",
            self.name,
            unit(self.median_s()),
            unit(self.mean_s()),
            unit(self.std_s()),
            unit(self.min_s()),
            self.samples.len()
        )
    }
}

/// Benchmark runner with warmup.
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_count: usize,
    pub iters_per_sample: usize,
    /// `"full"` or `"smoke"` — recorded in the JSON report so a baseline
    /// can never be silently compared against a smoke run.
    pub mode: &'static str,
    pub results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            sample_count: 10,
            iters_per_sample: 1,
            mode: "full",
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            sample_count: 5,
            iters_per_sample: 1,
            mode: "smoke",
            results: Vec::new(),
        }
    }

    /// The profile the environment asks for: [`Bencher::quick`] when
    /// `BLINK_BENCH_SMOKE` is set non-empty (and not `"0"`), the full
    /// default otherwise.
    pub fn from_env() -> Self {
        match std::env::var("BLINK_BENCH_SMOKE") {
            Ok(v) if !v.is_empty() && v != "0" => Bencher::quick(),
            _ => Bencher::default(),
        }
    }

    /// Time `f`, which must return a value (black-boxed to defeat DCE).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / self.iters_per_sample as f64);
        }
        let m = Measurement { name: name.to_string(), samples };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// The full machine-readable report (schema above). Objects are
    /// `BTreeMap`-backed, so the output is deterministic for a given set
    /// of measurements.
    pub fn to_json(&self, bench_name: &str) -> Json {
        let entries: Vec<(&str, Json)> =
            self.results.iter().map(|m| (m.name.as_str(), m.to_json())).collect();
        Json::obj(vec![
            ("schema_version", Json::Num(BENCH_SCHEMA_VERSION as f64)),
            ("bench", Json::Str(bench_name.to_string())),
            ("mode", Json::Str(self.mode.to_string())),
            ("entries", Json::obj(entries)),
        ])
    }

    /// Write the JSON report to the path in `BLINK_BENCH_JSON`, if set.
    /// Returns the path written to. A bench binary calls this once at the
    /// end of `main`.
    pub fn write_json_from_env(&self, bench_name: &str) -> std::io::Result<Option<String>> {
        let Ok(path) = std::env::var("BLINK_BENCH_JSON") else {
            return Ok(None);
        };
        if path.is_empty() {
            return Ok(None);
        }
        let mut text = self.to_json(bench_name).pretty();
        text.push('\n');
        std::fs::write(&path, text)?;
        Ok(Some(path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::quick();
        let m = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.mean_s() > 0.0);
        assert!(m.median_s() > 0.0);
        assert_eq!(m.samples.len(), 5);
    }

    #[test]
    fn report_formats_units() {
        let m = Measurement { name: "x".into(), samples: vec![2e-6, 2e-6] };
        assert!(m.report().contains("µs"));
        let m = Measurement { name: "x".into(), samples: vec![2.0, 2.0] };
        assert!(m.report().contains(" s"));
    }

    #[test]
    fn median_is_robust_to_one_outlier() {
        let m = Measurement { name: "x".into(), samples: vec![1.0, 1.0, 1.0, 1.0, 100.0] };
        assert_eq!(m.median_s(), 1.0);
        assert!(m.mean_s() > 20.0);
    }

    #[test]
    fn json_report_carries_schema_mode_and_entries() {
        let mut b = Bencher::quick();
        b.bench("a/first", || 1u64);
        b.bench("b/second", || 2u64);
        let j = b.to_json("hotpaths");
        assert_eq!(j.get("schema_version").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("bench").and_then(Json::as_str), Some("hotpaths"));
        assert_eq!(j.get("mode").and_then(Json::as_str), Some("smoke"));
        for name in ["a/first", "b/second"] {
            for field in ["median_s", "mean_s", "std_s", "min_s", "samples"] {
                let v = j.path(&["entries", name, field]).and_then(Json::as_f64);
                assert!(v.is_some(), "{name}.{field} missing");
                assert!(v.unwrap() >= 0.0, "{name}.{field} negative");
            }
        }
        // round-trips through the parser
        let text = j.pretty();
        let back = crate::util::json::parse(&text).expect("valid json");
        assert_eq!(back, j);
    }
}
