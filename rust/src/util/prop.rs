//! Miniature property-testing harness (offline stand-in for `proptest`).
//!
//! `forall` runs a property over `cases` seeded inputs produced by a
//! generator closure; on failure it retries with simpler inputs produced by
//! the generator's `shrink` hint (halving the size parameter) and reports
//! the smallest failing seed/size it found. This is deliberately small but
//! gives the coordinator invariants (routing, batching, memory-manager
//! state) real randomized coverage.

use super::prng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Upper bound of the "size" parameter handed to the generator.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xb111c, max_size: 64 }
    }
}

/// Outcome of a failed property, with the minimal size reproduced.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub case: usize,
    pub size: usize,
    pub message: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed (case {}, seed {:#x}, size {}): {}",
            self.case, self.seed, self.size, self.message
        )
    }
}

/// Run `prop` over `cfg.cases` generated inputs.
///
/// `gen(rng, size)` produces an input of roughly the given size;
/// `prop(input)` returns `Err(msg)` to signal a violation. On failure the
/// harness re-generates at smaller sizes from the same seed to find a
/// simpler counterexample before reporting.
pub fn forall<T, G, P>(cfg: &Config, mut gen: G, mut prop: P) -> Result<(), Failure>
where
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // ramp size up over the run, proptest-style
        let size = 1 + (cfg.max_size.saturating_sub(1)) * case / cfg.cases.max(1);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng, size);
        if let Err(message) = prop(&input) {
            // shrink: halve the size until the property passes again
            let mut best = Failure { seed: case_seed, case, size, message };
            let mut s = size;
            while s > 1 {
                s /= 2;
                let mut rng = Rng::new(case_seed);
                let input = gen(&mut rng, s);
                match prop(&input) {
                    Err(message) => {
                        best = Failure { seed: case_seed, case, size: s, message };
                    }
                    Ok(()) => break,
                }
            }
            return Err(best);
        }
    }
    Ok(())
}

/// Assert-style wrapper that panics with the failure report (for #[test]).
pub fn check<T, G, P>(cfg: &Config, gen: G, prop: P)
where
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    if let Err(f) = forall(cfg, gen, prop) {
        panic!("{f}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            &Config::default(),
            |rng, size| (0..size).map(|_| rng.f64()).collect::<Vec<_>>(),
            |xs| {
                if xs.iter().all(|x| (0.0..1.0).contains(x)) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let res = forall(
            &Config { cases: 64, seed: 9, max_size: 64 },
            |rng, size| (0..size).map(|_| rng.below(100)).collect::<Vec<_>>(),
            |xs: &Vec<usize>| {
                // false claim: vectors never contain a value > 10
                if xs.iter().all(|&x| x <= 10) {
                    Ok(())
                } else {
                    Err(format!("found {:?}", xs.iter().max()))
                }
            },
        );
        let f = res.expect_err("property should fail");
        assert!(f.size <= 64);
        assert!(f.message.contains("found"));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            forall(
                &Config { cases: 32, seed: 1234, max_size: 32 },
                |rng, size| rng.below(size.max(1)),
                |&x| if x < 30 { Ok(()) } else { Err(format!("{x}")) },
            )
            .err()
            .map(|f| (f.case, f.size))
        };
        assert_eq!(run(), run());
    }
}
