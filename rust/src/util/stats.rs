//! Summary-statistics helpers shared by the simulator, predictors and
//! benchmark harnesses.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance; 0.0 for < 2 elements.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation (σ/μ); 0.0 when the mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

/// Root mean square error between predictions and labels.
pub fn rmse(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    if pred.is_empty() {
        return 0.0;
    }
    let se: f64 = pred
        .iter()
        .zip(actual)
        .map(|(p, a)| (p - a) * (p - a))
        .sum();
    (se / pred.len() as f64).sqrt()
}

/// Relative error |pred - actual| / actual (actual must be non-zero).
pub fn rel_err(pred: f64, actual: f64) -> f64 {
    (pred - actual).abs() / actual.abs()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Index of the minimum value (first on ties); None when empty.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_and_degenerate() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[5.0]), 0.0);
        assert_eq!(cv(&[0.0, 0.0]), 0.0);
        assert_eq!(rmse(&[], &[]), 0.0);
    }

    #[test]
    fn rmse_known_value() {
        assert!((rmse(&[1.0, 2.0], &[2.0, 4.0]) - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn argmin_ties_take_first() {
        assert_eq!(argmin(&[3.0, 1.0, 1.0]), Some(1));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn rel_err_symmetric_magnitude() {
        assert!((rel_err(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert!((rel_err(90.0, 100.0) - 0.1).abs() < 1e-12);
    }
}
