//! # Blink — lightweight sample runs for cost optimization of big data apps
//!
//! Full reproduction of *"Blink: Lightweight Sample Runs for Cost
//! Optimization of Big Data Applications"* (Al-Sayeh et al., 2022) as a
//! three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: a Spark-like in-memory cluster
//!   substrate ([`sim`], [`memory`], [`dag`], [`hdfs`]), the Blink framework
//!   itself ([`blink`]: the session-oriented `Advisor`/`TrainedProfile` API
//!   — profile once, query many — over the sample-runs manager, size/memory
//!   predictors, cluster-size selector and the catalog-driven fleet
//!   planner, with typed text/JSON reports per query), the
//!   Ernest baseline ([`ernest`]), workload models of the eight HiBench
//!   apps plus a seeded synthetic-workload generator
//!   ([`workloads`], [`workloads::synth`]), a differential test harness
//!   asserting cross-layer invariants over that unbounded workload space
//!   ([`testkit`]), metrics accounting ([`metrics`]) with pluggable
//!   pricing ([`cost`]), and the PJRT runtime that executes the
//!   AOT-compiled JAX artifacts ([`runtime`], [`compute`]).
//! * **L2 (python/compile/model.py)** — jax compute graphs (workload
//!   iteration steps + the batched predictor fit).
//! * **L1 (python/compile/kernels/)** — Pallas kernels (interpret=True),
//!   lowered once by `make artifacts`; Python never runs at request time.
//!
//! See DESIGN.md for the module inventory, the per-table/figure experiment
//! index, and the planner/pricing design notes.

pub mod blink;
pub mod compute;
pub mod coordinator;
pub mod cost;
pub mod dag;
pub mod ernest;
pub mod experiments;
pub mod hdfs;
pub mod linalg;
pub mod memory;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;
pub mod workloads;
