//! The candidate model zoo + cross-validated fitting (§5.2).
//!
//! The paper: "the data size predictor applies cross validation to
//! determine the error of each model ... although [it] evaluates many
//! other models", converging on the linear Eq. 1. We fit every candidate
//! with non-negative least squares (scipy `curve_fit` with positive
//! bounds in the paper) and score by leave-one-out CV RMSE.
//!
//! Fitting dispatches through [`FitBackend`]: the production path executes
//! the whole batch of (model x fold) problems as ONE call of the
//! AOT-compiled Pallas `linfit` executable (see `runtime::linfit`); the
//! pure-Rust [`RustFit`] is the fallback and test oracle — both implement
//! the same projected-gradient NNLS.

use crate::linalg;

/// Feature families evaluated per dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// `θ0 + θ1·s` — the paper's Eq. 1.
    Linear,
    /// `θ0 + θ1·√s` — sublinear growth.
    Sqrt,
    /// `θ0 + θ1·s + θ2·s²` — superlinear growth.
    Quadratic,
    /// `θ0 + θ1·s + θ2·ln(1+s)` — linear with a logarithmic correction.
    LinearLog,
}

pub const ALL_KINDS: [ModelKind; 4] = [
    ModelKind::Linear,
    ModelKind::Sqrt,
    ModelKind::Quadratic,
    ModelKind::LinearLog,
];

impl ModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Linear => "linear",
            ModelKind::Sqrt => "sqrt",
            ModelKind::Quadratic => "quadratic",
            ModelKind::LinearLog => "linear+log",
        }
    }

    /// Build the feature row for a scale.
    pub fn features(&self, s: f64) -> Vec<f64> {
        match self {
            ModelKind::Linear => vec![1.0, s],
            ModelKind::Sqrt => vec![1.0, s.sqrt()],
            ModelKind::Quadratic => vec![1.0, s, s * s],
            ModelKind::LinearLog => vec![1.0, s, (1.0 + s).ln()],
        }
    }

    pub fn num_features(&self) -> usize {
        self.features(1.0).len()
    }
}

/// One NNLS problem handed to a fit backend.
#[derive(Debug, Clone)]
pub struct FitProblem {
    /// Design matrix rows (n points x k features).
    pub x: Vec<Vec<f64>>,
    pub y: Vec<f64>,
    /// Row weights; 0 excludes a row (CV folds / padding).
    pub w: Vec<f64>,
}

/// Result of one fit: coefficients + residual RMSE over active rows.
#[derive(Debug, Clone)]
pub struct FitResult {
    pub theta: Vec<f64>,
    pub rmse: f64,
}

/// Batched NNLS fitting service.
pub trait FitBackend {
    fn fit_batch(&mut self, problems: &[FitProblem]) -> Vec<FitResult>;
    /// Human-readable backend name for reports.
    fn name(&self) -> &'static str;
}

/// Pure-Rust backend (oracle / fallback when artifacts are absent).
pub struct RustFit {
    pub iters: usize,
}

impl Default for RustFit {
    fn default() -> Self {
        RustFit { iters: 3000 }
    }
}

impl FitBackend for RustFit {
    fn fit_batch(&mut self, problems: &[FitProblem]) -> Vec<FitResult> {
        problems
            .iter()
            .map(|p| {
                let theta = linalg::nnls(&p.x, &p.y, &p.w, self.iters);
                let rmse = linalg::residual_rmse(&p.x, &p.y, &p.w, &theta);
                FitResult { theta, rmse }
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "rust-nnls"
    }
}

/// A fitted, selected model for one measured quantity.
#[derive(Debug, Clone)]
pub struct SelectedModel {
    pub kind: ModelKind,
    pub theta: Vec<f64>,
    /// Leave-one-out cross-validation RMSE (the paper's model-error
    /// criterion, §5.2 / Fig. 9).
    pub cv_rmse: f64,
    /// CV RMSE relative to the mean label (dimensionless, reported in
    /// Fig. 9 as e.g. "53.9 % with 3 sample runs").
    pub cv_rel_err: f64,
}

impl SelectedModel {
    pub fn predict(&self, scale: f64) -> f64 {
        linalg::predict(&self.kind.features(scale), &self.theta)
    }
}

/// Fit all candidate models to `(scale, value)` points with LOO-CV and
/// return the best (lowest CV RMSE; ties prefer the simpler/earlier kind).
///
/// The whole (model x fold) grid is submitted as one `fit_batch` call so
/// the PJRT backend can run it as a single batched kernel dispatch.
pub fn select_model(
    backend: &mut dyn FitBackend,
    points: &[(f64, f64)],
) -> SelectedModel {
    assert!(points.len() >= 2, "need at least two sample runs (§4.4)");
    let n = points.len();
    let mean_y: f64 = points.iter().map(|p| p.1).sum::<f64>() / n as f64;

    // Only consider families whose LOO folds stay determined (k features
    // need k points in every n-1-sized fold); with the paper's 3 sample
    // runs that admits the 2-parameter families, matching its Eq. 1.
    let kinds: Vec<ModelKind> = ALL_KINDS
        .into_iter()
        .filter(|k| k.num_features() <= (n - 1).max(2))
        .collect();

    // batch layout: for each kind -> n fold problems + 1 full fit
    let mut problems = Vec::new();
    for kind in &kinds {
        let x: Vec<Vec<f64>> = points.iter().map(|p| kind.features(p.0)).collect();
        let y: Vec<f64> = points.iter().map(|p| p.1).collect();
        for fold in 0..n {
            let mut w = vec![1.0; n];
            w[fold] = 0.0;
            problems.push(FitProblem { x: x.clone(), y: y.clone(), w });
        }
        problems.push(FitProblem { x, y: y.clone(), w: vec![1.0; n] });
    }
    let results = backend.fit_batch(&problems);
    assert_eq!(results.len(), problems.len());

    let mut best: Option<SelectedModel> = None;
    for (ki, kind) in kinds.iter().enumerate() {
        let base = ki * (n + 1);
        // LOO-CV: predict each held-out point with the fold model
        let mut se = 0.0;
        for fold in 0..n {
            let theta = &results[base + fold].theta;
            let pred = linalg::predict(&kind.features(points[fold].0), theta);
            se += (pred - points[fold].1).powi(2);
        }
        let cv_rmse = (se / n as f64).sqrt();
        let full = &results[base + n];
        let candidate = SelectedModel {
            kind: *kind,
            theta: full.theta.clone(),
            cv_rmse,
            cv_rel_err: if mean_y.abs() > 1e-12 { cv_rmse / mean_y } else { 0.0 },
        };
        // Complexity guard: the paper's measurements always favored the
        // linear Eq. 1; a non-linear family may only displace it when its
        // cross-validation error is DECISIVELY lower (40 %+), because the
        // predictor extrapolates 2-6 orders of magnitude beyond the
        // sample scales and a noise-chasing quadratic/sqrt is
        // catastrophic out there.
        let better = match &best {
            None => true,
            Some(b) => {
                if *kind == ModelKind::Linear {
                    cv_rmse < b.cv_rmse - 1e-12
                } else {
                    cv_rmse < 0.6 * b.cv_rmse
                }
            }
        };
        if better {
            best = Some(candidate);
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_data_selects_linear_family() {
        let pts: Vec<(f64, f64)> = (1..=5).map(|s| (s as f64, 4.0 + 2.5 * s as f64)).collect();
        let m = select_model(&mut RustFit::default(), &pts);
        // quadratic with zero curvature also fits; accept any family but
        // demand exact predictions
        assert!((m.predict(1000.0) - (4.0 + 2500.0)).abs() / 2504.0 < 0.01, "{m:?}");
        assert!(m.cv_rel_err < 0.01, "{m:?}");
    }

    #[test]
    fn quadratic_data_prefers_quadratic() {
        let pts: Vec<(f64, f64)> =
            (1..=6).map(|s| (s as f64, 1.0 + 0.5 * (s * s) as f64)).collect();
        let m = select_model(&mut RustFit::default(), &pts);
        assert_eq!(m.kind, ModelKind::Quadratic);
        assert!((m.predict(10.0) - 51.0).abs() < 1.0, "{m:?}");
    }

    #[test]
    fn cv_error_reflects_noise() {
        let clean: Vec<(f64, f64)> = (1..=4).map(|s| (s as f64, 10.0 * s as f64)).collect();
        let noisy: Vec<(f64, f64)> = vec![(1.0, 12.0), (2.0, 17.0), (3.0, 35.0), (4.0, 36.0)];
        let mc = select_model(&mut RustFit::default(), &clean);
        let mn = select_model(&mut RustFit::default(), &noisy);
        assert!(mc.cv_rel_err < 0.01);
        assert!(mn.cv_rel_err > mc.cv_rel_err * 5.0);
    }

    #[test]
    fn coefficients_never_negative() {
        // decreasing data would want a negative slope; bounds forbid it
        let pts = vec![(1.0, 10.0), (2.0, 8.0), (3.0, 6.5)];
        let m = select_model(&mut RustFit::default(), &pts);
        assert!(m.theta.iter().all(|&t| t >= 0.0), "{m:?}");
    }

    #[test]
    fn two_points_suffice() {
        // §4.4: "two sample runs are sufficient to construct a model"
        let pts = vec![(1.0, 5.0), (3.0, 11.0)];
        let m = select_model(&mut RustFit::default(), &pts);
        assert!((m.predict(2.0) - 8.0).abs() < 0.3, "{m:?}");
    }

    #[test]
    fn features_shapes() {
        assert_eq!(ModelKind::Linear.num_features(), 2);
        assert_eq!(ModelKind::Quadratic.num_features(), 3);
        for k in ALL_KINDS {
            assert_eq!(k.features(2.0).len(), k.num_features());
        }
    }
}
