//! Advisor-as-a-service: persistent profiles + a sharded concurrent store.
//!
//! The session API ([`super::session`]) amortizes one sampling phase
//! across many queries, but its cache dies with the process and serializes
//! every caller through one `&mut` advisor. This module closes both gaps:
//!
//! * **Persistent profiles** — [`save_profile`] / [`load_profile`] encode a
//!   [`TrainedProfile`] as a `util::json` document. Every f64 is stored as
//!   its exact 16-hex-digit bit pattern, so a round-tripped profile answers
//!   `recommend`/`plan`/`max_scale` *bit-identically* to the in-process
//!   one. A fingerprint block (app name, the scalar-parameter bits of
//!   [`app_fingerprint`], the exact sampling-scale bits, and the predictor
//!   version) is validated on load: a stale profile for a changed app is
//!   rejected with a typed [`StoreError`] instead of silently answering.
//! * **[`ProfileStore`]** — N shards of `RwLock<HashMap<key, cell>>`,
//!   keyed by the same `(app name, fingerprint bits, scale bits)` tuple as
//!   the advisor cache and sharded by its hash. Reads never block reads
//!   (shared `read()` lock, clone the `Arc`, drop the lock); all compute
//!   on a profile happens with zero locks held. A cold miss inserts an
//!   empty per-key `OnceLock` cell under a brief shard write lock and
//!   trains *outside* it, so each key pays exactly one sampling phase
//!   (`sampling_phases()` counts the real trainings) and a slow training
//!   only blocks callers of that same key, never the shard's other keys.
//! * **[`serve_batch`]** — the `blink serve` loop: one `util::json` query
//!   doc per JSONL line, fanned out over [`crate::util::par`] workers,
//!   answers re-placed by line index (output position N answers input
//!   line N, blank lines included). Each answer is the same JSON the
//!   tested `--format json` CLI contract emits (or a per-query error doc
//!   carrying its 1-based `line` — a malformed line never aborts the
//!   batch). Because every answer is a pure function of its line and the
//!   trained profile is a pure function of `(app, scales, config)` no
//!   matter which racing thread trains it, the output is byte-identical
//!   at any shard or thread count.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use super::models::{FitBackend, ModelKind, RustFit, SelectedModel, ALL_KINDS};
use super::predictor::{ExecMemoryPredictor, SizePredictor};
use super::report::{BoundsReport, PlanReport, RecommendReport, Report};
use super::sample_runs::{SampleRun, SampleRunsManager};
use super::session::{app_fingerprint, normalize_scales, ScaleError, Scales, TrainedProfile};
use crate::cost::pricing_by_name;
use crate::metrics::RunSummary;
use crate::sim::{InstanceCatalog, MachineSpec};
use crate::util::json::{parse, Json};
use crate::util::par::{sweep_range_serial, sweep_range_with};
use crate::workloads::{app_by_name, AppModel, DagSpec, SizeLaw, SizeNoise, SynthConfig};

/// Version of the on-disk profile document layout.
pub const PROFILE_FORMAT_VERSION: u64 = 1;
/// Version of the predictor pipeline a profile was trained with; bump on
/// any change to model families, CV folds, or fitting numerics, so stale
/// trained state is rejected instead of silently answering differently.
pub const PREDICTOR_VERSION: u64 = 1;

/// Typed failure of profile persistence or store intake.
#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    /// Filesystem failure (message carries the path).
    Io(String),
    /// The file is not a `util::json` document.
    Parse(String),
    /// The document is JSON but not a profile of the expected shape.
    Schema(String),
    /// The document's format version is not this build's.
    Version { found: u64, expected: u64 },
    /// The stored fingerprint does not match the live application — the
    /// profile is stale (the app changed since it was trained) or the
    /// file was edited.
    Fingerprint { field: &'static str, app: String },
    /// The stored app name resolves to no live application.
    UnknownApp(String),
    /// The profile's sampling scales fail advisor intake validation.
    InvalidScale(ScaleError),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(m) => write!(f, "profile io error: {m}"),
            StoreError::Parse(m) => write!(f, "profile parse error: {m}"),
            StoreError::Schema(m) => write!(f, "profile schema error: {m}"),
            StoreError::Version { found, expected } => {
                write!(f, "profile format version {found} (this build reads {expected})")
            }
            StoreError::Fingerprint { field, app } => {
                write!(f, "stale profile for '{app}': fingerprint mismatch in {field}")
            }
            StoreError::UnknownApp(a) => write!(f, "profile for unknown app '{a}'"),
            StoreError::InvalidScale(e) => write!(f, "profile has invalid scales: {e}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<ScaleError> for StoreError {
    fn from(e: ScaleError) -> Self {
        StoreError::InvalidScale(e)
    }
}

// ======================================================================
// Bit-exact JSON encoding
// ======================================================================
//
// `Json::Num` is an f64 and the pretty-printer formats for humans, so
// floats round-trip *approximately* through text. Profiles must round-trip
// *exactly* (the acceptance bar is bit-identical answers), so every f64 is
// stored as its 16-hex-digit `to_bits()` string — which also survives
// NaN/±∞/-0.0, none of which JSON numbers can carry.

fn bits(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

fn u64_hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn schema(what: &str) -> StoreError {
    StoreError::Schema(format!("missing or malformed field '{what}'"))
}

fn get<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a Json, StoreError> {
    j.get(key).ok_or_else(|| schema(&format!("{ctx}.{key}")))
}

fn f64_bits(j: &Json, key: &str, ctx: &str) -> Result<f64, StoreError> {
    let s = get(j, key, ctx)?.as_str().ok_or_else(|| schema(&format!("{ctx}.{key}")))?;
    let b = u64::from_str_radix(s, 16)
        .map_err(|_| StoreError::Schema(format!("'{ctx}.{key}' is not a hex bit pattern")))?;
    Ok(f64::from_bits(b))
}

fn u64_field(j: &Json, key: &str, ctx: &str) -> Result<u64, StoreError> {
    let s = get(j, key, ctx)?.as_str().ok_or_else(|| schema(&format!("{ctx}.{key}")))?;
    u64::from_str_radix(s, 16)
        .map_err(|_| StoreError::Schema(format!("'{ctx}.{key}' is not a hex u64")))
}

fn usize_of(v: &Json, what: &str) -> Result<usize, StoreError> {
    let f = v.as_f64().ok_or_else(|| schema(what))?;
    if f < 0.0 || f.fract() != 0.0 || f > (1u64 << 53) as f64 {
        return Err(StoreError::Schema(format!("'{what}' is not a small integer")));
    }
    Ok(f as usize)
}

fn usize_field(j: &Json, key: &str, ctx: &str) -> Result<usize, StoreError> {
    usize_of(get(j, key, ctx)?, &format!("{ctx}.{key}"))
}

fn str_field<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a str, StoreError> {
    get(j, key, ctx)?.as_str().ok_or_else(|| schema(&format!("{ctx}.{key}")))
}

fn arr_field<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a [Json], StoreError> {
    get(j, key, ctx)?.as_arr().ok_or_else(|| schema(&format!("{ctx}.{key}")))
}

fn bits_arr(values: &[f64]) -> Json {
    Json::Arr(values.iter().map(|&v| bits(v)).collect())
}

fn bits_arr_from(j: &Json, key: &str, ctx: &str) -> Result<Vec<f64>, StoreError> {
    arr_field(j, key, ctx)?
        .iter()
        .map(|v| {
            let s = v.as_str().ok_or_else(|| schema(&format!("{ctx}.{key}[]")))?;
            let b = u64::from_str_radix(s, 16)
                .map_err(|_| StoreError::Schema(format!("'{ctx}.{key}[]' bad bit pattern")))?;
            Ok(f64::from_bits(b))
        })
        .collect()
}

// ======================================================================
// Domain encodings
// ======================================================================

fn law_json(l: &SizeLaw) -> Json {
    Json::obj(vec![
        ("theta0", bits(l.theta0)),
        ("theta1", bits(l.theta1)),
        ("gamma", bits(l.gamma)),
    ])
}

fn law_from(j: &Json, ctx: &str) -> Result<SizeLaw, StoreError> {
    Ok(SizeLaw {
        theta0: f64_bits(j, "theta0", ctx)?,
        theta1: f64_bits(j, "theta1", ctx)?,
        gamma: f64_bits(j, "gamma", ctx)?,
    })
}

fn noise_json(n: &SizeNoise) -> Json {
    Json::obj(vec![
        ("amp", bits(n.amp)),
        ("half_mb", bits(n.half_mb)),
        ("bias", bits(n.bias)),
    ])
}

fn noise_from(j: &Json, ctx: &str) -> Result<SizeNoise, StoreError> {
    Ok(SizeNoise {
        amp: f64_bits(j, "amp", ctx)?,
        half_mb: f64_bits(j, "half_mb", ctx)?,
        bias: f64_bits(j, "bias", ctx)?,
    })
}

/// A [`DagSpec::Builtin`] holds a fn pointer, which cannot be serialized —
/// but every builtin DAG belongs to exactly one registry app, so the app
/// *name* is its durable spelling and the registry restores the pointer.
fn dag_json(d: &DagSpec, app_name: &str) -> Json {
    match d {
        DagSpec::Builtin(_) => Json::obj(vec![("builtin", app_name.into())]),
        DagSpec::Layered { depth, width, cached, iterations } => Json::obj(vec![(
            "layered",
            Json::obj(vec![
                ("depth", (*depth).into()),
                ("width", (*width).into()),
                ("cached", (*cached).into()),
                ("iterations", (*iterations).into()),
            ]),
        )]),
    }
}

fn dag_from(j: &Json, ctx: &str) -> Result<DagSpec, StoreError> {
    if let Some(name) = j.get("builtin").and_then(Json::as_str) {
        let app = app_by_name(name).ok_or_else(|| StoreError::UnknownApp(name.to_string()))?;
        return Ok(app.dag_spec);
    }
    if let Some(l) = j.get("layered") {
        return Ok(DagSpec::Layered {
            depth: usize_field(l, "depth", ctx)?,
            width: usize_field(l, "width", ctx)?,
            cached: usize_field(l, "cached", ctx)?,
            iterations: usize_field(l, "iterations", ctx)?,
        });
    }
    Err(schema(&format!("{ctx}.dag")))
}

fn app_json(a: &AppModel) -> Json {
    Json::obj(vec![
        ("name", a.name.as_str().into()),
        ("input_mb_full", bits(a.input_mb_full)),
        ("blocks_full", a.blocks_full.into()),
        ("cached_laws", Json::Arr(a.cached_laws.iter().map(law_json).collect())),
        ("exec_law", law_json(&a.exec_law)),
        ("size_noise", noise_json(&a.size_noise)),
        ("iterations", a.iterations.into()),
        ("compute_s_per_mb", bits(a.compute_s_per_mb)),
        ("cached_speedup", bits(a.cached_speedup)),
        ("recompute_factor", bits(a.recompute_factor)),
        ("serial_fixed_s", bits(a.serial_fixed_s)),
        ("serial_per_scale_s", bits(a.serial_per_scale_s)),
        ("shuffle_mb_full", bits(a.shuffle_mb_full)),
        ("task_overhead_s", bits(a.task_overhead_s)),
        ("task_time_sigma", bits(a.task_time_sigma)),
        ("per_partition_overhead_mb", bits(a.per_partition_overhead_mb)),
        ("parallelism_cap", a.parallelism_cap.map_or(Json::Null, Json::from)),
        ("force_block_s", a.force_block_s.into()),
        ("enlarged_scale", bits(a.enlarged_scale)),
        ("dag", dag_json(&a.dag_spec, &a.name)),
    ])
}

fn app_from(j: &Json) -> Result<AppModel, StoreError> {
    let ctx = "app";
    let laws = arr_field(j, "cached_laws", ctx)?
        .iter()
        .map(|l| law_from(l, "app.cached_laws[]"))
        .collect::<Result<Vec<_>, _>>()?;
    let parallelism_cap = match get(j, "parallelism_cap", ctx)? {
        Json::Null => None,
        other => Some(usize_of(other, "app.parallelism_cap")?),
    };
    Ok(AppModel {
        name: str_field(j, "name", ctx)?.to_string(),
        input_mb_full: f64_bits(j, "input_mb_full", ctx)?,
        blocks_full: usize_field(j, "blocks_full", ctx)?,
        cached_laws: laws,
        exec_law: law_from(get(j, "exec_law", ctx)?, "app.exec_law")?,
        size_noise: noise_from(get(j, "size_noise", ctx)?, "app.size_noise")?,
        iterations: usize_field(j, "iterations", ctx)?,
        compute_s_per_mb: f64_bits(j, "compute_s_per_mb", ctx)?,
        cached_speedup: f64_bits(j, "cached_speedup", ctx)?,
        recompute_factor: f64_bits(j, "recompute_factor", ctx)?,
        serial_fixed_s: f64_bits(j, "serial_fixed_s", ctx)?,
        serial_per_scale_s: f64_bits(j, "serial_per_scale_s", ctx)?,
        shuffle_mb_full: f64_bits(j, "shuffle_mb_full", ctx)?,
        task_overhead_s: f64_bits(j, "task_overhead_s", ctx)?,
        task_time_sigma: f64_bits(j, "task_time_sigma", ctx)?,
        per_partition_overhead_mb: f64_bits(j, "per_partition_overhead_mb", ctx)?,
        parallelism_cap,
        force_block_s: get(j, "force_block_s", ctx)?
            .as_bool()
            .ok_or_else(|| schema("app.force_block_s"))?,
        enlarged_scale: f64_bits(j, "enlarged_scale", ctx)?,
        dag_spec: dag_from(get(j, "dag", ctx)?, "app.dag")?,
    })
}

fn summary_json(s: &RunSummary) -> Json {
    Json::obj(vec![
        ("app", s.app.as_str().into()),
        ("machines", s.machines.into()),
        ("data_scale", bits(s.data_scale)),
        ("duration_s", bits(s.duration_s)),
        (
            "cached_sizes_mb",
            Json::Arr(
                s.cached_sizes_mb
                    .iter()
                    .map(|(id, mb)| Json::obj(vec![("id", (*id).into()), ("mb", bits(*mb))]))
                    .collect(),
            ),
        ),
        ("evictions", s.evictions.into()),
        ("exec_memory_mb", bits(s.exec_memory_mb)),
        ("tasks", s.tasks.into()),
        ("cached_reads", s.cached_reads.into()),
        ("machines_lost", s.machines_lost.into()),
        ("machines_joined", s.machines_joined.into()),
        ("cost_machine_s", bits(s.cost_machine_s)),
    ])
}

fn summary_from(j: &Json) -> Result<RunSummary, StoreError> {
    let ctx = "run.summary";
    let sizes = arr_field(j, "cached_sizes_mb", ctx)?
        .iter()
        .map(|e| Ok((usize_field(e, "id", ctx)?, f64_bits(e, "mb", ctx)?)))
        .collect::<Result<Vec<_>, StoreError>>()?;
    Ok(RunSummary {
        app: str_field(j, "app", ctx)?.to_string(),
        machines: usize_field(j, "machines", ctx)?,
        data_scale: f64_bits(j, "data_scale", ctx)?,
        duration_s: f64_bits(j, "duration_s", ctx)?,
        cached_sizes_mb: sizes,
        evictions: usize_field(j, "evictions", ctx)?,
        exec_memory_mb: f64_bits(j, "exec_memory_mb", ctx)?,
        tasks: usize_field(j, "tasks", ctx)?,
        cached_reads: usize_field(j, "cached_reads", ctx)?,
        machines_lost: usize_field(j, "machines_lost", ctx)?,
        machines_joined: usize_field(j, "machines_joined", ctx)?,
        cost_machine_s: f64_bits(j, "cost_machine_s", ctx)?,
    })
}

fn run_json(r: &SampleRun) -> Json {
    Json::obj(vec![
        ("scale", bits(r.scale)),
        ("summary", summary_json(&r.summary)),
        ("rescaled", r.rescaled.into()),
    ])
}

fn run_from(j: &Json) -> Result<SampleRun, StoreError> {
    Ok(SampleRun {
        scale: f64_bits(j, "scale", "run")?,
        summary: summary_from(get(j, "summary", "run")?)?,
        rescaled: get(j, "rescaled", "run")?.as_bool().ok_or_else(|| schema("run.rescaled"))?,
    })
}

fn kind_by_name(name: &str) -> Option<ModelKind> {
    ALL_KINDS.into_iter().find(|k| k.name() == name)
}

fn model_json(m: &SelectedModel) -> Json {
    Json::obj(vec![
        ("kind", m.kind.name().into()),
        ("theta", bits_arr(&m.theta)),
        ("cv_rmse", bits(m.cv_rmse)),
        ("cv_rel_err", bits(m.cv_rel_err)),
    ])
}

fn model_from(j: &Json, ctx: &str) -> Result<SelectedModel, StoreError> {
    let kind_name = str_field(j, "kind", ctx)?;
    let kind = kind_by_name(kind_name)
        .ok_or_else(|| StoreError::Schema(format!("unknown model kind '{kind_name}'")))?;
    Ok(SelectedModel {
        kind,
        theta: bits_arr_from(j, "theta", ctx)?,
        cv_rmse: f64_bits(j, "cv_rmse", ctx)?,
        cv_rel_err: f64_bits(j, "cv_rel_err", ctx)?,
    })
}

fn predictors_json(sizes: &SizePredictor, exec: &ExecMemoryPredictor) -> Json {
    Json::obj(vec![
        (
            "sizes",
            Json::Arr(
                sizes
                    .models
                    .iter()
                    .map(|(ds, m)| {
                        Json::obj(vec![("dataset", (*ds).into()), ("model", model_json(m))])
                    })
                    .collect(),
            ),
        ),
        ("exec", model_json(&exec.model)),
    ])
}

fn predictors_from(j: &Json) -> Result<(SizePredictor, ExecMemoryPredictor), StoreError> {
    let mut models = std::collections::BTreeMap::new();
    for entry in arr_field(j, "sizes", "models")? {
        let ds = usize_field(entry, "dataset", "models.sizes[]")?;
        models.insert(ds, model_from(get(entry, "model", "models.sizes[]")?, "models.sizes[]")?);
    }
    let exec = model_from(get(j, "exec", "models")?, "models.exec")?;
    Ok((SizePredictor { models }, ExecMemoryPredictor { model: exec }))
}

fn fingerprint_json(app: &AppModel, scales: &[f64]) -> Json {
    Json::obj(vec![
        ("app", app.name.as_str().into()),
        ("app_bits", Json::Arr(app_fingerprint(app).into_iter().map(u64_hex).collect())),
        ("scale_bits", Json::Arr(scales.iter().map(|s| u64_hex(s.to_bits())).collect())),
        ("predictor_version", u64_hex(PREDICTOR_VERSION)),
    ])
}

fn hex_arr(j: &Json, key: &str, ctx: &str) -> Result<Vec<u64>, StoreError> {
    arr_field(j, key, ctx)?
        .iter()
        .map(|v| {
            let s = v.as_str().ok_or_else(|| schema(&format!("{ctx}.{key}[]")))?;
            u64::from_str_radix(s, 16)
                .map_err(|_| StoreError::Schema(format!("'{ctx}.{key}[]' bad hex")))
        })
        .collect()
}

/// Encode a trained profile as a self-describing `util::json` document.
pub fn profile_to_json(p: &TrainedProfile) -> Json {
    Json::obj(vec![
        ("blink_profile", u64_hex(PROFILE_FORMAT_VERSION)),
        ("fingerprint", fingerprint_json(&p.app, &p.scales)),
        (
            "profile",
            Json::obj(vec![
                ("app", app_json(&p.app)),
                ("scales", bits_arr(&p.scales)),
                ("max_machines", p.max_machines.into()),
                ("sample_cost_machine_s", bits(p.sample_cost_machine_s)),
                ("runs", Json::Arr(p.runs.iter().map(run_json).collect())),
                (
                    "models",
                    p.models
                        .as_ref()
                        .map_or(Json::Null, |(s, e)| predictors_json(s, e)),
                ),
            ]),
        ),
    ])
}

/// Decode a profile document, verifying the format version and that the
/// embedded fingerprint matches the *decoded* app and scales (a tampered
/// or truncated file fails here, before any query can consult it).
pub fn profile_from_json(doc: &Json) -> Result<TrainedProfile, StoreError> {
    let found = u64_field(doc, "blink_profile", "")?;
    if found != PROFILE_FORMAT_VERSION {
        return Err(StoreError::Version { found, expected: PROFILE_FORMAT_VERSION });
    }
    let body = get(doc, "profile", "")?;
    let app = app_from(get(body, "app", "profile")?)?;
    let scales = bits_arr_from(body, "scales", "profile")?;
    let runs = arr_field(body, "runs", "profile")?
        .iter()
        .map(run_from)
        .collect::<Result<Vec<_>, _>>()?;
    let models = match get(body, "models", "profile")? {
        Json::Null => None,
        m => Some(predictors_from(m)?),
    };
    let profile = TrainedProfile {
        app,
        scales,
        max_machines: usize_field(body, "max_machines", "profile")?,
        sample_cost_machine_s: f64_bits(body, "sample_cost_machine_s", "profile")?,
        runs,
        models,
    };
    // self-consistency: the stored fingerprint must match what the decoded
    // payload implies
    let fp = get(doc, "fingerprint", "")?;
    if str_field(fp, "app", "fingerprint")? != profile.app.name {
        return Err(StoreError::Fingerprint { field: "app", app: profile.app.name });
    }
    if u64_field(fp, "predictor_version", "fingerprint")? != PREDICTOR_VERSION {
        return Err(StoreError::Fingerprint {
            field: "predictor_version",
            app: profile.app.name,
        });
    }
    if hex_arr(fp, "app_bits", "fingerprint")? != app_fingerprint(&profile.app) {
        return Err(StoreError::Fingerprint { field: "app_bits", app: profile.app.name });
    }
    let scale_bits: Vec<u64> = profile.scales.iter().map(|s| s.to_bits()).collect();
    if hex_arr(fp, "scale_bits", "fingerprint")? != scale_bits {
        return Err(StoreError::Fingerprint { field: "scale_bits", app: profile.app.name });
    }
    Ok(profile)
}

/// Write `profile` to `path` as a pretty-printed JSON document.
pub fn save_profile(profile: &TrainedProfile, path: &Path) -> Result<(), StoreError> {
    let doc = profile_to_json(profile).pretty();
    std::fs::write(path, doc + "\n")
        .map_err(|e| StoreError::Io(format!("write {}: {e}", path.display())))
}

/// Load a profile from `path` and validate it against the *live*
/// definition of the application: the stored fingerprint must match
/// `app_fingerprint(live)` exactly, or the profile is stale (the app's
/// laws changed since training) and is rejected with a typed error.
pub fn load_profile(path: &Path, live: &AppModel) -> Result<TrainedProfile, StoreError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| StoreError::Io(format!("read {}: {e}", path.display())))?;
    let doc = parse(&text).map_err(|e| StoreError::Parse(format!("{}: {e}", path.display())))?;
    let profile = profile_from_json(&doc)?;
    if profile.app.name != live.name {
        return Err(StoreError::Fingerprint { field: "app", app: live.name.clone() });
    }
    if app_fingerprint(&profile.app) != app_fingerprint(live) {
        return Err(StoreError::Fingerprint { field: "app_bits", app: live.name.clone() });
    }
    Ok(profile)
}

// ======================================================================
// The sharded concurrent store
// ======================================================================

/// Same identity as the advisor's cache key: app name + scalar-parameter
/// fingerprint + exact (normalized) sampling-scale bits.
type StoreKey = (String, Vec<u64>, Vec<u64>);

fn store_key(app: &AppModel, scales: &[f64]) -> StoreKey {
    (app.name.clone(), app_fingerprint(app), scales.iter().map(|s| s.to_bits()).collect())
}

/// One key's slot. The cell is *created* under a brief shard write lock
/// but *filled* (trained) outside any shard lock, so a cold miss only
/// blocks callers of the same key — `OnceLock` runs the training closure
/// exactly once however many threads race it.
type ProfileCell = Arc<OnceLock<Arc<TrainedProfile>>>;

/// Configures a [`ProfileStore`].
pub struct ProfileStoreBuilder {
    shards: usize,
    max_machines: usize,
    scales: Scales,
    manager: SampleRunsManager,
}

impl Default for ProfileStoreBuilder {
    fn default() -> Self {
        ProfileStoreBuilder {
            shards: 8,
            max_machines: 12,
            scales: Scales::Paper,
            manager: SampleRunsManager::default(),
        }
    }
}

impl ProfileStoreBuilder {
    /// Shard count (≥ 1). Sharding only spreads lock contention; answers
    /// are identical at any count.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    pub fn max_machines(mut self, max_machines: usize) -> Self {
        self.max_machines = max_machines.max(1);
        self
    }

    pub fn scales(mut self, scales: &[f64]) -> Self {
        self.scales = Scales::Fixed(scales.to_vec());
        self
    }

    pub fn scales_policy(mut self, scales: Scales) -> Self {
        self.scales = scales;
        self
    }

    pub fn manager(mut self, manager: SampleRunsManager) -> Self {
        self.manager = manager;
        self
    }

    pub fn build(self) -> ProfileStore {
        ProfileStore {
            shards: (0..self.shards).map(|_| RwLock::new(HashMap::new())).collect(),
            manager: self.manager,
            max_machines: self.max_machines,
            scales: self.scales,
            sampling_phases: AtomicUsize::new(0),
        }
    }
}

/// A sharded, thread-safe profile cache: the [`super::session::Advisor`]
/// cache generalized from `&mut self` to `&self` so any number of threads
/// can query concurrently. Hot reads take one shard's `read()` lock just
/// long enough to clone an `Arc<TrainedProfile>`; all query compute
/// (`recommend`/`plan`/`max_scale`) runs with zero locks held. A miss
/// claims its key's [`ProfileCell`] under a brief shard write lock and
/// trains with no shard lock held: racing writers collapse to exactly one
/// sampling phase per key, and a slow training stalls only that key's
/// callers, not the rest of the shard.
///
/// Training uses the pure-Rust fit backend (it is `Send`-free state built
/// per call); profiles trained elsewhere — including by the PJRT backend —
/// enter via [`ProfileStore::insert`] after [`load_profile`].
pub struct ProfileStore {
    shards: Vec<RwLock<HashMap<StoreKey, ProfileCell>>>,
    manager: SampleRunsManager,
    max_machines: usize,
    scales: Scales,
    sampling_phases: AtomicUsize,
}

impl ProfileStore {
    pub fn builder() -> ProfileStoreBuilder {
        ProfileStoreBuilder::default()
    }

    fn shard_of(&self, key: &StoreKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// The hot path: return the cached profile for `(app, scales)` or
    /// train it exactly once. Scales go through the same intake
    /// validation as the advisor ([`normalize_scales`]).
    pub fn get_or_train(&self, app: &AppModel) -> Result<Arc<TrainedProfile>, ScaleError> {
        let scales = normalize_scales(&self.scales.for_app(app))?;
        let key = store_key(app, &scales);
        let shard = &self.shards[self.shard_of(&key)];
        let cell = shard.read().expect("shard lock poisoned").get(&key).cloned();
        let cell = match cell {
            Some(cell) => cell,
            None => {
                let mut guard = shard.write().expect("shard lock poisoned");
                Arc::clone(guard.entry(key).or_insert_with(|| Arc::new(OnceLock::new())))
            }
        };
        // fill outside any shard lock: only same-key callers wait here,
        // and exactly one of them runs the training closure
        let profile = cell.get_or_init(|| {
            self.sampling_phases.fetch_add(1, Ordering::Relaxed);
            let mut backend = RustFit::default();
            Arc::new(TrainedProfile::train(
                &mut backend,
                &self.manager,
                app,
                &scales,
                self.max_machines,
            ))
        });
        Ok(Arc::clone(profile))
    }

    /// Read-only probe: the cached profile, or `None` without training
    /// (a cell another thread is still training reads as absent).
    pub fn get(&self, app: &AppModel) -> Option<Arc<TrainedProfile>> {
        let scales = normalize_scales(&self.scales.for_app(app)).ok()?;
        let key = store_key(app, &scales);
        self.shards[self.shard_of(&key)]
            .read()
            .expect("shard lock poisoned")
            .get(&key)
            .and_then(|cell| cell.get().cloned())
    }

    /// Seed the store with an externally trained (e.g. loaded) profile,
    /// keyed by its own app and scales. Returns whether the key was new
    /// (losing a fill race with a trainer or another insert is `false`).
    pub fn insert(&self, profile: TrainedProfile) -> Result<bool, ScaleError> {
        let scales = normalize_scales(&profile.scales)?;
        let key = store_key(&profile.app, &scales);
        let shard = &self.shards[self.shard_of(&key)];
        let cell = {
            let mut guard = shard.write().expect("shard lock poisoned");
            Arc::clone(guard.entry(key).or_insert_with(|| Arc::new(OnceLock::new())))
        };
        Ok(cell.set(Arc::new(profile)).is_ok())
    }

    /// How many sampling phases this store actually paid for (loads and
    /// cache hits do not count).
    pub fn sampling_phases(&self) -> usize {
        self.sampling_phases.load(Ordering::Relaxed)
    }

    /// Trained profiles in the store (cells still mid-training excluded).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.read()
                    .expect("shard lock poisoned")
                    .values()
                    .filter(|cell| cell.get().is_some())
                    .count()
            })
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Name of the fit backend cold misses train with.
    pub fn backend_name(&self) -> &'static str {
        RustFit::default().name()
    }

    /// Every stored profile, sorted by key — a deterministic snapshot for
    /// persistence regardless of shard layout or insertion order.
    pub fn profiles(&self) -> Vec<Arc<TrainedProfile>> {
        let mut all: Vec<(StoreKey, Arc<TrainedProfile>)> = Vec::new();
        for shard in &self.shards {
            for (k, cell) in shard.read().expect("shard lock poisoned").iter() {
                if let Some(v) = cell.get() {
                    all.push((k.clone(), Arc::clone(v)));
                }
            }
        }
        all.sort_by(|a, b| a.0.cmp(&b.0));
        all.into_iter().map(|(_, v)| v).collect()
    }
}

// ======================================================================
// The serve loop
// ======================================================================

/// Resolve a serve-query app spelling: a registry name (`svm`), a seeded
/// synthetic workload as `synth:<preset>:<seed>` (the PR 5 generator —
/// what lets one query file exercise hundreds of apps), or the name a
/// generated workload carries (`synth-<preset>-<hexseed>`). The last is
/// what `--save-profiles` writes into `fingerprint.app`, so a saved synth
/// profile resolves on warm restart exactly like a registry one.
pub fn resolve_app(name: &str) -> Option<AppModel> {
    if let Some(rest) = name.strip_prefix("synth:") {
        let (preset, seed) = rest.split_once(':')?;
        let seed: u64 = seed.parse().ok()?;
        return Some(SynthConfig::by_name(preset)?.generate(seed));
    }
    if let Some(rest) = name.strip_prefix("synth-") {
        // generated spelling: preset names carry no '-' and the seed is
        // the `{seed:04x}` hex suffix (see `SynthConfig::generate`)
        if let Some((preset, seed)) = rest.rsplit_once('-') {
            if let (Some(cfg), Ok(seed)) =
                (SynthConfig::by_name(preset), u64::from_str_radix(seed, 16))
            {
                return Some(cfg.generate(seed));
            }
        }
    }
    app_by_name(name)
}

/// One serve answer: the JSON doc (an answer in the `--format json` CLI
/// contract, or an error doc) plus whether the query succeeded.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub doc: Json,
    pub ok: bool,
}

/// `index` is the query's 0-based batch position; the doc carries it
/// 1-based so an error maps straight back to its input line.
fn error_doc(msg: &str, index: usize) -> Json {
    Json::obj(vec![
        ("query", "error".into()),
        ("line", (index + 1).into()),
        ("error", msg.into()),
    ])
}

fn f64_of(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing or non-numeric '{key}'"))
}

/// Answer one JSONL query line against the store. Pure per line: any
/// failure becomes an error doc, never a panic or abort.
fn answer_line(store: &ProfileStore, line: &str) -> Result<Json, String> {
    let q = parse(line).map_err(|e| format!("malformed query line: {e}"))?;
    let kind = q
        .get("query")
        .and_then(Json::as_str)
        .ok_or_else(|| "missing 'query' field".to_string())?;
    let app_name =
        q.get("app").and_then(Json::as_str).ok_or_else(|| "missing 'app' field".to_string())?;
    let app = resolve_app(app_name).ok_or_else(|| format!("unknown app '{app_name}'"))?;
    let profile = store.get_or_train(&app).map_err(|e| e.to_string())?;
    match kind {
        "recommend" => {
            let scale = f64_of(&q, "scale")?;
            Ok(RecommendReport::new(
                store.backend_name(),
                &profile,
                scale,
                &MachineSpec::worker_node(),
                false,
            )
            .to_json())
        }
        "plan" => {
            let scale = f64_of(&q, "scale")?;
            let catalog_name = q.get("catalog").and_then(Json::as_str).unwrap_or("paper");
            let catalog = InstanceCatalog::by_name(catalog_name)
                .ok_or_else(|| format!("unknown catalog '{catalog_name}'"))?;
            let pricing_name =
                q.get("pricing").and_then(Json::as_str).unwrap_or("machine-seconds");
            let pricing = pricing_by_name(pricing_name)
                .ok_or_else(|| format!("unknown pricing model '{pricing_name}'"))?;
            let fractions: Vec<f64> = match q.get("fractions") {
                None | Some(Json::Null) => Vec::new(),
                Some(a) => a
                    .as_arr()
                    .ok_or_else(|| "'fractions' must be an array".to_string())?
                    .iter()
                    .map(|v| {
                        let f = v.as_f64().ok_or("non-numeric storage fraction")?;
                        if !f.is_finite() || f <= 0.0 || f >= 1.0 {
                            return Err("storage fraction out of range (0, 1)");
                        }
                        Ok(f)
                    })
                    .collect::<Result<_, &str>>()
                    .map_err(str::to_string)?,
            };
            let advice = if fractions.is_empty() {
                profile.plan(scale, &catalog, pricing.as_ref())
            } else {
                profile.plan_with_fractions(scale, &catalog, pricing.as_ref(), &fractions)
            };
            Ok(PlanReport {
                backend: store.backend_name().to_string(),
                app: app.name.clone(),
                scale,
                input_mb: app.input_mb(scale),
                predicted_cached_mb: advice.predicted_cached_mb,
                predicted_exec_mb: advice.predicted_exec_mb,
                sample_cost_machine_s: advice.sample_cost_machine_s,
                plan: advice.plan,
                catalog_name: catalog.name.to_string(),
                catalog_types: catalog.instances.len(),
                pricing: pricing.name().to_string(),
                risk: None,
            }
            .to_json())
        }
        "max_scale" => {
            let machines = f64_of(&q, "machines")?;
            if machines < 1.0 || machines.fract() != 0.0 {
                return Err(format!("'machines' must be a positive integer, got {machines}"));
            }
            let machines = machines as usize;
            let s = profile.max_scale(&MachineSpec::worker_node(), machines);
            Ok(BoundsReport {
                app: app.name.clone(),
                machines,
                max_scale: s,
                input_mb_at_max: if s.is_finite() { app.input_mb(s) } else { 0.0 },
            }
            .to_json())
        }
        other => Err(format!("unknown query kind '{other}'")),
    }
}

/// Answer a whole JSONL batch. `threads == 0` sizes the pool from the
/// host, `1` runs the reference serial loop, `n` runs exactly `n`
/// workers. Results are re-placed by line index and every input line —
/// blank ones included — gets exactly one output doc, so position N of
/// the output always answers line N+1 of the input (a blank line is
/// answered with an error doc rather than silently skipped, and error
/// docs carry their 1-based `line`). Each answer is a pure function of
/// its line (racing trainings produce the identical profile), so the
/// output is byte-identical at every `threads` and shard-count setting —
/// the serve determinism contract, property-tested in the testkit.
pub fn serve_batch(store: &ProfileStore, input: &str, threads: usize) -> Vec<ServeOutcome> {
    let lines: Vec<&str> = input.lines().collect();
    if lines.is_empty() {
        return Vec::new();
    }
    let one = |i: usize| {
        if lines[i].trim().is_empty() {
            return ServeOutcome { doc: error_doc("empty query line", i), ok: false };
        }
        match answer_line(store, lines[i]) {
            Ok(doc) => ServeOutcome { doc, ok: true },
            Err(msg) => ServeOutcome { doc: error_doc(&msg, i), ok: false },
        }
    };
    if threads == 1 {
        sweep_range_serial(0, lines.len() - 1, one)
    } else {
        sweep_range_with(threads, 0, lines.len() - 1, one)
    }
}

/// The deterministic payload of a serve run: every answer doc, rendered
/// and newline-joined — what the byte-identity property compares.
pub fn results_bytes(outcomes: &[ServeOutcome]) -> String {
    outcomes.iter().map(|o| o.doc.pretty()).collect::<Vec<_>>().join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blink::Advisor;

    fn svm() -> AppModel {
        app_by_name("svm").unwrap()
    }

    #[test]
    fn store_and_advisor_answer_identically() {
        let mut backend = RustFit::default();
        let mut advisor = Advisor::builder().build(&mut backend);
        let from_advisor = advisor.profile(&svm());
        let store = ProfileStore::builder().build();
        let from_store = store.get_or_train(&svm()).unwrap();
        let machine = MachineSpec::worker_node();
        let a = from_advisor.recommend(2000.0, &machine);
        let b = from_store.recommend(2000.0, &machine);
        assert_eq!(a.machines, b.machines);
        assert_eq!(a.predicted_cached_mb.to_bits(), b.predicted_cached_mb.to_bits());
        assert_eq!(store.sampling_phases(), 1);
        // second call hits
        store.get_or_train(&svm()).unwrap();
        assert_eq!(store.sampling_phases(), 1);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn profile_round_trips_bit_identically() {
        let store = ProfileStore::builder().build();
        let original = store.get_or_train(&svm()).unwrap();
        let doc = profile_to_json(&original);
        let text = doc.pretty();
        let reparsed = parse(&text).expect("round-trip parse");
        let loaded = profile_from_json(&reparsed).expect("round-trip decode");
        let machine = MachineSpec::worker_node();
        for scale in [50.0, 1000.0, 2000.0, 12_345.678] {
            let a = original.recommend(scale, &machine);
            let b = loaded.recommend(scale, &machine);
            assert_eq!(a.machines, b.machines, "scale {scale}");
            assert_eq!(a.predicted_cached_mb.to_bits(), b.predicted_cached_mb.to_bits());
            assert_eq!(a.predicted_exec_mb.to_bits(), b.predicted_exec_mb.to_bits());
        }
        assert_eq!(
            original.max_scale(&machine, 7).to_bits(),
            loaded.max_scale(&machine, 7).to_bits()
        );
    }

    #[test]
    fn tampered_fingerprint_is_rejected() {
        let store = ProfileStore::builder().build();
        let p = store.get_or_train(&svm()).unwrap();
        let doc = profile_to_json(&p);
        // flip one app_bits entry: decode must fail with a typed error
        let mut text = doc.pretty();
        let fp = app_fingerprint(&p.app);
        let needle = format!("{:016x}", fp[0]);
        let flipped = format!("{:016x}", fp[0] ^ 1);
        text = text.replacen(&needle, &flipped, 1);
        let reparsed = parse(&text).unwrap();
        match profile_from_json(&reparsed) {
            Err(StoreError::Fingerprint { field, .. }) => assert_eq!(field, "app_bits"),
            other => panic!("expected fingerprint error, got {other:?}"),
        }
    }

    #[test]
    fn resolve_app_handles_registry_and_synth_spellings() {
        assert!(resolve_app("svm").is_some());
        assert!(resolve_app("nope").is_none());
        let a = resolve_app("synth:smoke:7").expect("synth spelling");
        let b = SynthConfig::by_name("smoke").unwrap().generate(7);
        assert_eq!(a.name, b.name);
        assert!(resolve_app("synth:smoke:notanumber").is_none());
        assert!(resolve_app("synth:meteor:1").is_none());
        // the generated name itself resolves back to the same workload —
        // it is what --save-profiles writes into fingerprint.app, so warm
        // restarts of synth profiles depend on this round trip
        assert_eq!(b.name, "synth-smoke-0007");
        let c = resolve_app(&b.name).expect("generated spelling");
        assert_eq!(app_fingerprint(&c), app_fingerprint(&b));
        assert!(resolve_app("synth-smoke-zz").is_none(), "non-hex seed");
        assert!(resolve_app("synth-meteor-0001").is_none(), "unknown preset");
        assert!(resolve_app("synth-smoke").is_none(), "no seed suffix");
    }

    #[test]
    fn fractional_parallelism_cap_is_a_schema_error_not_a_truncation() {
        let mut app = svm();
        app.parallelism_cap = Some(64);
        let mut doc = app_json(&app);
        assert!(app_from(&doc).is_ok(), "integer cap decodes");
        if let Json::Obj(m) = &mut doc {
            m.insert("parallelism_cap".to_string(), Json::Num(64.5));
        }
        match app_from(&doc) {
            Err(StoreError::Schema(msg)) => assert!(msg.contains("parallelism_cap"), "{msg}"),
            other => panic!("expected schema error, got {other:?}"),
        }
        if let Json::Obj(m) = &mut doc {
            m.insert("parallelism_cap".to_string(), Json::Num(-3.0));
        }
        assert!(matches!(app_from(&doc), Err(StoreError::Schema(_))), "negative cap");
    }

    #[test]
    fn malformed_lines_become_error_docs_not_aborts() {
        let store = ProfileStore::builder().build();
        let input = "{\"query\":\"max_scale\",\"app\":\"svm\",\"machines\":4}\n\
                     not json at all\n\
                     {\"query\":\"warp\",\"app\":\"svm\"}\n\
                     {\"query\":\"recommend\",\"app\":\"nope\",\"scale\":100}";
        let out = serve_batch(&store, input, 1);
        assert_eq!(out.len(), 4);
        assert!(out[0].ok);
        assert!(!out[1].ok && !out[2].ok && !out[3].ok);
        for (i, bad) in out.iter().enumerate().skip(1) {
            assert_eq!(bad.doc.get("query").and_then(Json::as_str), Some("error"));
            assert!(bad.doc.get("error").is_some());
            // error docs name their 1-based input line
            assert_eq!(bad.doc.get("line").and_then(Json::as_f64), Some((i + 1) as f64));
        }
    }

    #[test]
    fn blank_lines_keep_output_positions_aligned_with_input_lines() {
        let store = ProfileStore::builder().build();
        let input = "{\"query\":\"max_scale\",\"app\":\"svm\",\"machines\":4}\n\
                     \n   \n\
                     {\"query\":\"max_scale\",\"app\":\"svm\",\"machines\":8}";
        let out = serve_batch(&store, input, 1);
        assert_eq!(out.len(), 4, "one outcome per input line, blanks included");
        assert!(out[0].ok && out[3].ok);
        for (i, blank) in [(1usize, &out[1]), (2, &out[2])] {
            assert!(!blank.ok);
            assert_eq!(blank.doc.get("query").and_then(Json::as_str), Some("error"));
            assert_eq!(blank.doc.get("line").and_then(Json::as_f64), Some((i + 1) as f64));
        }
        assert_eq!(out[3].doc.get("machines").and_then(Json::as_f64), Some(8.0));
    }
}
