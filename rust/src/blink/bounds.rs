//! Cluster-bounds prediction (§6.5 / Table 2).
//!
//! The inverse question of the selector: given a *fixed* resource-
//! constrained cluster (the paper fixes 12 machines), what is the maximum
//! data scale that still runs eviction-free? Blink answers from the same
//! trained models by searching the largest scale whose predicted cached
//! size and execution memory satisfy the §5.4 condition at `n` machines.

use super::predictor::{ExecMemoryPredictor, SizePredictor};
use crate::sim::MachineSpec;

/// Does the predicted footprint at `scale` fit `n` machines eviction-free?
pub fn fits(
    sizes: &SizePredictor,
    exec: &ExecMemoryPredictor,
    machine: &MachineSpec,
    n: usize,
    scale: f64,
) -> bool {
    let m = machine.unified_mb();
    let r = machine.storage_floor_mb();
    let cached = sizes.predict_total(scale);
    let exec_pm = (m - r).min(exec.predict_total(scale) / n as f64);
    cached / (n as f64) < m - exec_pm
}

/// Maximum data scale (paper units; monotone bisection to `tol` relative
/// precision) that the cluster runs eviction-free per the trained models.
pub fn max_scale(
    sizes: &SizePredictor,
    exec: &ExecMemoryPredictor,
    machine: &MachineSpec,
    n: usize,
    tol: f64,
) -> f64 {
    assert!(n >= 1);
    // exponential search for an upper bracket
    let mut lo = 0.0_f64;
    let mut hi = 1.0_f64;
    let mut guard = 0;
    while fits(sizes, exec, machine, n, hi) {
        lo = hi;
        hi *= 2.0;
        guard += 1;
        if guard > 64 {
            return hi; // unboundedly fits (e.g. θ1 == 0)
        }
    }
    // bisect the boundary
    while (hi - lo) > tol * hi.max(1.0) {
        let mid = 0.5 * (lo + hi);
        if fits(sizes, exec, machine, n, mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blink::models::RustFit;
    use crate::blink::predictor::{ExecMemoryPredictor, SizePredictor};
    use crate::blink::sample_runs::{SampleRunsManager, SamplingOutcome, DEFAULT_SCALES};
    use crate::workloads::app_by_name;

    fn predictors(name: &str) -> (SizePredictor, ExecMemoryPredictor) {
        let mgr = SampleRunsManager::default();
        let runs = match mgr.run(&app_by_name(name).unwrap(), &DEFAULT_SCALES) {
            SamplingOutcome::Profiled(r) => r,
            _ => panic!(),
        };
        let mut b = RustFit::default();
        (
            SizePredictor::train(&mut b, &runs),
            ExecMemoryPredictor::train(&mut b, &runs),
        )
    }

    #[test]
    fn bound_is_a_true_boundary() {
        let (sp, ep) = predictors("svm");
        let m = crate::sim::MachineSpec::worker_node();
        let s = max_scale(&sp, &ep, &m, 12, 1e-4);
        assert!(s > 0.0);
        assert!(fits(&sp, &ep, &m, 12, s * 0.99), "just below fits");
        assert!(!fits(&sp, &ep, &m, 12, s * 1.01), "just above does not");
    }

    #[test]
    fn more_machines_allow_larger_scales() {
        let (sp, ep) = predictors("lr");
        let m = crate::sim::MachineSpec::worker_node();
        let s6 = max_scale(&sp, &ep, &m, 6, 1e-4);
        let s12 = max_scale(&sp, &ep, &m, 12, 1e-4);
        assert!(s12 > s6, "{s12} vs {s6}");
    }

    #[test]
    fn svm_12_machine_bound_exceeds_its_150pct_scale() {
        // Table 1: svm at 150 % (scale 1500) runs eviction-free on <= 12
        let (sp, ep) = predictors("svm");
        let m = crate::sim::MachineSpec::worker_node();
        let s = max_scale(&sp, &ep, &m, 12, 1e-4);
        assert!(s > 1500.0, "{s}");
    }
}
