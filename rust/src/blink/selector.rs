//! Cluster size selector (§5.4).
//!
//! Given the predicted total cached size and the predicted execution
//! memory, plus the machine type's memory geometry (M, R), pick the
//! minimal cluster size that guarantees an eviction-free actual run:
//!
//! ```text
//! Machines_min = ceil(ΣD / M)        Machines_max = ceil(ΣD / R)
//! MachineMem_exec(n) = min(M - R, Mem_exec / n)
//! pick the minimal n with  ΣD / n  <  M - MachineMem_exec(n)
//! ```
//!
//! The models are built once; the selector can be re-evaluated for any
//! machine type or data scale without new sample runs (§5.4's adaptivity).

use crate::sim::MachineSpec;
use crate::util::units::Mb;

/// The selector's decision with its diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct Selection {
    pub machines: usize,
    pub machines_min: usize,
    pub machines_max: usize,
    /// Per-machine execution memory at the selected size.
    pub machine_exec_mb: Mb,
    /// Caching headroom per machine at the selected size.
    pub headroom_mb: Mb,
    /// The selector hit `max_machines` without satisfying the condition —
    /// the cluster cannot run this scale eviction-free.
    pub saturated: bool,
}

/// Select the optimal cluster size (§5.4) for a machine type.
pub fn select_cluster_size(
    cached_total_mb: Mb,
    exec_total_mb: Mb,
    machine: &MachineSpec,
    max_machines: usize,
) -> Selection {
    let m = machine.unified_mb();
    let r = machine.storage_floor_mb();
    assert!(max_machines >= 1);

    let machines_min = (cached_total_mb / m).ceil().max(1.0) as usize;
    let machines_max = (cached_total_mb / r).ceil().max(1.0) as usize;

    for n in 1..=max_machines {
        let exec_pm = (m - r).min(exec_total_mb / n as f64);
        let capacity = m - exec_pm;
        if cached_total_mb / (n as f64) < capacity {
            return Selection {
                machines: n,
                machines_min,
                machines_max,
                machine_exec_mb: exec_pm,
                headroom_mb: capacity - cached_total_mb / n as f64,
                saturated: false,
            };
        }
    }
    let exec_pm = (m - r).min(exec_total_mb / max_machines as f64);
    Selection {
        machines: max_machines,
        machines_min,
        machines_max,
        machine_exec_mb: exec_pm,
        headroom_mb: (m - exec_pm) - cached_total_mb / max_machines as f64,
        saturated: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn worker() -> MachineSpec {
        MachineSpec::worker_node()
    }

    #[test]
    fn small_cache_fits_one_machine() {
        let s = select_cluster_size(100.0, 50.0, &worker(), 12);
        assert_eq!(s.machines, 1);
        assert!(!s.saturated);
        assert_eq!(s.machines_min, 1);
    }

    #[test]
    fn min_max_bounds_bracket_selection() {
        // 40 GB cached: min = ceil(40960/7192.8) = 6, max = ceil(40960/3596.4) = 12
        let s = select_cluster_size(40.0 * 1024.0, 6000.0, &worker(), 20);
        assert_eq!(s.machines_min, 6);
        assert_eq!(s.machines_max, 12);
        assert!(s.machines >= s.machines_min && s.machines <= s.machines_max);
    }

    #[test]
    fn heavy_execution_memory_needs_more_machines() {
        let light = select_cluster_size(20_000.0, 100.0, &worker(), 20);
        let heavy = select_cluster_size(20_000.0, 40_000.0, &worker(), 20);
        assert!(heavy.machines >= light.machines);
    }

    #[test]
    fn saturation_reported_when_cluster_too_small() {
        let s = select_cluster_size(200_000.0, 1000.0, &worker(), 12);
        assert!(s.saturated);
        assert_eq!(s.machines, 12);
    }

    #[test]
    fn different_machine_type_changes_pick_without_resampling() {
        // §5.4: models are reused across machine types
        let cached = 20_000.0;
        let exec = 2_000.0;
        let small = select_cluster_size(cached, exec, &MachineSpec::sample_node(), 64);
        let big = select_cluster_size(cached, exec, &worker(), 64);
        assert!(small.machines > big.machines);
    }

    #[test]
    fn property_selection_is_minimal_and_sound() {
        prop::check(
            &prop::Config { cases: 128, seed: 0x5e1ec7, max_size: 64 },
            |rng: &mut Rng, _size| {
                (rng.range(10.0, 150_000.0), rng.range(0.0, 60_000.0))
            },
            |&(cached, exec)| {
                let m = worker();
                let s = select_cluster_size(cached, exec, &m, 16);
                let cond = |n: usize| {
                    let exec_pm = (m.unified_mb() - m.storage_floor_mb())
                        .min(exec / n as f64);
                    cached / n as f64 > m.unified_mb() - exec_pm
                };
                if !s.saturated {
                    // selected n satisfies the condition...
                    if cond(s.machines) {
                        return Err(format!("selected {} violates condition", s.machines));
                    }
                    // ...and is minimal
                    for n in 1..s.machines {
                        if !cond(n) {
                            return Err(format!("{n} < {} also satisfies", s.machines));
                        }
                    }
                    if s.headroom_mb < 0.0 {
                        return Err("negative headroom".into());
                    }
                } else {
                    for n in 1..=16 {
                        if !cond(n) {
                            return Err(format!("saturated but {n} satisfies"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
