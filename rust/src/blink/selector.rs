//! Cluster size selector (§5.4).
//!
//! Given the predicted total cached size and the predicted execution
//! memory, plus the machine type's memory geometry (M, R), pick the
//! minimal cluster size that guarantees an eviction-free actual run:
//!
//! ```text
//! Machines_min = ceil(ΣD / M)        Machines_max = ceil(ΣD / R)
//! MachineMem_exec(n) = min(M - R, Mem_exec / n)
//! pick the minimal n with  ΣD / n  <  M - MachineMem_exec(n)
//! ```
//!
//! The models are built once; the selector can be re-evaluated for any
//! machine type or data scale without new sample runs (§5.4's adaptivity).

use crate::sim::MachineSpec;
use crate::util::units::Mb;

/// The selector's decision with its diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct Selection {
    pub machines: usize,
    pub machines_min: usize,
    pub machines_max: usize,
    /// Per-machine execution memory at the selected size.
    pub machine_exec_mb: Mb,
    /// Caching headroom per machine at the selected size. Negative when
    /// `saturated` — the per-machine cache *deficit* the cluster cannot
    /// absorb (see [`Selection::cache_deficit_mb`]).
    pub headroom_mb: Mb,
    /// The selector hit `max_machines` without satisfying the condition —
    /// the cluster cannot run this scale eviction-free.
    pub saturated: bool,
}

impl Selection {
    /// Per-machine cache deficit when saturated (how far the cached data
    /// overflows each machine's capacity), 0 for an eviction-free pick.
    /// Renderers must report this instead of a "negative headroom".
    pub fn cache_deficit_mb(&self) -> Mb {
        (-self.headroom_mb).max(0.0)
    }
}

/// The §5.4 memory geometry at cluster size `n`: per-machine execution
/// share `MachineMem_exec(n) = min(M - R, Mem_exec / n)` and the caching
/// capacity `M - MachineMem_exec(n)` it leaves. Shared by the single-type
/// selector below and the catalog planner ([`crate::blink::planner`]), so
/// both evaluate candidates with identical numerics.
pub fn machine_split(exec_total_mb: Mb, machine: &MachineSpec, n: usize) -> (Mb, Mb) {
    machine_split_at(exec_total_mb, machine, machine.storage_fraction, n)
}

/// [`machine_split`] with an explicit storage fraction: the protected
/// floor becomes `R = M * storage_fraction` instead of the machine type's
/// configured value. With `storage_fraction == machine.storage_fraction`
/// this computes the exact same expressions as the original split — the
/// catalog planner uses it to search the memory split as a dimension
/// while the paper path stays bit-identical.
pub fn machine_split_at(
    exec_total_mb: Mb,
    machine: &MachineSpec,
    storage_fraction: f64,
    n: usize,
) -> (Mb, Mb) {
    let m = machine.unified_mb();
    let r = m * storage_fraction;
    let exec_pm = (m - r).min(exec_total_mb / n as f64);
    (exec_pm, m - exec_pm)
}

/// Select the optimal cluster size (§5.4) for a machine type.
///
/// This is the paper's single-type rule, now a thin wrapper over the same
/// [`machine_split`] geometry the catalog planner searches — Table 1/2
/// reproduction goes through this exact function and stays bit-identical.
pub fn select_cluster_size(
    cached_total_mb: Mb,
    exec_total_mb: Mb,
    machine: &MachineSpec,
    max_machines: usize,
) -> Selection {
    select_cluster_size_at(
        cached_total_mb,
        exec_total_mb,
        machine,
        machine.storage_fraction,
        max_machines,
    )
}

/// [`select_cluster_size`] with an explicit storage fraction (see
/// [`machine_split_at`]). `machines_max = ceil(ΣD / R)` uses the same
/// overridden floor, so the reported bracket matches the searched split.
pub fn select_cluster_size_at(
    cached_total_mb: Mb,
    exec_total_mb: Mb,
    machine: &MachineSpec,
    storage_fraction: f64,
    max_machines: usize,
) -> Selection {
    let m = machine.unified_mb();
    let r = m * storage_fraction;
    assert!(max_machines >= 1);

    let machines_min = (cached_total_mb / m).ceil().max(1.0) as usize;
    let machines_max = (cached_total_mb / r).ceil().max(1.0) as usize;

    for n in 1..=max_machines {
        let (exec_pm, capacity) = machine_split_at(exec_total_mb, machine, storage_fraction, n);
        if cached_total_mb / (n as f64) < capacity {
            return Selection {
                machines: n,
                machines_min,
                machines_max,
                machine_exec_mb: exec_pm,
                headroom_mb: capacity - cached_total_mb / n as f64,
                saturated: false,
            };
        }
    }
    let (exec_pm, capacity) =
        machine_split_at(exec_total_mb, machine, storage_fraction, max_machines);
    Selection {
        machines: max_machines,
        machines_min,
        machines_max,
        machine_exec_mb: exec_pm,
        headroom_mb: capacity - cached_total_mb / max_machines as f64,
        saturated: true,
    }
}

/// [`select_cluster_size_at`] seeded with a count `hint` that is already
/// known to satisfy the eviction-free condition (e.g. the selection at a
/// *lower* storage fraction on a dense `--fractions` grid — the minimal
/// count is non-increasing in the fraction, see the planner's pruning
/// argument). Instead of scanning up from 1, walk *down* from the hint
/// while the condition still holds, which visits `hint - n* + 1` counts
/// instead of `n*`. The eviction-free condition `ΣD/n < M - min(M-R,
/// Mem_exec/n)` is monotone in `n` (the left side strictly decreases, the
/// capacity is non-decreasing), so the first failing `n-1` proves `n` is
/// minimal and the result is identical to the ground-up scan — asserted in
/// debug builds.
pub fn select_cluster_size_seeded(
    cached_total_mb: Mb,
    exec_total_mb: Mb,
    machine: &MachineSpec,
    storage_fraction: f64,
    max_machines: usize,
    hint: usize,
) -> Selection {
    let m = machine.unified_mb();
    let r = m * storage_fraction;
    assert!(max_machines >= 1);
    let hint = hint.clamp(1, max_machines);

    let holds = |n: usize| {
        let (_, capacity) = machine_split_at(exec_total_mb, machine, storage_fraction, n);
        cached_total_mb / (n as f64) < capacity
    };
    if !holds(hint) {
        // bad hint: the caller's invariant does not apply; fall back
        return select_cluster_size_at(
            cached_total_mb,
            exec_total_mb,
            machine,
            storage_fraction,
            max_machines,
        );
    }
    let mut n = hint;
    while n > 1 && holds(n - 1) {
        n -= 1;
    }
    let machines_min = (cached_total_mb / m).ceil().max(1.0) as usize;
    let machines_max = (cached_total_mb / r).ceil().max(1.0) as usize;
    let (exec_pm, capacity) = machine_split_at(exec_total_mb, machine, storage_fraction, n);
    let selection = Selection {
        machines: n,
        machines_min,
        machines_max,
        machine_exec_mb: exec_pm,
        headroom_mb: capacity - cached_total_mb / n as f64,
        saturated: false,
    };
    debug_assert_eq!(
        selection,
        select_cluster_size_at(
            cached_total_mb,
            exec_total_mb,
            machine,
            storage_fraction,
            max_machines
        ),
        "seeded scan must match the ground-up scan"
    );
    selection
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn worker() -> MachineSpec {
        MachineSpec::worker_node()
    }

    #[test]
    fn small_cache_fits_one_machine() {
        let s = select_cluster_size(100.0, 50.0, &worker(), 12);
        assert_eq!(s.machines, 1);
        assert!(!s.saturated);
        assert_eq!(s.machines_min, 1);
    }

    #[test]
    fn min_max_bounds_bracket_selection() {
        // 40 GB cached: min = ceil(40960/7192.8) = 6, max = ceil(40960/3596.4) = 12
        let s = select_cluster_size(40.0 * 1024.0, 6000.0, &worker(), 20);
        assert_eq!(s.machines_min, 6);
        assert_eq!(s.machines_max, 12);
        assert!(s.machines >= s.machines_min && s.machines <= s.machines_max);
    }

    #[test]
    fn heavy_execution_memory_needs_more_machines() {
        let light = select_cluster_size(20_000.0, 100.0, &worker(), 20);
        let heavy = select_cluster_size(20_000.0, 40_000.0, &worker(), 20);
        assert!(heavy.machines >= light.machines);
    }

    #[test]
    fn saturation_reported_when_cluster_too_small() {
        let s = select_cluster_size(200_000.0, 1000.0, &worker(), 12);
        assert!(s.saturated);
        assert_eq!(s.machines, 12);
    }

    #[test]
    fn saturated_headroom_is_a_deficit() {
        // regression: a saturated selection must never read as positive
        // spare capacity — headroom <= 0 and the deficit helper flips it
        let s = select_cluster_size(200_000.0, 1000.0, &worker(), 12);
        assert!(s.saturated);
        assert!(s.headroom_mb <= 0.0, "saturated headroom {}", s.headroom_mb);
        assert!(s.cache_deficit_mb() > 0.0);
        assert_eq!(s.cache_deficit_mb(), -s.headroom_mb);
        // and an eviction-free pick reports no deficit
        let free = select_cluster_size(100.0, 50.0, &worker(), 12);
        assert!(!free.saturated);
        assert!(free.headroom_mb > 0.0);
        assert_eq!(free.cache_deficit_mb(), 0.0);
    }

    #[test]
    fn machine_split_matches_selector_geometry() {
        let m = worker();
        let (exec_pm, capacity) = machine_split(6000.0, &m, 4);
        assert_eq!(exec_pm, (m.unified_mb() - m.storage_floor_mb()).min(6000.0 / 4.0));
        assert_eq!(capacity, m.unified_mb() - exec_pm);
    }

    #[test]
    fn different_machine_type_changes_pick_without_resampling() {
        // §5.4: models are reused across machine types
        let cached = 20_000.0;
        let exec = 2_000.0;
        let small = select_cluster_size(cached, exec, &MachineSpec::sample_node(), 64);
        let big = select_cluster_size(cached, exec, &worker(), 64);
        assert!(small.machines > big.machines);
    }

    #[test]
    fn explicit_fraction_at_default_is_bit_identical() {
        let m = worker();
        for n in 1..=16 {
            assert_eq!(
                machine_split(6000.0, &m, n),
                machine_split_at(6000.0, &m, m.storage_fraction, n)
            );
        }
        let a = select_cluster_size(40.0 * 1024.0, 6000.0, &m, 20);
        let b = select_cluster_size_at(40.0 * 1024.0, 6000.0, &m, m.storage_fraction, 20);
        assert_eq!(a, b);
    }

    #[test]
    fn property_minimal_count_is_monotone_in_storage_fraction() {
        // the planner's fraction-pruning bound: raising the storage
        // fraction raises R, shrinks the execution share, grows capacity —
        // so the minimal eviction-free count never increases with f
        prop::check(
            &prop::Config { cases: 96, seed: 0xf7ac, max_size: 64 },
            |rng: &mut Rng, _size| {
                (rng.range(10.0, 120_000.0), rng.range(0.0, 50_000.0))
            },
            |&(cached, exec)| {
                let m = worker();
                let mut prev: Option<Selection> = None;
                for f in [0.2, 0.35, 0.5, 0.65, 0.8] {
                    let s = select_cluster_size_at(cached, exec, &m, f, 24);
                    if let Some(p) = &prev {
                        if !p.saturated && !s.saturated && s.machines > p.machines {
                            return Err(format!(
                                "n*({f}) = {} > n* at lower fraction = {}",
                                s.machines, p.machines
                            ));
                        }
                    }
                    prev = Some(s);
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_seeded_scan_is_identical_to_ground_up_scan() {
        // satellite of the dense-fraction planner speedup: any valid hint
        // (a count satisfying the condition), and any *invalid* hint via
        // the fallback, must reproduce select_cluster_size_at exactly
        prop::check(
            &prop::Config { cases: 96, seed: 0x5eed, max_size: 64 },
            |rng: &mut Rng, _size| {
                (
                    rng.range(10.0, 120_000.0),
                    rng.range(0.0, 50_000.0),
                    rng.range(0.2, 0.8),
                    1 + rng.below(24),
                )
            },
            |&(cached, exec, fraction, hint)| {
                let m = worker();
                let plain = select_cluster_size_at(cached, exec, &m, fraction, 24);
                let seeded = select_cluster_size_seeded(cached, exec, &m, fraction, 24, hint);
                if plain != seeded {
                    return Err(format!("hint {hint}: {seeded:?} != {plain:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_selection_is_minimal_and_sound() {
        prop::check(
            &prop::Config { cases: 128, seed: 0x5e1ec7, max_size: 64 },
            |rng: &mut Rng, _size| {
                (rng.range(10.0, 150_000.0), rng.range(0.0, 60_000.0))
            },
            |&(cached, exec)| {
                let m = worker();
                let s = select_cluster_size(cached, exec, &m, 16);
                let cond = |n: usize| {
                    let exec_pm = (m.unified_mb() - m.storage_floor_mb())
                        .min(exec / n as f64);
                    cached / n as f64 > m.unified_mb() - exec_pm
                };
                if !s.saturated {
                    // selected n satisfies the condition...
                    if cond(s.machines) {
                        return Err(format!("selected {} violates condition", s.machines));
                    }
                    // ...and is minimal
                    for n in 1..s.machines {
                        if !cond(n) {
                            return Err(format!("{n} < {} also satisfies", s.machines));
                        }
                    }
                    if s.headroom_mb < 0.0 {
                        return Err("negative headroom".into());
                    }
                } else {
                    for n in 1..=16 {
                        if !cond(n) {
                            return Err(format!("saturated but {n} satisfies"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
