//! Typed reports for every advisor query: one struct per CLI answer,
//! each with a text renderer (the `blink` CLI's human output) and a
//! `to_json` encoding via [`crate::util::json`] so other services can
//! consume the same answers machine-readably (`blink … --format json`).
//!
//! The coordinator's `cmd_*` functions are thin parse → query → render
//! shims over these types: compute paths never print, renderers never
//! compute.

use std::fmt::Write as _;

use super::adaptive::{AdaptOutcome, ReplanDecision};
use super::planner::{
    CandidateConfig, FleetCandidate, FleetPick, FleetPlan, Plan, RiskAdjustedPick, TypePick,
};
use super::selector::Selection;
use super::session::TrainedProfile;
use super::Recommendation;
use crate::sim::{MachineSpec, TenantRunStats};
use crate::util::json::Json;
use crate::util::units::{fmt_mb, fmt_mb_signed, fmt_pct, fmt_secs};

/// How the CLI renders a report (the global `--format` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputFormat {
    Text,
    Json,
}

impl OutputFormat {
    pub fn by_name(name: &str) -> Option<OutputFormat> {
        match name {
            "text" => Some(OutputFormat::Text),
            "json" => Some(OutputFormat::Json),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OutputFormat::Text => "text",
            OutputFormat::Json => "json",
        }
    }
}

/// A renderable query answer: text for humans, JSON for machines.
pub trait Report {
    /// The human rendering (no trailing newline; the CLI adds it).
    fn render_text(&self) -> String;
    /// The machine rendering; must re-parse with [`crate::util::json`].
    fn to_json(&self) -> Json;

    fn render(&self, format: OutputFormat) -> String {
        match format {
            OutputFormat::Text => self.render_text(),
            OutputFormat::Json => self.to_json().pretty(),
        }
    }
}

/// Drop the final newline a `writeln!`-built buffer carries, so the
/// caller's `println!` does not double it.
fn finish(mut out: String) -> String {
    out.pop();
    out
}

// ======================================================================
// JSON encodings of the shared query-result types
// ======================================================================

pub fn selection_json(s: &Selection) -> Json {
    Json::obj(vec![
        ("machines", s.machines.into()),
        ("machines_min", s.machines_min.into()),
        ("machines_max", s.machines_max.into()),
        ("machine_exec_mb", s.machine_exec_mb.into()),
        ("headroom_mb", s.headroom_mb.into()),
        ("cache_deficit_mb", s.cache_deficit_mb().into()),
        ("saturated", s.saturated.into()),
    ])
}

pub fn candidate_json(c: &CandidateConfig) -> Json {
    Json::obj(vec![
        ("instance", c.instance.as_str().into()),
        ("machines", c.machines.into()),
        ("storage_fraction", c.storage_fraction.into()),
        ("eviction_free", c.eviction_free.into()),
        ("headroom_mb", c.headroom_mb.into()),
        ("predicted_time_s", c.predicted_time_s.into()),
        ("predicted_cost", c.predicted_cost.into()),
    ])
}

pub fn type_pick_json(p: &TypePick) -> Json {
    Json::obj(vec![
        ("candidate", candidate_json(&p.candidate)),
        ("selection", selection_json(&p.selection)),
    ])
}

pub fn plan_json(p: &Plan) -> Json {
    Json::obj(vec![
        ("ranked", Json::Arr(p.ranked.iter().map(type_pick_json).collect())),
        ("pareto", Json::Arr(p.pareto.iter().map(candidate_json).collect())),
        ("best", p.best().map_or(Json::Null, type_pick_json)),
        ("fractions", Json::Arr(p.fractions.iter().map(|&f| f.into()).collect())),
    ])
}

/// Infinite realized costs (collapsed validation runs) encode as `null`.
pub fn risk_pick_json(r: &RiskAdjustedPick) -> Json {
    Json::obj(vec![
        ("instance", r.pick.candidate.instance.as_str().into()),
        ("machines", r.pick.candidate.machines.into()),
        ("predicted_cost", r.pick.candidate.predicted_cost.into()),
        ("realized_time_s", r.realized_time_s.into()),
        ("realized_cost", r.realized_cost.into()),
        ("machines_lost", r.machines_lost.into()),
        ("cost_inflation", r.cost_inflation.into()),
        ("completed_runs", r.completed_runs.into()),
        ("collapsed", (r.completed_runs == 0).into()),
    ])
}

// ======================================================================
// Shared text renderers (also reused by `experiments::report`)
// ======================================================================

/// The `blink advise` plan table: ranked per-type picks, then the
/// time/cost Pareto front over the whole (type × count) grid. When the
/// plan searched an explicit storage-fraction grid, a `split` column shows
/// each pick's fraction; the count-only layout is byte-identical to the
/// pre-dimension renderer.
pub fn render_plan_text(
    plan: &Plan,
    catalog_name: &str,
    catalog_types: usize,
    pricing: &str,
) -> String {
    let split = !plan.fractions.is_empty();
    let mut out = String::new();
    let _ = writeln!(
        out,
        "\nPLAN — catalog '{catalog_name}' ({catalog_types} types), pricing '{pricing}'"
    );
    if split {
        let fs: Vec<String> = plan.fractions.iter().map(|f| format!("{f:.2}")).collect();
        let _ = writeln!(out, "searched storage fractions: {}", fs.join(", "));
        let _ = writeln!(
            out,
            "{:>4} {:<12} {:>5} {:>4} {:>4}..{:<4} {:>10} {:>12} {:>14} {:>6}",
            "rank", "instance", "split", "n", "min", "max", "time", "cost", "headroom", "free"
        );
    } else {
        let _ = writeln!(
            out,
            "{:>4} {:<12} {:>4} {:>4}..{:<4} {:>10} {:>12} {:>14} {:>6}",
            "rank", "instance", "n", "min", "max", "time", "cost", "headroom", "free"
        );
    }
    for (i, pick) in plan.ranked.iter().enumerate() {
        let c = &pick.candidate;
        let s = &pick.selection;
        let headroom = if s.saturated {
            format!("-{} !", fmt_mb(s.cache_deficit_mb()))
        } else {
            fmt_mb_signed(c.headroom_mb)
        };
        if split {
            let _ = writeln!(
                out,
                "{:>4} {:<12} {:>5.2} {:>4} {:>4}..{:<4} {:>10} {:>12.2} {:>14} {:>6}",
                i + 1,
                c.instance,
                c.storage_fraction,
                c.machines,
                s.machines_min,
                s.machines_max,
                fmt_secs(c.predicted_time_s),
                c.predicted_cost,
                headroom,
                if c.eviction_free { "yes" } else { "NO" },
            );
        } else {
            let _ = writeln!(
                out,
                "{:>4} {:<12} {:>4} {:>4}..{:<4} {:>10} {:>12.2} {:>14} {:>6}",
                i + 1,
                c.instance,
                c.machines,
                s.machines_min,
                s.machines_max,
                fmt_secs(c.predicted_time_s),
                c.predicted_cost,
                headroom,
                if c.eviction_free { "yes" } else { "NO" },
            );
        }
    }
    if plan.pareto.iter().all(|c| c.eviction_free) {
        let _ = writeln!(out, "pareto front (time vs cost, eviction-free candidates):");
    } else {
        let _ = writeln!(
            out,
            "pareto front (time vs cost — NO candidate fits eviction-free; full grid):"
        );
    }
    for c in &plan.pareto {
        let at_split = if split { format!(" @{:.2}", c.storage_fraction) } else { String::new() };
        let _ = writeln!(
            out,
            "  {:<12} x{:<3} {:>10}  cost {:>10.2}{}",
            c.instance,
            c.machines,
            fmt_secs(c.predicted_time_s),
            c.predicted_cost,
            at_split
        );
    }
    if let Some(best) = plan.best() {
        let _ = writeln!(
            out,
            "-> recommend {} x{} ({}, cost {:.2}){}",
            best.candidate.instance,
            best.candidate.machines,
            fmt_secs(best.candidate.predicted_time_s),
            best.candidate.predicted_cost,
            if best.candidate.eviction_free {
                ""
            } else {
                "  — WARNING: cluster bound hit on every type; run will evict"
            }
        );
    }
    finish(out)
}

/// Risk cross-validation table: the planner's analytic picks realized by
/// event-driven engine runs under a disturbance scenario.
pub fn render_risk_text(risks: &[RiskAdjustedPick], scenario: &str, pricing: &str) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "\nRISK — top picks cross-validated by engine runs (scenario '{scenario}', pricing '{pricing}')"
    );
    if risks.is_empty() {
        let _ = writeln!(out, "  (no pick could be validated)");
        return finish(out);
    }
    let _ = writeln!(
        out,
        "{:>4} {:<12} {:>4} {:>12} {:>14} {:>10} {:>6}",
        "rank", "instance", "n", "time", "realized", "vs quote", "lost"
    );
    for (i, r) in risks.iter().enumerate() {
        if r.completed_runs == 0 {
            let _ = writeln!(
                out,
                "{:>4} {:<12} {:>4} {:>12} {:>14} {:>10} {:>6}",
                i + 1,
                r.pick.candidate.instance,
                r.pick.candidate.machines,
                "COLLAPSED",
                "inf",
                "-",
                r.machines_lost,
            );
            continue;
        }
        let _ = writeln!(
            out,
            "{:>4} {:<12} {:>4} {:>12} {:>14.4} {:>+9.1}% {:>6.1}",
            i + 1,
            r.pick.candidate.instance,
            r.pick.candidate.machines,
            fmt_secs(r.realized_time_s),
            r.realized_cost,
            (r.cost_inflation - 1.0) * 100.0,
            r.machines_lost,
        );
    }
    finish(out)
}

// ======================================================================
// blink decide
// ======================================================================

/// Per-dataset model diagnostics (the `--verbose` lines).
#[derive(Debug, Clone)]
pub struct ModelDiag {
    pub dataset: usize,
    pub kind: &'static str,
    pub cv_rel_err: f64,
}

/// `blink decide`: the §5.4 recommendation for one app/scale.
#[derive(Debug, Clone)]
pub struct RecommendReport {
    pub backend: String,
    pub app: String,
    pub scale: f64,
    pub input_mb: f64,
    pub recommendation: Recommendation,
    pub no_cached_data: bool,
    pub models: Vec<ModelDiag>,
    /// Include the per-dataset model lines in the text rendering.
    pub verbose: bool,
}

impl RecommendReport {
    pub fn new(
        backend: &str,
        profile: &TrainedProfile,
        scale: f64,
        machine: &MachineSpec,
        verbose: bool,
    ) -> RecommendReport {
        let models = profile.models.as_ref().map_or_else(Vec::new, |(sizes, _)| {
            sizes
                .models
                .iter()
                .map(|(ds, m)| ModelDiag {
                    dataset: *ds,
                    kind: m.kind.name(),
                    cv_rel_err: m.cv_rel_err,
                })
                .collect()
        });
        RecommendReport {
            backend: backend.to_string(),
            app: profile.app.name.to_string(),
            scale,
            input_mb: profile.app.input_mb(scale),
            recommendation: profile.recommend(scale, machine),
            no_cached_data: profile.no_cached_data(),
            models,
            verbose,
        }
    }
}

impl Report for RecommendReport {
    fn render_text(&self) -> String {
        let mut out = String::new();
        let d = &self.recommendation;
        let _ = writeln!(out, "fit backend: {}", self.backend);
        let _ = writeln!(
            out,
            "app {}  scale {:.0} ({} input)",
            self.app,
            self.scale,
            fmt_mb(self.input_mb)
        );
        let _ = writeln!(
            out,
            "predicted cached {}  exec memory {}",
            fmt_mb(d.predicted_cached_mb),
            fmt_mb(d.predicted_exec_mb)
        );
        if let Some(sel) = &d.selection {
            if sel.saturated {
                // a saturated selection has no headroom — report the deficit
                let _ = writeln!(
                    out,
                    "machines_min {}  machines_max {}  cache deficit/machine {}",
                    sel.machines_min,
                    sel.machines_max,
                    fmt_mb(sel.cache_deficit_mb())
                );
                let _ = writeln!(out, "WARNING: cluster bound hit; run will evict");
            } else {
                let _ = writeln!(
                    out,
                    "machines_min {}  machines_max {}  headroom/machine {}",
                    sel.machines_min,
                    sel.machines_max,
                    fmt_mb(sel.headroom_mb)
                );
            }
        }
        let _ = writeln!(
            out,
            "-> recommended cluster size: {} machines (sampling cost {})",
            d.machines,
            fmt_secs(d.sample_cost_machine_s)
        );
        if self.verbose {
            for m in &self.models {
                let _ = writeln!(
                    out,
                    "  dataset {}: {} model, cv err {}",
                    m.dataset,
                    m.kind,
                    fmt_pct(m.cv_rel_err)
                );
            }
        }
        finish(out)
    }

    fn to_json(&self) -> Json {
        let d = &self.recommendation;
        Json::obj(vec![
            ("query", "recommend".into()),
            ("backend", self.backend.as_str().into()),
            ("app", self.app.as_str().into()),
            ("scale", self.scale.into()),
            ("input_mb", self.input_mb.into()),
            ("machines", d.machines.into()),
            ("predicted_cached_mb", d.predicted_cached_mb.into()),
            ("predicted_exec_mb", d.predicted_exec_mb.into()),
            ("sample_cost_machine_s", d.sample_cost_machine_s.into()),
            ("no_cached_data", self.no_cached_data.into()),
            ("selection", d.selection.as_ref().map_or(Json::Null, selection_json)),
            (
                "models",
                Json::Arr(
                    self.models
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("dataset", m.dataset.into()),
                                ("kind", m.kind.into()),
                                ("cv_rel_err", m.cv_rel_err.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// ======================================================================
// blink advise
// ======================================================================

/// The risk table attached to a plan when a scenario was requested.
#[derive(Debug, Clone)]
pub struct RiskSection {
    pub scenario: String,
    pub picks: Vec<RiskAdjustedPick>,
}

/// `blink advise`: the catalog-wide plan plus sampling diagnostics.
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub backend: String,
    pub app: String,
    pub scale: f64,
    pub input_mb: f64,
    pub predicted_cached_mb: f64,
    pub predicted_exec_mb: f64,
    pub sample_cost_machine_s: f64,
    pub plan: Plan,
    pub catalog_name: String,
    pub catalog_types: usize,
    pub pricing: String,
    pub risk: Option<RiskSection>,
}

impl Report for PlanReport {
    fn render_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "fit backend: {}", self.backend);
        let _ = writeln!(
            out,
            "app {}  scale {:.0} ({} input)  predicted cached {}  exec {}  sampling cost {}",
            self.app,
            self.scale,
            fmt_mb(self.input_mb),
            fmt_mb(self.predicted_cached_mb),
            fmt_mb(self.predicted_exec_mb),
            fmt_secs(self.sample_cost_machine_s),
        );
        let _ = writeln!(
            out,
            "{}",
            render_plan_text(&self.plan, &self.catalog_name, self.catalog_types, &self.pricing)
        );
        if let Some(risk) = &self.risk {
            let _ = writeln!(
                out,
                "{}",
                render_risk_text(&risk.picks, &risk.scenario, &self.pricing)
            );
        }
        finish(out)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query", "plan".into()),
            ("backend", self.backend.as_str().into()),
            ("app", self.app.as_str().into()),
            ("scale", self.scale.into()),
            ("input_mb", self.input_mb.into()),
            ("predicted_cached_mb", self.predicted_cached_mb.into()),
            ("predicted_exec_mb", self.predicted_exec_mb.into()),
            ("sample_cost_machine_s", self.sample_cost_machine_s.into()),
            ("catalog", self.catalog_name.as_str().into()),
            ("catalog_types", self.catalog_types.into()),
            ("pricing", self.pricing.as_str().into()),
            ("plan", plan_json(&self.plan)),
            (
                "risk",
                self.risk.as_ref().map_or(Json::Null, |r| {
                    Json::obj(vec![
                        ("scenario", r.scenario.as_str().into()),
                        ("picks", Json::Arr(r.picks.iter().map(risk_pick_json).collect())),
                    ])
                }),
            ),
        ])
    }
}

// ======================================================================
// blink bounds
// ======================================================================

/// `blink bounds`: the Table-2 max-scale answer for a fixed cluster.
#[derive(Debug, Clone)]
pub struct BoundsReport {
    pub app: String,
    pub machines: usize,
    /// Infinite when the app caches nothing (any scale fits).
    pub max_scale: f64,
    /// Input size at the boundary scale (0 when unbounded).
    pub input_mb_at_max: f64,
}

impl BoundsReport {
    pub fn unbounded(&self) -> bool {
        self.max_scale.is_infinite()
    }
}

impl Report for BoundsReport {
    fn render_text(&self) -> String {
        if self.unbounded() {
            format!("{} caches nothing; any scale fits", self.app)
        } else {
            format!(
                "{}: max eviction-free data scale on {} machines ~ {:.1} ({} input)",
                self.app,
                self.machines,
                self.max_scale,
                fmt_mb(self.input_mb_at_max)
            )
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query", "max_scale".into()),
            ("app", self.app.as_str().into()),
            ("machines", self.machines.into()),
            // infinity encodes as null; `unbounded` carries the meaning
            ("max_scale", self.max_scale.into()),
            ("unbounded", self.unbounded().into()),
            (
                "input_mb_at_max",
                if self.unbounded() { Json::Null } else { self.input_mb_at_max.into() },
            ),
        ])
    }
}

// ======================================================================
// blink simulate
// ======================================================================

/// One engine run's headline numbers (baseline or disturbed).
#[derive(Debug, Clone)]
pub struct RunStats {
    pub duration_s: f64,
    pub cost_machine_min: f64,
    pub evictions: usize,
    pub machines_lost: usize,
    pub machines_joined: usize,
    pub cached_fraction_after_load: f64,
}

impl RunStats {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("duration_s", self.duration_s.into()),
            ("cost_machine_min", self.cost_machine_min.into()),
            ("evictions", self.evictions.into()),
            ("machines_lost", self.machines_lost.into()),
            ("machines_joined", self.machines_joined.into()),
            ("cached_fraction_after_load", self.cached_fraction_after_load.into()),
        ])
    }
}

/// `blink simulate`: realized vs naive cost under a disturbance scenario.
#[derive(Debug, Clone)]
pub struct SimulateReport {
    pub app: String,
    pub scale: f64,
    pub input_mb: f64,
    pub machines: usize,
    pub instance: String,
    pub scenario: String,
    pub pricing: String,
    pub baseline: RunStats,
    pub disturbed: RunStats,
    pub naive_quote: f64,
    pub realized_cost: f64,
}

impl Report for SimulateReport {
    fn render_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "app {}  scale {:.0} ({} input)  fleet {} x {}  scenario '{}'",
            self.app,
            self.scale,
            fmt_mb(self.input_mb),
            self.machines,
            self.instance,
            self.scenario,
        );
        let _ = writeln!(
            out,
            "baseline: {} ({:.1} machine-min), evictions {}, cached after load {}",
            fmt_secs(self.baseline.duration_s),
            self.baseline.cost_machine_min,
            self.baseline.evictions,
            fmt_pct(self.baseline.cached_fraction_after_load),
        );
        let _ = writeln!(
            out,
            "scenario: {} ({:+.1} %), evictions {}, machines lost {}, joined {}, cached after load {}",
            fmt_secs(self.disturbed.duration_s),
            (self.disturbed.duration_s / self.baseline.duration_s.max(1e-12) - 1.0) * 100.0,
            self.disturbed.evictions,
            self.disturbed.machines_lost,
            self.disturbed.machines_joined,
            fmt_pct(self.disturbed.cached_fraction_after_load),
        );
        let _ = writeln!(
            out,
            "{} pricing — naive quote {:.4}  realized (per-machine uptime) {:.4}  ({:+.1} %)",
            self.pricing,
            self.naive_quote,
            self.realized_cost,
            (self.realized_cost / self.naive_quote.max(1e-12) - 1.0) * 100.0,
        );
        finish(out)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query", "simulate".into()),
            ("app", self.app.as_str().into()),
            ("scale", self.scale.into()),
            ("input_mb", self.input_mb.into()),
            ("machines", self.machines.into()),
            ("instance", self.instance.as_str().into()),
            ("scenario", self.scenario.as_str().into()),
            ("pricing", self.pricing.as_str().into()),
            ("baseline", self.baseline.to_json()),
            ("disturbed", self.disturbed.to_json()),
            ("naive_quote", self.naive_quote.into()),
            ("realized_cost", self.realized_cost.into()),
        ])
    }
}

// ======================================================================
// blink run
// ======================================================================

/// `blink run`: the recommendation plus the actual run at the pick.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub decide: RecommendReport,
    pub seed: u64,
    pub duration_s: f64,
    pub cost_machine_min: f64,
    pub cost_machine_s: f64,
    pub evictions: usize,
}

impl RunReport {
    /// Sampling + actual run, machine-seconds.
    pub fn total_cost_machine_s(&self) -> f64 {
        self.decide.recommendation.sample_cost_machine_s + self.cost_machine_s
    }

    /// Sampling cost as a fraction of the actual-run cost.
    pub fn sampling_overhead(&self) -> f64 {
        self.decide.recommendation.sample_cost_machine_s / self.cost_machine_s.max(1e-9)
    }
}

impl Report for RunReport {
    fn render_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.decide.render_text());
        let _ = writeln!(
            out,
            "actual run: {} on {} machines -> {} ({:.1} machine-min, {} evictions)",
            self.decide.app,
            self.decide.recommendation.machines,
            fmt_secs(self.duration_s),
            self.cost_machine_min,
            self.evictions
        );
        let _ = writeln!(
            out,
            "total cost incl. sampling: {:.1} machine-min (sampling {})",
            self.total_cost_machine_s() / 60.0,
            fmt_pct(self.sampling_overhead())
        );
        finish(out)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query", "run".into()),
            ("recommendation", self.decide.to_json()),
            // as a string: JSON numbers are f64 and would round a u64
            // seed above 2^53, breaking reproducibility
            ("seed", self.seed.to_string().into()),
            (
                "actual",
                Json::obj(vec![
                    ("duration_s", self.duration_s.into()),
                    ("cost_machine_min", self.cost_machine_min.into()),
                    ("evictions", self.evictions.into()),
                ]),
            ),
            ("total_cost_machine_min", (self.total_cost_machine_s() / 60.0).into()),
            ("sampling_overhead", self.sampling_overhead().into()),
        ])
    }
}

// ======================================================================
// blink apps
// ======================================================================

/// One row of the workload-model listing.
#[derive(Debug, Clone)]
pub struct AppRow {
    pub name: String,
    pub input_mb: f64,
    pub blocks: usize,
    pub iterations: usize,
    pub cached_mb_at_100: f64,
    pub approach: String,
}

/// `blink apps`: the registered workload models.
#[derive(Debug, Clone)]
pub struct AppsReport {
    pub rows: Vec<AppRow>,
}

impl Report for AppsReport {
    fn render_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<7} {:>10} {:>8} {:>7} {:>12} {:>10}",
            "app", "input", "blocks", "iters", "cached@100%", "approach"
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{:<7} {:>10} {:>8} {:>7} {:>12} {:>10}",
                r.name,
                fmt_mb(r.input_mb),
                r.blocks,
                r.iterations,
                fmt_mb(r.cached_mb_at_100),
                r.approach,
            );
        }
        finish(out)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query", "apps".into()),
            (
                "apps",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", r.name.as_str().into()),
                                ("input_mb", r.input_mb.into()),
                                ("blocks", r.blocks.into()),
                                ("iterations", r.iterations.into()),
                                ("cached_mb_at_100", r.cached_mb_at_100.into()),
                                ("approach", r.approach.as_str().into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// ======================================================================
// blink synth
// ======================================================================

/// One generated workload's advisor answers (`blink synth`).
#[derive(Debug, Clone)]
pub struct SynthRow {
    pub name: String,
    /// Generator seed — reproduces the workload exactly.
    pub seed: u64,
    pub datasets: usize,
    pub input_mb: f64,
    pub predicted_cached_mb: f64,
    pub predicted_exec_mb: f64,
    pub sample_cost_machine_s: f64,
    /// The §5.4 worker-node pick.
    pub machines: usize,
    /// The catalog planner's best pick (instance, count, cost).
    pub best_instance: String,
    pub best_machines: usize,
    pub best_cost: f64,
    pub eviction_free: bool,
    pub no_cached_data: bool,
}

/// `blink synth`: advisor answers over a batch of generated workloads,
/// optionally cross-checked against the testkit's analytic invariants.
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub backend: String,
    pub preset: String,
    pub first_seed: u64,
    pub scale: f64,
    pub catalog_name: String,
    pub catalog_types: usize,
    pub pricing: String,
    pub rows: Vec<SynthRow>,
    /// Invariant checks run (`--check`); 0 when checking was off.
    pub checks: usize,
    /// Rendered testkit violations (each carries its reproduction seed).
    pub violations: Vec<String>,
}

impl Report for SynthReport {
    fn render_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "SYNTH — preset '{}', {} workloads from seed {}, scale {:.0}, catalog '{}' ({} types), pricing '{}'",
            self.preset,
            self.rows.len(),
            self.first_seed,
            self.scale,
            self.catalog_name,
            self.catalog_types,
            self.pricing,
        );
        let _ = writeln!(out, "fit backend: {}", self.backend);
        let _ = writeln!(
            out,
            "{:<22} {:>3} {:>10} {:>10} {:>5} {:<16} {:>10} {:>5}",
            "workload", "ds", "cached", "exec", "pick", "best", "cost", "free"
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{:<22} {:>3} {:>10} {:>10} {:>5} {:<16} {:>10.3} {:>5}",
                r.name,
                r.datasets,
                fmt_mb(r.predicted_cached_mb),
                fmt_mb(r.predicted_exec_mb),
                r.machines,
                format!("{} x{}", r.best_instance, r.best_machines),
                r.best_cost,
                if r.eviction_free { "yes" } else { "NO" },
            );
        }
        let free = self.rows.iter().filter(|r| r.eviction_free).count();
        let mean_sample = self.rows.iter().map(|r| r.sample_cost_machine_s).sum::<f64>()
            / self.rows.len().max(1) as f64;
        let _ = writeln!(
            out,
            "eviction-free best picks: {free}/{}   mean sampling cost {}",
            self.rows.len(),
            fmt_secs(mean_sample),
        );
        if self.checks > 0 {
            let _ = writeln!(
                out,
                "invariants: {} checks, {} violations",
                self.checks,
                self.violations.len()
            );
            for v in &self.violations {
                let _ = writeln!(out, "  VIOLATION {v}");
            }
        }
        finish(out)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query", "synth".into()),
            ("backend", self.backend.as_str().into()),
            ("preset", self.preset.as_str().into()),
            // string: u64 seeds above 2^53 would round as JSON numbers
            ("first_seed", self.first_seed.to_string().into()),
            ("scale", self.scale.into()),
            ("catalog", self.catalog_name.as_str().into()),
            ("catalog_types", self.catalog_types.into()),
            ("pricing", self.pricing.as_str().into()),
            (
                "workloads",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", r.name.as_str().into()),
                                ("seed", r.seed.to_string().into()),
                                ("datasets", r.datasets.into()),
                                ("input_mb", r.input_mb.into()),
                                ("predicted_cached_mb", r.predicted_cached_mb.into()),
                                ("predicted_exec_mb", r.predicted_exec_mb.into()),
                                ("sample_cost_machine_s", r.sample_cost_machine_s.into()),
                                ("machines", r.machines.into()),
                                ("best_instance", r.best_instance.as_str().into()),
                                ("best_machines", r.best_machines.into()),
                                ("best_cost", r.best_cost.into()),
                                ("eviction_free", r.eviction_free.into()),
                                ("no_cached_data", r.no_cached_data.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("checks", self.checks.into()),
            (
                "violations",
                Json::Arr(self.violations.iter().map(|v| v.as_str().into()).collect()),
            ),
        ])
    }
}

/// `blink serve`: one JSONL batch answered from the sharded profile
/// store. The `results` array (one doc per query line, in line order) is
/// the deterministic payload — byte-identical at any shard or thread
/// count; `elapsed_s`/`queries_per_s` are wall-clock diagnostics and
/// deliberately sit outside it.
pub struct ServeReport {
    pub backend: String,
    pub queries: usize,
    pub ok: usize,
    pub errors: usize,
    /// Distinct profiles in the store after the batch.
    pub profiles: usize,
    /// Sampling phases actually paid (cold misses; preloads don't count).
    pub sampling_phases: usize,
    pub shards: usize,
    /// Requested worker count (0 = sized from the host).
    pub threads: usize,
    pub elapsed_s: f64,
    /// One answer doc per query line, in line order.
    pub results: Vec<Json>,
}

impl ServeReport {
    pub fn queries_per_s(&self) -> f64 {
        self.queries as f64 / self.elapsed_s.max(1e-9)
    }
}

impl Report for ServeReport {
    fn render_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "SERVE — {} queries ({} ok, {} errors) from {} profiles ({} sampling phases)",
            self.queries, self.ok, self.errors, self.profiles, self.sampling_phases,
        );
        let _ = writeln!(
            out,
            "fit backend: {}; {} shards, {} threads{}",
            self.backend,
            self.shards,
            self.threads,
            if self.threads == 0 { " (auto)" } else { "" },
        );
        let _ = writeln!(
            out,
            "elapsed {} ({:.0} queries/s)",
            fmt_secs(self.elapsed_s),
            self.queries_per_s(),
        );
        for (i, doc) in self.results.iter().enumerate() {
            let kind = doc.get("query").and_then(Json::as_str).unwrap_or("?");
            let detail = if kind == "error" {
                doc.get("error").and_then(Json::as_str).unwrap_or("").to_string()
            } else {
                doc.get("app").and_then(Json::as_str).unwrap_or("").to_string()
            };
            let _ = writeln!(out, "  [{i}] {kind} {detail}");
        }
        finish(out)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query", "serve".into()),
            ("backend", self.backend.as_str().into()),
            ("queries", self.queries.into()),
            ("ok", self.ok.into()),
            ("errors", self.errors.into()),
            ("profiles", self.profiles.into()),
            ("sampling_phases", self.sampling_phases.into()),
            ("shards", self.shards.into()),
            ("threads", self.threads.into()),
            ("elapsed_s", self.elapsed_s.into()),
            ("queries_per_s", self.queries_per_s().into()),
            ("results", Json::Arr(self.results.clone())),
        ])
    }
}

// ======================================================================
// blink fleet
// ======================================================================

pub fn fleet_candidate_json(c: &FleetCandidate) -> Json {
    Json::obj(vec![
        ("instance", c.instance.as_str().into()),
        ("machines", c.machines.into()),
        ("storage_fraction", c.storage_fraction.into()),
        ("eviction_free", c.eviction_free.into()),
        ("headroom_mb", c.headroom_mb.into()),
        ("predicted_time_s", c.predicted_time_s.into()),
        ("predicted_cost", c.predicted_cost.into()),
        (
            "per_tenant_time_s",
            Json::Arr(c.per_tenant_time_s.iter().map(|&t| t.into()).collect()),
        ),
    ])
}

pub fn fleet_pick_json(p: &FleetPick) -> Json {
    Json::obj(vec![
        ("candidate", fleet_candidate_json(&p.candidate)),
        ("selection", selection_json(&p.selection)),
    ])
}

pub fn fleet_plan_json(p: &FleetPlan) -> Json {
    Json::obj(vec![
        ("tenants", Json::Arr(p.tenants.iter().map(|t| t.as_str().into()).collect())),
        ("ranked", Json::Arr(p.ranked.iter().map(fleet_pick_json).collect())),
        ("best", p.best().map_or(Json::Null, fleet_pick_json)),
        ("grid", Json::Arr(p.grid.iter().map(fleet_candidate_json).collect())),
    ])
}

/// One tenant's sampled predictions feeding the fleet plan.
#[derive(Debug, Clone)]
pub struct FleetTenantRow {
    pub name: String,
    pub predicted_cached_mb: f64,
    pub predicted_exec_mb: f64,
    pub sample_cost_machine_s: f64,
}

/// The interleaved engine run at the plan's best pick: the realized
/// shared-fleet outcome `plan_fleet` only predicted.
#[derive(Debug, Clone)]
pub struct FleetRealized {
    pub instance: String,
    pub machines: usize,
    pub seed: u64,
    /// Fleet makespan (the last tenant's finish).
    pub duration_s: f64,
    pub realized_cost: f64,
    /// Order-sensitive digest of the whole run (the `check_fleet`
    /// determinism handle) — JSON only, too long for the text table.
    pub fingerprint: String,
    pub tenants: Vec<TenantRunStats>,
}

/// `blink fleet`: N concurrent tenants planned onto one shared fleet
/// (the §5.4 bound over summed working sets), then realized by the
/// interleaved engine at the best pick.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub backend: String,
    pub scale: f64,
    pub catalog_name: String,
    pub catalog_types: usize,
    pub pricing: String,
    pub fairness: String,
    pub scenario: String,
    pub rows: Vec<FleetTenantRow>,
    pub plan: FleetPlan,
    pub realized: Option<FleetRealized>,
}

impl Report for FleetReport {
    fn render_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "FLEET — {} tenants at scale {:.0}, catalog '{}' ({} types), pricing '{}', fairness '{}', scenario '{}'",
            self.rows.len(),
            self.scale,
            self.catalog_name,
            self.catalog_types,
            self.pricing,
            self.fairness,
            self.scenario,
        );
        let _ = writeln!(out, "fit backend: {}", self.backend);
        let _ = writeln!(
            out,
            "{:<22} {:>10} {:>10} {:>10}",
            "tenant", "cached", "exec", "sampling"
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{:<22} {:>10} {:>10} {:>10}",
                r.name,
                fmt_mb(r.predicted_cached_mb),
                fmt_mb(r.predicted_exec_mb),
                fmt_secs(r.sample_cost_machine_s),
            );
        }
        let _ = writeln!(out, "shared plan (summed working sets, serialized runtimes):");
        let _ = writeln!(
            out,
            "{:>4} {:<12} {:>4} {:>4}..{:<4} {:>10} {:>12} {:>14} {:>6}",
            "rank", "instance", "n", "min", "max", "time", "cost", "headroom", "free"
        );
        for (i, pick) in self.plan.ranked.iter().enumerate() {
            let c = &pick.candidate;
            let s = &pick.selection;
            let headroom = if s.saturated {
                format!("-{} !", fmt_mb(s.cache_deficit_mb()))
            } else {
                fmt_mb_signed(c.headroom_mb)
            };
            let _ = writeln!(
                out,
                "{:>4} {:<12} {:>4} {:>4}..{:<4} {:>10} {:>12.2} {:>14} {:>6}",
                i + 1,
                c.instance,
                c.machines,
                s.machines_min,
                s.machines_max,
                fmt_secs(c.predicted_time_s),
                c.predicted_cost,
                headroom,
                if c.eviction_free { "yes" } else { "NO" },
            );
        }
        if let Some(best) = self.plan.best() {
            let _ = writeln!(
                out,
                "-> recommend {} x{} ({}, cost {:.2}){}",
                best.candidate.instance,
                best.candidate.machines,
                fmt_secs(best.candidate.predicted_time_s),
                best.candidate.predicted_cost,
                if best.candidate.eviction_free {
                    ""
                } else {
                    "  — WARNING: no eviction-free count within the bracket; tenants will evict"
                }
            );
        }
        if let Some(r) = &self.realized {
            let _ = writeln!(
                out,
                "realized run (seed {}): {} x{} — makespan {}, cost {:.4}",
                r.seed,
                r.instance,
                r.machines,
                fmt_secs(r.duration_s),
                r.realized_cost,
            );
            let _ = writeln!(
                out,
                "  {:<22} {:>5} {:>6} {:>10} {:>10} {:>7}",
                "tenant", "jobs", "evict", "lost", "finish", "cached"
            );
            for t in &r.tenants {
                let _ = writeln!(
                    out,
                    "  {:<22} {:>5} {:>6} {:>10} {:>10} {:>7}",
                    t.name,
                    t.jobs,
                    t.evictions,
                    fmt_mb(t.cached_mb_lost),
                    fmt_secs(t.finish_s),
                    fmt_pct(t.cached_fraction_after_load),
                );
            }
        }
        finish(out)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query", "fleet".into()),
            ("backend", self.backend.as_str().into()),
            ("scale", self.scale.into()),
            ("catalog", self.catalog_name.as_str().into()),
            ("catalog_types", self.catalog_types.into()),
            ("pricing", self.pricing.as_str().into()),
            ("fairness", self.fairness.as_str().into()),
            ("scenario", self.scenario.as_str().into()),
            (
                "tenants",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", r.name.as_str().into()),
                                ("predicted_cached_mb", r.predicted_cached_mb.into()),
                                ("predicted_exec_mb", r.predicted_exec_mb.into()),
                                ("sample_cost_machine_s", r.sample_cost_machine_s.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("plan", fleet_plan_json(&self.plan)),
            (
                "realized",
                self.realized.as_ref().map_or(Json::Null, |r| {
                    Json::obj(vec![
                        ("instance", r.instance.as_str().into()),
                        ("machines", r.machines.into()),
                        // string: u64 seeds above 2^53 would round as
                        // JSON numbers
                        ("seed", r.seed.to_string().into()),
                        ("duration_s", r.duration_s.into()),
                        ("realized_cost", r.realized_cost.into()),
                        ("fingerprint", r.fingerprint.as_str().into()),
                        (
                            "tenants",
                            Json::Arr(
                                r.tenants
                                    .iter()
                                    .map(|t| {
                                        Json::obj(vec![
                                            ("name", t.name.as_str().into()),
                                            ("jobs", t.jobs.into()),
                                            ("evictions", t.evictions.into()),
                                            ("cached_mb_lost", t.cached_mb_lost.into()),
                                            ("finish_s", t.finish_s.into()),
                                            (
                                                "cached_fraction_after_load",
                                                t.cached_fraction_after_load.into(),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                }),
            ),
        ])
    }
}

// ======================================================================
// blink adapt
// ======================================================================

/// `blink adapt`: the observe → refit → re-plan → act loop's answer —
/// the static pick, what the run's own observations did to the size
/// models, the re-plan decision (if any), and the realized comparison.
#[derive(Debug, Clone)]
pub struct AdaptReport {
    pub backend: String,
    pub catalog_name: String,
    pub pricing: String,
    pub scenario: String,
    /// The divergence threshold the loop ran with.
    pub threshold: f64,
    pub outcome: AdaptOutcome,
}

fn replan_json(d: &ReplanDecision) -> Json {
    Json::obj(vec![
        ("job", d.job.into()),
        ("at_s", d.at_s.into()),
        ("predicted_mb", d.predicted_mb.into()),
        ("refit_mb", d.refit_mb.into()),
        ("divergence", d.divergence.into()),
        ("deficit_mb", d.deficit_mb.into()),
        ("replanned_machines", d.replanned_machines.into()),
        ("add_machines", d.add_machines.into()),
        ("remove_machines", d.remove_machines.into()),
    ])
}

impl Report for AdaptReport {
    fn render_text(&self) -> String {
        let o = &self.outcome;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "ADAPT — app {}  scale {:.0}  pick {} x{} (catalog '{}', pricing '{}', scenario '{}')",
            o.app,
            o.scale,
            o.instance,
            o.machines,
            self.catalog_name,
            self.pricing,
            self.scenario,
        );
        let _ = writeln!(out, "fit backend: {}", self.backend);
        let _ = writeln!(
            out,
            "predicted cached {}  refit {} after {} job barriers (threshold {})",
            fmt_mb(o.predicted_mb),
            fmt_mb(o.refit_mb),
            o.observations,
            fmt_pct(self.threshold),
        );
        match &o.decision {
            Some(d) => {
                // a deficit scales out (+n), a surplus scales in (-n);
                // a decision with neither arm is advisory only
                let arm = if d.add_machines > 0 {
                    format!("+{}", d.add_machines)
                } else if d.remove_machines > 0 {
                    format!("-{}", d.remove_machines)
                } else {
                    "advisory".to_string()
                };
                let _ = writeln!(
                    out,
                    "replan @ job {} (t={}): divergence {}, deficit {} -> {} machines ({arm})",
                    d.job,
                    fmt_secs(d.at_s),
                    fmt_pct(d.divergence),
                    fmt_mb_signed(d.deficit_mb),
                    d.replanned_machines,
                );
            }
            None => {
                let _ = writeln!(out, "no replan: refit stayed within the threshold");
            }
        }
        let _ = writeln!(
            out,
            "static run: {} cost {:.4}",
            fmt_secs(o.static_time_s),
            o.static_cost,
        );
        if o.adopted {
            let _ = writeln!(
                out,
                "-> corrective run ADOPTED: {} cost {:.4} ({:+.1} %)",
                fmt_secs(o.adaptive_time_s),
                o.adaptive_cost,
                (o.adaptive_cost / o.static_cost.max(1e-12) - 1.0) * 100.0,
            );
        } else if o
            .decision
            .as_ref()
            .is_some_and(|d| d.add_machines > 0 || d.remove_machines > 0)
        {
            let _ = writeln!(out, "-> corrective run cost more; static pick kept");
        } else {
            let _ = writeln!(out, "-> static pick kept");
        }
        finish(out)
    }

    fn to_json(&self) -> Json {
        let o = &self.outcome;
        Json::obj(vec![
            ("query", "adapt".into()),
            ("backend", self.backend.as_str().into()),
            ("app", o.app.as_str().into()),
            ("scale", o.scale.into()),
            ("catalog", self.catalog_name.as_str().into()),
            ("pricing", self.pricing.as_str().into()),
            ("scenario", self.scenario.as_str().into()),
            ("threshold", self.threshold.into()),
            ("instance", o.instance.as_str().into()),
            ("machines", o.machines.into()),
            ("predicted_mb", o.predicted_mb.into()),
            ("refit_mb", o.refit_mb.into()),
            ("observations", o.observations.into()),
            ("replan", o.decision.as_ref().map_or(Json::Null, replan_json)),
            ("adopted", o.adopted.into()),
            ("static_time_s", o.static_time_s.into()),
            ("static_cost", o.static_cost.into()),
            ("adaptive_time_s", o.adaptive_time_s.into()),
            ("adaptive_cost", o.adaptive_cost.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_format_names_round_trip() {
        for f in [OutputFormat::Text, OutputFormat::Json] {
            assert_eq!(OutputFormat::by_name(f.name()), Some(f));
        }
        assert_eq!(OutputFormat::by_name("yaml"), None);
    }

    #[test]
    fn synth_report_renders_and_roundtrips_json() {
        let report = SynthReport {
            backend: "rust-nnls".into(),
            preset: "smoke".into(),
            first_seed: u64::MAX, // must survive JSON (encoded as string)
            scale: 1000.0,
            catalog_name: "paper".into(),
            catalog_types: 2,
            pricing: "machine-seconds".into(),
            rows: vec![SynthRow {
                name: "synth-smoke-ffff".into(),
                seed: u64::MAX,
                datasets: 2,
                input_mb: 1234.0,
                predicted_cached_mb: 500.0,
                predicted_exec_mb: 100.0,
                sample_cost_machine_s: 9.5,
                machines: 2,
                best_instance: "i5-worker".into(),
                best_machines: 2,
                best_cost: 77.0,
                eviction_free: true,
                no_cached_data: false,
            }],
            checks: 12,
            violations: vec!["[demo] workload x (generator seed 3): boom".into()],
        };
        let text = report.render_text();
        assert!(text.contains("preset 'smoke'"));
        assert!(text.contains("VIOLATION"));
        let j = crate::util::json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.get("query").and_then(Json::as_str), Some("synth"));
        assert_eq!(
            j.path(&["workloads"]).unwrap().as_arr().unwrap()[0]
                .get("seed")
                .and_then(Json::as_str),
            Some(u64::MAX.to_string().as_str())
        );
        assert_eq!(j.get("checks").and_then(Json::as_f64), Some(12.0));
    }

    #[test]
    fn adapt_report_renders_and_roundtrips_json() {
        let mut report = AdaptReport {
            backend: "rust-nnls".into(),
            catalog_name: "cloud".into(),
            pricing: "machine-seconds".into(),
            scenario: "none".into(),
            threshold: 0.5,
            outcome: AdaptOutcome {
                app: "synth-superlinear-000b".into(),
                scale: 300.0,
                instance: "gp.xlarge".into(),
                machines: 3,
                predicted_mb: 100.0,
                refit_mb: 250.0,
                observations: 6,
                decision: Some(ReplanDecision {
                    job: 1,
                    at_s: 12.0,
                    predicted_mb: 100.0,
                    refit_mb: 240.0,
                    divergence: 1.4,
                    deficit_mb: 80.0,
                    replanned_machines: 5,
                    add_machines: 2,
                    remove_machines: 0,
                }),
                adopted: true,
                static_time_s: 50.0,
                static_cost: 150.0,
                adaptive_time_s: 45.0,
                adaptive_cost: 120.0,
            },
        };
        let text = report.render_text();
        assert!(text.contains("replan @ job 1"), "{text}");
        assert!(text.contains("ADOPTED"), "{text}");
        let j = crate::util::json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.get("query").and_then(Json::as_str), Some("adapt"));
        assert_eq!(
            j.path(&["replan"]).unwrap().get("add_machines").and_then(Json::as_f64),
            Some(2.0)
        );
        assert_eq!(j.get("adopted").and_then(Json::as_bool), Some(true));
        // the no-replan branch renders the quiet path and encodes null
        report.outcome.decision = None;
        report.outcome.adopted = false;
        let text = report.render_text();
        assert!(text.contains("no replan"), "{text}");
        assert!(text.contains("static pick kept"), "{text}");
        let j = crate::util::json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.get("replan"), Some(&Json::Null));
        // the surplus arm renders a retirement and encodes remove_machines
        report.outcome.decision = Some(ReplanDecision {
            job: 2,
            at_s: 20.0,
            predicted_mb: 300.0,
            refit_mb: 90.0,
            divergence: 0.7,
            deficit_mb: -60.0,
            replanned_machines: 1,
            add_machines: 0,
            remove_machines: 2,
        });
        let text = report.render_text();
        assert!(text.contains("-> 1 machines (-2)"), "{text}");
        assert!(text.contains("corrective run cost more"), "{text}");
        let j = crate::util::json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(
            j.path(&["replan"]).unwrap().get("remove_machines").and_then(Json::as_f64),
            Some(2.0)
        );
    }

    #[test]
    fn fleet_report_renders_and_roundtrips_json() {
        let candidate = FleetCandidate {
            instance: "i5-worker".into(),
            machines: 7,
            storage_fraction: 0.5,
            eviction_free: true,
            headroom_mb: 120.0,
            predicted_time_s: 900.0,
            predicted_cost: 63.0,
            per_tenant_time_s: vec![400.0, 300.0, 200.0],
        };
        let pick = FleetPick {
            candidate: candidate.clone(),
            selection: Selection {
                machines: 7,
                machines_min: 7,
                machines_max: 12,
                machine_exec_mb: 500.0,
                headroom_mb: 120.0,
                saturated: false,
            },
        };
        let report = FleetReport {
            backend: "rust-nnls".into(),
            scale: 1000.0,
            catalog_name: "paper".into(),
            catalog_types: 1,
            pricing: "machine-seconds".into(),
            fairness: "shared-lru".into(),
            scenario: "none".into(),
            rows: vec![FleetTenantRow {
                name: "svm".into(),
                predicted_cached_mb: 9000.0,
                predicted_exec_mb: 800.0,
                sample_cost_machine_s: 12.0,
            }],
            plan: FleetPlan {
                tenants: vec!["svm".into(), "km".into(), "lr".into()],
                ranked: vec![pick],
                grid: vec![candidate],
            },
            realized: Some(FleetRealized {
                instance: "i5-worker".into(),
                machines: 7,
                seed: u64::MAX, // must survive JSON (encoded as string)
                duration_s: 910.0,
                realized_cost: 63.7,
                fingerprint: "svm|6|0|0|0|0|deadbeef#".into(),
                tenants: vec![TenantRunStats {
                    name: "svm".into(),
                    jobs: 6,
                    evictions: 0,
                    cached_mb_lost: 0.0,
                    finish_s: 910.0,
                    cached_fraction_after_load: 1.0,
                }],
            }),
        };
        let text = report.render_text();
        assert!(text.contains("FLEET — 1 tenants"), "{text}");
        assert!(text.contains("-> recommend i5-worker x7"), "{text}");
        assert!(text.contains("realized run (seed"), "{text}");
        let j = crate::util::json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.get("query").and_then(Json::as_str), Some("fleet"));
        assert_eq!(j.get("fairness").and_then(Json::as_str), Some("shared-lru"));
        assert_eq!(
            j.path(&["realized"]).unwrap().get("seed").and_then(Json::as_str),
            Some(u64::MAX.to_string().as_str())
        );
        assert_eq!(
            j.path(&["plan", "best", "candidate"]).unwrap().get("machines").and_then(Json::as_f64),
            Some(7.0)
        );
        // the plan-only shape (no realized run) encodes null
        let mut report = report;
        report.realized = None;
        let text = report.render_text();
        assert!(!text.contains("realized run"), "{text}");
        let j = crate::util::json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.get("realized"), Some(&Json::Null));
    }

    #[test]
    fn bounds_report_handles_the_unbounded_case() {
        let r = BoundsReport {
            app: "pca".into(),
            machines: 12,
            max_scale: f64::INFINITY,
            input_mb_at_max: 0.0,
        };
        assert!(r.unbounded());
        assert_eq!(r.render_text(), "pca caches nothing; any scale fits");
        let j = crate::util::json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("max_scale"), Some(&Json::Null));
        assert_eq!(j.get("unbounded").and_then(Json::as_bool), Some(true));
    }
}
