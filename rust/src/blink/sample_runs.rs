//! Sample runs manager (§5.1).
//!
//! Carries out three lightweight sample runs (0.1 %–0.3 % of the input) on
//! a single machine, monitors each run for the atypical cases, and analyzes
//! the *serialized listener logs* (JSON lines, as a real SparkListener
//! would leave on HDFS):
//!
//! * no cached dataset at all -> skip prediction, run the actual job on a
//!   single machine (longest time, cheapest cost);
//! * eviction during a sample run (unusual for tiny datasets) -> abort and
//!   retry that scale at half the sampling fraction.

use crate::hdfs::Sampler;
use crate::memory::EvictionPolicy;
use crate::metrics::{EventLog, RunSummary};
use crate::sim::{simulate, ClusterSpec, SimOptions};
use crate::workloads::AppModel;

/// Default sampling scales, in paper units (0.1 %, 0.2 %, 0.3 %).
pub const DEFAULT_SCALES: [f64; 3] = [1.0, 2.0, 3.0];

/// Outcome of the sampling phase.
#[derive(Debug, Clone)]
pub enum SamplingOutcome {
    /// Normal case: per-run summaries to feed the predictors.
    Profiled(Vec<SampleRun>),
    /// Atypical case 1: the application caches nothing.
    NoCachedData { sample_cost_machine_s: f64 },
}

/// One completed sample run.
#[derive(Debug, Clone)]
pub struct SampleRun {
    pub scale: f64,
    pub summary: RunSummary,
    /// Scale was reduced from the requested one due to eviction retries.
    pub rescaled: bool,
}

/// Configuration of the sampling phase.
#[derive(Debug, Clone)]
pub struct SampleRunsManager {
    pub sampler: Sampler,
    /// The single machine the samples run on (the paper's i3 node).
    pub node: ClusterSpec,
    pub policy: EvictionPolicy,
    pub seed: u64,
    /// Max halving retries per scale when evictions occur.
    pub max_retries: usize,
}

impl Default for SampleRunsManager {
    fn default() -> Self {
        SampleRunsManager {
            sampler: Sampler::default(),
            node: ClusterSpec::single_sample_node(),
            policy: EvictionPolicy::Lru,
            seed: 7,
            max_retries: 4,
        }
    }
}

impl SampleRunsManager {
    /// Run the sampling phase at the given scales.
    pub fn run(&self, app: &AppModel, scales: &[f64]) -> SamplingOutcome {
        let mut runs = Vec::new();
        for (i, &scale) in scales.iter().enumerate() {
            let (run, log) = self.one_run(app, scale, self.seed + i as u64);
            // atypical case 1: nothing cached -> single machine, done
            if run.summary.cached_sizes_mb.is_empty() {
                let spent: f64 = run.summary.cost_machine_s
                    + runs.iter().map(|r: &SampleRun| r.summary.cost_machine_s).sum::<f64>();
                let _ = log;
                return SamplingOutcome::NoCachedData { sample_cost_machine_s: spent };
            }
            runs.push(run);
        }
        SamplingOutcome::Profiled(runs)
    }

    /// Execute one monitored sample run, retrying at lower scales on
    /// eviction (atypical case 2).
    fn one_run(&self, app: &AppModel, requested_scale: f64, seed: u64) -> (SampleRun, EventLog) {
        let mut scale = requested_scale;
        let mut wasted_cost = 0.0;
        for attempt in 0..=self.max_retries {
            let profile = app.sample_profile(scale, &self.sampler);
            let res = simulate(
                &profile,
                &self.node,
                SimOptions { policy: self.policy, seed: seed + 1000 * attempt as u64, compute: None, detailed_log: true },
            )
            .expect("sample node is valid");
            // the manager consumes logs the way a real deployment would:
            // serialized, then re-parsed
            let text = res.log.to_jsonl();
            let log = EventLog::from_jsonl(&text).expect("own logs must parse");
            let mut summary = RunSummary::from_log(&log);
            if summary.evictions == 0 {
                summary.cost_machine_s += wasted_cost;
                return (
                    SampleRun { scale, summary, rescaled: attempt > 0 },
                    log,
                );
            }
            // terminated: count what we spent, halve and retry
            wasted_cost += summary.cost_machine_s;
            scale /= 2.0;
        }
        panic!(
            "sample run for {} evicts even at scale {scale}; sample node too small",
            app.name
        );
    }

    /// Total cost of a set of sample runs, machine-seconds.
    pub fn total_cost_machine_s(runs: &[SampleRun]) -> f64 {
        runs.iter().map(|r| r.summary.cost_machine_s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::app_by_name;

    #[test]
    fn three_sample_runs_profile_cached_sizes() {
        let mgr = SampleRunsManager::default();
        let app = app_by_name("svm").unwrap();
        match mgr.run(&app, &DEFAULT_SCALES) {
            SamplingOutcome::Profiled(runs) => {
                assert_eq!(runs.len(), 3);
                for (i, r) in runs.iter().enumerate() {
                    assert_eq!(r.scale, DEFAULT_SCALES[i]);
                    assert!(!r.rescaled);
                    assert_eq!(r.summary.machines, 1, "samples run on one machine");
                    assert_eq!(r.summary.cached_sizes_mb.len(), 1);
                    assert!(r.summary.total_cached_mb() > 0.0);
                }
                // sizes grow with scale
                assert!(runs[2].summary.total_cached_mb() > runs[0].summary.total_cached_mb());
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }

    #[test]
    fn sample_costs_are_tiny_vs_input() {
        let mgr = SampleRunsManager::default();
        let app = app_by_name("lr").unwrap();
        if let SamplingOutcome::Profiled(runs) = mgr.run(&app, &DEFAULT_SCALES) {
            let cost = SampleRunsManager::total_cost_machine_s(&runs);
            assert!(cost > 0.0);
            // a sample run handles ~0.1% of data; minutes, not hours
            assert!(cost < 1800.0, "{cost}");
        } else {
            panic!("lr caches data");
        }
    }

    #[test]
    fn block_s_apps_pay_preparation_in_cost() {
        let mgr = SampleRunsManager::default();
        let km = app_by_name("km").unwrap(); // Block-s (forced)
        let lr = app_by_name("lr").unwrap(); // Block-n
        let cost = |app| match mgr.run(app, &DEFAULT_SCALES) {
            SamplingOutcome::Profiled(runs) => SampleRunsManager::total_cost_machine_s(&runs),
            _ => panic!(),
        };
        let km_profile = km.sample_profile(1.0, &mgr.sampler);
        assert!(km_profile.sample_prep_s > 0.0);
        // km input at 0.1% is ~22 MB -> prep ~0.55s each run; just assert
        // both phases complete and are positive
        let (km_cost, lr_cost) = (cost(&km), cost(&lr));
        assert!(km_cost > 0.0 && lr_cost > 0.0);
    }

    #[test]
    fn deterministic_sizes_across_repeated_sampling() {
        let mgr = SampleRunsManager::default();
        let app = app_by_name("gbt").unwrap();
        let sizes = |seed: u64| {
            let m = SampleRunsManager { seed, ..Default::default() };
            match m.run(&app, &DEFAULT_SCALES) {
                SamplingOutcome::Profiled(runs) => runs
                    .iter()
                    .map(|r| r.summary.total_cached_mb())
                    .collect::<Vec<_>>(),
                _ => panic!(),
            }
        };
        // Fig. 4: different runs (seeds) measure identical cached sizes
        assert_eq!(sizes(1), sizes(99));
    }
}
