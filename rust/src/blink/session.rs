//! Session-oriented advisor API: profile once, query many.
//!
//! Blink's economics rest on one cheap sampling phase amortizing across
//! every downstream decision (§5, Fig. 5). This module makes that shape
//! the public API:
//!
//! * [`Advisor`] — a long-lived, builder-configured session around a fit
//!   backend. [`Advisor::profile`] runs the sampling phase for an
//!   application **once** and caches the result keyed by
//!   `(app, sampling scales)`, so repeated CLI or service calls hit
//!   trained state instead of re-sampling.
//! * [`TrainedProfile`] — the product of that one phase: the fitted
//!   [`SizePredictor`]/[`ExecMemoryPredictor`] plus sampling diagnostics
//!   (per-run summaries, total cost, the no-cached-data atypical case).
//!   Every query hangs off it and **never re-samples or re-trains**:
//!   [`TrainedProfile::recommend`] (§5.4 cluster size),
//!   [`TrainedProfile::plan`] (catalog-wide `(type × count)` search),
//!   [`TrainedProfile::max_scale`] (the Table-2 inverse question) and
//!   [`TrainedProfile::validate`] (risk cross-validation under a
//!   disturbance scenario).
//!
//! The legacy [`super::Blink`] facade is a thin wrapper over this module,
//! equivalence-tested in `rust/tests/session.rs`.

use std::collections::BTreeMap;

use super::bounds;
use super::models::FitBackend;
use super::planner::{self, Plan, PlanInput, RiskAdjustedPick};
use super::predictor::{ExecMemoryPredictor, SizePredictor};
use super::sample_runs::{SampleRun, SampleRunsManager, SamplingOutcome, DEFAULT_SCALES};
use super::selector::{select_cluster_size, Selection};
use super::Advice;
use crate::cost::PricingModel;
use crate::sim::{InstanceCatalog, MachineSpec, Scenario};
use crate::workloads::AppModel;

/// Which sampling scales the advisor uses when profiling an application.
#[derive(Debug, Clone, PartialEq)]
pub enum Scales {
    /// The paper's defaults: three runs at 0.1–0.3 % of the input, with
    /// the §6.4 exception (GBT samples 10 scales, ALS 5).
    Paper,
    /// A fixed explicit set for every application (Fig. 8-style studies).
    Fixed(Vec<f64>),
}

impl Scales {
    /// Resolve the sampling scales for one application.
    pub fn for_app(&self, app: &AppModel) -> Vec<f64> {
        match self {
            Scales::Fixed(s) => s.clone(),
            Scales::Paper => match app.name.as_str() {
                "gbt" => (1..=10).map(|s| s as f64).collect(),
                "als" => (1..=5).map(|s| s as f64).collect(),
                _ => DEFAULT_SCALES.to_vec(),
            },
        }
    }
}

/// Why a sampling-scale set was rejected at advisor intake.
///
/// Scales enter the profile cache key as **exact f64 bit patterns**, so
/// values whose bit pattern is ambiguous or absorbing must be handled
/// here rather than silently keyed: `-0.0 == 0.0` numerically but has a
/// different bit pattern (one logical scale set would split into two
/// cache entries, re-paying the sampling phase), and `NaN != NaN` (a key
/// that can never hit — every query re-samples forever).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScaleError {
    /// A scale was NaN or ±∞.
    NonFinite { index: usize, value: f64 },
    /// A scale was strictly negative — data scales are magnitudes.
    Negative { index: usize, value: f64 },
}

impl std::fmt::Display for ScaleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScaleError::NonFinite { index, value } => {
                write!(f, "sampling scale #{index} is not finite ({value})")
            }
            ScaleError::Negative { index, value } => {
                write!(f, "sampling scale #{index} is negative ({value})")
            }
        }
    }
}

impl std::error::Error for ScaleError {}

/// Validate and canonicalize sampling scales at advisor intake: reject
/// non-finite and negative values with a typed [`ScaleError`], and
/// normalize `-0.0` to `0.0` so the bit-exact cache key cannot split one
/// logical scale set into two entries. Every other value passes through
/// bit-identically.
pub fn normalize_scales(scales: &[f64]) -> Result<Vec<f64>, ScaleError> {
    scales
        .iter()
        .enumerate()
        .map(|(index, &value)| {
            if !value.is_finite() {
                Err(ScaleError::NonFinite { index, value })
            } else if value < 0.0 {
                Err(ScaleError::Negative { index, value })
            } else if value == 0.0 {
                Ok(0.0) // collapse -0.0 onto +0.0
            } else {
                Ok(value)
            }
        })
        .collect()
}

/// Configures and builds an [`Advisor`] — the only way to make one.
pub struct AdvisorBuilder {
    max_machines: usize,
    scales: Scales,
    manager: SampleRunsManager,
}

impl Default for AdvisorBuilder {
    fn default() -> Self {
        AdvisorBuilder {
            max_machines: 12,
            scales: Scales::Paper,
            manager: SampleRunsManager::default(),
        }
    }
}

impl AdvisorBuilder {
    /// Largest cluster any query may recommend (default 12, the paper's
    /// testbed bound).
    pub fn max_machines(mut self, n: usize) -> Self {
        assert!(n >= 1, "max_machines must be at least 1");
        self.max_machines = n;
        self
    }

    /// Use a fixed sampling-scale set for every application instead of
    /// the per-app paper policy ([`Scales::Paper`]).
    pub fn scales(mut self, scales: &[f64]) -> Self {
        self.scales = Scales::Fixed(scales.to_vec());
        self
    }

    /// Full control over the scales policy.
    pub fn scales_policy(mut self, scales: Scales) -> Self {
        self.scales = scales;
        self
    }

    /// Replace the sampling-phase configuration (sample node, eviction
    /// policy, seed, retry budget).
    pub fn manager(mut self, manager: SampleRunsManager) -> Self {
        self.manager = manager;
        self
    }

    /// Bind the configuration to a fit backend.
    pub fn build(self, backend: &mut dyn FitBackend) -> Advisor<'_> {
        Advisor {
            backend,
            manager: self.manager,
            max_machines: self.max_machines,
            scales: self.scales,
            cache: BTreeMap::new(),
            sampling_phases: 0,
        }
    }
}

/// Cache key: application name + a fingerprint of the model laws that
/// drive sampling + the exact sampling scales (all f64s as bit patterns,
/// so `1.0` and `1.0 + ε` never collide). The fingerprint keeps two
/// same-named but differently-parameterized [`AppModel`]s (e.g. an ad-hoc
/// variant with its cached laws edited) from sharing a trained profile.
type ProfileKey = (String, Vec<u64>, Vec<u64>);

/// Every scalar model parameter that can influence what a sampling phase
/// measures or costs — two same-named models differing in ANY of these
/// must not share a cached profile.
pub fn app_fingerprint(app: &AppModel) -> Vec<u64> {
    let mut bits: Vec<u64> = Vec::with_capacity(3 * app.cached_laws.len() + 16);
    for law in &app.cached_laws {
        bits.push(law.theta0.to_bits());
        bits.push(law.theta1.to_bits());
        bits.push(law.gamma.to_bits());
    }
    bits.push(app.exec_law.theta0.to_bits());
    bits.push(app.exec_law.theta1.to_bits());
    bits.push(app.exec_law.gamma.to_bits());
    bits.push(app.input_mb_full.to_bits());
    bits.push(app.blocks_full as u64);
    bits.push(app.size_noise.amp.to_bits());
    bits.push(app.size_noise.half_mb.to_bits());
    bits.push(app.size_noise.bias.to_bits());
    bits.push(app.iterations as u64);
    bits.push(app.compute_s_per_mb.to_bits());
    bits.push(app.cached_speedup.to_bits());
    bits.push(app.recompute_factor.to_bits());
    bits.push(app.serial_fixed_s.to_bits());
    bits.push(app.serial_per_scale_s.to_bits());
    bits.push(app.shuffle_mb_full.to_bits());
    bits.push(app.task_overhead_s.to_bits());
    bits.push(app.task_time_sigma.to_bits());
    bits.push(app.per_partition_overhead_mb.to_bits());
    bits.push(app.parallelism_cap.map_or(u64::MAX, |c| c as u64));
    bits.push(app.force_block_s as u64);
    bits
}

/// A long-lived Blink session: one fit backend, one sampling
/// configuration, and a cache of trained profiles.
pub struct Advisor<'a> {
    backend: &'a mut dyn FitBackend,
    manager: SampleRunsManager,
    max_machines: usize,
    scales: Scales,
    cache: BTreeMap<ProfileKey, TrainedProfile>,
    sampling_phases: usize,
}

impl<'a> Advisor<'a> {
    /// Start configuring an advisor.
    pub fn builder() -> AdvisorBuilder {
        AdvisorBuilder::default()
    }

    /// Profile `app`: run the sampling phase and fit the predictors —
    /// or return the cached [`TrainedProfile`] if this session already
    /// profiled `(app, scales)`. The returned profile is an owned
    /// snapshot; all queries on it are backend-free.
    pub fn profile(&mut self, app: &AppModel) -> TrainedProfile {
        self.try_profile(app)
            .unwrap_or_else(|e| panic!("invalid sampling scales: {e}"))
    }

    /// Like [`Advisor::profile`], but surfaces bad sampling scales
    /// (NaN, ±∞, negative) as a typed [`ScaleError`] instead of
    /// panicking. `-0.0` scales are normalized to `0.0` before keying,
    /// so the sign of zero can never split the cache.
    pub fn try_profile(&mut self, app: &AppModel) -> Result<TrainedProfile, ScaleError> {
        let scales = normalize_scales(&self.scales.for_app(app))?;
        let key: ProfileKey = (
            app.name.to_string(),
            app_fingerprint(app),
            scales.iter().map(|s| s.to_bits()).collect(),
        );
        Ok(match self.cache.entry(key) {
            std::collections::btree_map::Entry::Occupied(hit) => hit.get().clone(),
            std::collections::btree_map::Entry::Vacant(miss) => {
                self.sampling_phases += 1;
                miss.insert(TrainedProfile::train(
                    self.backend,
                    &self.manager,
                    app,
                    &scales,
                    self.max_machines,
                ))
                .clone()
            }
        })
    }

    /// How many sampling phases this session has actually paid for
    /// (cache hits do not count — the point of the session API).
    pub fn sampling_phases(&self) -> usize {
        self.sampling_phases
    }

    /// Name of the fit backend this session trains with.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

/// The §5.4 answer for one `(scale, machine type)` query.
#[derive(Debug, Clone, PartialEq)]
pub struct Recommendation {
    /// Recommended cluster size for the actual run.
    pub machines: usize,
    /// Predicted total cached size at the target scale (MB).
    pub predicted_cached_mb: f64,
    /// Predicted total execution memory at the target scale (MB).
    pub predicted_exec_mb: f64,
    /// Cost of the sampling phase that trained the profile (machine-s).
    pub sample_cost_machine_s: f64,
    /// Selector diagnostics, absent for the no-cached-data atypical case.
    pub selection: Option<Selection>,
}

/// How a risk cross-validation should be run (see
/// [`TrainedProfile::validate`]).
pub struct ValidationSpec<'s> {
    pub scenario: &'s dyn Scenario,
    /// Engine seeds; each pick is realized once per seed.
    pub seeds: &'s [u64],
    /// How many of the plan's top ranked picks to validate.
    pub top_k: usize,
}

/// The product of one sampling phase: fitted predictors + diagnostics.
/// Built by [`Advisor::profile`]; every query reuses the trained state.
#[derive(Debug, Clone)]
pub struct TrainedProfile {
    /// The profiled application model.
    pub app: AppModel,
    /// The sampling scales that were actually run.
    pub scales: Vec<f64>,
    /// Largest cluster queries may recommend (from the advisor config).
    pub max_machines: usize,
    /// Total cost of the sampling phase, machine-seconds.
    pub sample_cost_machine_s: f64,
    /// Per-run diagnostics (empty for the no-cached-data atypical case).
    pub runs: Vec<SampleRun>,
    /// Fitted predictors; `None` when the app caches nothing (atypical
    /// case 1 — the cheapest actual run is a single machine).
    pub models: Option<(SizePredictor, ExecMemoryPredictor)>,
}

impl TrainedProfile {
    pub(crate) fn train(
        backend: &mut dyn FitBackend,
        manager: &SampleRunsManager,
        app: &AppModel,
        scales: &[f64],
        max_machines: usize,
    ) -> TrainedProfile {
        match manager.run(app, scales) {
            SamplingOutcome::NoCachedData { sample_cost_machine_s } => TrainedProfile {
                app: app.clone(),
                scales: scales.to_vec(),
                max_machines,
                sample_cost_machine_s,
                runs: Vec::new(),
                models: None,
            },
            SamplingOutcome::Profiled(runs) => {
                let sizes = SizePredictor::train(backend, &runs);
                let exec = ExecMemoryPredictor::train(backend, &runs);
                TrainedProfile {
                    app: app.clone(),
                    scales: scales.to_vec(),
                    max_machines,
                    sample_cost_machine_s: SampleRunsManager::total_cost_machine_s(&runs),
                    runs,
                    models: Some((sizes, exec)),
                }
            }
        }
    }

    /// Atypical case 1: the application caches nothing.
    pub fn no_cached_data(&self) -> bool {
        self.models.is_none()
    }

    /// Predicted total cached size at `scale` (0 when nothing is cached).
    pub fn predicted_cached_mb(&self, scale: f64) -> f64 {
        self.models.as_ref().map_or(0.0, |(s, _)| s.predict_total(scale))
    }

    /// Predicted total execution memory at `scale`.
    pub fn predicted_exec_mb(&self, scale: f64) -> f64 {
        self.models.as_ref().map_or(0.0, |(_, e)| e.predict_total(scale))
    }

    /// The §5.4 query: minimal eviction-free cluster size for an actual
    /// run at `scale` on `machine`-type nodes. No re-sampling.
    pub fn recommend(&self, scale: f64, machine: &MachineSpec) -> Recommendation {
        match &self.models {
            None => Recommendation {
                // atypical case 1: cheapest possible actual run
                machines: 1,
                predicted_cached_mb: 0.0,
                predicted_exec_mb: 0.0,
                sample_cost_machine_s: self.sample_cost_machine_s,
                selection: None,
            },
            Some((sizes, exec)) => {
                let cached = sizes.predict_total(scale);
                let exec_mb = exec.predict_total(scale);
                let sel = select_cluster_size(cached, exec_mb, machine, self.max_machines);
                Recommendation {
                    machines: sel.machines,
                    predicted_cached_mb: cached,
                    predicted_exec_mb: exec_mb,
                    sample_cost_machine_s: self.sample_cost_machine_s,
                    selection: Some(sel),
                }
            }
        }
    }

    /// The fleet-aware query: search every `(instance type × count)`
    /// candidate of `catalog` under `pricing`. Same trained state; the
    /// no-cached-data case flows through with zero predicted footprint.
    pub fn plan(
        &self,
        scale: f64,
        catalog: &InstanceCatalog,
        pricing: &dyn PricingModel,
    ) -> Advice {
        let cached = self.predicted_cached_mb(scale);
        let exec_mb = self.predicted_exec_mb(scale);
        let profile = self.app.profile(scale);
        let input = PlanInput {
            profile: &profile,
            cached_total_mb: cached,
            exec_total_mb: exec_mb,
        };
        Advice {
            plan: planner::plan(&input, catalog, pricing, self.max_machines),
            predicted_cached_mb: cached,
            predicted_exec_mb: exec_mb,
            sample_cost_machine_s: self.sample_cost_machine_s,
        }
    }

    /// [`TrainedProfile::plan`] with explicit candidate
    /// `spark.memory.storageFraction` settings: each `(type × fraction)`
    /// pair is searched as a virtual type. An empty list is exactly
    /// [`TrainedProfile::plan`] (each type at its configured fraction);
    /// the advisor's `max_machines` still bounds the count dimension.
    pub fn plan_with_fractions(
        &self,
        scale: f64,
        catalog: &InstanceCatalog,
        pricing: &dyn PricingModel,
        storage_fractions: &[f64],
    ) -> Advice {
        let cached = self.predicted_cached_mb(scale);
        let exec_mb = self.predicted_exec_mb(scale);
        let profile = self.app.profile(scale);
        let input = PlanInput {
            profile: &profile,
            cached_total_mb: cached,
            exec_total_mb: exec_mb,
        };
        let space = planner::SearchSpace {
            max_machines: self.max_machines,
            storage_fractions: storage_fractions.to_vec(),
        };
        Advice {
            plan: planner::plan_search(&input, catalog, pricing, &space),
            predicted_cached_mb: cached,
            predicted_exec_mb: exec_mb,
            sample_cost_machine_s: self.sample_cost_machine_s,
        }
    }

    /// The Table-2 inverse query: the maximum data scale that still runs
    /// eviction-free on a fixed cluster of `machines` nodes of `machine`
    /// type. Infinite when the app caches nothing.
    pub fn max_scale(&self, machine: &MachineSpec, machines: usize) -> f64 {
        match &self.models {
            None => f64::INFINITY,
            Some((sizes, exec)) => bounds::max_scale(sizes, exec, machine, machines, 1e-5),
        }
    }

    /// Risk query: realize the top picks of `plan` with event-driven
    /// engine runs under a disturbance scenario and re-rank by realized
    /// cost ([`planner::risk_adjusted`]).
    pub fn validate(
        &self,
        scale: f64,
        plan: &Plan,
        catalog: &InstanceCatalog,
        pricing: &dyn PricingModel,
        spec: &ValidationSpec<'_>,
    ) -> Vec<RiskAdjustedPick> {
        let profile = self.app.profile(scale);
        planner::risk_adjusted(
            &profile,
            plan,
            catalog,
            pricing,
            spec.scenario,
            spec.seeds,
            spec.top_k,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blink::models::RustFit;
    use crate::cost::MachineSeconds;
    use crate::workloads::{app_by_name, FULL_SCALE};

    #[test]
    fn profile_is_cached_per_app_and_scales() {
        let app = app_by_name("svm").unwrap();
        let mut b = RustFit::default();
        let mut advisor = Advisor::builder().build(&mut b);
        let p1 = advisor.profile(&app);
        let p2 = advisor.profile(&app);
        assert_eq!(advisor.sampling_phases(), 1, "second call must hit the cache");
        assert_eq!(p1.sample_cost_machine_s, p2.sample_cost_machine_s);
        // a different scale set is a different profile
        let mut b2 = RustFit::default();
        let mut advisor2 = Advisor::builder().scales(&[1.0, 2.0]).build(&mut b2);
        advisor2.profile(&app);
        let p3 = advisor2.profile(&app);
        assert_eq!(advisor2.sampling_phases(), 1);
        assert_eq!(p3.scales, vec![1.0, 2.0]);
        // a same-named app with different laws must not share the profile
        let mut variant = app.clone();
        variant.cached_laws[0].theta1 *= 2.0;
        advisor2.profile(&variant);
        assert_eq!(advisor2.sampling_phases(), 2, "law change invalidates the cache");
    }

    #[test]
    fn paper_scales_policy_matches_section_6_4() {
        let gbt = app_by_name("gbt").unwrap();
        let als = app_by_name("als").unwrap();
        let svm = app_by_name("svm").unwrap();
        assert_eq!(Scales::Paper.for_app(&gbt).len(), 10);
        assert_eq!(Scales::Paper.for_app(&als).len(), 5);
        assert_eq!(Scales::Paper.for_app(&svm), DEFAULT_SCALES.to_vec());
        assert_eq!(Scales::Fixed(vec![4.0]).for_app(&gbt), vec![4.0]);
    }

    #[test]
    fn one_profile_answers_recommend_plan_and_bounds() {
        let app = app_by_name("svm").unwrap();
        let mut b = RustFit::default();
        let mut advisor = Advisor::builder().scales(&DEFAULT_SCALES).build(&mut b);
        let profile = advisor.profile(&app);
        let machine = MachineSpec::worker_node();
        let rec = profile.recommend(FULL_SCALE, &machine);
        // single-type catalog: the plan must degenerate to the §5.4 pick
        let worker_only = InstanceCatalog::single(crate::sim::InstanceType::paper_worker());
        let advice = profile.plan(FULL_SCALE, &worker_only, &MachineSeconds);
        let bound = profile.max_scale(&machine, 12);
        assert_eq!(advisor.sampling_phases(), 1, "three queries, one sampling phase");
        assert_eq!(rec.machines, 7, "the Table 1 svm pick");
        assert_eq!(advice.plan.best().unwrap().candidate.machines, rec.machines);
        assert!(bound > FULL_SCALE, "svm fits 12 machines beyond 100 %");
    }

    #[test]
    fn no_cached_data_profile_degenerates_gracefully() {
        // a synthetic app that caches nothing exercises atypical case 1
        let mut app = app_by_name("svm").unwrap();
        app.cached_laws = Vec::new();
        let mut b = RustFit::default();
        let mut advisor = Advisor::builder().build(&mut b);
        let profile = advisor.profile(&app);
        assert!(profile.no_cached_data());
        assert!(profile.sample_cost_machine_s > 0.0);
        let rec = profile.recommend(FULL_SCALE, &MachineSpec::worker_node());
        assert_eq!(rec.machines, 1);
        assert!(rec.selection.is_none());
        assert_eq!(profile.max_scale(&MachineSpec::worker_node(), 12), f64::INFINITY);
    }
}
