//! Data-size predictor (§5.2) and execution-memory predictor (§5.3).
//!
//! Both consume the sample-run summaries, build `(scale, value)` training
//! points per quantity and select a cross-validated non-negative model from
//! the zoo in [`super::models`]. One `FitBackend` call covers the whole
//! application (all cached datasets + execution memory), which the PJRT
//! backend executes as a single batched `linfit` dispatch.

use std::collections::BTreeMap;

use super::models::{select_model, FitBackend, SelectedModel};
use super::sample_runs::SampleRun;
use crate::util::units::Mb;

/// Trained size models, one per cached dataset id.
#[derive(Debug, Clone)]
pub struct SizePredictor {
    pub models: BTreeMap<usize, SelectedModel>,
}

impl SizePredictor {
    /// Train from sample runs (§5.2: scale as feature, size as label).
    pub fn train(backend: &mut dyn FitBackend, runs: &[SampleRun]) -> SizePredictor {
        let mut per_dataset: BTreeMap<usize, Vec<(f64, f64)>> = BTreeMap::new();
        for r in runs {
            for &(ds, size) in &r.summary.cached_sizes_mb {
                per_dataset.entry(ds).or_default().push((r.scale, size));
            }
        }
        let models = per_dataset
            .into_iter()
            .map(|(ds, pts)| (ds, select_model(backend, &pts)))
            .collect();
        SizePredictor { models }
    }

    /// Predicted size of one dataset at a scale.
    pub fn predict_dataset(&self, ds: usize, scale: f64) -> Option<Mb> {
        self.models.get(&ds).map(|m| m.predict(scale))
    }

    /// Predicted total cached bytes at a scale (the selector's input).
    pub fn predict_total(&self, scale: f64) -> Mb {
        self.models.values().map(|m| m.predict(scale)).sum()
    }

    /// Worst model CV error across datasets (relative; Fig. 9's metric).
    pub fn worst_cv_rel_err(&self) -> f64 {
        self.models
            .values()
            .map(|m| m.cv_rel_err)
            .fold(0.0, f64::max)
    }
}

/// Trained execution-memory model (§5.3).
#[derive(Debug, Clone)]
pub struct ExecMemoryPredictor {
    pub model: SelectedModel,
}

impl ExecMemoryPredictor {
    pub fn train(backend: &mut dyn FitBackend, runs: &[SampleRun]) -> ExecMemoryPredictor {
        let pts: Vec<(f64, f64)> = runs
            .iter()
            .map(|r| (r.scale, r.summary.exec_memory_mb))
            .collect();
        ExecMemoryPredictor { model: select_model(backend, &pts) }
    }

    /// Total execution memory the actual run needs at a scale.
    pub fn predict_total(&self, scale: f64) -> Mb {
        self.model.predict(scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blink::models::RustFit;
    use crate::blink::sample_runs::{SampleRunsManager, SamplingOutcome, DEFAULT_SCALES};
    use crate::util::stats::rel_err;
    use crate::workloads::{app_by_name, FULL_SCALE};

    fn sample(name: &str) -> Vec<SampleRun> {
        let mgr = SampleRunsManager::default();
        match mgr.run(&app_by_name(name).unwrap(), &DEFAULT_SCALES) {
            SamplingOutcome::Profiled(runs) => runs,
            _ => panic!("{name} caches data"),
        }
    }

    #[test]
    fn svm_size_prediction_is_nearly_exact() {
        let runs = sample("svm");
        let p = SizePredictor::train(&mut RustFit::default(), &runs);
        let app = app_by_name("svm").unwrap();
        let pred = p.predict_total(FULL_SCALE);
        let actual = app.total_true_cached_mb(FULL_SCALE);
        // paper Fig. 7: svm error 0.0008 %; ours must be well under 1 %
        assert!(rel_err(pred, actual) < 0.01, "pred {pred} vs {actual}");
    }

    #[test]
    fn gbt_three_samples_predict_poorly_but_more_samples_fix_it() {
        // the Fig. 8 effect
        let app = app_by_name("gbt").unwrap();
        let mgr = SampleRunsManager::default();
        let actual = app.total_true_cached_mb(FULL_SCALE);

        let three = match mgr.run(&app, &DEFAULT_SCALES) {
            SamplingOutcome::Profiled(r) => r,
            _ => panic!(),
        };
        let p3 = SizePredictor::train(&mut RustFit::default(), &three);
        let err3 = rel_err(p3.predict_total(FULL_SCALE), actual);

        let scales10: Vec<f64> = (1..=10).map(|s| s as f64).collect();
        let ten = match mgr.run(&app, &scales10) {
            SamplingOutcome::Profiled(r) => r,
            _ => panic!(),
        };
        let p10 = SizePredictor::train(&mut RustFit::default(), &ten);
        let err10 = rel_err(p10.predict_total(FULL_SCALE), actual);

        assert!(err3 > 0.10, "gbt 3-sample error should be large, got {err3}");
        assert!(err10 < err3, "more samples must improve ({err10} vs {err3})");
        assert!(err10 < 0.10, "10-sample error should be small, got {err10}");
    }

    #[test]
    fn exec_memory_prediction_tracks_law() {
        let runs = sample("lr");
        let p = ExecMemoryPredictor::train(&mut RustFit::default(), &runs);
        let app = app_by_name("lr").unwrap();
        let pred = p.predict_total(FULL_SCALE);
        let actual = app.exec_mem_mb(FULL_SCALE);
        assert!(rel_err(pred, actual) < 0.05, "pred {pred} vs {actual}");
    }

    #[test]
    fn per_dataset_predictions_available() {
        let runs = sample("km");
        let p = SizePredictor::train(&mut RustFit::default(), &runs);
        assert_eq!(p.models.len(), 1);
        assert!(p.predict_dataset(0, 500.0).unwrap() > 0.0);
        assert!(p.predict_dataset(42, 500.0).is_none());
    }
}
