//! Fleet-aware cost planner: a catalog-driven configuration search.
//!
//! The §5.4 selector answers "how many identical paper-testbed nodes?".
//! This module generalizes it, in the spirit of Crispy and of "Selecting
//! Efficient Cluster Resources for Data Analytics" (Will et al., 2022/23):
//! given the trained Blink predictors, search every `(instance type ×
//! count)` candidate of an [`InstanceCatalog`] for eviction-freeness using
//! the same memory geometry ([`machine_split`]), estimate each candidate's
//! runtime from the workload's compute profile (observable from the sample
//! runs), price it through a pluggable [`PricingModel`], and return
//!
//! * one *recommended* configuration per instance type (the minimal
//!   eviction-free count — exactly the §5.4 rule applied to that type),
//!   ranked across types by predicted cost;
//! * the evaluation grid (pruned — see below);
//! * the Pareto front of the (time, cost) trade-off, for operators who can
//!   spend money to go faster.
//!
//! On a single-type catalog the ranked list degenerates to the classic
//! [`select_cluster_size`] answer — the reproduction path never changes.
//!
//! ## The memory-split dimension
//!
//! Crispy-style assistants tune the executor memory split, not just the
//! machine count. [`SearchSpace::storage_fractions`] adds candidate
//! `spark.memory.storageFraction` settings as a planner dimension: each
//! `(type × fraction)` pair is searched as a virtual type through the
//! same §5.4 geometry ([`machine_split_at`]), producing one ranked pick
//! per pair and a Pareto front over the full `(type × fraction × count)`
//! grid. An empty fraction list (the default, and what [`plan`] passes)
//! evaluates each type at its configured `storage_fraction` with
//! arithmetic identical to the pre-dimension planner — the paper catalog
//! and Table 1/2 stay byte-identical.
//!
//! ## Branch-and-bound pruning
//!
//! [`plan_search`] does not evaluate the exhaustive grid.
//! [`select_cluster_size_at`] scans counts upward and returns the *first*
//! eviction-free `n` for a `(type, fraction)` (the §5.4 lower bound), so
//! every count below `selection.machines` is saturated — never a ranked
//! pick, and never on the Pareto front, which is drawn from eviction-free
//! candidates. Each pair therefore only evaluates
//! `selection.machines..=max_machines` (a saturated pair contributes just
//! its boundary candidate).
//!
//! The fraction dimension extends the bound (DESIGN §8): raising the
//! storage fraction `f` raises `R = M·f`, which shrinks the execution
//! share `min(M − R, exec/n)` and therefore *grows* the caching capacity
//! `M − MachineMem_exec(n)` at every count — so the minimal eviction-free
//! count `n*(f)` is non-increasing in `f`. Fractions are scanned
//! ascending and each unsaturated `n*` caps the next fraction's count
//! scan; a capped scan cannot miss (the condition already holds at the
//! previous `n*` under the larger capacity) and cannot saturate, so the
//! returned `Selection` is identical to an uncapped scan.
//!
//! When *every* `(type, fraction)` saturates, the front falls back to the
//! whole grid, so [`plan_search`] delegates to the frozen
//! [`plan_exhaustive_search`] — the pre-pruning implementation kept as
//! the reference the property tests compare against. Ranked picks and
//! Pareto front are byte-identical between the two; only `Plan::grid`
//! shrinks. On large catalogs the per-type work fans out over
//! [`crate::util::par::sweep_range`], whose index-ordered results keep
//! the parallel path bit-identical to the serial one.

use super::selector::{
    machine_split_at, select_cluster_size_at, select_cluster_size_seeded, Selection,
};
use crate::cost::PricingModel;
use crate::memory::EvictionPolicy;
use crate::metrics::RunSummary;
use crate::sim::{
    engine, shuffle_s, ClusterSpec, FleetSpec, InstanceCatalog, InstanceType, MachineSpec,
    Scenario, SimOptions, WorkloadProfile,
};
use crate::util::units::Mb;

/// What the planner needs to know about one target run: the workload's
/// compute shape (parallelism, cost coefficients — all observable from
/// sample runs) plus the *predicted* memory quantities at the target scale.
pub struct PlanInput<'a> {
    pub profile: &'a WorkloadProfile,
    /// Predicted total cached size at the target scale, MB.
    pub cached_total_mb: Mb,
    /// Predicted total execution memory at the target scale, MB.
    pub exec_total_mb: Mb,
}

/// One evaluated `(instance type × storage fraction × count)`
/// configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct CandidateConfig {
    /// Instance type name (from the catalog).
    pub instance: String,
    pub machines: usize,
    /// The `spark.memory.storageFraction` this candidate was evaluated at
    /// (the type's configured value unless the search space supplied an
    /// explicit fraction grid).
    pub storage_fraction: f64,
    /// Whether the predicted footprint fits eviction-free (§5.4 geometry).
    pub eviction_free: bool,
    /// Per-machine caching headroom; negative = deficit.
    pub headroom_mb: Mb,
    /// Analytic runtime estimate, seconds.
    pub predicted_time_s: f64,
    /// Price of that runtime under the active pricing model.
    pub predicted_cost: f64,
}

/// The recommended configuration for one instance type, with the §5.4
/// selector diagnostics (min/max bracket, saturation) for that type.
#[derive(Debug, Clone, PartialEq)]
pub struct TypePick {
    pub candidate: CandidateConfig,
    pub selection: Selection,
}

/// The planner's full answer.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    /// One pick per `(instance type × searched fraction)`, best
    /// (eviction-free, then cheapest) first. One per type when no explicit
    /// fraction grid was searched.
    pub ranked: Vec<TypePick>,
    /// Every evaluated candidate. [`plan_exhaustive_search`] fills the
    /// full types × fractions × 1..=max_machines grid; [`plan_search`]
    /// prunes counts below each pair's §5.4 lower bound (they can
    /// influence neither the ranked picks nor the Pareto front).
    pub grid: Vec<CandidateConfig>,
    /// Non-dominated (time, cost) candidates among the eviction-free grid
    /// (the whole grid when nothing fits), sorted fastest-first.
    pub pareto: Vec<CandidateConfig>,
    /// The explicit storage-fraction grid that was searched, ascending —
    /// empty when each type ran at its own configured fraction (the
    /// default). Renderers use this to decide whether the split is worth
    /// a column.
    pub fractions: Vec<f64>,
}

/// The dimensions [`plan_search`] explores beyond the catalog itself.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Upper bound on the per-candidate machine count (≥ 1).
    pub max_machines: usize,
    /// Candidate `spark.memory.storageFraction` values, each in (0, 1).
    /// Empty = evaluate each type at its configured fraction only.
    pub storage_fractions: Vec<f64>,
}

impl SearchSpace {
    /// A count-only search — exactly the pre-dimension planner.
    pub fn counts(max_machines: usize) -> SearchSpace {
        SearchSpace { max_machines, storage_fractions: Vec::new() }
    }

    /// The searched fraction grid: finite values in (0, 1), ascending,
    /// deduplicated. Both search paths normalize through this, so the
    /// caller's ordering can never desynchronize pruned vs exhaustive.
    fn normalized_fractions(&self) -> Vec<f64> {
        let mut fs: Vec<f64> = self
            .storage_fractions
            .iter()
            .copied()
            .filter(|f| f.is_finite() && *f > 0.0 && *f < 1.0)
            .collect();
        fs.sort_by(f64::total_cmp);
        fs.dedup();
        fs
    }
}

impl Plan {
    /// The overall recommendation, if any type produced a pick.
    pub fn best(&self) -> Option<&TypePick> {
        self.ranked.first()
    }
}

/// Closed-form runtime estimate for an eviction-aware run on `machines`
/// nodes of `machine` type: the simulator's deterministic skeleton (wave
/// scheduling, disk-bound load, cached vs recomputed iteration tasks,
/// serial + shuffle + coordination per job) without noise or skew.
/// `resident_fraction` is the predicted fraction of cached partitions that
/// stay resident (1.0 when eviction-free).
pub fn estimate_time_s(
    profile: &WorkloadProfile,
    machine: &MachineSpec,
    machines: usize,
    cached_total_mb: Mb,
    resident_fraction: f64,
) -> f64 {
    let n = machines.max(1);
    let parts = profile.parallelism.max(1) as f64;
    let slots = (n * machine.cores.max(1)) as f64;
    let waves = (parts / slots).ceil();
    let cluster = ClusterSpec { machines: n, machine: machine.clone() };
    let per_job_s = profile.serial_s + shuffle_s(profile, &cluster);

    // job 0: read the input from DFS, compute, cache
    let input_pp = profile.input_mb / parts;
    let t_load = input_pp / machine.disk_mb_s
        + input_pp * profile.compute_s_per_mb
        + profile.task_overhead_s;
    let mut t = profile.sample_prep_s + waves * t_load + per_job_s;

    // iteration jobs: cached reads where resident, lineage recomputation
    // elsewhere (the Area-A penalty)
    let cached_pp = cached_total_mb / parts;
    let t_cached = cached_pp * profile.compute_s_per_mb / profile.cached_speedup
        + profile.task_overhead_s;
    let t_recompute = input_pp / machine.disk_mb_s
        + input_pp * profile.compute_s_per_mb * profile.recompute_factor
        + profile.task_overhead_s;
    let r = resident_fraction.clamp(0.0, 1.0);
    let t_task = r * t_cached + (1.0 - r) * t_recompute;
    t += profile.iterations as f64 * (waves * t_task + per_job_s);
    t
}

fn evaluate_at(
    input: &PlanInput<'_>,
    instance: &InstanceType,
    storage_fraction: f64,
    machines: usize,
    pricing: &dyn PricingModel,
) -> CandidateConfig {
    let (_, capacity) =
        machine_split_at(input.exec_total_mb, &instance.spec, storage_fraction, machines);
    let cached_pm = input.cached_total_mb / machines as f64;
    let eviction_free = cached_pm < capacity;
    let resident = if input.cached_total_mb <= 0.0 {
        1.0
    } else {
        (machines as f64 * capacity / input.cached_total_mb).min(1.0)
    };
    let time_s = estimate_time_s(
        input.profile,
        &instance.spec,
        machines,
        input.cached_total_mb,
        resident,
    );
    CandidateConfig {
        instance: instance.name.to_string(),
        machines,
        storage_fraction,
        eviction_free,
        headroom_mb: capacity - cached_pm,
        predicted_time_s: time_s,
        predicted_cost: pricing.price(instance, machines, time_s),
    }
}

fn dominates(a: &CandidateConfig, b: &CandidateConfig) -> bool {
    a.predicted_time_s <= b.predicted_time_s
        && a.predicted_cost <= b.predicted_cost
        && (a.predicted_time_s < b.predicted_time_s || a.predicted_cost < b.predicted_cost)
}

/// The frozen quadratic Pareto filter the pre-pruning planner shipped
/// with, kept verbatim for [`plan_exhaustive`]: every pool member is
/// tested against every other via [`dominates`].
fn pareto_front_exhaustive(grid: &[CandidateConfig]) -> Vec<CandidateConfig> {
    let free: Vec<&CandidateConfig> = grid.iter().filter(|c| c.eviction_free).collect();
    let pool: Vec<&CandidateConfig> =
        if free.is_empty() { grid.iter().collect() } else { free };
    let mut front: Vec<CandidateConfig> = pool
        .iter()
        .filter(|c| !pool.iter().any(|o| dominates(o, c)))
        .map(|c| (*c).clone())
        .collect();
    sort_front(&mut front);
    front.dedup();
    front
}

// Tie-break chain shared by the front sort: equal (time, cost) candidates
// order by type name, then count, then fraction. The trailing keys make the
// comparator total over distinct candidates, so the front's order is a pure
// function of its *contents* — duplicate-priced types can never pick up
// insertion order from whichever search path (pruned, exhaustive, parallel
// chunks) produced the pool.
fn sort_front(front: &mut [CandidateConfig]) {
    front.sort_by(|a, b| {
        a.predicted_time_s
            .total_cmp(&b.predicted_time_s)
            .then(a.predicted_cost.total_cmp(&b.predicted_cost))
            .then(a.instance.cmp(&b.instance))
            .then(a.machines.cmp(&b.machines))
            .then(a.storage_fraction.total_cmp(&b.storage_fraction))
    });
}

/// Non-dominated (time, cost) filter in `O(G log G)`: sort by time then
/// cost, sweep in time order keeping the lowest cost seen at strictly
/// earlier times; within an equal-time group only the group's cost minima
/// survive, and only when they strictly undercut every earlier time.
/// Produces the same front as [`pareto_front_exhaustive`] — same
/// survivors, same final order — which the planner property suites assert
/// across the testkit matrix.
fn pareto_front(grid: &[CandidateConfig]) -> Vec<CandidateConfig> {
    let free: Vec<&CandidateConfig> = grid.iter().filter(|c| c.eviction_free).collect();
    let mut pool: Vec<&CandidateConfig> =
        if free.is_empty() { grid.iter().collect() } else { free };
    pool.sort_by(|a, b| {
        a.predicted_time_s
            .total_cmp(&b.predicted_time_s)
            .then(a.predicted_cost.total_cmp(&b.predicted_cost))
    });
    let mut front: Vec<CandidateConfig> = Vec::new();
    let mut best_cost = f64::INFINITY;
    let mut i = 0;
    while i < pool.len() {
        // arithmetic (==) grouping so ±0.0 times merge exactly as the
        // `dominates` comparisons treat them; equal times are contiguous
        // after the total_cmp sort
        let t = pool[i].predicted_time_s;
        let mut j = i;
        let mut group_min = f64::INFINITY;
        while j < pool.len() && pool[j].predicted_time_s == t {
            group_min = group_min.min(pool[j].predicted_cost);
            j += 1;
        }
        if group_min < best_cost {
            for c in &pool[i..j] {
                if c.predicted_cost == group_min {
                    front.push((*c).clone());
                }
            }
            best_cost = group_min;
        }
        i = j;
    }
    sort_front(&mut front);
    front.dedup();
    front
}

fn sort_ranked(ranked: &mut [TypePick]) {
    ranked.sort_by(|a, b| {
        b.candidate
            .eviction_free
            .cmp(&a.candidate.eviction_free)
            .then(a.candidate.predicted_cost.total_cmp(&b.candidate.predicted_cost))
            .then(a.candidate.predicted_time_s.total_cmp(&b.candidate.predicted_time_s))
            .then(a.candidate.instance.cmp(&b.candidate.instance))
            .then(a.candidate.machines.cmp(&b.candidate.machines))
            .then(a.candidate.storage_fraction.total_cmp(&b.candidate.storage_fraction))
    });
}

/// Above this many catalog types the per-type search fans out over the
/// bounded sweep pool; below it the serial path avoids pool setup on the
/// 2–7-type hand-written catalogs (whose whole search is microseconds).
const PAR_TYPE_THRESHOLD: usize = 16;

/// Everything one instance type contributes to the pruned search: one
/// pick and one grid chunk per searched fraction, plus whether any
/// fraction produced an eviction-free selection. Pure per type (reads
/// shared inputs, owns its outputs), which is what lets [`plan_search`]
/// run types concurrently with bit-identical results.
fn plan_type_pruned(
    input: &PlanInput<'_>,
    instance: &InstanceType,
    fractions: &[f64],
    max_machines: usize,
    pricing: &dyn PricingModel,
) -> (Vec<TypePick>, Vec<CandidateConfig>, bool) {
    let own = [instance.spec.storage_fraction];
    let fractions = if fractions.is_empty() { &own[..] } else { fractions };
    let mut picks = Vec::with_capacity(fractions.len());
    let mut grid = Vec::new();
    let mut any_free = false;
    // fractions ascend, so each unsaturated n* seeds the next fraction's
    // count scan (the extended §5.4 bound, module docs / DESIGN §8): the
    // condition already holds at the previous n* under the larger
    // capacity, so the seeded selector walks *down* from it instead of
    // re-scanning up from 1 — on a dense fraction grid each scan visits
    // only the (usually zero or one) counts the pick actually moved by,
    // and returns the identical Selection
    let mut hint: Option<usize> = None;
    for &fraction in fractions {
        let selection = match hint {
            Some(h) => select_cluster_size_seeded(
                input.cached_total_mb,
                input.exec_total_mb,
                &instance.spec,
                fraction,
                max_machines,
                h,
            ),
            None => select_cluster_size_at(
                input.cached_total_mb,
                input.exec_total_mb,
                &instance.spec,
                fraction,
                max_machines,
            ),
        };
        debug_assert!(
            !selection.saturated || hint.is_none(),
            "a seeded fraction scan can never saturate"
        );
        if !selection.saturated {
            any_free = true;
            hint = Some(selection.machines);
        }
        // the selector scanned upward and `selection.machines` is the
        // first eviction-free count (== max_machines when saturated):
        // everything below is saturated and prunable
        for n in selection.machines..=max_machines {
            let c = evaluate_at(input, instance, fraction, n, pricing);
            if n == selection.machines {
                picks.push(TypePick { candidate: c.clone(), selection: selection.clone() });
            }
            grid.push(c);
        }
    }
    (picks, grid, any_free)
}

/// Branch-and-bound search over `catalog × space`: per `(type, fraction)`
/// pair, counts below the §5.4 eviction-free lower bound are pruned and
/// the fraction dimension reuses each unsaturated bound as the next scan
/// cap (see the module docs), so a Crispy-sized catalog costs
/// `O(pairs × free-range)` instead of `O(pairs × max_machines)`
/// evaluations — with the per-type work fanned out over the sweep pool on
/// large catalogs. Ranked picks and Pareto front are byte-identical to
/// [`plan_exhaustive_search`].
pub fn plan_search(
    input: &PlanInput<'_>,
    catalog: &InstanceCatalog,
    pricing: &dyn PricingModel,
    space: &SearchSpace,
) -> Plan {
    assert!(space.max_machines >= 1);
    let fractions = space.normalized_fractions();
    let types = catalog.instances.len();
    if types == 0 {
        return Plan { fractions, ..Plan::default() };
    }
    let per_type = |i: usize| {
        plan_type_pruned(input, &catalog.instances[i], &fractions, space.max_machines, pricing)
    };
    // sweep_range re-places results by index, so the parallel fan-out
    // concatenates exactly as the serial loop would
    let chunks = if types >= PAR_TYPE_THRESHOLD {
        crate::util::par::sweep_range(0, types - 1, per_type)
    } else {
        crate::util::par::sweep_range_serial(0, types - 1, per_type)
    };
    if !chunks.iter().any(|(_, _, any_free)| *any_free) {
        // nothing fits anywhere: the Pareto front falls back to the whole
        // grid, so every candidate matters — no pruning is sound
        return plan_exhaustive_search(input, catalog, pricing, space);
    }
    let mut ranked = Vec::with_capacity(types * fractions.len().max(1));
    let mut grid = Vec::new();
    for (picks, chunk, _) in chunks {
        ranked.extend(picks);
        grid.extend(chunk);
    }
    sort_ranked(&mut ranked);
    let pareto = pareto_front(&grid);
    Plan { ranked, grid, pareto, fractions }
}

/// Branch-and-bound search over `(type × count)` with each type at its
/// configured storage fraction — the classic planner surface, now a thin
/// wrapper over [`plan_search`] with a count-only [`SearchSpace`].
pub fn plan(
    input: &PlanInput<'_>,
    catalog: &InstanceCatalog,
    pricing: &dyn PricingModel,
    max_machines: usize,
) -> Plan {
    plan_search(input, catalog, pricing, &SearchSpace::counts(max_machines))
}

/// The frozen exhaustive reference: every `(type × fraction × count)`
/// candidate of `catalog × space`, filtered by the quadratic Pareto pass
/// — the planner exactly as it shipped before pruning, extended over the
/// fraction grid with the same nested iteration order the pruned path
/// concatenates in. Kept public so property tests (and the
/// `planner/plan-exhaustive-*` bench) can assert [`plan_search`] never
/// diverges from it.
pub fn plan_exhaustive_search(
    input: &PlanInput<'_>,
    catalog: &InstanceCatalog,
    pricing: &dyn PricingModel,
    space: &SearchSpace,
) -> Plan {
    assert!(space.max_machines >= 1);
    let fractions = space.normalized_fractions();
    let max_machines = space.max_machines;
    let mut grid = Vec::with_capacity(catalog.instances.len() * max_machines);
    let mut ranked = Vec::with_capacity(catalog.instances.len());
    for instance in &catalog.instances {
        let own = [instance.spec.storage_fraction];
        let fs = if fractions.is_empty() { &own[..] } else { &fractions[..] };
        for &fraction in fs {
            let selection = select_cluster_size_at(
                input.cached_total_mb,
                input.exec_total_mb,
                &instance.spec,
                fraction,
                max_machines,
            );
            for n in 1..=max_machines {
                let c = evaluate_at(input, instance, fraction, n, pricing);
                if n == selection.machines {
                    ranked.push(TypePick { candidate: c.clone(), selection: selection.clone() });
                }
                grid.push(c);
            }
        }
    }
    sort_ranked(&mut ranked);
    let pareto = pareto_front_exhaustive(&grid);
    Plan { ranked, grid, pareto, fractions }
}

/// [`plan_exhaustive_search`] with a count-only [`SearchSpace`] — the
/// pre-dimension exhaustive reference, signature unchanged.
pub fn plan_exhaustive(
    input: &PlanInput<'_>,
    catalog: &InstanceCatalog,
    pricing: &dyn PricingModel,
    max_machines: usize,
) -> Plan {
    plan_exhaustive_search(input, catalog, pricing, &SearchSpace::counts(max_machines))
}

// ---------------------------------------------------------------------
// fleet-level planning (multi-tenant)
// ---------------------------------------------------------------------

/// One tenant's contribution to a fleet plan: the workload's compute
/// shape plus its predicted memory footprint at the target scale — a
/// named [`PlanInput`].
pub struct FleetPlanInput<'a> {
    pub name: String,
    pub profile: &'a WorkloadProfile,
    pub cached_total_mb: Mb,
    pub exec_total_mb: Mb,
}

/// One evaluated `(instance type × count)` shared-fleet configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetCandidate {
    pub instance: String,
    pub machines: usize,
    pub storage_fraction: f64,
    /// Whether *every* tenant fits eviction-free: the §5.4 condition on
    /// the summed working sets, `Σ cached / n < capacity(Σ exec, n)`.
    pub eviction_free: bool,
    /// Per-machine headroom against the summed working set; negative =
    /// the shared deficit.
    pub headroom_mb: Mb,
    /// Sum of the per-tenant runtime estimates — tenants' jobs serialize
    /// on the shared fleet ([`crate::sim::run_fleet`] is FIFO), so the
    /// fleet makespan is the serialized sum.
    pub predicted_time_s: f64,
    pub predicted_cost: f64,
    /// Per-tenant runtime estimates, tenant input order.
    pub per_tenant_time_s: Vec<f64>,
}

/// The fleet recommendation for one instance type: the minimal
/// eviction-free count (or the saturated boundary), with the extended
/// §5.4 selector diagnostics over the summed working sets.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetPick {
    pub candidate: FleetCandidate,
    pub selection: Selection,
}

/// The fleet planner's full answer.
#[derive(Debug, Clone, Default)]
pub struct FleetPlan {
    /// Tenant names, input order (column headers for renderers).
    pub tenants: Vec<String>,
    /// One pick per instance type, best (eviction-free, then cheapest)
    /// first.
    pub ranked: Vec<FleetPick>,
    /// Every evaluated `(type × count)` candidate, catalog order then
    /// count ascending from each type's eviction-free floor.
    pub grid: Vec<FleetCandidate>,
}

impl FleetPlan {
    /// The overall recommendation, if any type produced a pick.
    pub fn best(&self) -> Option<&FleetPick> {
        self.ranked.first()
    }

    /// Minimal eviction-free machine count for `instance`, if that type
    /// has one within the searched bracket — the fleet's §5.4 floor for
    /// the type. `testkit::check_fleet` asserts this never *shrinks*
    /// when a tenant is added (the summed working set only grows).
    pub fn min_eviction_free_machines(&self, instance: &str) -> Option<usize> {
        self.ranked
            .iter()
            .find(|p| p.candidate.instance == instance && !p.selection.saturated)
            .map(|p| p.selection.machines)
    }
}

/// Search `catalog` for the cheapest configuration that runs all
/// `tenants` concurrently with every tenant eviction-free: the §5.4
/// bound extended with summed working sets (`Σ cached` against the
/// capacity left by `Σ exec`), priced over the *serialized* runtime —
/// [`crate::sim::run_fleet`] interleaves jobs FIFO on one fleet, so N
/// tenants take roughly the sum of their individual times.
///
/// Degeneracies mirror [`plan`]: one tenant reduces to the single-app
/// bound exactly (same selector arithmetic), and an empty tenant list
/// returns an empty plan. Counts below each type's eviction-free floor
/// are pruned from the grid as in [`plan_search`]; a saturated type
/// contributes only its `max_machines` boundary candidate.
pub fn plan_fleet(
    tenants: &[FleetPlanInput<'_>],
    catalog: &InstanceCatalog,
    pricing: &dyn PricingModel,
    max_machines: usize,
) -> FleetPlan {
    assert!(max_machines >= 1);
    if tenants.is_empty() {
        return FleetPlan::default();
    }
    let names: Vec<String> = tenants.iter().map(|t| t.name.clone()).collect();
    let sum_cached: Mb = tenants.iter().map(|t| t.cached_total_mb).sum();
    let sum_exec: Mb = tenants.iter().map(|t| t.exec_total_mb).sum();

    let mut ranked = Vec::with_capacity(catalog.instances.len());
    let mut grid = Vec::new();
    for instance in &catalog.instances {
        let fraction = instance.spec.storage_fraction;
        let selection =
            select_cluster_size_at(sum_cached, sum_exec, &instance.spec, fraction, max_machines);
        for n in selection.machines..=max_machines {
            let (_, capacity) = machine_split_at(sum_exec, &instance.spec, fraction, n);
            let cached_pm = sum_cached / n as f64;
            let eviction_free = cached_pm < capacity;
            // the shared store offers every tenant the same resident
            // fraction of its working set (one arbitration, N victims)
            let resident = if sum_cached <= 0.0 {
                1.0
            } else {
                (n as f64 * capacity / sum_cached).min(1.0)
            };
            let per_tenant_time_s: Vec<f64> = tenants
                .iter()
                .map(|t| {
                    estimate_time_s(t.profile, &instance.spec, n, t.cached_total_mb, resident)
                })
                .collect();
            let time_s: f64 = per_tenant_time_s.iter().sum();
            let c = FleetCandidate {
                instance: instance.name.to_string(),
                machines: n,
                storage_fraction: fraction,
                eviction_free,
                headroom_mb: capacity - cached_pm,
                predicted_time_s: time_s,
                predicted_cost: pricing.price(instance, n, time_s),
                per_tenant_time_s,
            };
            if n == selection.machines {
                ranked.push(FleetPick { candidate: c.clone(), selection: selection.clone() });
            }
            grid.push(c);
        }
    }
    ranked.sort_by(|a, b| {
        b.candidate
            .eviction_free
            .cmp(&a.candidate.eviction_free)
            .then(a.candidate.predicted_cost.total_cmp(&b.candidate.predicted_cost))
            .then(a.candidate.predicted_time_s.total_cmp(&b.candidate.predicted_time_s))
            .then(a.candidate.instance.cmp(&b.candidate.instance))
            .then(a.candidate.machines.cmp(&b.candidate.machines))
    });
    FleetPlan { tenants: names, ranked, grid }
}

/// One analytic pick cross-validated against event-driven engine runs
/// under a disturbance scenario.
#[derive(Debug, Clone)]
pub struct RiskAdjustedPick {
    pub pick: TypePick,
    /// Mean realized run time across the completed seeds, seconds.
    /// Infinite when no validation run completed.
    pub realized_time_s: f64,
    /// Mean realized cost, priced on the per-machine uptime timeline.
    /// Infinite when no validation run completed.
    pub realized_cost: f64,
    /// Mean machines lost per run under the scenario.
    pub machines_lost: f64,
    /// `realized_cost / predicted_cost` — how optimistic the analytic
    /// quote was once the scenario bites (1.0 = spot-on).
    pub cost_inflation: f64,
    /// Engine runs that finished. 0 means the scenario collapsed every
    /// run (e.g. it reclaimed a 1-machine fleet with no restart) — the
    /// pick stays in the ranking with infinite realized cost so the
    /// failure is visible, not silently dropped.
    pub completed_runs: usize,
}

/// Cross-validate the top `top_k` ranked picks of `plan` by actually
/// running `profile` through the event-driven engine under `scenario` for
/// each validation seed, pricing the realized per-machine timeline, and
/// re-ranking by mean realized cost. The engine exercises the workload's
/// *true* physics, so no predicted footprints are consumed here — they
/// already shaped `plan`.
///
/// This is what keeps the catalog search honest about dynamic conditions:
/// the analytic ranking assumes machines never disappear, so a cheap
/// spot-style pick can lose to a nominally pricier one once preemption
/// recompute is priced in.
pub fn risk_adjusted(
    profile: &WorkloadProfile,
    plan: &Plan,
    catalog: &InstanceCatalog,
    pricing: &dyn PricingModel,
    scenario: &dyn Scenario,
    seeds: &[u64],
    top_k: usize,
) -> Vec<RiskAdjustedPick> {
    let picks: Vec<&TypePick> = plan.ranked.iter().take(top_k).collect();
    if picks.is_empty() {
        return Vec::new();
    }
    // one engine-validation task per pick, fanned out over the bounded
    // sweep pool; the per-seed loop stays serial inside each task, so the
    // f64 accumulation order — and thus every mean — is bit-identical to
    // the historical serial path
    let validated = crate::util::par::sweep_range(0, picks.len() - 1, |i| {
        let pick = picks[i];
        // validate at the pick's searched memory split: the engine's
        // UnifiedMemory floor must match what the planner promised (for a
        // count-only search this writes the spec's own value back — no-op)
        let mut instance = catalog.get(&pick.candidate.instance)?.clone();
        instance.spec.storage_fraction = pick.candidate.storage_fraction;
        let fleet = FleetSpec::homogeneous(instance, pick.candidate.machines).ok()?;
        let (mut time, mut cost, mut lost, mut runs) = (0.0, 0.0, 0.0, 0usize);
        for &seed in seeds {
            let opts = SimOptions {
                policy: EvictionPolicy::Lru,
                seed,
                compute: None,
                detailed_log: false,
            };
            let Ok(res) = engine::run(profile, &fleet, scenario, opts) else {
                continue;
            };
            let s = RunSummary::from_log(&res.sim.log);
            time += s.duration_s;
            cost += pricing.price_timeline(&res.timeline);
            lost += s.machines_lost as f64;
            runs += 1;
        }
        if runs == 0 {
            // every validation run collapsed: rank the pick last, loudly
            return Some(RiskAdjustedPick {
                pick: pick.clone(),
                realized_time_s: f64::INFINITY,
                realized_cost: f64::INFINITY,
                machines_lost: pick.candidate.machines as f64,
                cost_inflation: f64::INFINITY,
                completed_runs: 0,
            });
        }
        let k = runs as f64;
        let realized_cost = cost / k;
        Some(RiskAdjustedPick {
            pick: pick.clone(),
            realized_time_s: time / k,
            realized_cost,
            machines_lost: lost / k,
            cost_inflation: realized_cost / pick.candidate.predicted_cost.max(1e-12),
            completed_runs: runs,
        })
    });
    let mut out: Vec<RiskAdjustedPick> = validated.into_iter().flatten().collect();
    out.sort_by(|a, b| {
        a.realized_cost
            .total_cmp(&b.realized_cost)
            .then(a.realized_time_s.total_cmp(&b.realized_time_s))
            .then(a.pick.candidate.instance.cmp(&b.pick.candidate.instance))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blink::selector::select_cluster_size;
    use crate::cost::{MachineSeconds, PerInstanceHour};
    use crate::sim::scenario::{NoDisturbances, SpotPreemption};
    use crate::workloads::{app_by_name, FULL_SCALE};

    fn input_for(app: &str, scale: f64) -> (crate::sim::WorkloadProfile, Mb, Mb) {
        let a = app_by_name(app).unwrap();
        (a.profile(scale), a.total_true_cached_mb(scale), a.exec_mem_mb(scale))
    }

    #[test]
    fn single_type_catalog_degenerates_to_selector() {
        let (profile, cached, exec) = input_for("svm", FULL_SCALE);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let catalog = InstanceCatalog::single(InstanceType::paper_worker());
        let p = plan(&input, &catalog, &MachineSeconds, 12);
        assert_eq!(p.ranked.len(), 1);
        let sel = select_cluster_size(cached, exec, &MachineSpec::worker_node(), 12);
        assert_eq!(p.ranked[0].selection, sel);
        assert_eq!(p.ranked[0].candidate.machines, sel.machines);
        // the pruned grid starts at the §5.4 lower bound instead of 1
        assert_eq!(p.grid.len(), 12 - sel.machines + 1);
        let full = plan_exhaustive(&input, &catalog, &MachineSeconds, 12);
        assert_eq!(full.grid.len(), 12);
        assert_eq!(p.ranked, full.ranked);
        assert_eq!(p.pareto, full.pareto);
    }

    #[test]
    fn pruned_plan_matches_the_frozen_exhaustive_reference() {
        // picks and front byte-identical across catalogs, pricing models
        // and scales — the grid is the only thing pruning may change
        for (app, scale) in [("svm", FULL_SCALE), ("als", FULL_SCALE), ("km", 300.0)] {
            let (profile, cached, exec) = input_for(app, scale);
            let input =
                PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
            for max in [1, 4, 12] {
                let a = plan(&input, &InstanceCatalog::cloud(), &PerInstanceHour::hourly(), max);
                let b = plan_exhaustive(
                    &input,
                    &InstanceCatalog::cloud(),
                    &PerInstanceHour::hourly(),
                    max,
                );
                assert_eq!(a.ranked, b.ranked, "{app}@{scale} max={max}");
                assert_eq!(a.pareto, b.pareto, "{app}@{scale} max={max}");
                assert!(a.grid.len() <= b.grid.len());
                // every pruned-away candidate was saturated
                let kept: std::collections::BTreeSet<(String, usize)> =
                    a.grid.iter().map(|c| (c.instance.clone(), c.machines)).collect();
                for c in &b.grid {
                    if !kept.contains(&(c.instance.clone(), c.machines)) {
                        assert!(!c.eviction_free, "pruned a free candidate: {c:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn fraction_search_matches_the_exhaustive_reference() {
        // the new dimension through both paths: picks, front AND the
        // per-pair grid coverage must agree, on hand-written and
        // generated catalogs alike
        let space = SearchSpace {
            max_machines: 12,
            storage_fractions: vec![0.7, 0.3, 0.5, 0.5], // unsorted + dup on purpose
        };
        for catalog in [InstanceCatalog::cloud(), InstanceCatalog::generate(9, 24)] {
            let (profile, cached, exec) = input_for("als", FULL_SCALE);
            let input =
                PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
            let a = plan_search(&input, &catalog, &PerInstanceHour::hourly(), &space);
            let b = plan_exhaustive_search(&input, &catalog, &PerInstanceHour::hourly(), &space);
            assert_eq!(a.fractions, vec![0.3, 0.5, 0.7], "normalized ascending, deduped");
            assert_eq!(a.fractions, b.fractions);
            assert_eq!(a.ranked.len(), catalog.instances.len() * 3, "one pick per pair");
            assert_eq!(a.ranked, b.ranked, "{}", catalog.name);
            assert_eq!(a.pareto, b.pareto, "{}", catalog.name);
            assert!(a.grid.len() <= b.grid.len());
        }
    }

    #[test]
    fn count_only_search_keeps_the_default_fraction() {
        let (profile, cached, exec) = input_for("svm", FULL_SCALE);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let p = plan(&input, &InstanceCatalog::cloud(), &MachineSeconds, 12);
        assert!(p.fractions.is_empty(), "no explicit grid was searched");
        for c in &p.grid {
            assert_eq!(
                c.storage_fraction,
                InstanceCatalog::cloud().get(&c.instance).unwrap().spec.storage_fraction
            );
        }
    }

    #[test]
    fn duplicate_priced_types_keep_a_deterministic_front_order() {
        // satellite regression: two types with identical spec and price
        // produce pairwise-equal (time, cost) candidates; the front must
        // order them by (name, count) regardless of which search path —
        // or which insertion order — built the pool
        let mut twin_a = InstanceCatalog::cloud().get("gp.xlarge").unwrap().clone();
        let mut twin_b = twin_a.clone();
        twin_a.name = "twin-a".into();
        twin_b.name = "twin-b".into();
        let fwd = InstanceCatalog {
            name: "twins".into(),
            instances: vec![twin_a.clone(), twin_b.clone()],
        };
        let rev = InstanceCatalog { name: "twins-rev".into(), instances: vec![twin_b, twin_a] };
        let (profile, cached, exec) = input_for("als", FULL_SCALE);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let pricing = PerInstanceHour::hourly();
        let pf = plan(&input, &fwd, &pricing, 12);
        let pr = plan(&input, &rev, &pricing, 12);
        let xf = plan_exhaustive(&input, &fwd, &pricing, 12);
        assert_eq!(pf.pareto, pr.pareto, "front order must not depend on catalog order");
        assert_eq!(pf.pareto, xf.pareto);
        // equal-(time, cost) neighbors are name-then-count ordered
        for w in pf.pareto.windows(2) {
            if w[0].predicted_time_s == w[1].predicted_time_s
                && w[0].predicted_cost == w[1].predicted_cost
            {
                assert!(
                    (w[0].instance.as_str(), w[0].machines)
                        < (w[1].instance.as_str(), w[1].machines),
                    "{w:?}"
                );
            }
        }
        // both twins appear somewhere in the evaluated pool
        assert!(pf.grid.iter().any(|c| c.instance == "twin-a"));
        assert!(pf.grid.iter().any(|c| c.instance == "twin-b"));
    }

    #[test]
    fn generated_512_search_is_pruned_and_covered() {
        // the cloud-scale path stays exact at a size where the win shows:
        // one pick per type, grid strictly smaller than exhaustive
        let catalog = InstanceCatalog::generate(42, 512);
        let (profile, cached, exec) = input_for("als", FULL_SCALE);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let p = plan(&input, &catalog, &PerInstanceHour::hourly(), 24);
        assert_eq!(p.ranked.len(), 512);
        assert!(p.grid.len() < 512 * 24, "pruning must bite at this scale");
        assert!(!p.pareto.is_empty());
        let free = p.ranked.iter().filter(|t| t.candidate.eviction_free).count();
        assert!(free > 0, "a 512-type menu must contain fitting shapes");
    }

    #[test]
    fn all_saturated_catalog_falls_back_to_the_full_grid() {
        // a footprint nothing fits: the front must be drawn from the whole
        // grid, so plan() delegates to the exhaustive reference wholesale
        let (profile, _, _) = input_for("svm", FULL_SCALE);
        let input =
            PlanInput { profile: &profile, cached_total_mb: 9.0e9, exec_total_mb: 1.0e6 };
        let p = plan(&input, &InstanceCatalog::cloud(), &MachineSeconds, 6);
        let full = plan_exhaustive(&input, &InstanceCatalog::cloud(), &MachineSeconds, 6);
        assert!(p.ranked.iter().all(|t| t.selection.saturated));
        assert_eq!(p.grid.len(), InstanceCatalog::cloud().instances.len() * 6);
        assert_eq!(p.ranked, full.ranked);
        assert_eq!(p.grid, full.grid);
        assert_eq!(p.pareto, full.pareto);
        assert!(!p.pareto.is_empty(), "saturated front still offers trade-offs");
    }

    #[test]
    fn ranked_covers_every_type_and_prefers_eviction_free() {
        let (profile, cached, exec) = input_for("als", FULL_SCALE);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let p = plan(&input, &InstanceCatalog::cloud(), &PerInstanceHour::hourly(), 12);
        assert_eq!(p.ranked.len(), InstanceCatalog::cloud().instances.len());
        // ranked order: all eviction-free picks precede saturated ones,
        // and within the free block costs are non-decreasing
        let mut seen_saturated = false;
        let mut last_cost = f64::NEG_INFINITY;
        for pick in &p.ranked {
            if pick.candidate.eviction_free {
                assert!(!seen_saturated, "free pick after saturated one");
                assert!(pick.candidate.predicted_cost >= last_cost);
                last_cost = pick.candidate.predicted_cost;
            } else {
                seen_saturated = true;
            }
            assert!(pick.candidate.predicted_cost.is_finite());
            assert!(pick.candidate.predicted_time_s > 0.0);
        }
    }

    #[test]
    fn pareto_front_is_nondominated_and_free() {
        let (profile, cached, exec) = input_for("svm", FULL_SCALE);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let p = plan(&input, &InstanceCatalog::all(), &PerInstanceHour::per_second(), 12);
        assert!(!p.pareto.is_empty());
        for a in &p.pareto {
            assert!(a.eviction_free, "front drawn from eviction-free candidates");
            for b in &p.pareto {
                assert!(!dominates(a, b) || a == b, "{a:?} dominates {b:?}");
            }
        }
        // fastest-first ordering
        for w in p.pareto.windows(2) {
            assert!(w[0].predicted_time_s <= w[1].predicted_time_s);
        }
    }

    #[test]
    fn bigger_memory_types_need_fewer_machines() {
        let (profile, cached, exec) = input_for("svm", FULL_SCALE);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let cloud = InstanceCatalog::cloud();
        let p = plan(&input, &cloud, &MachineSeconds, 16);
        let machines_of = |name: &str| {
            p.ranked.iter().find(|t| t.candidate.instance == name).unwrap().candidate.machines
        };
        assert!(machines_of("mem.2xlarge") <= machines_of("gp.xlarge"));
    }

    #[test]
    fn time_estimate_shows_area_a_and_parallel_speedup() {
        let (profile, cached, _) = input_for("svm", FULL_SCALE);
        let w = MachineSpec::worker_node();
        // under-provisioned (partial residency) is slower than resident
        let slow = estimate_time_s(&profile, &w, 3, cached, 0.4);
        let fast = estimate_time_s(&profile, &w, 3, cached, 1.0);
        assert!(slow > fast);
        // more machines shrink the parallel part when fully resident
        let t4 = estimate_time_s(&profile, &w, 4, cached, 1.0);
        let t8 = estimate_time_s(&profile, &w, 8, cached, 1.0);
        assert!(t8 < t4);
    }

    #[test]
    fn risk_adjusted_under_none_tracks_the_simulator() {
        // with no disturbances, the engine realizes roughly the analytic
        // picture: no machines lost, finite realized cost per pick
        let (profile, cached, exec) = input_for("svm", 300.0);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let p = plan(&input, &InstanceCatalog::cloud(), &MachineSeconds, 8);
        let risks = risk_adjusted(
            &profile,
            &p,
            &InstanceCatalog::cloud(),
            &MachineSeconds,
            &NoDisturbances,
            &[11, 12],
            3,
        );
        assert_eq!(risks.len(), 3);
        for r in &risks {
            assert_eq!(r.machines_lost, 0.0);
            assert_eq!(r.completed_runs, 2);
            assert!(r.realized_cost > 0.0 && r.realized_cost.is_finite());
            assert!(r.realized_time_s > 0.0);
        }
        // sorted by realized cost
        for w in risks.windows(2) {
            assert!(w[0].realized_cost <= w[1].realized_cost);
        }
    }

    #[test]
    fn risk_adjusted_spot_costs_more_than_undisturbed() {
        // a single-type catalog at a scale whose pick needs >= 2 machines,
        // so the default spot scenario has a machine to reclaim
        let (profile, cached, exec) = input_for("svm", 500.0);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let gp = InstanceCatalog::cloud().get("gp.xlarge").unwrap().clone();
        let catalog = InstanceCatalog::single(gp);
        let p = plan(&input, &catalog, &MachineSeconds, 8);
        assert!(p.ranked[0].candidate.machines >= 2, "scale must need a real cluster");
        let calm =
            risk_adjusted(&profile, &p, &catalog, &MachineSeconds, &NoDisturbances, &[21], 1);
        let spot = risk_adjusted(
            &profile,
            &p,
            &catalog,
            &MachineSeconds,
            &SpotPreemption::default(),
            &[21],
            1,
        );
        assert_eq!(calm.len(), 1);
        assert_eq!(spot.len(), 1);
        assert_eq!(spot[0].completed_runs, 1);
        assert!(spot[0].machines_lost >= 1.0);
        assert!(
            spot[0].realized_time_s > calm[0].realized_time_s,
            "preemption must stretch the run: {} vs {}",
            spot[0].realized_time_s,
            calm[0].realized_time_s
        );
    }

    #[test]
    fn collapsed_picks_rank_last_instead_of_vanishing() {
        // a scenario that reclaims machine 0 unconditionally kills every
        // 1-machine candidate; the pick must survive in the ranking with
        // infinite realized cost, not disappear from the risk table
        struct KillFirst;
        impl crate::sim::Scenario for KillFirst {
            fn name(&self) -> &'static str {
                "kill-first"
            }
            fn schedule(
                &self,
                _ctx: &crate::sim::scenario::ScenarioCtx<'_>,
            ) -> Vec<crate::sim::Disturbance> {
                vec![crate::sim::Disturbance {
                    at_s: 0.0,
                    kind: crate::sim::DisturbanceKind::Preempt { machine: 0 },
                }]
            }
        }
        let (profile, _, _) = input_for("svm", 10.0);
        let input = PlanInput { profile: &profile, cached_total_mb: 0.0, exec_total_mb: 0.0 };
        let catalog = InstanceCatalog::single(InstanceType::paper_worker());
        let p = plan(&input, &catalog, &MachineSeconds, 4);
        assert_eq!(p.ranked[0].candidate.machines, 1, "nothing cached -> one machine");
        let risks = risk_adjusted(&profile, &p, &catalog, &MachineSeconds, &KillFirst, &[3], 1);
        assert_eq!(risks.len(), 1, "the collapsed pick stays visible");
        assert_eq!(risks[0].completed_runs, 0);
        assert!(risks[0].realized_cost.is_infinite());
        assert!(risks[0].realized_time_s.is_infinite());
    }

    #[test]
    fn nothing_cached_plans_one_machine_per_type() {
        let (profile, _, _) = input_for("svm", 10.0);
        let input = PlanInput { profile: &profile, cached_total_mb: 0.0, exec_total_mb: 0.0 };
        let p = plan(&input, &InstanceCatalog::paper(), &MachineSeconds, 12);
        for pick in &p.ranked {
            assert_eq!(pick.candidate.machines, 1, "{}", pick.candidate.instance);
            assert!(pick.candidate.eviction_free);
        }
    }

    #[test]
    fn fleet_plan_of_one_tenant_matches_the_single_app_bound() {
        let (profile, cached, exec) = input_for("svm", FULL_SCALE);
        let t = FleetPlanInput {
            name: "svm".into(),
            profile: &profile,
            cached_total_mb: cached,
            exec_total_mb: exec,
        };
        let fp = plan_fleet(&[t], &InstanceCatalog::cloud(), &MachineSeconds, 12);
        let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
        let p = plan(&input, &InstanceCatalog::cloud(), &MachineSeconds, 12);
        assert_eq!(fp.tenants, vec!["svm".to_string()]);
        // the summed bound of one tenant IS the single-app §5.4 bound:
        // same floor, same pick arithmetic, per type
        for pick in &p.ranked {
            assert_eq!(
                fp.min_eviction_free_machines(&pick.candidate.instance),
                (!pick.selection.saturated).then_some(pick.selection.machines),
                "{}",
                pick.candidate.instance
            );
            let fpick = fp
                .ranked
                .iter()
                .find(|f| f.candidate.instance == pick.candidate.instance)
                .unwrap();
            assert_eq!(fpick.selection, pick.selection);
            assert_eq!(fpick.candidate.machines, pick.candidate.machines);
            assert_eq!(fpick.candidate.predicted_time_s, pick.candidate.predicted_time_s);
            assert_eq!(fpick.candidate.predicted_cost, pick.candidate.predicted_cost);
        }
    }

    #[test]
    fn adding_a_tenant_never_shrinks_the_fleet_floor() {
        let (svm, c1, e1) = input_for("svm", 150.0);
        let (als, c2, e2) = input_for("als", 150.0);
        let t1 = FleetPlanInput {
            name: "svm".into(),
            profile: &svm,
            cached_total_mb: c1,
            exec_total_mb: e1,
        };
        let one = plan_fleet(&[t1], &InstanceCatalog::cloud(), &MachineSeconds, 16);
        let t1 = FleetPlanInput {
            name: "svm".into(),
            profile: &svm,
            cached_total_mb: c1,
            exec_total_mb: e1,
        };
        let t2 = FleetPlanInput {
            name: "als".into(),
            profile: &als,
            cached_total_mb: c2,
            exec_total_mb: e2,
        };
        let two = plan_fleet(&[t1, t2], &InstanceCatalog::cloud(), &MachineSeconds, 16);
        for inst in InstanceCatalog::cloud().instances.iter().map(|i| i.name.as_str()) {
            if let (Some(a), Some(b)) =
                (one.min_eviction_free_machines(inst), two.min_eviction_free_machines(inst))
            {
                assert!(b >= a, "{inst}: adding a tenant shrank the floor {a} -> {b}");
            }
        }
        // at this scale the pair still fits somewhere, and sharing one
        // fleet costs at least as much as running the first tenant alone
        let best_two = two.best().unwrap();
        assert!(best_two.candidate.eviction_free);
        assert!(
            best_two.candidate.predicted_cost >= one.best().unwrap().candidate.predicted_cost
        );
    }

    #[test]
    fn fleet_ranked_prefers_cheap_eviction_free_and_sums_tenant_times() {
        let (svm, c1, e1) = input_for("svm", 150.0);
        let (als, c2, e2) = input_for("als", 150.0);
        let (km, c3, e3) = input_for("km", 150.0);
        let tenants = vec![
            FleetPlanInput {
                name: "svm".into(),
                profile: &svm,
                cached_total_mb: c1,
                exec_total_mb: e1,
            },
            FleetPlanInput {
                name: "als".into(),
                profile: &als,
                cached_total_mb: c2,
                exec_total_mb: e2,
            },
            FleetPlanInput {
                name: "km".into(),
                profile: &km,
                cached_total_mb: c3,
                exec_total_mb: e3,
            },
        ];
        let fp = plan_fleet(&tenants, &InstanceCatalog::cloud(), &PerInstanceHour::hourly(), 16);
        assert_eq!(fp.ranked.len(), InstanceCatalog::cloud().instances.len());
        let mut seen_saturated = false;
        let mut last = f64::NEG_INFINITY;
        for p in &fp.ranked {
            if p.candidate.eviction_free {
                assert!(!seen_saturated, "free pick after saturated one");
                assert!(p.candidate.predicted_cost >= last);
                last = p.candidate.predicted_cost;
            } else {
                seen_saturated = true;
            }
            assert_eq!(p.candidate.per_tenant_time_s.len(), 3);
            let sum: f64 = p.candidate.per_tenant_time_s.iter().sum();
            assert_eq!(sum, p.candidate.predicted_time_s, "serialized makespan is the sum");
        }
    }

    #[test]
    fn empty_tenant_list_yields_an_empty_fleet_plan() {
        let fp = plan_fleet(&[], &InstanceCatalog::cloud(), &MachineSeconds, 8);
        assert!(fp.ranked.is_empty() && fp.grid.is_empty() && fp.tenants.is_empty());
        assert!(fp.best().is_none());
    }
}
