//! BLINK (§5): the autonomous sampling-based cluster-size optimizer.
//!
//! The facade wires the four components of Fig. 5 together:
//!
//! 1. [`sample_runs::SampleRunsManager`] carries out three tiny sample runs
//!    on one machine and analyzes their listener logs;
//! 2. [`predictor::SizePredictor`] fits cross-validated non-negative models
//!    of cached-dataset size vs. data scale;
//! 3. [`predictor::ExecMemoryPredictor`] does the same for execution
//!    memory;
//! 4. [`selector::select_cluster_size`] picks the minimal eviction-free
//!    cluster size for the actual run; [`bounds::max_scale`] answers the
//!    inverse (Table 2) question.
//!
//! Beyond the paper, [`planner`] generalizes step 4 into a catalog-driven
//! `(instance type × count)` search with pluggable pricing
//! ([`crate::cost`]), exposed as [`TrainedProfile::plan`] / `blink advise`;
//! its analytic picks can be cross-validated against event-driven engine
//! runs under a disturbance scenario ([`planner::risk_adjusted`],
//! `blink advise --scenario spot`). [`adaptive`] closes the loop at
//! runtime: job-barrier size observations refit the trained models by
//! recursive least squares, a diverging refit re-plans the remaining
//! iterations, and a `DeficitController` scale-out enacts the correction
//! (`blink adapt`).
//!
//! The public entry point is the **session API** ([`session`]): build an
//! [`Advisor`] once, [`Advisor::profile`] an application once, then answer
//! any number of [`TrainedProfile::recommend`] / [`TrainedProfile::plan`] /
//! [`TrainedProfile::max_scale`] / [`TrainedProfile::validate`] queries
//! from the cached trained state — profile once, query many. Each query's
//! answer has a typed report ([`report`]) with text and JSON renderers.
//! The original [`Blink`] facade survives as a thin wrapper over the
//! advisor (equivalence-tested in `rust/tests/session.rs`).
//!
//! Model fitting dispatches through [`models::FitBackend`]: in production
//! the batched Pallas `linfit` executable via PJRT (`runtime::linfit`), in
//! tests the pure-Rust oracle.

pub mod adaptive;
pub mod bounds;
pub mod models;
pub mod planner;
pub mod predictor;
pub mod report;
pub mod sample_runs;
pub mod selector;
pub mod session;
pub mod store;

pub use adaptive::{
    adapt, observations_from_log, observations_from_run, AdaptConfig, AdaptOutcome, Refit,
    ReplanDecision, RlsState, SizeObservation,
};
pub use models::{FitBackend, RustFit};
pub use planner::{
    plan, plan_exhaustive, plan_exhaustive_search, plan_fleet, plan_search, risk_adjusted,
    CandidateConfig, FleetCandidate, FleetPick, FleetPlan, FleetPlanInput, Plan, PlanInput,
    RiskAdjustedPick, SearchSpace, TypePick,
};
pub use predictor::{ExecMemoryPredictor, SizePredictor};
pub use report::{OutputFormat, Report};
pub use sample_runs::{SampleRun, SampleRunsManager, SamplingOutcome, DEFAULT_SCALES};
pub use selector::{
    machine_split, machine_split_at, select_cluster_size, select_cluster_size_at,
    select_cluster_size_seeded, Selection,
};
pub use session::{
    app_fingerprint, normalize_scales, Advisor, AdvisorBuilder, Recommendation, ScaleError, Scales,
    TrainedProfile, ValidationSpec,
};
pub use store::{
    load_profile, profile_from_json, profile_to_json, resolve_app, results_bytes, save_profile,
    serve_batch, ProfileStore, ProfileStoreBuilder, ServeOutcome, StoreError, PREDICTOR_VERSION,
    PROFILE_FORMAT_VERSION,
};

use crate::cost::PricingModel;
use crate::sim::{InstanceCatalog, MachineSpec};
use crate::workloads::AppModel;

/// Blink's end-to-end decision for one application.
#[derive(Debug, Clone)]
pub struct BlinkDecision {
    /// Recommended cluster size for the actual run.
    pub machines: usize,
    /// Predicted total cached size at the target scale (MB).
    pub predicted_cached_mb: f64,
    /// Predicted total execution memory at the target scale (MB).
    pub predicted_exec_mb: f64,
    /// Cost of the sampling phase, machine-seconds.
    pub sample_cost_machine_s: f64,
    /// Trained predictors (reusable across scales/machine types), absent
    /// for the no-cached-data atypical case.
    pub predictors: Option<(SizePredictor, ExecMemoryPredictor)>,
    pub selection: Option<Selection>,
}

/// The original Blink facade, kept for the reproduction tests and as a
/// one-shot convenience. It is a thin wrapper over the session API: each
/// call builds a throwaway [`Advisor`], so **every call re-samples** —
/// long-lived callers should hold an [`Advisor`] and profile once.
pub struct Blink<'a> {
    pub manager: SampleRunsManager,
    pub backend: &'a mut dyn FitBackend,
    /// Largest cluster the selector may recommend.
    pub max_machines: usize,
}

impl<'a> Blink<'a> {
    pub fn new(backend: &'a mut dyn FitBackend) -> Blink<'a> {
        Blink { manager: SampleRunsManager::default(), backend, max_machines: 12 }
    }

    /// One advisor session configured like this facade, sampling `scales`.
    fn session(&mut self, scales: &[f64]) -> Advisor<'_> {
        Advisor::builder()
            .max_machines(self.max_machines)
            .scales(scales)
            .manager(self.manager.clone())
            .build(&mut *self.backend)
    }

    /// Run the full pipeline of Fig. 5 for `app`, recommending a cluster
    /// size for an actual run at `target_scale` on `machine`-type nodes.
    pub fn decide(
        &mut self,
        app: &AppModel,
        target_scale: f64,
        machine: &MachineSpec,
    ) -> BlinkDecision {
        self.decide_with_scales(app, target_scale, machine, &DEFAULT_SCALES)
    }

    /// Same, with explicit sampling scales (Fig. 8 uses up to 10).
    pub fn decide_with_scales(
        &mut self,
        app: &AppModel,
        target_scale: f64,
        machine: &MachineSpec,
        scales: &[f64],
    ) -> BlinkDecision {
        let profile = self.session(scales).profile(app);
        let r = profile.recommend(target_scale, machine);
        BlinkDecision {
            machines: r.machines,
            predicted_cached_mb: r.predicted_cached_mb,
            predicted_exec_mb: r.predicted_exec_mb,
            sample_cost_machine_s: r.sample_cost_machine_s,
            predictors: profile.models,
            selection: r.selection,
        }
    }
}

/// Blink's catalog-wide answer: the planner output plus the sampling
/// diagnostics the CLI reports.
#[derive(Debug, Clone)]
pub struct Advice {
    pub plan: Plan,
    pub predicted_cached_mb: f64,
    pub predicted_exec_mb: f64,
    pub sample_cost_machine_s: f64,
}

impl<'a> Blink<'a> {
    /// Fleet-aware planning: one sampling phase, then a catalog search.
    ///
    /// Generalizes [`Blink::decide`] from "how many worker nodes?" to
    /// "which instance type, how many, at what predicted cost?". The
    /// atypical no-cached-data case flows through with zero predicted
    /// footprint, which the planner maps to one machine of every type.
    pub fn advise(
        &mut self,
        app: &AppModel,
        target_scale: f64,
        catalog: &InstanceCatalog,
        pricing: &dyn PricingModel,
    ) -> Advice {
        self.advise_with_scales(app, target_scale, catalog, pricing, &DEFAULT_SCALES)
    }

    /// Same, with explicit sampling scales (GBT/ALS use extended sets).
    pub fn advise_with_scales(
        &mut self,
        app: &AppModel,
        target_scale: f64,
        catalog: &InstanceCatalog,
        pricing: &dyn PricingModel,
        scales: &[f64],
    ) -> Advice {
        self.session(scales).profile(app).plan(target_scale, catalog, pricing)
    }
}

/// The ground-truth optimum: minimal n whose *true* footprint satisfies
/// the eviction-free condition (what Table 1's first green cell shows).
pub fn true_optimal(app: &AppModel, scale: f64, machine: &MachineSpec, max: usize) -> usize {
    select_cluster_size(
        app.total_true_cached_mb(scale),
        app.exec_mem_mb(scale),
        machine,
        max,
    )
    .machines
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{all_apps, app_by_name, FULL_SCALE};

    #[test]
    fn table1_picks_at_100pct() {
        // the paper's bold numbers, 100 % scale
        let expect = [
            ("als", 1),
            ("bayes", 7),
            ("gbt", 1),
            ("km", 4),
            ("lr", 5),
            ("pca", 1),
            ("rfc", 4),
            ("svm", 7),
        ];
        let machine = MachineSpec::worker_node();
        for (name, want) in expect {
            let app = app_by_name(name).unwrap();
            let mut backend = RustFit::default();
            let mut blink = Blink::new(&mut backend);
            let d = blink.decide(&app, FULL_SCALE, &machine);
            assert_eq!(d.machines, want, "{name}: predicted {} MB", d.predicted_cached_mb);
            // and the pick matches the true optimum (optimal in 8/8 cases)
            assert_eq!(
                d.machines,
                true_optimal(&app, FULL_SCALE, &machine, 12),
                "{name} pick vs truth"
            );
        }
    }

    #[test]
    fn enlarged_scale_picks_reuse_models() {
        // Table 1 bottom half: same sample runs, larger target scales.
        // GBT and ALS need their extended sampling (10 and 5 runs, §6.4).
        let machine = MachineSpec::worker_node();
        for app in all_apps() {
            let mut backend = RustFit::default();
            let mut blink = Blink::new(&mut backend);
            let scales: Vec<f64> = match app.name.as_str() {
                "gbt" => (1..=10).map(|s| s as f64).collect(),
                "als" => (1..=5).map(|s| s as f64).collect(),
                _ => DEFAULT_SCALES.to_vec(),
            };
            let d = blink.decide_with_scales(&app, app.enlarged_scale, &machine, &scales);
            let truth = true_optimal(&app, app.enlarged_scale, &machine, 12);
            assert_eq!(
                d.machines, truth,
                "{}: blink {} vs selector-truth {}",
                app.name, d.machines, truth
            );
        }
    }

    #[test]
    fn gbt_picks_one_machine_despite_bad_size_prediction() {
        // §6.2: "In spite of data size prediction error, BLINK selects the
        // optimal cluster size (a single machine) because both the
        // predicted and the actual size fit the memory of a single machine"
        let app = app_by_name("gbt").unwrap();
        let mut backend = RustFit::default();
        let mut blink = Blink::new(&mut backend);
        let d = blink.decide(&app, FULL_SCALE, &MachineSpec::worker_node());
        assert_eq!(d.machines, 1);
    }

    #[test]
    fn sample_cost_small_fraction_of_actual_cost() {
        // the headline 4.6 % claim is checked end-to-end in the benches;
        // here: sampling an app costs << an hour of one machine
        let app = app_by_name("svm").unwrap();
        let mut backend = RustFit::default();
        let mut blink = Blink::new(&mut backend);
        let d = blink.decide(&app, FULL_SCALE, &MachineSpec::worker_node());
        assert!(d.sample_cost_machine_s < 600.0, "{}", d.sample_cost_machine_s);
        assert!(d.sample_cost_machine_s > 0.0);
    }
}
