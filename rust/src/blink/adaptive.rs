//! `blink::adaptive` — the observe → refit → re-plan → act loop.
//!
//! Blink (§4–5) fits cached-size growth laws from sample runs *once*; if
//! the fitted γ is wrong, the chosen cluster stays wrong for the whole
//! run. This module closes the feedback loop the paper leaves open:
//!
//! 1. **Observation intake** — per-iteration observed cached-dataset
//!    sizes from a live run, sourced either from the engine's job-barrier
//!    snapshots ([`crate::sim::IterationObservation`], the precise path)
//!    or reconstructed best-effort from a detailed `metrics` listener log
//!    ([`observations_from_log`]). Each resident snapshot extrapolates to
//!    a full-dataset size the way a listener extrapolates from the blocks
//!    it has seen: `resident_mb / resident_parts × parallelism`.
//! 2. **Recursive least-squares refit** ([`RlsState`]) — each observation
//!    folds into the trained [`SizePredictor`]'s selected model with the
//!    textbook λ=1 RLS update, seeded from the sample fit's coefficients.
//!    No re-sampling, no matrix solves; exact serial arithmetic in a
//!    fixed order (job ascending, dataset ascending), so replays are
//!    bit-identical at any thread count and feeding a model its own
//!    predictions is a bit-exact no-op (the fixed-point property).
//! 3. **Re-planner** — at each job barrier past a warm-up history, the
//!    refit total is compared against the launch-time prediction; past a
//!    configurable relative divergence, [`super::planner::plan`] re-runs
//!    over the *remaining* iterations with the refit footprint and emits
//!    a typed [`ReplanDecision`].
//! 4. **Controller / act** — a decided correction is enacted by replaying
//!    the run with the base scenario composed with a
//!    [`DeficitController`] anchored at the realized decision time
//!    (`at_s`): a positive deficit scales out, a surplus (the refit came
//!    in *below* the launch-time prediction and the re-plan wants fewer
//!    machines) retires the excess, highest index first. Either arm is
//!    adopted only if its realized cost does not exceed the static run's
//!    — the adaptive loop never does worse than the static pick by
//!    construction, and the differential `check_adaptive` invariant
//!    (testkit) keeps that falsifiable end to end.

use std::collections::BTreeMap;

use super::models::{ModelKind, SelectedModel};
use super::planner::{self, PlanInput};
use super::predictor::SizePredictor;
use super::session::TrainedProfile;
use crate::cost::PricingModel;
use crate::linalg;
use crate::metrics::{Event, EventLog};
use crate::sim::engine;
use crate::sim::scenario::{DeficitController, ScenarioCtx};
use crate::sim::{
    Disturbance, FleetSpec, InstanceCatalog, IterationObservation, Scenario, SimError, SimOptions,
};

/// Recursive least-squares state for one dataset's size model.
///
/// Seeded from the sample-phase [`SelectedModel`]: θ starts at the batch
/// fit's coefficients and `P` at `prior·I`, so the first observations
/// correct the extrapolation without discarding what the samples
/// established. λ = 1 (no forgetting): every observation keeps full
/// weight, matching the batch objective in the limit.
#[derive(Debug, Clone)]
pub struct RlsState {
    /// The model family being refined (fixes the feature map).
    pub kind: ModelKind,
    /// Current coefficient vector θ.
    pub theta: Vec<f64>,
    /// Inverse-covariance estimate `P`, row-major k×k.
    p: Vec<f64>,
    /// Observations folded in so far (zero-residual ones included).
    pub updates: usize,
}

impl RlsState {
    /// Seed the recursion from a batch-fitted model. `prior` scales the
    /// initial `P = prior·I`: large means "trust the observations", small
    /// means "trust the sample fit".
    pub fn from_model(model: &SelectedModel, prior: f64) -> RlsState {
        let k = model.theta.len();
        let mut p = vec![0.0; k * k];
        for i in 0..k {
            p[i * k + i] = prior;
        }
        RlsState { kind: model.kind, theta: model.theta.clone(), p, updates: 0 }
    }

    /// Predict the dataset size at `scale` under the current θ. Uses the
    /// same dot product as [`SelectedModel::predict`], so before any
    /// update the two are bitwise equal.
    pub fn predict(&self, scale: f64) -> f64 {
        linalg::predict(&self.kind.features(scale), &self.theta)
    }

    /// Fold one `(scale, observed MB)` pair in.
    ///
    /// Standard RLS with λ=1: `K = P·x / (1 + xᵀP·x)`, `θ += K·residual`,
    /// `P -= K·(xᵀP)`. An exactly-zero residual skips the update entirely
    /// — not an optimization but the fixed-point contract: a model fed
    /// its own predictions keeps θ *and* P bit-identical, so replaying a
    /// converged log is a no-op.
    pub fn observe(&mut self, scale: f64, observed_mb: f64) {
        let x = self.kind.features(scale);
        let k = x.len();
        let residual = observed_mb - linalg::predict(&x, &self.theta);
        self.updates += 1;
        if residual == 0.0 {
            return;
        }
        let mut px = vec![0.0; k];
        for i in 0..k {
            let mut acc = 0.0;
            for j in 0..k {
                acc += self.p[i * k + j] * x[j];
            }
            px[i] = acc;
        }
        let denom = 1.0 + x.iter().zip(&px).map(|(a, b)| a * b).sum::<f64>();
        // P is symmetric, so xᵀP = (P·x)ᵀ and both updates reuse px.
        for i in 0..k {
            let gain = px[i] / denom;
            self.theta[i] += gain * residual;
            for j in 0..k {
                self.p[i * k + j] -= gain * px[j];
            }
        }
    }
}

/// One observed cached-dataset size, extrapolated to the full dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct SizeObservation {
    /// Job barrier the snapshot was taken at (0 = materialization).
    pub job: usize,
    /// Realized time of that barrier, seconds.
    pub at_s: f64,
    /// Dataset id in the application DAG.
    pub dataset: usize,
    /// Data scale the run executes at.
    pub scale: f64,
    /// Extrapolated full-dataset size at `scale`, MB.
    pub observed_mb: f64,
}

/// Flatten the engine's job-barrier snapshots into per-dataset size
/// observations at `scale`, in canonical fold order (job ascending,
/// dataset ascending — the order the engine emits them in). Datasets
/// with nothing resident at a barrier yield no observation: an empty
/// cache is absence of evidence, not evidence of an empty dataset.
pub fn observations_from_run(
    observations: &[IterationObservation],
    scale: f64,
    parallelism: usize,
) -> Vec<SizeObservation> {
    let mut out = Vec::new();
    for snap in observations {
        for &(dataset, resident_parts, resident_mb) in &snap.cached {
            if resident_parts == 0 {
                continue;
            }
            out.push(SizeObservation {
                job: snap.job,
                at_s: snap.at_s,
                dataset,
                scale,
                observed_mb: resident_mb / resident_parts as f64 * parallelism as f64,
            });
        }
    }
    out
}

/// Best-effort reconstruction of size observations from a detailed
/// `metrics` listener log — the path a real deployment uses when only
/// event logs are available. Per-partition `BlockUpdate`s maintain the
/// resident set; each `JobEnd` barrier snapshots it, extrapolating by
/// the largest partition index ever stored for the dataset. Aggregate
/// (non-detailed) logs collapse each dataset to one partition and so
/// reconstruct the resident size without extrapolation; the engine
/// observation hook is the precise source.
///
/// A real listener delivers block updates asynchronously, so the tail of
/// a job's `BlockUpdate`s can land *after* its `JobEnd` marker in the
/// log. Snapshotting eagerly at the marker would drop those late blocks,
/// so the barrier is held pending instead and flushed only once the
/// job's block stream has provably drained: at the next `TaskEnd` (the
/// following job has started running, so everything before it belonged
/// to the ended job), at the next `JobEnd`/`AppEnd`, or at the end of
/// the log. In-order logs — the engine writes `TaskEnd`s before any of a
/// job's block traffic — snapshot exactly what the eager reading did.
pub fn observations_from_log(log: &EventLog) -> Vec<SizeObservation> {
    let mut scale = 1.0_f64;
    let mut resident: BTreeMap<usize, BTreeMap<usize, f64>> = BTreeMap::new();
    let mut parts_total: BTreeMap<usize, usize> = BTreeMap::new();
    let mut now = 0.0_f64;
    let mut pending: Option<(usize, f64)> = None;
    let mut out = Vec::new();
    let flush = |pending: &mut Option<(usize, f64)>,
                 resident: &BTreeMap<usize, BTreeMap<usize, f64>>,
                 parts_total: &BTreeMap<usize, usize>,
                 out: &mut Vec<SizeObservation>,
                 scale: f64| {
        let Some((job, at_s)) = pending.take() else { return };
        for (&dataset, parts) in resident {
            let count = parts.len();
            if count == 0 {
                continue;
            }
            let sum: f64 = parts.values().sum();
            let total = parts_total.get(&dataset).copied().unwrap_or(count).max(count);
            out.push(SizeObservation {
                job,
                at_s,
                dataset,
                scale,
                observed_mb: sum / count as f64 * total as f64,
            });
        }
    };
    for ev in &log.events {
        match ev {
            Event::AppStart { data_scale, .. } => scale = *data_scale,
            Event::BlockUpdate { dataset, partition, size_mb, stored } => {
                // no flush: a block update right after a JobEnd marker is
                // the ended job's late traffic and belongs in its snapshot
                let parts = resident.entry(*dataset).or_default();
                if *stored {
                    parts.insert(*partition, *size_mb);
                    let seen = parts_total.entry(*dataset).or_insert(0);
                    *seen = (*seen).max(*partition + 1);
                } else {
                    parts.remove(partition);
                }
            }
            Event::TaskEnd { .. } => {
                flush(&mut pending, &resident, &parts_total, &mut out, scale);
            }
            Event::JobEnd { job, duration_s } => {
                flush(&mut pending, &resident, &parts_total, &mut out, scale);
                now += *duration_s;
                pending = Some((*job, now));
            }
            Event::AppEnd { .. } => {
                flush(&mut pending, &resident, &parts_total, &mut out, scale);
            }
            _ => {}
        }
    }
    flush(&mut pending, &resident, &parts_total, &mut out, scale);
    out
}

/// Per-dataset RLS refit of a trained [`SizePredictor`].
#[derive(Debug, Clone)]
pub struct Refit {
    /// One RLS recursion per dataset, keyed like `SizePredictor::models`.
    pub states: BTreeMap<usize, RlsState>,
}

impl Refit {
    pub fn new(sizes: &SizePredictor, prior: f64) -> Refit {
        Refit {
            states: sizes
                .models
                .iter()
                .map(|(&id, m)| (id, RlsState::from_model(m, prior)))
                .collect(),
        }
    }

    /// Fold one observation into its dataset's recursion. Observations
    /// for datasets the predictor never modeled are ignored.
    pub fn observe(&mut self, o: &SizeObservation) {
        if let Some(rls) = self.states.get_mut(&o.dataset) {
            rls.observe(o.scale, o.observed_mb);
        }
    }

    /// Fold a batch in its given order (callers pass canonical order).
    pub fn observe_all(&mut self, obs: &[SizeObservation]) {
        for o in obs {
            self.observe(o);
        }
    }

    /// Refit total predicted cached size at `scale`, MB. Mirrors
    /// [`SizePredictor::predict_total`]'s non-negative clamp per dataset.
    pub fn predict_total(&self, scale: f64) -> f64 {
        self.states.values().map(|s| s.predict(scale).max(0.0)).sum()
    }
}

/// Tuning knobs for the adaptive loop.
#[derive(Debug, Clone)]
pub struct AdaptConfig {
    /// Relative divergence `|refit − predicted| / max(predicted, 1 MB)`
    /// at which the re-planner fires. The default is wide enough that
    /// sample-noise wobble on a well-estimated law never trips it, while
    /// a mis-fit growth exponent (the superlinear synth preset diverges
    /// ≈2× at full scale) always does.
    pub threshold: f64,
    /// Job barriers to fold in before the divergence check may fire —
    /// one snapshot is noise, two establish a trend.
    pub min_history: usize,
    /// RLS prior variance on the sample-fit coefficients (`P = prior·I`).
    pub prior: f64,
    /// Engine noise seed, shared by the static and the corrective run so
    /// the comparison isolates the controller's effect.
    pub seed: u64,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig { threshold: 0.5, min_history: 2, prior: 1e6, seed: 11 }
    }
}

/// The re-planner's typed verdict, emitted when the refit diverges.
#[derive(Debug, Clone)]
pub struct ReplanDecision {
    /// Job barrier the divergence check fired at.
    pub job: usize,
    /// Realized time of that barrier — the corrective action's anchor.
    pub at_s: f64,
    /// Launch-time predicted total cached size at the target scale, MB.
    pub predicted_mb: f64,
    /// Refit prediction at the same scale when the check fired, MB.
    pub refit_mb: f64,
    /// `|refit − predicted| / max(predicted, 1)` at the decision point.
    pub divergence: f64,
    /// Observed cache deficit vs the static fleet's storage floor, MB.
    pub deficit_mb: f64,
    /// Machine count the re-plan recommends for the remaining iterations.
    pub replanned_machines: usize,
    /// Machines the controller adds (0 = the deficit arm did not fire:
    /// the re-plan kept the static count, or the fleet already fits the
    /// refit footprint).
    pub add_machines: usize,
    /// Machines the controller retires on a surplus (the refit footprint
    /// fits the fleet with room to spare and the re-plan wants fewer
    /// machines). At most one of `add_machines` / `remove_machines` is
    /// non-zero; both zero = advisory only.
    pub remove_machines: usize,
}

/// The adaptive loop's full answer for one application run.
#[derive(Debug, Clone)]
pub struct AdaptOutcome {
    pub app: String,
    pub scale: f64,
    /// The static pick the loop launched with.
    pub instance: String,
    pub machines: usize,
    /// Launch-time predicted total cached size, MB.
    pub predicted_mb: f64,
    /// Final refit total after every observation (equals `predicted_mb`
    /// when the profile has no size models to refit).
    pub refit_mb: f64,
    /// Job-barrier snapshots folded into the refit.
    pub observations: usize,
    /// The re-plan, if the divergence check fired.
    pub decision: Option<ReplanDecision>,
    /// Whether the corrective run was adopted (its realized cost did not
    /// exceed the static run's).
    pub adopted: bool,
    pub static_time_s: f64,
    pub static_cost: f64,
    /// Realized time/cost of the adaptive loop: the corrective run when
    /// adopted, the static run otherwise — never worse than static by
    /// construction.
    pub adaptive_time_s: f64,
    pub adaptive_cost: f64,
}

impl AdaptOutcome {
    /// Canonical bit-exact rendering of everything the loop decided —
    /// floats as IEEE bit patterns, so two runs agree iff every realized
    /// number agrees to the last bit. The determinism invariants
    /// (`check_adaptive`, `rust/tests/adaptive.rs`) compare these across
    /// the thread matrix.
    pub fn fingerprint(&self) -> String {
        let mut s = format!(
            "{}|{:x}|{}|{}|{:x}|{:x}|{}|{}|{:x}|{:x}|{:x}|{:x}",
            self.app,
            self.scale.to_bits(),
            self.instance,
            self.machines,
            self.predicted_mb.to_bits(),
            self.refit_mb.to_bits(),
            self.observations,
            self.adopted,
            self.static_time_s.to_bits(),
            self.static_cost.to_bits(),
            self.adaptive_time_s.to_bits(),
            self.adaptive_cost.to_bits(),
        );
        if let Some(d) = &self.decision {
            s.push_str(&format!(
                "|replan@{}:{:x}:{:x}:{:x}:{:x}:{}:{}:{}",
                d.job,
                d.at_s.to_bits(),
                d.refit_mb.to_bits(),
                d.divergence.to_bits(),
                d.deficit_mb.to_bits(),
                d.replanned_machines,
                d.add_machines,
                d.remove_machines,
            ));
        }
        s
    }
}

/// The act step's composite scenario: the base scenario's disturbances
/// plus the controller's corrective scale-out. `engine::run` takes one
/// scenario, so enacting a decision composes the two schedules.
struct Enacted<'a> {
    base: &'a dyn Scenario,
    controller: DeficitController,
}

impl Scenario for Enacted<'_> {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
        let mut ds = self.base.schedule(ctx);
        ds.extend(self.controller.schedule(ctx));
        ds
    }

    fn validate(&self) -> Result<(), SimError> {
        self.base.validate()?;
        self.controller.validate()
    }
}

fn opts(seed: u64) -> SimOptions<'static> {
    SimOptions { seed, detailed_log: false, ..Default::default() }
}

/// Run the full observe → refit → re-plan → act loop for one trained
/// profile at `scale`.
///
/// The static pick (the profile's catalog plan) is launched under
/// `scenario` and observed at every job barrier. Observations refit the
/// size models by RLS; if the refit total diverges from the launch-time
/// prediction beyond `cfg.threshold`, the planner re-runs over the
/// remaining iterations. A re-plan asking for more machines while the
/// refit footprint exceeds the fleet's storage floor replays the run
/// with a [`DeficitController`] scale-out anchored at the realized
/// decision time; a re-plan asking for *fewer* machines while the
/// footprint fits with room to spare replays with the controller's
/// surplus arm retiring the excess. Either corrective run is adopted
/// only if its realized cost does not exceed the static run's.
pub fn adapt(
    trained: &TrainedProfile,
    scale: f64,
    catalog: &InstanceCatalog,
    pricing: &dyn PricingModel,
    scenario: &dyn Scenario,
    cfg: &AdaptConfig,
) -> Result<AdaptOutcome, SimError> {
    let advice = trained.plan(scale, catalog, pricing);
    let pick = advice.plan.best().ok_or(SimError::EmptyFleet)?;
    let instance = catalog
        .get(&pick.candidate.instance)
        .expect("plan picks name catalog instances")
        .clone();
    let machines = pick.candidate.machines;
    let fleet = FleetSpec::homogeneous(instance.clone(), machines)?;
    let wp = trained.app.profile(scale);

    // launch the static pick, observing every job barrier
    let static_run = engine::run(&wp, &fleet, scenario, opts(cfg.seed))?;
    let static_time = static_run.timeline.duration_s;
    let static_cost = pricing.price_timeline(&static_run.timeline);
    let predicted_mb = trained.predicted_cached_mb(scale);

    let outcome = |refit_mb, decision, adopted, a_time, a_cost| AdaptOutcome {
        app: trained.app.name.clone(),
        scale,
        instance: instance.name.clone(),
        machines,
        predicted_mb,
        refit_mb,
        observations: static_run.observations.len(),
        decision,
        adopted,
        static_time_s: static_time,
        static_cost,
        adaptive_time_s: a_time,
        adaptive_cost: a_cost,
    };

    let Some((sizes, _)) = trained.models.as_ref() else {
        // atypical no-cached-data profile: nothing to refit, static final
        return Ok(outcome(predicted_mb, None, false, static_time, static_cost));
    };

    // observe → refit, one job barrier at a time, in canonical order;
    // the divergence check fires at the first barrier past the warm-up
    let obs = observations_from_run(&static_run.observations, scale, wp.parallelism);
    let mut refit = Refit::new(sizes, cfg.prior);
    let mut decision: Option<ReplanDecision> = None;
    let denom = predicted_mb.max(1.0);
    let mut i = 0;
    while i < obs.len() {
        let job = obs[i].job;
        let mut at_s = obs[i].at_s;
        while i < obs.len() && obs[i].job == job {
            at_s = obs[i].at_s;
            refit.observe(&obs[i]);
            i += 1;
        }
        // snapshots are one per job from 0, so job+1 = history folded
        if decision.is_none() && job + 1 >= cfg.min_history {
            let refit_now = refit.predict_total(scale);
            let divergence = (refit_now - predicted_mb).abs() / denom;
            if divergence >= cfg.threshold {
                // re-plan the remaining iterations with the refit
                // footprint, same instance type (mid-run you can add
                // machines of the running type, not swap the fleet)
                let mut remaining = wp.clone();
                remaining.iterations = wp.iterations.saturating_sub(job).max(1);
                let input = PlanInput {
                    profile: &remaining,
                    cached_total_mb: refit_now,
                    exec_total_mb: trained.predicted_exec_mb(scale),
                };
                let replan = planner::plan(
                    &input,
                    &InstanceCatalog::single(instance.clone()),
                    pricing,
                    trained.max_machines,
                );
                let replanned =
                    replan.best().map(|p| p.candidate.machines).unwrap_or(machines);
                let deficit =
                    refit_now - machines as f64 * instance.spec.storage_floor_mb();
                let (add, remove) = if deficit > 0.0 {
                    (replanned.saturating_sub(machines), 0)
                } else {
                    // surplus: the fleet already fits the refit footprint;
                    // if the re-plan wants fewer machines, retire the
                    // excess (never below one surviving machine)
                    (0, machines.saturating_sub(replanned.max(1)))
                };
                decision = Some(ReplanDecision {
                    job,
                    at_s,
                    predicted_mb,
                    refit_mb: refit_now,
                    divergence,
                    deficit_mb: deficit,
                    replanned_machines: replanned,
                    add_machines: add,
                    remove_machines: remove,
                });
            }
        }
    }
    let refit_final = refit.predict_total(scale);

    // act: replay with the corrective scale-out (deficit) or scale-in
    // (surplus), adopt only if it pays
    let (adopted, a_time, a_cost) = match &decision {
        Some(d) if d.add_machines > 0 || d.remove_machines > 0 => {
            let enacted = Enacted {
                base: scenario,
                controller: DeficitController {
                    at_frac: 0.0,
                    add: d.add_machines,
                    remove: d.remove_machines,
                    deficit_mb: Some(d.deficit_mb),
                    at_s: Some(d.at_s),
                },
            };
            let run = engine::run(&wp, &fleet, &enacted, opts(cfg.seed))?;
            let cost = pricing.price_timeline(&run.timeline);
            if cost <= static_cost {
                (true, run.timeline.duration_s, cost)
            } else {
                (false, static_time, static_cost)
            }
        }
        _ => (false, static_time, static_cost),
    };
    Ok(outcome(refit_final, decision, adopted, a_time, a_cost))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::scenario::StepAutoscale;
    use crate::sim::{CachedData, DisturbanceKind, InstanceType, WorkloadProfile};

    fn model(kind: ModelKind, theta: &[f64]) -> SelectedModel {
        SelectedModel { kind, theta: theta.to_vec(), cv_rmse: 0.0, cv_rel_err: 0.0 }
    }

    #[test]
    fn rls_self_observation_is_a_bit_exact_fixed_point() {
        let m = model(ModelKind::Quadratic, &[3.0, 0.7, 0.002]);
        let mut rls = RlsState::from_model(&m, 1e6);
        let theta0: Vec<u64> = rls.theta.iter().map(|t| t.to_bits()).collect();
        let p0: Vec<u64> = rls.p.iter().map(|v| v.to_bits()).collect();
        for s in 1..=50 {
            let s = s as f64;
            rls.observe(s, linalg::predict(&m.kind.features(s), &m.theta));
        }
        let theta1: Vec<u64> = rls.theta.iter().map(|t| t.to_bits()).collect();
        let p1: Vec<u64> = rls.p.iter().map(|v| v.to_bits()).collect();
        assert_eq!(theta0, theta1, "θ moved on zero residuals");
        assert_eq!(p0, p1, "P moved on zero residuals");
        assert_eq!(rls.updates, 50);
    }

    #[test]
    fn rls_converges_to_the_generating_law() {
        // seed with a deliberately wrong fit, feed the true law
        let mut rls = RlsState::from_model(&model(ModelKind::Linear, &[0.0, 1.0]), 1e6);
        for s in 1..=30 {
            let s = s as f64;
            rls.observe(s, 5.0 + 7.0 * s);
        }
        let got = rls.predict(100.0);
        assert!((got - 705.0).abs() < 1.0, "predict(100) = {got}");
    }

    #[test]
    fn run_observations_extrapolate_from_residency() {
        let snaps = vec![IterationObservation {
            job: 2,
            at_s: 12.5,
            // 10 of 40 partitions resident holding 25 MB → 100 MB full
            cached: vec![(0, 10, 25.0), (1, 0, 0.0)],
        }];
        let obs = observations_from_run(&snaps, 300.0, 40);
        assert_eq!(obs.len(), 1, "empty residency yields no observation");
        assert_eq!(obs[0].dataset, 0);
        assert_eq!(obs[0].job, 2);
        assert!((obs[0].observed_mb - 100.0).abs() < 1e-9);
    }

    #[test]
    fn log_observations_track_residency_and_evictions() {
        let mut log = EventLog::new();
        log.push(Event::AppStart { app: "toy".into(), machines: 2, data_scale: 300.0 });
        for p in 0..4 {
            log.push(Event::BlockUpdate {
                dataset: 0,
                partition: p,
                size_mb: 2.0,
                stored: true,
            });
        }
        log.push(Event::JobEnd { job: 0, duration_s: 10.0 });
        // one partition evicted before the next barrier
        log.push(Event::BlockUpdate { dataset: 0, partition: 3, size_mb: 2.0, stored: false });
        log.push(Event::JobEnd { job: 1, duration_s: 5.0 });
        let obs = observations_from_log(&log);
        assert_eq!(obs.len(), 2);
        assert_eq!((obs[0].job, obs[0].at_s), (0, 10.0));
        assert!((obs[0].observed_mb - 8.0).abs() < 1e-9);
        assert_eq!(obs[0].scale, 300.0);
        // 3 of 4 known partitions resident → still extrapolates to 8 MB
        assert_eq!((obs[1].job, obs[1].at_s), (1, 15.0));
        assert!((obs[1].observed_mb - 8.0).abs() < 1e-9);
    }

    #[test]
    fn log_observations_tolerate_blocks_landing_after_the_job_end_marker() {
        // two logs of the same run: in the second, partition 3's update is
        // delivered late — after the JobEnd marker — the way a threaded
        // listener interleaves. Both must reconstruct identically.
        let build = |late: bool| {
            let mut log = EventLog::new();
            log.push(Event::AppStart { app: "toy".into(), machines: 2, data_scale: 200.0 });
            for p in 0..3 {
                log.push(Event::BlockUpdate {
                    dataset: 0,
                    partition: p,
                    size_mb: (p + 1) as f64,
                    stored: true,
                });
            }
            let tail =
                Event::BlockUpdate { dataset: 0, partition: 3, size_mb: 4.0, stored: true };
            if !late {
                log.push(tail.clone());
            }
            log.push(Event::JobEnd { job: 0, duration_s: 8.0 });
            if late {
                log.push(tail);
            }
            // the next job's first task proves job 0's block stream has
            // drained; the eviction after it must not deflate job 0
            log.push(Event::TaskEnd {
                stage: 1,
                task: 0,
                machine: 0,
                duration_s: 1.0,
                cached_read: true,
            });
            log.push(Event::BlockUpdate {
                dataset: 0,
                partition: 3,
                size_mb: 4.0,
                stored: false,
            });
            log.push(Event::JobEnd { job: 1, duration_s: 4.0 });
            log.push(Event::AppEnd { duration_s: 12.0 });
            log
        };
        let ordered = observations_from_log(&build(false));
        let reordered = observations_from_log(&build(true));
        assert_eq!(ordered, reordered, "late block delivery changed the reconstruction");
        assert_eq!(ordered.len(), 2);
        // job 0 saw all four partitions: 1 + 2 + 3 + 4 = 10 MB
        assert_eq!((ordered[0].job, ordered[0].at_s), (0, 8.0));
        assert!((ordered[0].observed_mb - 10.0).abs() < 1e-9);
        // job 1 lost p3: 6 MB over 3 resident of 4 known parts → 8 MB
        assert_eq!((ordered[1].job, ordered[1].at_s), (1, 12.0));
        assert!((ordered[1].observed_mb - 8.0).abs() < 1e-9);
    }

    #[test]
    fn enacted_composes_base_and_controller_schedules() {
        let fleet = FleetSpec::homogeneous(InstanceType::paper_worker(), 2).unwrap();
        let profile = WorkloadProfile {
            name: "toy".into(),
            scale: 1000.0,
            input_mb: 1000.0,
            parallelism: 32,
            cached: vec![CachedData { id: 0, true_total_mb: 500.0, measured_total_mb: 500.0 }],
            iterations: 5,
            compute_s_per_mb: 0.01,
            cached_speedup: 97.0,
            recompute_factor: 1.0,
            serial_s: 1.0,
            shuffle_mb: 100.0,
            exec_mem_total_mb: 500.0,
            task_overhead_s: 0.01,
            task_time_sigma: 0.1,
            sample_prep_s: 0.0,
        };
        let ctx = ScenarioCtx { fleet: &fleet, profile: &profile, horizon_s: 100.0 };
        let base = StepAutoscale { at_frac: 0.5, add: 1 };
        let enacted = Enacted {
            base: &base,
            controller: DeficitController {
                at_frac: 0.0,
                add: 3,
                remove: 0,
                deficit_mb: Some(750.0),
                at_s: Some(42.0),
            },
        };
        assert_eq!(enacted.name(), "adaptive");
        assert!(enacted.validate().is_ok());
        let ds = enacted.schedule(&ctx);
        assert_eq!(ds.len(), 2, "base + controller");
        assert_eq!(ds[0].at_s, 50.0);
        assert_eq!(ds[1].at_s, 42.0);
        assert!(matches!(ds[1].kind, DisturbanceKind::ScaleOut { count: 3, .. }));
        // an invalid base poisons the composite at intake
        let bad = StepAutoscale { at_frac: f64::NAN, add: 1 };
        let poisoned = Enacted { base: &bad, controller: DeficitController::default() };
        assert!(matches!(
            poisoned.validate().unwrap_err(),
            SimError::BadScheduleFraction { .. }
        ));
    }

    #[test]
    fn fingerprint_is_total_over_the_decision() {
        let base = AdaptOutcome {
            app: "synth".into(),
            scale: 300.0,
            instance: "gp.xlarge".into(),
            machines: 3,
            predicted_mb: 100.0,
            refit_mb: 250.0,
            observations: 6,
            decision: None,
            adopted: false,
            static_time_s: 50.0,
            static_cost: 150.0,
            adaptive_time_s: 50.0,
            adaptive_cost: 150.0,
        };
        let mut replanned = base.clone();
        replanned.decision = Some(ReplanDecision {
            job: 1,
            at_s: 12.0,
            predicted_mb: 100.0,
            refit_mb: 250.0,
            divergence: 1.5,
            deficit_mb: 80.0,
            replanned_machines: 5,
            add_machines: 2,
            remove_machines: 0,
        });
        assert_eq!(base.fingerprint(), base.fingerprint());
        assert_ne!(base.fingerprint(), replanned.fingerprint());
        assert!(replanned.fingerprint().contains("replan@1"));
        // the scale-in arm is part of the total order too
        let mut shrunk = replanned.clone();
        if let Some(d) = shrunk.decision.as_mut() {
            d.deficit_mb = -80.0;
            d.add_machines = 0;
            d.remove_machines = 2;
        }
        assert_ne!(replanned.fingerprint(), shrunk.fingerprint());
    }
}
