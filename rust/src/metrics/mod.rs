//! SparkListener-style runtime metrics + cost accounting.
//!
//! While a (simulated or real-compute) run executes, an [`EventLog`]
//! collects structured events — task ends, block updates, evictions, job
//! boundaries — exactly the information the paper's *SparkListener* dumps
//! to HDFS log files. Blink's sample-runs manager consumes the *serialized
//! JSON* form of these logs (not in-process state), mirroring the paper's
//! architecture and exercising the same parse path a real deployment would.

use crate::util::json::Json;
use crate::util::units::Mb;

/// One listener event.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Application started on a cluster of `machines`.
    AppStart { app: String, machines: usize, data_scale: f64 },
    /// One task finished.
    TaskEnd {
        stage: usize,
        task: usize,
        machine: usize,
        duration_s: f64,
        /// Whether the task's input partition was served from cache.
        cached_read: bool,
    },
    /// A partition of a cached dataset was stored (or failed to store).
    BlockUpdate {
        dataset: usize,
        partition: usize,
        size_mb: Mb,
        stored: bool,
    },
    /// A cached partition was evicted.
    Eviction { machine: usize },
    /// A job (action) completed.
    JobEnd { job: usize, duration_s: f64 },
    /// Peak execution memory observed on a machine.
    ExecMemory { machine: usize, peak_mb: Mb },
    /// Application finished.
    AppEnd { duration_s: f64 },
}

impl Event {
    pub fn to_json(&self) -> Json {
        match self {
            Event::AppStart { app, machines, data_scale } => Json::obj(vec![
                ("event", "AppStart".into()),
                ("app", app.as_str().into()),
                ("machines", (*machines).into()),
                ("dataScale", (*data_scale).into()),
            ]),
            Event::TaskEnd { stage, task, machine, duration_s, cached_read } => Json::obj(vec![
                ("event", "TaskEnd".into()),
                ("stage", (*stage).into()),
                ("task", (*task).into()),
                ("machine", (*machine).into()),
                ("durationS", (*duration_s).into()),
                ("cachedRead", (*cached_read).into()),
            ]),
            Event::BlockUpdate { dataset, partition, size_mb, stored } => Json::obj(vec![
                ("event", "BlockUpdate".into()),
                ("dataset", (*dataset).into()),
                ("partition", (*partition).into()),
                ("sizeMb", (*size_mb).into()),
                ("stored", (*stored).into()),
            ]),
            Event::Eviction { machine } => Json::obj(vec![
                ("event", "Eviction".into()),
                ("machine", (*machine).into()),
            ]),
            Event::JobEnd { job, duration_s } => Json::obj(vec![
                ("event", "JobEnd".into()),
                ("job", (*job).into()),
                ("durationS", (*duration_s).into()),
            ]),
            Event::ExecMemory { machine, peak_mb } => Json::obj(vec![
                ("event", "ExecMemory".into()),
                ("machine", (*machine).into()),
                ("peakMb", (*peak_mb).into()),
            ]),
            Event::AppEnd { duration_s } => Json::obj(vec![
                ("event", "AppEnd".into()),
                ("durationS", (*duration_s).into()),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Option<Event> {
        let kind = j.get("event")?.as_str()?;
        let f = |k: &str| j.get(k).and_then(Json::as_f64);
        let u = |k: &str| f(k).map(|v| v as usize);
        Some(match kind {
            "AppStart" => Event::AppStart {
                app: j.get("app")?.as_str()?.to_string(),
                machines: u("machines")?,
                data_scale: f("dataScale")?,
            },
            "TaskEnd" => Event::TaskEnd {
                stage: u("stage")?,
                task: u("task")?,
                machine: u("machine")?,
                duration_s: f("durationS")?,
                cached_read: j.get("cachedRead")?.as_bool()?,
            },
            "BlockUpdate" => Event::BlockUpdate {
                dataset: u("dataset")?,
                partition: u("partition")?,
                size_mb: f("sizeMb")?,
                stored: j.get("stored")?.as_bool()?,
            },
            "Eviction" => Event::Eviction { machine: u("machine")? },
            "JobEnd" => Event::JobEnd { job: u("job")?, duration_s: f("durationS")? },
            "ExecMemory" => Event::ExecMemory {
                machine: u("machine")?,
                peak_mb: f("peakMb")?,
            },
            "AppEnd" => Event::AppEnd { duration_s: f("durationS")? },
            _ => return None,
        })
    }
}

/// In-memory event log; serializes to JSON-lines like a listener log file.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    pub events: Vec<Event>,
}

impl EventLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    /// Serialize as JSON lines (the on-DFS log file format).
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for e in &self.events {
            s.push_str(&e.to_json().to_string());
            s.push('\n');
        }
        s
    }

    /// Parse a JSON-lines log. Unknown events are skipped (forward compat).
    pub fn from_jsonl(text: &str) -> Result<EventLog, crate::util::json::ParseError> {
        let mut log = EventLog::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let j = crate::util::json::parse(line)?;
            if let Some(e) = Event::from_json(&j) {
                log.push(e);
            }
        }
        Ok(log)
    }
}

/// Post-run summary scraped from an event log — everything Blink's
/// analyzers need.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunSummary {
    pub app: String,
    pub machines: usize,
    pub data_scale: f64,
    pub duration_s: f64,
    /// Final stored size per cached dataset id, MB.
    pub cached_sizes_mb: Vec<(usize, Mb)>,
    pub evictions: usize,
    /// Peak execution memory summed across machines, MB.
    pub exec_memory_mb: Mb,
    pub tasks: usize,
    pub cached_reads: usize,
    /// Cost = machines x time (machine-seconds — the paper's accounting,
    /// computed by [`crate::cost::MachineSeconds`]; other pricing models
    /// re-price a summary via [`crate::cost::PricingModel::price_run`]).
    pub cost_machine_s: f64,
}

impl RunSummary {
    /// Analyze a log (the paper's "sample runs manager analyzes the logs").
    pub fn from_log(log: &EventLog) -> RunSummary {
        let mut s = RunSummary::default();
        let mut sizes: std::collections::BTreeMap<usize, f64> = Default::default();
        let mut exec: std::collections::BTreeMap<usize, f64> = Default::default();
        for e in &log.events {
            match e {
                Event::AppStart { app, machines, data_scale } => {
                    s.app = app.clone();
                    s.machines = *machines;
                    s.data_scale = *data_scale;
                }
                Event::TaskEnd { cached_read, .. } => {
                    s.tasks += 1;
                    if *cached_read {
                        s.cached_reads += 1;
                    }
                }
                Event::BlockUpdate { dataset, size_mb, stored, .. } => {
                    if *stored {
                        *sizes.entry(*dataset).or_default() += size_mb;
                    }
                }
                Event::Eviction { .. } => s.evictions += 1,
                Event::ExecMemory { machine, peak_mb } => {
                    let e = exec.entry(*machine).or_default();
                    *e = e.max(*peak_mb);
                }
                Event::JobEnd { .. } => {}
                Event::AppEnd { duration_s } => s.duration_s = *duration_s,
            }
        }
        s.cached_sizes_mb = sizes.into_iter().collect();
        s.exec_memory_mb = exec.values().sum();
        // the paper's accounting, delegated to the pluggable cost layer
        s.cost_machine_s = crate::cost::MachineSeconds.machine_seconds(s.machines, s.duration_s);
        s
    }

    pub fn total_cached_mb(&self) -> Mb {
        self.cached_sizes_mb.iter().map(|(_, s)| s).sum()
    }

    pub fn cost_machine_min(&self) -> f64 {
        self.cost_machine_s / 60.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> EventLog {
        let mut log = EventLog::new();
        log.push(Event::AppStart { app: "svm".into(), machines: 2, data_scale: 1.0 });
        log.push(Event::TaskEnd {
            stage: 0,
            task: 0,
            machine: 0,
            duration_s: 2.0,
            cached_read: false,
        });
        log.push(Event::BlockUpdate { dataset: 1, partition: 0, size_mb: 61.0, stored: true });
        log.push(Event::BlockUpdate { dataset: 1, partition: 1, size_mb: 60.5, stored: true });
        log.push(Event::BlockUpdate { dataset: 1, partition: 2, size_mb: 10.0, stored: false });
        log.push(Event::TaskEnd {
            stage: 1,
            task: 1,
            machine: 1,
            duration_s: 0.1,
            cached_read: true,
        });
        log.push(Event::Eviction { machine: 0 });
        log.push(Event::ExecMemory { machine: 0, peak_mb: 300.0 });
        log.push(Event::ExecMemory { machine: 1, peak_mb: 200.0 });
        log.push(Event::ExecMemory { machine: 0, peak_mb: 250.0 });
        log.push(Event::AppEnd { duration_s: 90.0 });
        log
    }

    #[test]
    fn jsonl_roundtrip_preserves_events() {
        let log = sample_log();
        let text = log.to_jsonl();
        let back = EventLog::from_jsonl(&text).unwrap();
        assert_eq!(log.events, back.events);
    }

    #[test]
    fn summary_aggregates_correctly() {
        let s = RunSummary::from_log(&sample_log());
        assert_eq!(s.app, "svm");
        assert_eq!(s.machines, 2);
        assert_eq!(s.tasks, 2);
        assert_eq!(s.cached_reads, 1);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.cached_sizes_mb, vec![(1, 121.5)]);
        assert_eq!(s.exec_memory_mb, 500.0, "peak per machine, summed");
        assert_eq!(s.duration_s, 90.0);
        assert_eq!(s.cost_machine_s, 180.0);
        assert_eq!(s.cost_machine_min(), 3.0);
        assert_eq!(s.total_cached_mb(), 121.5);
    }

    #[test]
    fn summary_via_serialized_logs_matches_in_memory() {
        // the sample-runs manager reads files, not structs — both must agree
        let log = sample_log();
        let direct = RunSummary::from_log(&log);
        let reparsed = RunSummary::from_log(&EventLog::from_jsonl(&log.to_jsonl()).unwrap());
        assert_eq!(direct, reparsed);
    }

    #[test]
    fn unknown_events_skipped() {
        let text = "{\"event\":\"FutureThing\",\"x\":1}\n{\"event\":\"AppEnd\",\"durationS\":5}\n";
        let log = EventLog::from_jsonl(text).unwrap();
        assert_eq!(log.events.len(), 1);
    }

    #[test]
    fn bad_json_is_an_error() {
        assert!(EventLog::from_jsonl("{nope}").is_err());
    }
}
