//! SparkListener-style runtime metrics + cost accounting.
//!
//! While a (simulated or real-compute) run executes, an [`EventLog`]
//! collects structured events — task ends, block updates, evictions, job
//! boundaries, machine lifecycle — exactly the information the paper's
//! *SparkListener* dumps to HDFS log files. Blink's sample-runs manager
//! consumes the *serialized JSON* form of these logs (not in-process
//! state), mirroring the paper's architecture and exercising the same
//! parse path a real deployment would.
//!
//! Parsing is explicit about failure modes: a malformed known event is a
//! typed [`EventDecodeError`] (hard error), while an *unknown* event kind
//! is skipped for forward compatibility — and counted, via
//! [`EventLog::from_jsonl_counted`], so a consumer can tell "clean log"
//! from "log written by a newer producer".

use crate::util::json::Json;
use crate::util::units::Mb;

/// One listener event.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Application started on a cluster of `machines`.
    AppStart { app: String, machines: usize, data_scale: f64 },
    /// One task finished.
    TaskEnd {
        stage: usize,
        task: usize,
        machine: usize,
        duration_s: f64,
        /// Whether the task's input partition was served from cache.
        cached_read: bool,
    },
    /// A partition of a cached dataset was stored (or failed to store).
    BlockUpdate {
        dataset: usize,
        partition: usize,
        size_mb: Mb,
        stored: bool,
    },
    /// A cached partition was evicted.
    Eviction { machine: usize },
    /// A job (action) completed.
    JobEnd { job: usize, duration_s: f64 },
    /// Peak execution memory observed on a machine.
    ExecMemory { machine: usize, peak_mb: Mb },
    /// A machine left the fleet (spot reclaim or failure): its cached
    /// bytes vanished and `inflight_tasks` of the running job were rewound
    /// onto survivors.
    MachineLost {
        machine: usize,
        time_s: f64,
        cached_mb_lost: Mb,
        inflight_tasks: usize,
    },
    /// A machine (re)joined the fleet with empty memory (failure restart
    /// or step autoscaling).
    MachineJoined { machine: usize, time_s: f64 },
    /// Application finished.
    AppEnd { duration_s: f64 },
}

/// Typed decode failure for one serialized event.
#[derive(Debug, Clone, PartialEq)]
pub enum EventDecodeError {
    /// The `event` kind is not one this consumer knows. Forward-compatible
    /// log readers skip (and count) these.
    UnknownKind(String),
    /// A known kind is missing a field or carries the wrong type.
    Malformed { kind: String, field: &'static str },
}

impl std::fmt::Display for EventDecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EventDecodeError::UnknownKind(kind) => write!(f, "unknown event kind '{kind}'"),
            EventDecodeError::Malformed { kind, field } => {
                write!(f, "event '{kind}': missing or mistyped field '{field}'")
            }
        }
    }
}

impl std::error::Error for EventDecodeError {}

impl Event {
    pub fn to_json(&self) -> Json {
        match self {
            Event::AppStart { app, machines, data_scale } => Json::obj(vec![
                ("event", "AppStart".into()),
                ("app", app.as_str().into()),
                ("machines", (*machines).into()),
                ("dataScale", (*data_scale).into()),
            ]),
            Event::TaskEnd { stage, task, machine, duration_s, cached_read } => Json::obj(vec![
                ("event", "TaskEnd".into()),
                ("stage", (*stage).into()),
                ("task", (*task).into()),
                ("machine", (*machine).into()),
                ("durationS", (*duration_s).into()),
                ("cachedRead", (*cached_read).into()),
            ]),
            Event::BlockUpdate { dataset, partition, size_mb, stored } => Json::obj(vec![
                ("event", "BlockUpdate".into()),
                ("dataset", (*dataset).into()),
                ("partition", (*partition).into()),
                ("sizeMb", (*size_mb).into()),
                ("stored", (*stored).into()),
            ]),
            Event::Eviction { machine } => Json::obj(vec![
                ("event", "Eviction".into()),
                ("machine", (*machine).into()),
            ]),
            Event::JobEnd { job, duration_s } => Json::obj(vec![
                ("event", "JobEnd".into()),
                ("job", (*job).into()),
                ("durationS", (*duration_s).into()),
            ]),
            Event::ExecMemory { machine, peak_mb } => Json::obj(vec![
                ("event", "ExecMemory".into()),
                ("machine", (*machine).into()),
                ("peakMb", (*peak_mb).into()),
            ]),
            Event::MachineLost { machine, time_s, cached_mb_lost, inflight_tasks } => {
                Json::obj(vec![
                    ("event", "MachineLost".into()),
                    ("machine", (*machine).into()),
                    ("timeS", (*time_s).into()),
                    ("cachedMbLost", (*cached_mb_lost).into()),
                    ("inflightTasks", (*inflight_tasks).into()),
                ])
            }
            Event::MachineJoined { machine, time_s } => Json::obj(vec![
                ("event", "MachineJoined".into()),
                ("machine", (*machine).into()),
                ("timeS", (*time_s).into()),
            ]),
            Event::AppEnd { duration_s } => Json::obj(vec![
                ("event", "AppEnd".into()),
                ("durationS", (*duration_s).into()),
            ]),
        }
    }

    /// Decode one serialized event. Unknown kinds and malformed known
    /// kinds are distinct typed errors so callers can skip the former and
    /// abort on the latter.
    pub fn from_json(j: &Json) -> Result<Event, EventDecodeError> {
        let kind = j
            .get("event")
            .and_then(Json::as_str)
            .ok_or_else(|| EventDecodeError::Malformed { kind: String::new(), field: "event" })?;
        let f = |k: &'static str| -> Result<f64, EventDecodeError> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| EventDecodeError::Malformed { kind: kind.to_string(), field: k })
        };
        let u = |k: &'static str| -> Result<usize, EventDecodeError> {
            f(k).map(|v| v as usize)
        };
        let b = |k: &'static str| -> Result<bool, EventDecodeError> {
            j.get(k)
                .and_then(Json::as_bool)
                .ok_or_else(|| EventDecodeError::Malformed { kind: kind.to_string(), field: k })
        };
        Ok(match kind {
            "AppStart" => Event::AppStart {
                app: j
                    .get("app")
                    .and_then(Json::as_str)
                    .ok_or_else(|| EventDecodeError::Malformed {
                        kind: kind.to_string(),
                        field: "app",
                    })?
                    .to_string(),
                machines: u("machines")?,
                data_scale: f("dataScale")?,
            },
            "TaskEnd" => Event::TaskEnd {
                stage: u("stage")?,
                task: u("task")?,
                machine: u("machine")?,
                duration_s: f("durationS")?,
                cached_read: b("cachedRead")?,
            },
            "BlockUpdate" => Event::BlockUpdate {
                dataset: u("dataset")?,
                partition: u("partition")?,
                size_mb: f("sizeMb")?,
                stored: b("stored")?,
            },
            "Eviction" => Event::Eviction { machine: u("machine")? },
            "JobEnd" => Event::JobEnd { job: u("job")?, duration_s: f("durationS")? },
            "ExecMemory" => Event::ExecMemory {
                machine: u("machine")?,
                peak_mb: f("peakMb")?,
            },
            "MachineLost" => Event::MachineLost {
                machine: u("machine")?,
                time_s: f("timeS")?,
                cached_mb_lost: f("cachedMbLost")?,
                inflight_tasks: u("inflightTasks")?,
            },
            "MachineJoined" => Event::MachineJoined {
                machine: u("machine")?,
                time_s: f("timeS")?,
            },
            "AppEnd" => Event::AppEnd { duration_s: f("durationS")? },
            other => return Err(EventDecodeError::UnknownKind(other.to_string())),
        })
    }
}

/// Why a JSONL log failed to parse.
#[derive(Debug)]
pub enum LogParseError {
    /// A line is not valid JSON.
    Json(crate::util::json::ParseError),
    /// A line is valid JSON but a malformed known event.
    Event(EventDecodeError),
}

impl std::fmt::Display for LogParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LogParseError::Json(e) => write!(f, "{e}"),
            LogParseError::Event(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for LogParseError {}

/// A parsed log plus forward-compatibility diagnostics.
#[derive(Debug, Clone)]
pub struct ParsedLog {
    pub log: EventLog,
    /// Lines whose `event` kind this consumer does not know (skipped).
    pub unknown_skipped: usize,
}

/// In-memory event log; serializes to JSON-lines like a listener log file.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    pub events: Vec<Event>,
}

impl EventLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    /// Serialize as JSON lines (the on-DFS log file format).
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for e in &self.events {
            s.push_str(&e.to_json().to_string());
            s.push('\n');
        }
        s
    }

    /// Parse a JSON-lines log. Unknown event kinds are skipped (forward
    /// compat — use [`EventLog::from_jsonl_counted`] to observe how many);
    /// malformed lines are an error.
    pub fn from_jsonl(text: &str) -> Result<EventLog, LogParseError> {
        Self::from_jsonl_counted(text).map(|p| p.log)
    }

    /// Like [`EventLog::from_jsonl`], but reports how many unknown-kind
    /// lines were skipped instead of dropping them silently.
    pub fn from_jsonl_counted(text: &str) -> Result<ParsedLog, LogParseError> {
        let mut log = EventLog::new();
        let mut unknown_skipped = 0usize;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let j = crate::util::json::parse(line).map_err(LogParseError::Json)?;
            match Event::from_json(&j) {
                Ok(e) => log.push(e),
                Err(EventDecodeError::UnknownKind(_)) => unknown_skipped += 1,
                Err(e) => return Err(LogParseError::Event(e)),
            }
        }
        Ok(ParsedLog { log, unknown_skipped })
    }
}

/// Post-run summary scraped from an event log — everything Blink's
/// analyzers need.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunSummary {
    pub app: String,
    pub machines: usize,
    pub data_scale: f64,
    pub duration_s: f64,
    /// Final stored size per cached dataset id, MB.
    pub cached_sizes_mb: Vec<(usize, Mb)>,
    pub evictions: usize,
    /// Peak execution memory summed across machines, MB.
    pub exec_memory_mb: Mb,
    pub tasks: usize,
    pub cached_reads: usize,
    /// Machines lost mid-run (spot reclaim / failure).
    pub machines_lost: usize,
    /// Machines that (re)joined mid-run (restart / autoscaling).
    pub machines_joined: usize,
    /// Cost = machines x time (machine-seconds — the paper's accounting,
    /// computed by [`crate::cost::MachineSeconds`]; other pricing models
    /// re-price a summary via [`crate::cost::PricingModel::price_run`],
    /// and disturbed engine runs price their realized per-machine uptime
    /// via [`crate::cost::PricingModel::price_timeline`]).
    pub cost_machine_s: f64,
}

impl RunSummary {
    /// Analyze a log (the paper's "sample runs manager analyzes the logs").
    pub fn from_log(log: &EventLog) -> RunSummary {
        let mut s = RunSummary::default();
        let mut sizes: std::collections::BTreeMap<usize, f64> = Default::default();
        let mut exec: std::collections::BTreeMap<usize, f64> = Default::default();
        for e in &log.events {
            match e {
                Event::AppStart { app, machines, data_scale } => {
                    s.app = app.clone();
                    s.machines = *machines;
                    s.data_scale = *data_scale;
                }
                Event::TaskEnd { cached_read, .. } => {
                    s.tasks += 1;
                    if *cached_read {
                        s.cached_reads += 1;
                    }
                }
                Event::BlockUpdate { dataset, size_mb, stored, .. } => {
                    if *stored {
                        *sizes.entry(*dataset).or_default() += size_mb;
                    }
                }
                Event::Eviction { .. } => s.evictions += 1,
                Event::ExecMemory { machine, peak_mb } => {
                    let e = exec.entry(*machine).or_default();
                    *e = e.max(*peak_mb);
                }
                Event::MachineLost { .. } => s.machines_lost += 1,
                Event::MachineJoined { .. } => s.machines_joined += 1,
                Event::JobEnd { .. } => {}
                Event::AppEnd { duration_s } => s.duration_s = *duration_s,
            }
        }
        s.cached_sizes_mb = sizes.into_iter().collect();
        s.exec_memory_mb = exec.values().sum();
        // the paper's accounting, delegated to the pluggable cost layer
        s.cost_machine_s = crate::cost::MachineSeconds.machine_seconds(s.machines, s.duration_s);
        s
    }

    pub fn total_cached_mb(&self) -> Mb {
        self.cached_sizes_mb.iter().map(|(_, s)| s).sum()
    }

    pub fn cost_machine_min(&self) -> f64 {
        self.cost_machine_s / 60.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> EventLog {
        let mut log = EventLog::new();
        log.push(Event::AppStart { app: "svm".into(), machines: 2, data_scale: 1.0 });
        log.push(Event::TaskEnd {
            stage: 0,
            task: 0,
            machine: 0,
            duration_s: 2.0,
            cached_read: false,
        });
        log.push(Event::BlockUpdate { dataset: 1, partition: 0, size_mb: 61.0, stored: true });
        log.push(Event::BlockUpdate { dataset: 1, partition: 1, size_mb: 60.5, stored: true });
        log.push(Event::BlockUpdate { dataset: 1, partition: 2, size_mb: 10.0, stored: false });
        log.push(Event::TaskEnd {
            stage: 1,
            task: 1,
            machine: 1,
            duration_s: 0.1,
            cached_read: true,
        });
        log.push(Event::Eviction { machine: 0 });
        log.push(Event::ExecMemory { machine: 0, peak_mb: 300.0 });
        log.push(Event::ExecMemory { machine: 1, peak_mb: 200.0 });
        log.push(Event::ExecMemory { machine: 0, peak_mb: 250.0 });
        log.push(Event::AppEnd { duration_s: 90.0 });
        log
    }

    /// One of every variant, for exhaustive round-trip coverage.
    fn one_of_each() -> Vec<Event> {
        vec![
            Event::AppStart { app: "x".into(), machines: 3, data_scale: 1.5 },
            Event::TaskEnd {
                stage: 1,
                task: 2,
                machine: 0,
                duration_s: 0.25,
                cached_read: true,
            },
            Event::BlockUpdate { dataset: 0, partition: 9, size_mb: 12.5, stored: false },
            Event::Eviction { machine: 2 },
            Event::JobEnd { job: 4, duration_s: 9.0 },
            Event::ExecMemory { machine: 1, peak_mb: 333.25 },
            Event::MachineLost {
                machine: 3,
                time_s: 42.5,
                cached_mb_lost: 1024.0,
                inflight_tasks: 7,
            },
            Event::MachineJoined { machine: 3, time_s: 60.25 },
            Event::AppEnd { duration_s: 77.5 },
        ]
    }

    #[test]
    fn jsonl_roundtrip_preserves_events() {
        let log = sample_log();
        let text = log.to_jsonl();
        let back = EventLog::from_jsonl(&text).unwrap();
        assert_eq!(log.events, back.events);
    }

    #[test]
    fn jsonl_roundtrip_covers_every_variant() {
        let mut log = EventLog::new();
        for e in one_of_each() {
            log.push(e);
        }
        let parsed = EventLog::from_jsonl_counted(&log.to_jsonl()).unwrap();
        assert_eq!(parsed.log.events, log.events);
        assert_eq!(parsed.unknown_skipped, 0);
    }

    #[test]
    fn summary_aggregates_correctly() {
        let s = RunSummary::from_log(&sample_log());
        assert_eq!(s.app, "svm");
        assert_eq!(s.machines, 2);
        assert_eq!(s.tasks, 2);
        assert_eq!(s.cached_reads, 1);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.cached_sizes_mb, vec![(1, 121.5)]);
        assert_eq!(s.exec_memory_mb, 500.0, "peak per machine, summed");
        assert_eq!(s.duration_s, 90.0);
        assert_eq!(s.cost_machine_s, 180.0);
        assert_eq!(s.cost_machine_min(), 3.0);
        assert_eq!(s.total_cached_mb(), 121.5);
        assert_eq!(s.machines_lost, 0);
        assert_eq!(s.machines_joined, 0);
    }

    #[test]
    fn summary_counts_machine_lifecycle() {
        let mut log = sample_log();
        log.push(Event::MachineLost {
            machine: 1,
            time_s: 30.0,
            cached_mb_lost: 60.5,
            inflight_tasks: 2,
        });
        log.push(Event::MachineJoined { machine: 1, time_s: 45.0 });
        log.push(Event::MachineJoined { machine: 2, time_s: 50.0 });
        let s = RunSummary::from_log(&log);
        assert_eq!(s.machines_lost, 1);
        assert_eq!(s.machines_joined, 2);
    }

    #[test]
    fn summary_via_serialized_logs_matches_in_memory() {
        // the sample-runs manager reads files, not structs — both must agree
        let log = sample_log();
        let direct = RunSummary::from_log(&log);
        let reparsed = RunSummary::from_log(&EventLog::from_jsonl(&log.to_jsonl()).unwrap());
        assert_eq!(direct, reparsed);
    }

    #[test]
    fn unknown_events_skipped_and_counted() {
        let text = "{\"event\":\"FutureThing\",\"x\":1}\n{\"event\":\"AppEnd\",\"durationS\":5}\n";
        let log = EventLog::from_jsonl(text).unwrap();
        assert_eq!(log.events.len(), 1);
        let parsed = EventLog::from_jsonl_counted(text).unwrap();
        assert_eq!(parsed.unknown_skipped, 1);
        assert_eq!(parsed.log.events.len(), 1);
    }

    #[test]
    fn unknown_kind_is_a_typed_error_at_the_event_level() {
        let j = crate::util::json::parse("{\"event\":\"FutureThing\",\"x\":1}").unwrap();
        assert_eq!(
            Event::from_json(&j),
            Err(EventDecodeError::UnknownKind("FutureThing".into()))
        );
    }

    #[test]
    fn malformed_known_event_is_a_hard_error() {
        // a JobEnd without its duration must not be silently dropped
        let text = "{\"event\":\"JobEnd\",\"job\":3}\n";
        let err = EventLog::from_jsonl(text).unwrap_err();
        match err {
            LogParseError::Event(EventDecodeError::Malformed { kind, field }) => {
                assert_eq!(kind, "JobEnd");
                assert_eq!(field, "durationS");
            }
            other => panic!("unexpected error {other:?}"),
        }
        // and a line without an `event` kind at all
        let err = EventLog::from_jsonl("{\"x\":1}\n").unwrap_err();
        assert!(matches!(
            err,
            LogParseError::Event(EventDecodeError::Malformed { field: "event", .. })
        ));
    }

    #[test]
    fn bad_json_is_an_error() {
        assert!(matches!(
            EventLog::from_jsonl("{nope}"),
            Err(LogParseError::Json(_))
        ));
    }
}
