//! Legacy-path equivalence: the event-driven engine with the no-op
//! scenario must be **byte-identical** (event-log JSONL) to the
//! pre-refactor serial simulator, across apps, seeds and the paper's
//! cluster range — so Table 1/2 and every figure reproduction is
//! untouched by the engine refactor.
//!
//! The `reference` module below is a frozen copy of the monolithic
//! `simulate()` loop as it existed before the engine landed (analytic
//! durations only — these tests pass no `TaskCompute` override, and the
//! RNG draw sequence is unchanged). The tests drive both implementations
//! over the same inputs and demand identical serialized logs and
//! placement diagnostics.

use blink::memory::EvictionPolicy;
use blink::sim::{simulate, CachedData, ClusterSpec, SimOptions, WorkloadProfile};
use blink::util::prng::Rng;
use blink::util::prop::{check, Config};
use blink::workloads::all_apps;

/// The pre-refactor serial simulator, frozen for regression.
mod reference {
    use blink::memory::{EvictionPolicy, PartitionKey, UnifiedMemory};
    use blink::metrics::{Event, EventLog};
    use blink::sim::{shuffle_s, ClusterSpec, WorkloadProfile};
    use blink::util::prng::Rng;

    struct Machine {
        slots: Vec<f64>,
        mem: UnifiedMemory,
        evictions: usize,
    }

    pub struct RefResult {
        pub log: EventLog,
        pub iter_tasks_per_machine: Vec<usize>,
        pub evictions_per_machine: Vec<usize>,
        pub cached_fraction_after_load: f64,
    }

    pub fn simulate(
        profile: &WorkloadProfile,
        cluster: &ClusterSpec,
        policy: EvictionPolicy,
        seed: u64,
        detailed: bool,
    ) -> RefResult {
        let n = cluster.machines;
        assert!(n > 0, "cluster needs at least one machine");
        let mut rng = Rng::new(seed ^ 0x5117_c0de);
        let mut log = EventLog::new();
        log.push(Event::AppStart {
            app: profile.name.clone(),
            machines: n,
            data_scale: profile.scale,
        });

        let mut machines: Vec<Machine> = (0..n)
            .map(|_| Machine {
                slots: vec![0.0; cluster.machine.cores],
                mem: UnifiedMemory::new(
                    cluster.machine.unified_mb(),
                    cluster.machine.storage_floor_mb(),
                    policy,
                ),
                evictions: 0,
            })
            .collect();

        let mut now = profile.sample_prep_s;
        for m in &mut machines {
            for s in &mut m.slots {
                *s = now;
            }
        }

        let parts = profile.parallelism.max(1);
        let mut location: Vec<Vec<Option<usize>>> =
            profile.cached.iter().map(|_| vec![None; parts]).collect();

        let exec_per_machine = profile.exec_mem_total_mb / n as f64;

        // -------------------------------------------------- job 0 ----
        let input_per_task = profile.input_mb / parts as f64;
        for p in 0..parts {
            let (mi, si) = earliest_slot(&machines);
            let base = input_per_task / cluster.machine.disk_mb_s
                + input_per_task * profile.compute_s_per_mb
                + profile.task_overhead_s;
            let dur = task_duration(base, profile, &mut rng);
            let start = machines[mi].slots[si];
            machines[mi].slots[si] = start + dur;
            if detailed {
                log.push(Event::TaskEnd {
                    stage: 0,
                    task: p,
                    machine: mi,
                    duration_s: dur,
                    cached_read: false,
                });
            }
            for (di, ds) in profile.cached.iter().enumerate() {
                let true_part = ds.true_total_mb / parts as f64;
                let measured_part = ds.measured_total_mb / parts as f64;
                let stored = machines[mi].mem.insert(
                    PartitionKey { dataset: ds.id, index: p },
                    true_part,
                    profile.iterations + 1,
                    1,
                );
                for key in machines[mi].mem.drain_evicted() {
                    machines[mi].evictions += 1;
                    log.push(Event::Eviction { machine: mi });
                    mark_evicted(&mut location, profile, key);
                }
                if stored {
                    location[di][p] = Some(mi);
                }
                if detailed {
                    log.push(Event::BlockUpdate {
                        dataset: ds.id,
                        partition: p,
                        size_mb: measured_part,
                        stored,
                    });
                }
            }
        }
        now = barrier(&machines, now);
        now += profile.serial_s + shuffle_s(profile, cluster);
        set_all_slots(&mut machines, now);

        let cached_fraction_after_load = if profile.cached.is_empty() {
            0.0
        } else {
            location[0].iter().filter(|l| l.is_some()).count() as f64 / parts as f64
        };

        // ----------------------------------------- iteration jobs ----
        let mut iter_tasks = vec![0usize; n];
        for job in 1..=profile.iterations {
            for (mi, m) in machines.iter_mut().enumerate() {
                m.mem.claim_execution(exec_per_machine);
                for key in m.mem.drain_evicted() {
                    m.evictions += 1;
                    log.push(Event::Eviction { machine: mi });
                    mark_evicted(&mut location, profile, key);
                }
            }

            for p in 0..parts {
                let pinned = profile.cached.first().and_then(|_| location[0][p]);
                let (mi, si) = match pinned {
                    Some(m) => (m, earliest_slot_on(&machines[m])),
                    None => earliest_slot(&machines),
                };
                let cached_read = pinned.is_some();
                let part_input = profile.input_mb / parts as f64;
                let base = if cached_read {
                    let part_cached: f64 = profile
                        .cached
                        .iter()
                        .map(|d| d.true_total_mb / parts as f64)
                        .sum();
                    part_cached * profile.compute_s_per_mb / profile.cached_speedup
                        + profile.task_overhead_s
                } else {
                    part_input / cluster.machine.disk_mb_s
                        + part_input * profile.compute_s_per_mb * profile.recompute_factor
                        + profile.task_overhead_s
                };
                let dur = task_duration(base, profile, &mut rng);
                let start = machines[mi].slots[si];
                machines[mi].slots[si] = start + dur;
                iter_tasks[mi] += 1;
                if detailed {
                    log.push(Event::TaskEnd {
                        stage: job,
                        task: p,
                        machine: mi,
                        duration_s: dur,
                        cached_read,
                    });
                }
                if cached_read {
                    for ds in &profile.cached {
                        machines[mi].mem.touch(PartitionKey { dataset: ds.id, index: p });
                    }
                } else {
                    for (di, ds) in profile.cached.iter().enumerate() {
                        let true_part = ds.true_total_mb / parts as f64;
                        let stored = machines[mi].mem.insert(
                            PartitionKey { dataset: ds.id, index: p },
                            true_part,
                            profile.iterations - job + 1,
                            1,
                        );
                        for key in machines[mi].mem.drain_evicted() {
                            machines[mi].evictions += 1;
                            log.push(Event::Eviction { machine: mi });
                            mark_evicted(&mut location, profile, key);
                        }
                        if stored {
                            location[di][p] = Some(mi);
                        }
                    }
                }
            }
            let job_start = now;
            now = barrier(&machines, now);
            now += profile.serial_s + shuffle_s(profile, cluster);
            set_all_slots(&mut machines, now);
            log.push(Event::JobEnd { job, duration_s: now - job_start });
        }

        if !detailed {
            for (di, ds) in profile.cached.iter().enumerate() {
                let resident = location[di].iter().filter(|l| l.is_some()).count();
                let measured_part = ds.measured_total_mb / parts as f64;
                log.push(Event::BlockUpdate {
                    dataset: ds.id,
                    partition: 0,
                    size_mb: measured_part * resident as f64,
                    stored: resident > 0,
                });
            }
        }
        for (mi, m) in machines.iter().enumerate() {
            log.push(Event::ExecMemory { machine: mi, peak_mb: m.mem.exec_used_mb() });
        }
        log.push(Event::AppEnd { duration_s: now });

        RefResult {
            log,
            iter_tasks_per_machine: iter_tasks,
            evictions_per_machine: machines.iter().map(|m| m.evictions).collect(),
            cached_fraction_after_load,
        }
    }

    fn mark_evicted(
        location: &mut [Vec<Option<usize>>],
        profile: &WorkloadProfile,
        key: PartitionKey,
    ) {
        for (di, ds) in profile.cached.iter().enumerate() {
            if ds.id == key.dataset {
                if let Some(slot) = location[di].get_mut(key.index) {
                    *slot = None;
                }
            }
        }
    }

    fn task_duration(base_s: f64, profile: &WorkloadProfile, rng: &mut Rng) -> f64 {
        rng.lognormal(base_s, profile.task_time_sigma).max(1e-6)
    }

    fn earliest_slot(machines: &[Machine]) -> (usize, usize) {
        let mut best = (0usize, 0usize, f64::INFINITY);
        for (mi, m) in machines.iter().enumerate() {
            for (si, &t) in m.slots.iter().enumerate() {
                if t < best.2 {
                    best = (mi, si, t);
                }
            }
        }
        (best.0, best.1)
    }

    fn earliest_slot_on(m: &Machine) -> usize {
        let mut best = (0usize, f64::INFINITY);
        for (si, &t) in m.slots.iter().enumerate() {
            if t < best.1 {
                best = (si, t);
            }
        }
        best.0
    }

    fn barrier(machines: &[Machine], now: f64) -> f64 {
        machines
            .iter()
            .flat_map(|m| m.slots.iter().copied())
            .fold(now, f64::max)
    }

    fn set_all_slots(machines: &mut [Machine], t: f64) {
        for m in machines {
            for s in &mut m.slots {
                *s = t;
            }
        }
    }
}

fn assert_identical(
    profile: &WorkloadProfile,
    machines: usize,
    seed: u64,
    detailed: bool,
    label: &str,
) {
    let cluster = ClusterSpec::workers(machines);
    let new = simulate(
        profile,
        &cluster,
        SimOptions { policy: EvictionPolicy::Lru, seed, compute: None, detailed_log: detailed },
    )
    .unwrap();
    let old = reference::simulate(profile, &cluster, EvictionPolicy::Lru, seed, detailed);
    assert_eq!(
        new.log.to_jsonl(),
        old.log.to_jsonl(),
        "{label}: serialized logs diverged (machines={machines}, seed={seed}, detailed={detailed})"
    );
    assert_eq!(new.iter_tasks_per_machine, old.iter_tasks_per_machine, "{label}: iter tasks");
    assert_eq!(new.evictions_per_machine, old.evictions_per_machine, "{label}: evictions");
    assert_eq!(
        new.cached_fraction_after_load, old.cached_fraction_after_load,
        "{label}: cached fraction"
    );
}

#[test]
fn every_app_is_byte_identical_across_the_paper_machine_range() {
    // all 8 workloads over the paper's 4–24 machine span (plus both log
    // granularities at the boundary sizes)
    for app in all_apps() {
        let profile = app.profile(30.0);
        for machines in [4usize, 7, 12, 16, 24] {
            assert_identical(&profile, machines, 1000 + machines as u64, true, &app.name);
        }
        assert_identical(&profile, 4, 77, false, &app.name);
        assert_identical(&profile, 24, 78, false, &app.name);
    }
}

#[test]
fn under_provisioned_runs_are_byte_identical_too() {
    // area-A heavy path (eviction churn + recompute) at a scale a small
    // cluster cannot hold
    let app = all_apps().into_iter().find(|a| a.name == "svm").unwrap();
    let profile = app.profile(300.0);
    for machines in [1usize, 2, 4] {
        assert_identical(&profile, machines, 5, true, "svm-underprovisioned");
    }
}

#[test]
fn property_random_profiles_are_byte_identical() {
    fn random_profile(rng: &mut Rng, size: usize) -> WorkloadProfile {
        let parallelism = 4 + rng.below(size.max(1) * 4 + 4);
        WorkloadProfile {
            name: "prop".into(),
            scale: rng.range(1.0, 2000.0),
            input_mb: rng.range(10.0, 20_000.0),
            parallelism,
            cached: (0..1 + rng.below(2))
                .map(|i| {
                    let mb = rng.range(1.0, 30_000.0);
                    CachedData { id: i, true_total_mb: mb, measured_total_mb: mb }
                })
                .collect(),
            iterations: rng.below(6),
            compute_s_per_mb: rng.range(0.001, 0.3),
            cached_speedup: 97.0,
            recompute_factor: rng.range(0.2, 8.0),
            serial_s: rng.range(0.0, 5.0),
            shuffle_mb: rng.range(0.0, 500.0),
            exec_mem_total_mb: rng.range(0.0, 20_000.0),
            task_overhead_s: 0.01,
            task_time_sigma: rng.range(0.0, 0.5),
            sample_prep_s: rng.range(0.0, 10.0),
        }
    }

    check(
        &Config { cases: 48, seed: 0xe9_1dea, max_size: 12 },
        |rng, size| {
            let machines = 1 + rng.below(24);
            let detailed = rng.below(2) == 0;
            (random_profile(rng, size), machines, rng.next_u64(), detailed)
        },
        |(profile, machines, seed, detailed)| {
            let cluster = ClusterSpec::workers(*machines);
            let new = simulate(
                profile,
                &cluster,
                SimOptions {
                    policy: EvictionPolicy::Lru,
                    seed: *seed,
                    compute: None,
                    detailed_log: *detailed,
                },
            )
            .map_err(|e| e.to_string())?;
            let old =
                reference::simulate(profile, &cluster, EvictionPolicy::Lru, *seed, *detailed);
            if new.log.to_jsonl() != old.log.to_jsonl() {
                return Err(format!(
                    "logs diverged at machines={machines}, seed={seed}, detailed={detailed}"
                ));
            }
            if new.iter_tasks_per_machine != old.iter_tasks_per_machine {
                return Err("iter task placement diverged".into());
            }
            if new.evictions_per_machine != old.evictions_per_machine {
                return Err("eviction counts diverged".into());
            }
            if new.cached_fraction_after_load != old.cached_fraction_after_load {
                return Err("cached fraction diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn eviction_policies_also_match_the_reference() {
    // the LRC/MRD paths run through the same engine core
    let app = all_apps().into_iter().find(|a| a.name == "km").unwrap();
    let profile = app.profile(60.0);
    for policy in [EvictionPolicy::Lru, EvictionPolicy::Lrc, EvictionPolicy::Mrd] {
        let cluster = ClusterSpec::workers(3);
        let new = simulate(
            &profile,
            &cluster,
            SimOptions { policy, seed: 9, compute: None, detailed_log: true },
        )
        .unwrap();
        let old = reference::simulate(&profile, &cluster, policy, 9, true);
        assert_eq!(new.log.to_jsonl(), old.log.to_jsonl(), "{policy}");
    }
}
