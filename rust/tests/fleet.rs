//! Integration: multi-tenant fleet scheduling — `sim::run_fleet`,
//! `planner::plan_fleet`, and the `testkit::check_fleet` contract.
//!
//! * a **one-tenant fleet run degenerates byte-for-byte** to the
//!   single-tenant engine: identical event-log JSONL, bit-identical
//!   makespan;
//! * the **3-tenant interleaved run is byte-identical across the thread
//!   matrix**: the fleet fingerprint replayed under `[0, 1, 2, 8]`
//!   worker pools matches the serial reference, under contention
//!   pressure, for both fairness knobs;
//! * the **shared plan realizes**: the cheapest eviction-free pick from
//!   `plan_fleet` over the summed true working sets actually runs every
//!   tenant to completion with zero evictions;
//! * the **eviction-free floor is monotone** in the tenant count on the
//!   paper apps, per catalog type;
//! * the `testkit::check_fleet` **differential invariants** hold on
//!   smoke batches of synthetic tenants.

use blink::blink::{plan_fleet, FleetPlanInput};
use blink::cost::pricing_by_name;
use blink::memory::EvictionPolicy;
use blink::sim::{
    engine, scenario, FleetFairness, FleetSpec, InstanceCatalog, InstanceType, SimError,
    SimOptions, TenantSpec,
};
use blink::testkit::{check_fleet, Violation};
use blink::util::par::sweep_range_with;
use blink::workloads::app_by_name;

fn render(violations: &[Violation]) -> String {
    violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
}

fn opts(seed: u64) -> SimOptions<'static> {
    SimOptions { policy: EvictionPolicy::Lru, seed, compute: None, detailed_log: false }
}

#[test]
fn one_tenant_fleet_run_is_byte_identical_to_the_single_engine() {
    let fleet = FleetSpec::homogeneous(InstanceType::paper_worker(), 4).unwrap();
    for (name, seed) in [("svm", 7u64), ("km", 11), ("gbt", 23)] {
        let app = app_by_name(name).unwrap();
        let wp = app.profile(300.0);
        let single = engine::run(&wp, &fleet, &scenario::NoDisturbances, opts(seed)).unwrap();
        let tenant = TenantSpec { name: name.to_string(), profile: wp.clone() };
        let wrapped = engine::run_fleet(
            std::slice::from_ref(&tenant),
            &fleet,
            &scenario::NoDisturbances,
            FleetFairness::SharedLru,
            opts(seed),
        )
        .unwrap();
        assert_eq!(wrapped.logs.len(), 1, "{name}");
        assert_eq!(
            wrapped.logs[0].to_jsonl(),
            single.sim.log.to_jsonl(),
            "{name}: one-tenant fleet log diverged from the engine"
        );
        assert_eq!(
            wrapped.duration_s.to_bits(),
            single.timeline.duration_s.to_bits(),
            "{name}: makespan not bit-identical"
        );
        assert_eq!(wrapped.tenants.len(), 1, "{name}");
        assert_eq!(wrapped.tenants[0].jobs, wp.iterations + 1, "{name}: job count");
    }
}

#[test]
fn three_tenants_interleave_deterministically_across_the_thread_matrix() {
    // svm + km + lr at 30 % scale massively oversubscribe two paper
    // workers, so the arbitration path (shared LRU / reservation
    // floors) is actually exercised — and must still replay
    // byte-for-byte at every pool size.
    let tenants: Vec<TenantSpec> = ["svm", "km", "lr"]
        .iter()
        .map(|n| {
            let app = app_by_name(n).unwrap();
            TenantSpec { name: n.to_string(), profile: app.profile(300.0) }
        })
        .collect();
    let fleet = FleetSpec::homogeneous(InstanceType::paper_worker(), 2).unwrap();
    let contention = scenario::by_name("contention").unwrap();
    for fairness in [FleetFairness::SharedLru, FleetFairness::ReservationFloors] {
        let reference =
            engine::run_fleet(&tenants, &fleet, contention.as_ref(), fairness, opts(11)).unwrap();
        assert_eq!(reference.tenants.len(), 3);
        for (t, spec) in reference.tenants.iter().zip(&tenants) {
            assert_eq!(t.jobs, spec.profile.iterations + 1, "{}: job count", t.name);
            assert!(
                t.finish_s <= reference.duration_s + 1e-9,
                "{}: finished after the fleet makespan",
                t.name
            );
        }
        assert!(
            reference
                .tenants
                .iter()
                .any(|t| (t.finish_s - reference.duration_s).abs() <= 1e-9),
            "some tenant must define the makespan"
        );
        let want = reference.fingerprint();
        for workers in [0usize, 1, 2, 8] {
            let got = sweep_range_with(workers, 0, 3, |_| {
                engine::run_fleet(&tenants, &fleet, contention.as_ref(), fairness, opts(11))
                    .map(|r| r.fingerprint())
                    .unwrap_or_default()
            });
            for fp in &got {
                assert_eq!(
                    fp, &want,
                    "{fairness:?}: {workers}-worker replay diverged from the serial reference"
                );
            }
        }
    }
}

#[test]
fn the_cheapest_eviction_free_fleet_plan_realizes_with_zero_evictions() {
    // als + gbt + pca at 30 % scale: the summed working set fits a
    // single paper worker with >1 GB of headroom, so whatever count the
    // plan picks, the realized run must never evict a tenant's block.
    let apps: Vec<_> = ["als", "gbt", "pca"].iter().map(|n| app_by_name(n).unwrap()).collect();
    let wps: Vec<_> = apps.iter().map(|a| a.profile(300.0)).collect();
    let inputs: Vec<FleetPlanInput<'_>> = apps
        .iter()
        .zip(&wps)
        .map(|(a, w)| FleetPlanInput {
            name: a.name.clone(),
            profile: w,
            cached_total_mb: a.total_true_cached_mb(300.0),
            exec_total_mb: a.exec_mem_mb(300.0),
        })
        .collect();
    let catalog = InstanceCatalog::paper();
    let pricing = pricing_by_name("machine-seconds").unwrap();
    let plan = plan_fleet(&inputs, &catalog, pricing.as_ref(), 12);
    let best = plan.best().expect("a feasible shared configuration exists");
    assert!(
        best.candidate.eviction_free,
        "the summed working set fits the paper catalog: {:?}",
        best.candidate
    );
    assert!(best.candidate.headroom_mb > 0.0, "{:?}", best.candidate);
    assert_eq!(best.candidate.per_tenant_time_s.len(), 3);

    let instance = catalog.get(&best.candidate.instance).unwrap().clone();
    let fleet = FleetSpec::homogeneous(instance, best.candidate.machines).unwrap();
    let tenants: Vec<TenantSpec> = apps
        .iter()
        .zip(&wps)
        .map(|(a, w)| TenantSpec { name: a.name.clone(), profile: w.clone() })
        .collect();
    let run = engine::run_fleet(
        &tenants,
        &fleet,
        &scenario::NoDisturbances,
        FleetFairness::SharedLru,
        opts(1),
    )
    .unwrap();
    for (t, w) in run.tenants.iter().zip(&wps) {
        assert_eq!(t.evictions, 0, "{}: plan promised eviction-free", t.name);
        assert_eq!(t.cached_mb_lost, 0.0, "{}", t.name);
        assert_eq!(t.jobs, w.iterations + 1, "{}", t.name);
    }
    assert!(run.duration_s > 0.0);
}

#[test]
fn adding_a_paper_tenant_never_shrinks_the_eviction_free_floor() {
    let apps: Vec<_> = ["svm", "km", "lr"].iter().map(|n| app_by_name(n).unwrap()).collect();
    let wps: Vec<_> = apps.iter().map(|a| a.profile(300.0)).collect();
    let pricing = pricing_by_name("machine-seconds").unwrap();
    for catalog in [InstanceCatalog::paper(), InstanceCatalog::cloud()] {
        let mut prev: Vec<Option<usize>> = vec![None; catalog.instances.len()];
        for k in 1..=apps.len() {
            let inputs: Vec<FleetPlanInput<'_>> = apps[..k]
                .iter()
                .zip(&wps[..k])
                .map(|(a, w)| FleetPlanInput {
                    name: a.name.clone(),
                    profile: w,
                    cached_total_mb: a.total_true_cached_mb(300.0),
                    exec_total_mb: a.exec_mem_mb(300.0),
                })
                .collect();
            let plan = plan_fleet(&inputs, &catalog, pricing.as_ref(), 16);
            for (i, instance) in catalog.instances.iter().enumerate() {
                let floor = plan.min_eviction_free_machines(&instance.name);
                if k > 1 {
                    match (prev[i], floor) {
                        (Some(p), Some(n)) => assert!(
                            n >= p,
                            "'{}' floor shrank {p} -> {n} at {k} tenants",
                            instance.name
                        ),
                        (None, Some(n)) => panic!(
                            "'{}' saturated at {} tenants but eviction-free at {n} for {k}",
                            instance.name,
                            k - 1
                        ),
                        _ => {}
                    }
                }
                prev[i] = floor;
            }
        }
    }
}

#[test]
fn an_empty_tenant_list_is_rejected() {
    let fleet = FleetSpec::homogeneous(InstanceType::paper_worker(), 2).unwrap();
    let res = engine::run_fleet(
        &[],
        &fleet,
        &scenario::NoDisturbances,
        FleetFairness::SharedLru,
        opts(1),
    );
    match res {
        Err(SimError::NoTenants) => {}
        Err(other) => panic!("expected NoTenants, got {other:?}"),
        Ok(_) => panic!("an empty tenant list must be rejected"),
    }
}

#[test]
fn check_fleet_release_matrix() {
    for preset in ["linear", "noisy", "superlinear"] {
        let (checks, violations) = check_fleet(preset, 1, 3);
        assert!(checks >= 17, "{preset}: {checks}");
        assert!(violations.is_empty(), "{preset}:\n{}", render(&violations));
    }
}
