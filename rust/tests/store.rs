//! Integration: the sharded concurrent profile store and persistent
//! profiles (`blink::blink::store`).
//!
//! * concurrency — M racing threads over K apps pay exactly one sampling
//!   phase per key and never observe a torn profile;
//! * persistence — a profile saved to disk and loaded back answers every
//!   query bit-identically, and seeds a store without re-sampling;
//! * staleness — a profile whose app changed since training (or whose
//!   format version drifted) is rejected with a typed error;
//! * serve determinism — the testkit property: `serve_batch` output is
//!   byte-identical at every shard × thread setting (smoke here, the
//!   release-scale matrix behind `--include-ignored` in CI).

use blink::blink::{load_profile, save_profile, ProfileStore, StoreError};
use blink::sim::MachineSpec;
use blink::testkit;
use blink::workloads::{app_by_name, AppModel, SynthConfig};

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("blink-store-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir
}

#[test]
fn racing_threads_pay_one_sampling_phase_per_key() {
    // registry + synthetic apps, so keys span shards
    let smoke = SynthConfig::by_name("smoke").unwrap();
    let apps: Vec<AppModel> = ["svm", "km", "lr", "bayes"]
        .into_iter()
        .map(|n| app_by_name(n).unwrap())
        .chain((1..=4).map(|s| smoke.generate(s)))
        .collect();
    let store = ProfileStore::builder().shards(4).build();
    std::thread::scope(|scope| {
        for t in 0..8usize {
            let apps = &apps;
            let store = &store;
            scope.spawn(move || {
                // each thread starts at a different offset, so every key
                // sees racing first-callers
                for i in 0..apps.len() {
                    let app = &apps[(i + t) % apps.len()];
                    let p = store.get_or_train(app).expect("valid scales");
                    assert_eq!(p.app.name, app.name);
                }
            });
        }
    });
    assert_eq!(store.sampling_phases(), apps.len(), "one sampling phase per key");
    assert_eq!(store.len(), apps.len());

    // no torn reads: every profile answers exactly like a fresh
    // single-threaded, single-shard store
    let fresh = ProfileStore::builder().shards(1).build();
    let machine = MachineSpec::worker_node();
    for app in &apps {
        let a = store.get_or_train(app).unwrap();
        let b = fresh.get_or_train(app).unwrap();
        let (ra, rb) = (a.recommend(900.0, &machine), b.recommend(900.0, &machine));
        assert_eq!(ra.machines, rb.machines, "{}", app.name);
        assert_eq!(
            ra.predicted_cached_mb.to_bits(),
            rb.predicted_cached_mb.to_bits(),
            "{}",
            app.name
        );
        assert_eq!(
            a.max_scale(&machine, 4).to_bits(),
            b.max_scale(&machine, 4).to_bits(),
            "{}",
            app.name
        );
    }
}

#[test]
fn profiles_round_trip_through_files_bit_identically() {
    let dir = temp_dir("roundtrip");
    let store = ProfileStore::builder().build();
    let machine = MachineSpec::worker_node();
    // svm exercises fitted predictors; gbt the extended-sampling paper app
    for name in ["svm", "gbt"] {
        let app = app_by_name(name).unwrap();
        let original = store.get_or_train(&app).unwrap();
        let path = dir.join(format!("{name}.json"));
        save_profile(&original, &path).expect("save");
        let loaded = load_profile(&path, &app).expect("load");
        for scale in [100.0, 1000.0, 3333.25] {
            let a = original.recommend(scale, &machine);
            let b = loaded.recommend(scale, &machine);
            assert_eq!(a.machines, b.machines, "{name} @ {scale}");
            assert_eq!(a.predicted_cached_mb.to_bits(), b.predicted_cached_mb.to_bits());
            assert_eq!(a.predicted_exec_mb.to_bits(), b.predicted_exec_mb.to_bits());
        }
        assert_eq!(
            original.max_scale(&machine, 7).to_bits(),
            loaded.max_scale(&machine, 7).to_bits()
        );
        // a loaded profile seeds a store without paying a sampling phase
        let warm = ProfileStore::builder().build();
        assert!(warm.insert(loaded).unwrap(), "first insert is new");
        assert!(warm.get(&app).is_some());
        assert_eq!(warm.sampling_phases(), 0);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_profile_for_a_changed_app_is_rejected() {
    let dir = temp_dir("stale");
    let app = app_by_name("svm").unwrap();
    let store = ProfileStore::builder().build();
    let profile = store.get_or_train(&app).unwrap();
    let path = dir.join("svm.json");
    save_profile(&profile, &path).expect("save");

    // the app's laws change after the profile was trained: stale
    let mut changed = app.clone();
    changed.cached_laws[0].theta1 *= 1.5;
    match load_profile(&path, &changed) {
        Err(StoreError::Fingerprint { field, app }) => {
            assert_eq!(field, "app_bits");
            assert_eq!(app, "svm");
        }
        other => panic!("expected a fingerprint rejection, got {other:?}"),
    }

    // format-version drift is a typed error, not a decode panic. The doc
    // is key-sorted, so the first 16-hex "...0001" is `blink_profile`.
    let text = std::fs::read_to_string(&path).unwrap();
    let drifted = text.replacen("0000000000000001", "00000000000003e7", 1);
    let drifted_path = dir.join("svm-drifted.json");
    std::fs::write(&drifted_path, drifted).unwrap();
    match load_profile(&drifted_path, &app) {
        Err(StoreError::Version { found, expected }) => {
            assert_eq!(found, 0x3e7);
            assert_eq!(expected, 1);
        }
        other => panic!("expected a version rejection, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_determinism_property_smoke() {
    // 3 workloads × a 4-shard × 4-thread grid; the release-scale matrix
    // runs behind --include-ignored in the differential CI job
    let (checks, violations) = testkit::check_serve("smoke", 1, 3);
    assert!(checks >= 32, "{checks}");
    assert!(violations.is_empty(), "{violations:#?}");
}

#[test]
#[ignore = "release-scale serve determinism matrix (differential CI job)"]
fn serve_determinism_property_at_scale() {
    let (checks, violations) = testkit::check_serve("mixed", 1, 24);
    assert!(checks >= 32, "{checks}");
    assert!(violations.is_empty(), "{violations:#?}");
}
