//! Integration: the listener-log contract — sample runs are analyzed from
//! *serialized* JSON logs exactly as a real SparkListener deployment would
//! be, and the analysis round-trips losslessly.

use blink::blink::{SampleRunsManager, SamplingOutcome, DEFAULT_SCALES};
use blink::memory::EvictionPolicy;
use blink::metrics::{Event, EventLog, RunSummary};
use blink::sim::{simulate, ClusterSpec, SimOptions};
use blink::workloads::app_by_name;

#[test]
fn sample_run_logs_roundtrip_through_jsonl() {
    let app = app_by_name("km").unwrap();
    let profile = app.sample_profile(2.0, &blink::hdfs::Sampler::default());
    let res = simulate(
        &profile,
        &ClusterSpec::single_sample_node(),
        SimOptions {
            policy: EvictionPolicy::Lru,
            seed: 1,
            compute: None,
            detailed_log: true,
        },
    )
    .unwrap();
    let text = res.log.to_jsonl();
    assert!(text.lines().count() > 3);
    let back = EventLog::from_jsonl(&text).unwrap();
    assert_eq!(res.log.events, back.events);
    assert_eq!(RunSummary::from_log(&res.log), RunSummary::from_log(&back));
}

#[test]
fn log_carries_everything_blink_needs() {
    let app = app_by_name("svm").unwrap();
    let mgr = SampleRunsManager::default();
    let SamplingOutcome::Profiled(runs) = mgr.run(&app, &DEFAULT_SCALES) else {
        panic!("svm caches data");
    };
    for r in &runs {
        assert!(r.summary.total_cached_mb() > 0.0, "cached sizes extracted");
        assert!(r.summary.exec_memory_mb > 0.0, "exec memory extracted");
        assert!(r.summary.duration_s > 0.0);
        assert_eq!(r.summary.machines, 1);
    }
}

#[test]
fn coarse_logs_summarize_like_detailed_logs() {
    // the aggregate BlockUpdate path must preserve size/eviction totals
    let app = app_by_name("bayes").unwrap();
    let profile = app.profile(100.0);
    let run = |detailed| {
        let res = simulate(
            &profile,
            &ClusterSpec::workers(3),
            SimOptions {
                policy: EvictionPolicy::Lru,
                seed: 2,
                compute: None,
                detailed_log: detailed,
            },
        )
        .unwrap();
        RunSummary::from_log(&res.log)
    };
    let fine = run(true);
    let coarse = run(false);
    assert_eq!(fine.duration_s, coarse.duration_s);
    assert_eq!(fine.evictions, coarse.evictions);
    assert!((fine.total_cached_mb() - coarse.total_cached_mb()).abs() < 1e-6);
    assert!(coarse.tasks < fine.tasks, "coarse log drops task events");
}

#[test]
fn unknown_and_malformed_lines_behave() {
    let good = Event::AppEnd { duration_s: 1.0 }.to_json().to_string();
    let text = format!("{{\"event\":\"NewThing\"}}\n{good}\n");
    let log = EventLog::from_jsonl(&text).unwrap();
    assert_eq!(log.events.len(), 1);
    assert!(EventLog::from_jsonl("not json").is_err());
}
