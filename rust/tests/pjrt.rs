//! Integration: the AOT artifacts load, execute and agree with the
//! pure-Rust oracles (the rust half of the HLO-text interchange contract;
//! the python half is python/tests/test_aot.py).
//!
//! Tests skip (with a note) when `make artifacts` has not been run.

use blink::blink::models::{FitBackend, FitProblem, RustFit};
use blink::blink::{Blink, RustFit as RustBackend};
use blink::compute::{gen_data, RealCompute, KM_DIM, KM_K, SVM_DIM};
use blink::runtime::{artifacts_available, PjrtFit, Runtime};
use blink::sim::MachineSpec;
use blink::workloads::{app_by_name, FULL_SCALE};

fn runtime_or_skip() -> Option<Runtime> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::from_repo_root().expect("runtime"))
}

#[test]
fn all_artifacts_compile_and_execute() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert_eq!(rt.platform().to_lowercase(), "cpu");
    let names = rt.artifact_names();
    for n in ["linfit", "svm_step", "logreg_step", "kmeans_step"] {
        assert!(names.iter().any(|x| x == n), "{n} in manifest");
        rt.get(n).unwrap_or_else(|e| panic!("{n}: {e:#}"));
    }
}

#[test]
fn linfit_kernel_matches_rust_oracle() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // a batch of solvable problems incl. fold masks and a clamped case
    let mut problems = Vec::new();
    for i in 0..24 {
        let slope = 0.5 + (i % 7) as f64;
        let icept = (i % 3) as f64;
        let xs: Vec<Vec<f64>> = (1..=5).map(|s| vec![1.0, s as f64]).collect();
        let y: Vec<f64> = xs.iter().map(|r| icept + slope * r[1]).collect();
        let mut w = vec![1.0; 5];
        if i % 4 == 0 {
            w[i % 5] = 0.0; // CV-fold style mask
        }
        problems.push(FitProblem { x: xs, y, w });
    }
    // decreasing data -> NNLS clamps the slope at 0
    problems.push(FitProblem {
        x: (1..=4).map(|s| vec![1.0, s as f64]).collect(),
        y: vec![10.0, 8.0, 6.0, 4.0],
        w: vec![1.0; 4],
    });

    let mut pjrt = PjrtFit::new(&mut rt);
    let got = pjrt.fit_batch(&problems);
    let dispatches = pjrt.dispatches;
    let want = RustFit::default().fit_batch(&problems);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        for (a, b) in g.theta.iter().zip(&w.theta) {
            assert!((a - b).abs() < 2e-2, "problem {i}: {:?} vs {:?}", g.theta, w.theta);
        }
        assert!((g.rmse - w.rmse).abs() < 2e-2, "problem {i} rmse");
        assert!(g.theta.iter().all(|&t| t >= 0.0));
    }
    assert_eq!(dispatches, 1, "24+1 problems fit one 64-problem batch");
}

#[test]
fn blink_decisions_identical_between_backends() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let machine = MachineSpec::worker_node();
    for name in ["svm", "km", "lr", "pca"] {
        let app = app_by_name(name).unwrap();
        let rust_pick = {
            let mut b = RustBackend::default();
            Blink::new(&mut b).decide(&app, FULL_SCALE, &machine).machines
        };
        let pjrt_pick = {
            let mut fit = PjrtFit::new(&mut rt);
            Blink::new(&mut fit).decide(&app, FULL_SCALE, &machine).machines
        };
        assert_eq!(rust_pick, pjrt_pick, "{name}: backend-dependent pick");
    }
}

#[test]
fn svm_kernel_reduces_loss_over_passes() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rc = RealCompute::new(&mut rt, "svm", 3);
    let first = rc.one_pass().unwrap();
    let mut last = first;
    for _ in 0..6 {
        last = rc.one_pass().unwrap();
    }
    assert!(last < first, "hinge loss should fall: {first} -> {last}");
    assert!(last.is_finite());
}

#[test]
fn kmeans_kernel_reduces_inertia_over_passes() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rc = RealCompute::new(&mut rt, "km", 4);
    let first = rc.one_pass().unwrap();
    let mut last = first;
    for _ in 0..5 {
        last = rc.one_pass().unwrap();
    }
    assert!(last <= first * 1.001, "inertia monotone-ish: {first} -> {last}");
}

#[test]
fn executable_rejects_wrong_shapes() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let exe = rt.get("svm_step").unwrap();
    let bad = vec![0.0f32; 7];
    assert!(exe.run_f32(&[&bad, &bad, &bad]).is_err());
    let d = gen_data("svm", 0);
    assert!(exe.run_f32(&[&d.x]).is_err(), "wrong arity");
}

#[test]
fn data_generator_matches_kernel_contracts() {
    let d = gen_data("svm", 0);
    assert_eq!(d.x.len() % SVM_DIM, 0);
    let k = gen_data("km", 0);
    assert_eq!(k.centroids.len(), KM_K * KM_DIM);
}
