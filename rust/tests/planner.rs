//! Integration: the fleet-aware planner and the parallel sweep engine.
//!
//! * the catalog search degenerates to the classic §5.4 selector on a
//!   single-type catalog (property-tested over random footprints);
//! * the single-type path reproduces the seed's Table-1 picks exactly;
//! * `blink advise` over the cloud catalog ranks candidates across
//!   instance types with per-candidate predicted costs;
//! * the parallel experiment sweep is byte-identical to the serial path
//!   for fixed seeds;
//! * saturated selections surface a deficit, never positive headroom.

use blink::blink::{
    plan, plan_exhaustive, plan_exhaustive_search, plan_search, select_cluster_size, Blink,
    PlanInput, RustFit, SearchSpace, DEFAULT_SCALES,
};
use blink::cost::{MachineSeconds, PerInstanceHour};
use blink::experiments;
use blink::metrics::RunSummary;
use blink::sim::{InstanceCatalog, InstanceType, MachineSpec};
use blink::util::par;
use blink::util::prng::Rng;
use blink::util::prop::{check, Config};
use blink::workloads::{app_by_name, FULL_SCALE};

#[test]
fn property_single_type_catalog_degenerates_to_selector() {
    let app = app_by_name("svm").unwrap();
    let profile = app.profile(500.0);
    check(
        &Config { cases: 96, seed: 0x91a77e5, max_size: 64 },
        |rng: &mut Rng, _size| (rng.range(10.0, 150_000.0), rng.range(0.0, 60_000.0)),
        |&(cached, exec)| {
            let catalog = InstanceCatalog::single(InstanceType::paper_worker());
            let input =
                PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
            let p = plan(&input, &catalog, &MachineSeconds, 16);
            let sel = select_cluster_size(cached, exec, &MachineSpec::worker_node(), 16);
            if p.ranked.len() != 1 {
                return Err(format!("expected one pick, got {}", p.ranked.len()));
            }
            let pick = &p.ranked[0];
            if pick.selection != sel {
                return Err(format!("selection diverged: {:?} vs {:?}", pick.selection, sel));
            }
            if pick.candidate.machines != sel.machines {
                return Err(format!(
                    "candidate machines {} vs selector {}",
                    pick.candidate.machines, sel.machines
                ));
            }
            if pick.candidate.eviction_free == sel.saturated {
                return Err("eviction_free must be the negation of saturated".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_pruned_plan_equals_the_frozen_exhaustive_grid() {
    // branch-and-bound prunes counts below each type's §5.4 lower bound;
    // ranked picks and Pareto front must be byte-identical to the frozen
    // exhaustive reference for any footprint (the prop harness prints the
    // failing seed and input on violation)
    let app = app_by_name("als").unwrap();
    let profile = app.profile(500.0);
    check(
        &Config { cases: 64, seed: 0xb1a6f00d, max_size: 64 },
        |rng: &mut Rng, _size| (rng.range(10.0, 300_000.0), rng.range(0.0, 80_000.0)),
        |&(cached, exec)| {
            let input =
                PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
            for catalog in [InstanceCatalog::cloud(), InstanceCatalog::all()] {
                let pruned = plan(&input, &catalog, &PerInstanceHour::hourly(), 12);
                let full = plan_exhaustive(&input, &catalog, &PerInstanceHour::hourly(), 12);
                if pruned.ranked != full.ranked {
                    return Err(format!(
                        "ranked diverged on '{}' (cached {cached:.1} MB, exec {exec:.1} MB)",
                        catalog.name
                    ));
                }
                if pruned.pareto != full.pareto {
                    return Err(format!(
                        "pareto diverged on '{}' (cached {cached:.1} MB, exec {exec:.1} MB)",
                        catalog.name
                    ));
                }
                if pruned.grid.len() > full.grid.len() {
                    return Err("pruned grid larger than exhaustive".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_fraction_grid_search_equals_the_exhaustive_reference() {
    // the tentpole invariant at property scale: with the storage fraction
    // as a third search dimension, the pruned search stays byte-identical
    // to the exhaustive (type × fraction × count) reference over random
    // footprints and a small generated catalog
    let app = app_by_name("als").unwrap();
    let profile = app.profile(500.0);
    let catalog = InstanceCatalog::generate(17, 24);
    check(
        &Config { cases: 32, seed: 0xf2ac7104, max_size: 64 },
        |rng: &mut Rng, _size| (rng.range(10.0, 300_000.0), rng.range(0.0, 80_000.0)),
        |&(cached, exec)| {
            let input =
                PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec };
            let space = SearchSpace { max_machines: 12, storage_fractions: vec![0.3, 0.5, 0.7] };
            let pruned = plan_search(&input, &catalog, &PerInstanceHour::hourly(), &space);
            let full = plan_exhaustive_search(&input, &catalog, &PerInstanceHour::hourly(), &space);
            if pruned.ranked != full.ranked {
                return Err(format!("ranked diverged (cached {cached:.1} MB, exec {exec:.1} MB)"));
            }
            if pruned.pareto != full.pareto {
                return Err(format!("pareto diverged (cached {cached:.1} MB, exec {exec:.1} MB)"));
            }
            if pruned.grid.len() > full.grid.len() {
                return Err("pruned grid larger than exhaustive".into());
            }
            Ok(())
        },
    );
}

// the ISSUE acceptance bar, ignored in the default run because the
// quadratic exhaustive Pareto reference over 512 × 3 × 12 candidates is
// slow in debug builds: `cargo test --release -- --include-ignored`
#[test]
#[ignore]
fn generated_512_catalog_plan_is_byte_identical_to_exhaustive() {
    let app = app_by_name("als").unwrap();
    let profile = app.profile(FULL_SCALE);
    let input = PlanInput {
        profile: &profile,
        cached_total_mb: app.total_true_cached_mb(FULL_SCALE),
        exec_total_mb: app.exec_mem_mb(FULL_SCALE),
    };
    let catalog = InstanceCatalog::generate(42, 512);
    let space = SearchSpace { max_machines: 12, storage_fractions: vec![0.3, 0.5, 0.7] };
    let pruned = plan_search(&input, &catalog, &PerInstanceHour::hourly(), &space);
    let full = plan_exhaustive_search(&input, &catalog, &PerInstanceHour::hourly(), &space);
    assert_eq!(pruned.fractions, full.fractions);
    assert_eq!(pruned.ranked, full.ranked, "ranked picks diverged on the 512-type catalog");
    assert_eq!(pruned.pareto, full.pareto, "pareto front diverged on the 512-type catalog");
    assert_eq!(pruned.ranked.len(), 512 * 3, "one pick per (type, fraction) pair");
    assert!(pruned.grid.len() <= full.grid.len());
}

#[test]
fn single_type_planner_reproduces_table1_picks() {
    // the paper's bold numbers at 100 % — the wrapper path must not move
    let expect = [
        ("als", 1),
        ("bayes", 7),
        ("gbt", 1),
        ("km", 4),
        ("lr", 5),
        ("pca", 1),
        ("rfc", 4),
        ("svm", 7),
    ];
    let worker_only = InstanceCatalog::single(InstanceType::paper_worker());
    for (name, want) in expect {
        let app = app_by_name(name).unwrap();
        let mut b = RustFit::default();
        let advice =
            Blink::new(&mut b).advise(&app, FULL_SCALE, &worker_only, &MachineSeconds);
        let best = advice.plan.best().expect("one pick");
        assert_eq!(best.candidate.machines, want, "{name}");
        // and it agrees with the legacy decide() pipeline
        let mut b2 = RustFit::default();
        let d = Blink::new(&mut b2).decide(&app, FULL_SCALE, &MachineSpec::worker_node());
        assert_eq!(best.candidate.machines, d.machines, "{name} vs decide()");
        assert_eq!(best.selection.machines, d.machines, "{name} selection");
    }
}

#[test]
fn advise_ranks_cloud_candidates_for_als() {
    // acceptance: ALS over >= 2 instance types with per-candidate cost
    let app = app_by_name("als").unwrap();
    let mut b = RustFit::default();
    let mut blink = Blink::new(&mut b);
    let scales: Vec<f64> = (1..=5).map(|s| s as f64).collect(); // §6.4 extended sampling
    let advice = blink.advise_with_scales(
        &app,
        FULL_SCALE,
        &InstanceCatalog::cloud(),
        &PerInstanceHour::hourly(),
        &scales,
    );
    let names: std::collections::BTreeSet<&str> =
        advice.plan.ranked.iter().map(|p| p.candidate.instance.as_str()).collect();
    assert!(names.len() >= 2, "ranked list spans {} instance types", names.len());
    for pick in &advice.plan.ranked {
        assert!(
            pick.candidate.predicted_cost > 0.0 && pick.candidate.predicted_cost.is_finite(),
            "{}: cost {}",
            pick.candidate.instance,
            pick.candidate.predicted_cost
        );
        assert!(pick.candidate.predicted_time_s > 0.0);
    }
    let best = advice.plan.best().expect("cloud catalog fits als");
    assert!(best.candidate.eviction_free, "top pick must be eviction-free");
    assert!(!advice.plan.pareto.is_empty());
    assert!(advice.sample_cost_machine_s > 0.0);
    assert!(advice.predicted_cached_mb > 0.0);
}

#[test]
fn parallel_sweep_byte_identical_to_serial() {
    // the exact listener logs, serialized — not just aggregate equality
    let app = app_by_name("svm").unwrap();
    let run = |n: usize| {
        experiments::actual_run_full(&app, 200.0, n, 40 + n as u64).log.to_jsonl()
    };
    let parallel = par::sweep_range(1, 8, run);
    let serial = par::sweep_range_serial(1, 8, run);
    assert_eq!(parallel, serial);
}

#[test]
fn table1_row_matches_serial_reference() {
    // the driver's internal sweep got parallelized; replay the old serial
    // loop and demand identical rows
    let app = app_by_name("svm").unwrap();
    let mut b = RustFit::default();
    let row = experiments::table1_row(&app, FULL_SCALE, &DEFAULT_SCALES, &mut b, 1);
    let mut runs = Vec::new();
    for n in 1..=experiments::MAX_MACHINES {
        let res = experiments::actual_run_full(&app, FULL_SCALE, n, 1 + n as u64);
        let s = RunSummary::from_log(&res.log);
        let free = s.evictions == 0 && (res.cached_fraction_after_load - 1.0).abs() < 1e-9;
        runs.push((s.duration_s / 60.0, s.cost_machine_s / 60.0, free));
    }
    assert_eq!(row.runs, runs);
    let first_free = runs.iter().position(|r| r.2).map_or(experiments::MAX_MACHINES, |i| i + 1);
    assert_eq!(row.optimal, first_free);
}

#[test]
fn saturated_selection_never_reports_positive_headroom() {
    // regression for the selector's saturated path, at the API the
    // coordinator and examples consume
    for machine in [MachineSpec::worker_node(), MachineSpec::sample_node()] {
        let s = select_cluster_size(500_000.0, 2_000.0, &machine, 12);
        assert!(s.saturated);
        assert!(s.headroom_mb <= 0.0, "headroom {}", s.headroom_mb);
        assert_eq!(s.cache_deficit_mb(), -s.headroom_mb);
        // the renderers' signed formatting keeps the sign visible
        let shown = blink::util::units::fmt_mb_signed(-s.cache_deficit_mb());
        assert!(shown.starts_with('-'), "rendered as '{shown}'");
    }
}
