//! Integration: the paper's claims, checked across module boundaries
//! (sample runs -> listener logs -> predictors -> selector -> simulator).
//! Heavier sweeps live in `cargo bench`; these stay debug-affordable.

use blink::blink::{true_optimal, Blink, RustFit};
use blink::experiments;
use blink::memory::EvictionPolicy;
use blink::metrics::RunSummary;
use blink::sim::{simulate, ClusterSpec, MachineSpec, SimOptions};
use blink::util::stats;
use blink::workloads::{all_apps, app_by_name, FULL_SCALE};

#[test]
fn headline_100pct_picks_are_optimal_for_all_8_apps() {
    let machine = MachineSpec::worker_node();
    for app in all_apps() {
        let mut b = RustFit::default();
        // 3 standard sample runs suffice at 100 % for every app (§6.1)
        let d = Blink::new(&mut b).decide(&app, FULL_SCALE, &machine);
        assert_eq!(
            d.machines,
            true_optimal(&app, FULL_SCALE, &machine, 12),
            "{}",
            app.name
        );
    }
}

#[test]
fn blink_pick_is_eviction_free_in_the_simulator() {
    // the selector's promise must hold under the actual (simulated) physics
    let machine = MachineSpec::worker_node();
    for name in ["svm", "lr", "bayes", "rfc"] {
        let app = app_by_name(name).unwrap();
        let mut b = RustFit::default();
        let d = Blink::new(&mut b).decide(&app, FULL_SCALE, &machine);
        let res = experiments::actual_run_full(&app, FULL_SCALE, d.machines, 9);
        let s = RunSummary::from_log(&res.log);
        assert_eq!(s.evictions, 0, "{name} evicted at its pick");
        assert!(
            (res.cached_fraction_after_load - 1.0).abs() < 1e-9,
            "{name} not fully cached at its pick"
        );
        // one machine fewer must NOT be eviction-free (minimality)
        if d.machines > 1 {
            let res = experiments::actual_run_full(&app, FULL_SCALE, d.machines - 1, 9);
            let s2 = RunSummary::from_log(&res.log);
            let free = s2.evictions == 0 && (res.cached_fraction_after_load - 1.0).abs() < 1e-9;
            assert!(!free, "{name}: pick not minimal");
        }
    }
}

#[test]
fn under_provisioned_run_costs_more() {
    // area A penalty end-to-end: svm at 3 machines vs its optimal 7
    let app = app_by_name("svm").unwrap();
    let under = experiments::actual_run(&app, FULL_SCALE, 3, 5);
    let optimal = experiments::actual_run(&app, FULL_SCALE, 7, 5);
    assert!(under.cost_machine_s > 3.0 * optimal.cost_machine_s);
}

#[test]
fn fig11_km_story_reproduces() {
    let f = experiments::fig11(1);
    assert_eq!(f.blink_pick, 7);
    assert_eq!(f.true_optimal, 8);
    assert!(f.evictions_per_machine.iter().sum::<usize>() > 0);
    assert!(f.pick_cost > f.optimal_cost);
}

#[test]
fn sampling_overhead_band() {
    // paper: sample runs average 4.6 % of the optimal actual-run cost.
    // we assert the order of magnitude: every app under 25 %, mean under 12 %
    let rows = experiments::table1_at_100(3);
    let overheads: Vec<f64> = rows
        .iter()
        .map(|r| r.sample_cost_machine_min / r.runs[r.optimal - 1].1)
        .collect();
    for (r, o) in rows.iter().zip(&overheads) {
        assert!(*o < 0.25, "{}: sampling overhead {o}", r.app);
    }
    assert!(stats::mean(&overheads) < 0.12, "{overheads:?}");
}

#[test]
fn fig6_cost_savings_band() {
    let rows = experiments::fig6(&blink::experiments::Table1 {
        at_100: experiments::table1_at_100(2),
        enlarged: Vec::new(),
    });
    let (vs_avg, vs_worst) = experiments::fig6_ratios(&rows);
    assert!(vs_avg < 0.75 && vs_avg > 0.3, "{vs_avg}");
    assert!(vs_worst < vs_avg, "{vs_worst}");
}

#[test]
fn fig7_gbt_is_worst_others_good() {
    let rows = experiments::fig7();
    let worst = rows
        .iter()
        .max_by(|a, b| a.error.partial_cmp(&b.error).unwrap())
        .unwrap();
    assert_eq!(worst.app, "gbt");
    let others: Vec<f64> = rows.iter().filter(|r| r.app != "gbt").map(|r| r.error).collect();
    assert!(stats::mean(&others) < 0.05);
}

#[test]
fn eviction_policies_equivalent_on_single_dataset_apps() {
    // §2: MRD/LRC bring no improvement when one dataset is cached
    let app = app_by_name("svm").unwrap();
    let mut costs = Vec::new();
    for policy in [EvictionPolicy::Lru, EvictionPolicy::Lrc, EvictionPolicy::Mrd] {
        let res = simulate(
            &app.profile(200.0), // small scale for debug speed, area A on 1 machine
            &ClusterSpec::workers(1),
            SimOptions { policy, seed: 4, compute: None, detailed_log: false },
        )
        .unwrap();
        costs.push(RunSummary::from_log(&res.log).cost_machine_s);
    }
    let spread = (stats::max(&costs) - stats::min(&costs)) / stats::mean(&costs);
    assert!(spread < 1e-9, "policies diverged on single-dataset app: {costs:?}");
}

#[test]
fn scalability_models_reused_across_machine_types() {
    // §5.4: one sampling phase serves different machine types
    let app = app_by_name("svm").unwrap();
    let mut b = RustFit::default();
    let d = Blink::new(&mut b).decide(&app, FULL_SCALE, &MachineSpec::worker_node());
    let (sizes, exec) = d.predictors.expect("models");
    let mut big = MachineSpec::worker_node();
    big.heap_mb *= 2.0;
    let pick_big = blink::blink::select_cluster_size(
        sizes.predict_total(FULL_SCALE),
        exec.predict_total(FULL_SCALE),
        &big,
        64,
    )
    .machines;
    assert!(pick_big < d.machines, "bigger machines, fewer of them");
}
