//! Golden snapshots: freeze the Table 1/Table 2 text and JSON renderings
//! (and two cheap deterministic reports) byte-for-byte, so refactors
//! cannot silently drift the paper reproduction.
//!
//! See `rust/tests/golden/README.md` for the bless/compare workflow.
//! Every test renders its report twice from independent driver runs at
//! the same seed and byte-compares the two, so determinism holds even on
//! the run that first blesses a snapshot.

use std::fs;
use std::path::PathBuf;

use blink::blink::Report;
use blink::coordinator;
use blink::experiments::{self, report};

/// The seed every snapshot is rendered at (the CLI's default).
const SEED: u64 = 1;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden").join(name)
}

/// Byte-compare `actual` against the stored snapshot; bless it when the
/// snapshot is missing or `UPDATE_GOLDEN` is set.
fn assert_golden(name: &str, actual: &str) {
    let path = golden_path(name);
    let bless = std::env::var_os("UPDATE_GOLDEN").is_some();
    if bless || !path.exists() {
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, actual).unwrap();
        eprintln!("golden: blessed {}", path.display());
        if !bless && std::env::var_os("CI").is_some() {
            // a fresh CI checkout has no committed snapshot: the compare
            // cannot run, only the in-process double-render determinism
            // check did. Surface that loudly so the gap gets closed by
            // committing the blessed file (GitHub Actions warning syntax).
            println!("::warning::golden snapshot {name} was missing — blessed, not compared; commit rust/tests/golden/{name} to arm the byte-compare");
        }
        return;
    }
    let expected = fs::read_to_string(&path).unwrap();
    if expected != actual {
        let diff_path = golden_path(&format!("{name}.actual"));
        fs::write(&diff_path, actual).unwrap();
        panic!(
            "golden mismatch for {name} ({} expected bytes vs {} actual).\n  \
             expected: {}\n  actual:   {}\n  \
             re-bless with UPDATE_GOLDEN=1 if the change is intentional",
            expected.len(),
            actual.len(),
            path.display(),
            diff_path.display(),
        );
    }
}

#[test]
fn fig9_json_snapshot() {
    // cheap + fully deterministic (hash-based measured sizes): exercises
    // the bless/compare harness on every tier-1 run
    let render = || report::json_fig9(&experiments::fig9_sizes()).pretty();
    let (a, b) = (render(), render());
    assert_eq!(a, b, "fig9 JSON must be deterministic");
    assert_golden("fig9.json", &a);
}

#[test]
fn apps_report_text_snapshot() {
    let render = || coordinator::cmd_apps(blink::blink::OutputFormat::Text).render_text();
    let (a, b) = (render(), render());
    assert_eq!(a, b, "apps report must be deterministic");
    assert_golden("apps.txt", &a);
}

#[test]
#[ignore = "simulates the enlarged scales; run in the release CI job (--include-ignored)"]
fn table1_snapshots_are_byte_stable() {
    // two independent full Table-1 runs at the same seed must agree
    // byte-for-byte, and match the frozen snapshot
    let t1 = experiments::table1(SEED);
    let t2 = experiments::table1(SEED);
    let (text1, text2) = (report::render_table1(&t1), report::render_table1(&t2));
    assert_eq!(text1, text2, "table1 text must be byte-identical across runs");
    assert_golden("table1.txt", &text1);
    let (json1, json2) = (report::json_table1(&t1).pretty(), report::json_table1(&t2).pretty());
    assert_eq!(json1, json2, "table1 JSON must be byte-identical across runs");
    assert_golden("table1.json", &json1);
}

#[test]
#[ignore = "simulates the boundary probes; run in the release CI job (--include-ignored)"]
fn table2_snapshots_are_byte_stable() {
    let r1 = experiments::table2(SEED);
    let r2 = experiments::table2(SEED);
    let (text1, text2) = (report::render_table2(&r1), report::render_table2(&r2));
    assert_eq!(text1, text2, "table2 text must be byte-identical across runs");
    assert_golden("table2.txt", &text1);
    let (json1, json2) = (report::json_table2(&r1).pretty(), report::json_table2(&r2).pretty());
    assert_eq!(json1, json2, "table2 JSON must be byte-identical across runs");
    assert_golden("table2.json", &json1);
}
