//! Integration: disturbance scenarios end-to-end — the acceptance story
//! of the engine refactor.
//!
//! * a spot-preemption engine run on a cloud catalog shape shows
//!   cached-partition loss, recompute recovery, and a realized cost
//!   strictly above the naive `SpotDiscount` quote;
//! * the same story is surfaced through the CLI layer
//!   (`blink simulate --scenario spot` → `coordinator::cmd_simulate`);
//! * failure-with-restart and autoscaling thread machine lifecycle events
//!   through the serialized listener-log round trip.

use blink::coordinator;
use blink::cost::{PricingModel, SpotDiscount};
use blink::memory::EvictionPolicy;
use blink::metrics::{Event, EventLog, RunSummary};
use blink::sim::scenario::ScenarioCtx;
use blink::sim::{
    engine, scenario, scenario_names, Disturbance, DisturbanceKind, FleetSpec, InstanceCatalog,
    Scenario, SimError, SimOptions,
};
use blink::workloads::app_by_name;

fn opts(seed: u64, detailed: bool) -> SimOptions<'static> {
    SimOptions { policy: EvictionPolicy::Lru, seed, compute: None, detailed_log: detailed }
}

fn cloud_fleet(instance: &str, machines: usize) -> FleetSpec {
    let catalog = InstanceCatalog::cloud();
    FleetSpec::homogeneous(catalog.get(instance).unwrap().clone(), machines).unwrap()
}

#[test]
fn spot_preemption_at_the_minimal_pick_realizes_above_the_naive_quote() {
    // svm at 40 % scale on 3 gp.xlarge — the planner's minimal
    // eviction-free count for this shape, i.e. no slack. The naive
    // SpotDiscount quote prices zero interruption risk; reclaiming one
    // machine pushes the survivors below the eviction-free boundary, so
    // every remaining iteration pays the Area-A recompute penalty and the
    // realized per-machine cost blows past the quote.
    let app = app_by_name("svm").unwrap();
    let profile = app.profile(400.0);
    let fleet = cloud_fleet("gp.xlarge", 3);
    let instance = InstanceCatalog::cloud().get("gp.xlarge").unwrap().clone();

    let base = engine::run(&profile, &fleet, &scenario::NoDisturbances, opts(3, true)).unwrap();
    let bs = RunSummary::from_log(&base.sim.log);
    assert_eq!(bs.evictions, 0, "baseline fits eviction-free");
    assert_eq!(bs.machines_lost, 0);

    let spot = engine::run(
        &profile,
        &fleet,
        &scenario::SpotPreemption { victims: 1, ..Default::default() },
        opts(3, true),
    )
    .unwrap();
    let ss = RunSummary::from_log(&spot.sim.log);
    assert_eq!(ss.machines_lost, 1);

    // cached-partition loss is visible in the log
    let lost_mb: f64 = spot
        .sim
        .log
        .events
        .iter()
        .filter_map(|e| match e {
            Event::MachineLost { cached_mb_lost, .. } => Some(*cached_mb_lost),
            _ => None,
        })
        .sum();
    assert!(lost_mb > 0.0, "the reclaimed machine held cached partitions");

    // survivors recompute the lost partitions via the lineage path
    let recompute_tasks = spot
        .sim
        .log
        .events
        .iter()
        .filter(|e| {
            matches!(e, Event::TaskEnd { stage, cached_read, .. } if *stage > 0 && !*cached_read)
        })
        .count();
    assert!(recompute_tasks > 0, "survivors must recompute the lost partitions");
    assert!(ss.duration_s > bs.duration_s, "the loss stretches the run");

    // realized cost strictly above the naive SpotDiscount quote
    let pricing = SpotDiscount::typical();
    let naive_quote = pricing.price(&instance, 3, bs.duration_s);
    let realized = pricing.price_timeline(&spot.timeline);
    assert!(
        realized > naive_quote,
        "realized {realized} must exceed the naive quote {naive_quote}"
    );
    // and the realized timeline stops billing the reclaimed machine early
    assert!(spot.timeline.machine_seconds() < 3.0 * ss.duration_s);
}

#[test]
fn spot_preemption_with_slack_recovers_full_caching() {
    // the same workload on 6 gp.xlarge has headroom: after the reclaim the
    // survivors re-cache the recomputed partitions, and by the final job
    // every read is served from cache again
    let app = app_by_name("svm").unwrap();
    let profile = app.profile(400.0);
    let fleet = cloud_fleet("gp.xlarge", 6);
    let spot = engine::run(
        &profile,
        &fleet,
        &scenario::SpotPreemption { victims: 1, ..Default::default() },
        opts(3, true),
    )
    .unwrap();
    let ss = RunSummary::from_log(&spot.sim.log);
    assert_eq!(ss.machines_lost, 1);
    let (mut recompute_tasks, mut last_total, mut last_cached) = (0usize, 0usize, 0usize);
    for e in &spot.sim.log.events {
        if let Event::TaskEnd { stage, cached_read, .. } = e {
            if *stage == 0 {
                continue;
            }
            if !*cached_read {
                recompute_tasks += 1;
            }
            if *stage == profile.iterations {
                last_total += 1;
                if *cached_read {
                    last_cached += 1;
                }
            }
        }
    }
    assert!(recompute_tasks > 0, "the loss forces a recompute wave");
    assert_eq!(last_total, profile.parallelism);
    assert_eq!(last_cached, last_total, "recovery: the final job reads cache only");
}

#[test]
fn cmd_simulate_surfaces_the_spot_story() {
    // the CLI path: blink simulate --app svm --scenario spot
    let q = |app, scale, machines, instance, scenario, pricing, seed| {
        coordinator::SimulateQuery { app, scale, machines, instance, scenario, pricing, seed }
    };
    let s = coordinator::cmd_simulate(
        &q("svm", 400.0, 3, "gp.xlarge", "spot", "spot", 3),
        blink::blink::OutputFormat::Text,
    )
    .unwrap();
    assert!(s.disturbed.machines_lost >= 1, "spot scenario must reclaim a machine");
    assert!(s.disturbed.duration_s > 0.0);
    // none is also valid and loses nothing
    let calm = coordinator::cmd_simulate(
        &q("svm", 100.0, 4, "i5-worker", "none", "machine-seconds", 1),
        blink::blink::OutputFormat::Text,
    )
    .unwrap();
    assert_eq!(calm.disturbed.machines_lost, 0);
    assert_eq!(calm.disturbed.machines_joined, 0);
}

#[test]
fn machine_lifecycle_events_roundtrip_through_jsonl() {
    let app = app_by_name("svm").unwrap();
    let profile = app.profile(200.0);
    let fleet = cloud_fleet("gp.xlarge", 4);
    let res =
        engine::run(&profile, &fleet, &scenario::FailureRestart::default(), opts(7, true))
            .unwrap();
    let text = res.sim.log.to_jsonl();
    let back = EventLog::from_jsonl(&text).unwrap();
    assert_eq!(res.sim.log.events, back.events);
    let s = RunSummary::from_log(&back);
    assert_eq!(s.machines_lost, 1, "failure loses the machine once");
    assert_eq!(s.machines_joined, 1, "and the restart brings it back");
    assert!(
        RunSummary::from_log(&res.sim.log) == s,
        "summary identical through the serialized round trip"
    );
}

#[test]
fn autoscale_and_straggler_scenarios_complete_with_consistent_logs() {
    let app = app_by_name("km").unwrap();
    let profile = app.profile(100.0);
    let fleet = cloud_fleet("cpu.xlarge", 3);
    let scaled =
        engine::run(&profile, &fleet, &scenario::StepAutoscale::default(), opts(2, false))
            .unwrap();
    let ss = RunSummary::from_log(&scaled.sim.log);
    assert_eq!(ss.machines_joined, 3, "default autoscale doubles the fleet");
    assert_eq!(ss.machines_lost, 0);
    assert_eq!(scaled.timeline.entries.len(), 6);

    let base = engine::run(&profile, &fleet, &scenario::NoDisturbances, opts(2, false)).unwrap();
    let slow = engine::run(
        &profile,
        &fleet,
        &scenario::StragglerSlowdown { factor: 6.0, ..Default::default() },
        opts(2, false),
    )
    .unwrap();
    let bt = RunSummary::from_log(&base.sim.log).duration_s;
    let st = RunSummary::from_log(&slow.sim.log).duration_s;
    assert!(st > bt, "straggler must slow the run: {st} vs {bt}");
}

#[test]
fn every_scenario_from_by_name_leaves_its_engine_level_signature() {
    // one engine-level assertion on the realized timeline per CLI-visible
    // scenario, so a new scenario cannot ship as an accidental no-op
    let app = app_by_name("svm").unwrap();
    let profile = app.profile(150.0);
    let fleet = cloud_fleet("gp.xlarge", 6);
    let base = engine::run(&profile, &fleet, &scenario::NoDisturbances, opts(5, false)).unwrap();
    let bs = RunSummary::from_log(&base.sim.log);
    for name in scenario_names() {
        let sc = scenario::by_name(name).unwrap();
        let run = engine::run(&profile, &fleet, sc.as_ref(), opts(5, false)).unwrap();
        let s = RunSummary::from_log(&run.sim.log);
        let lost_events = run
            .sim
            .log
            .events
            .iter()
            .filter(|e| matches!(e, Event::MachineLost { .. }))
            .count();
        let joined_events = run
            .sim
            .log
            .events
            .iter()
            .filter(|e| matches!(e, Event::MachineJoined { .. }))
            .count();
        match name {
            "none" => {
                assert_eq!(run.timeline, base.timeline, "none must replay the baseline");
                assert_eq!(s.duration_s, bs.duration_s);
                assert_eq!((lost_events, joined_events), (0, 0));
            }
            "spot" => {
                // 6 machines -> 1 auto victim; it stops billing at reclaim
                assert_eq!(lost_events, 1, "spot reclaims one machine");
                assert_eq!(s.machines_lost, 1);
                assert!(
                    run.timeline.machine_seconds() < 6.0 * s.duration_s,
                    "the reclaimed machine's uptime segment must end early"
                );
            }
            "straggler" => {
                assert!(
                    s.duration_s > bs.duration_s,
                    "straggler must strictly stretch the run: {} vs {}",
                    s.duration_s,
                    bs.duration_s
                );
                assert_eq!((lost_events, joined_events), (0, 0));
            }
            "failure" => {
                assert_eq!((lost_events, joined_events), (1, 1), "crash then restart");
                // the restarted machine bills two uptime segments
                assert_eq!(run.timeline.entries.len(), 7);
                assert!(s.duration_s > bs.duration_s, "losing in-flight work costs time");
            }
            "autoscale" => {
                assert_eq!(joined_events, 6, "default autoscale doubles the fleet");
                assert_eq!(lost_events, 0);
                assert_eq!(run.timeline.entries.len(), 12);
                // late joiners bill only from their join time
                let late: Vec<_> =
                    run.timeline.entries.iter().filter(|e| e.up_from_s > 0.0).collect();
                assert_eq!(late.len(), 6);
            }
            "deficit" => {
                // the conditional controller: it only acts when the fleet's
                // storage floor cannot hold the measured working set
                let demand: f64 = profile.cached.iter().map(|d| d.measured_total_mb).sum();
                let capacity = 6.0
                    * InstanceCatalog::cloud().get("gp.xlarge").unwrap().spec.storage_floor_mb();
                if demand > capacity {
                    assert!(joined_events >= 1, "a real deficit must scale out");
                } else {
                    assert_eq!(
                        run.timeline, base.timeline,
                        "no deficit: the controller must replay the baseline"
                    );
                    assert_eq!((lost_events, joined_events), (0, 0));
                }
            }
            "contention" => {
                // transient memory pressure: every machine squeezed at one
                // instant — no lifecycle churn, and a cache squeeze can
                // never make the run finish earlier
                assert_eq!((lost_events, joined_events), (0, 0), "pressure keeps the fleet");
                assert_eq!(run.timeline.entries.len(), 6, "no extra uptime segments");
                assert!(
                    s.duration_s + 1e-9 >= bs.duration_s,
                    "a cache squeeze can never shorten the run: {} vs {}",
                    s.duration_s,
                    bs.duration_s
                );
            }
            other => unreachable!("unknown scenario {other}"),
        }
    }
}

#[test]
fn bad_autoscale_fractions_are_a_typed_error_not_a_misfire() {
    // regression for scenario schedule-time validation: a NaN or
    // out-of-range at_frac used to flow straight into `horizon_s *
    // at_frac`, producing a disturbance in the unreachable past or future
    // (a silent no-op) instead of an error — intake must reject it
    let app = app_by_name("svm").unwrap();
    let profile = app.profile(150.0);
    let fleet = cloud_fleet("gp.xlarge", 4);
    for at_frac in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.1, 1.5] {
        let sc = scenario::StepAutoscale { at_frac, ..Default::default() };
        let err = engine::run(&profile, &fleet, &sc, opts(1, false)).unwrap_err();
        match err {
            SimError::BadScheduleFraction { ref scenario, at_frac: bad } => {
                assert_eq!(scenario, "autoscale");
                assert!(bad.is_nan() == at_frac.is_nan() && (bad.is_nan() || bad == at_frac));
            }
            other => panic!("at_frac {at_frac}: expected BadScheduleFraction, got {other:?}"),
        }
        assert!(err.to_string().contains("autoscale"), "{err}");
    }
    // the boundary values are legal schedules, not errors
    for at_frac in [0.0, 1.0] {
        let sc = scenario::StepAutoscale { at_frac, ..Default::default() };
        assert!(engine::run(&profile, &fleet, &sc, opts(1, false)).is_ok());
    }
}

#[test]
fn zero_count_scale_out_is_a_no_op_not_a_phantom_group() {
    // regression for the ScaleOut zero-count bug: validation used
    // `count.max(1)` while the spawn loop used `count`, so a scenario
    // emitting `count == 0` pushed an empty InstanceGroup into the fleet
    // state and a zero-machine entry into the realized timeline
    struct ZeroJoin;
    impl Scenario for ZeroJoin {
        fn name(&self) -> &'static str {
            "zero-join"
        }
        fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
            vec![Disturbance {
                at_s: ctx.horizon_s * 0.3,
                kind: DisturbanceKind::ScaleOut {
                    instance: InstanceCatalog::cloud().get("gp.xlarge").unwrap().clone(),
                    count: 0,
                },
            }]
        }
    }
    let app = app_by_name("km").unwrap();
    let profile = app.profile(100.0);
    let fleet = cloud_fleet("cpu.xlarge", 3);
    let joined = engine::run(&profile, &fleet, &ZeroJoin, opts(2, false)).unwrap();
    let base = engine::run(&profile, &fleet, &scenario::NoDisturbances, opts(2, false)).unwrap();
    let s = RunSummary::from_log(&joined.sim.log);
    assert_eq!(s.machines_joined, 0, "a zero-count join must not join anything");
    assert_eq!(joined.timeline, base.timeline, "no phantom timeline entry");
    assert_eq!(joined.sim.log.to_jsonl(), base.sim.log.to_jsonl());
}

#[test]
fn non_finite_disturbance_times_are_a_typed_error_not_a_hang() {
    // adversarial scenario: NaN/infinite deadlines sort after every finite
    // time under total order, so pre-guard they would sit in the queue
    // forever (a silently-starved disturbance) — intake must reject them
    struct BadClock {
        at_s: f64,
    }
    impl Scenario for BadClock {
        fn name(&self) -> &'static str {
            "bad-clock"
        }
        fn schedule(&self, _ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
            vec![Disturbance { at_s: self.at_s, kind: DisturbanceKind::Preempt { machine: 0 } }]
        }
    }
    struct BadRestart;
    impl Scenario for BadRestart {
        fn name(&self) -> &'static str {
            "bad-restart"
        }
        fn schedule(&self, ctx: &ScenarioCtx<'_>) -> Vec<Disturbance> {
            vec![Disturbance {
                at_s: ctx.horizon_s * 0.2,
                kind: DisturbanceKind::Fail { machine: 0, restart_delay_s: f64::INFINITY },
            }]
        }
    }
    let app = app_by_name("svm").unwrap();
    let profile = app.profile(150.0);
    let fleet = cloud_fleet("gp.xlarge", 4);
    for at_s in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        let err = engine::run(&profile, &fleet, &BadClock { at_s }, opts(1, false)).unwrap_err();
        match err {
            SimError::NonFiniteEventTime { ref scenario, .. } => {
                assert_eq!(scenario, "bad-clock");
            }
            other => panic!("expected NonFiniteEventTime, got {other:?}"),
        }
        assert!(err.to_string().contains("non-finite"), "{err}");
    }
    // a finite disturbance time with a non-finite restart delay is the
    // same starvation in disguise (the rejoin event never fires)
    let err = engine::run(&profile, &fleet, &BadRestart, opts(1, false)).unwrap_err();
    assert!(matches!(err, SimError::NonFiniteEventTime { .. }), "{err:?}");
}

#[test]
fn blink_table1_picks_survive_the_engine_refactor() {
    // the legacy path (simulate -> engine + none) still lands the paper's
    // bold numbers; redundant with blink's own tests, but cheap insurance
    // at the integration boundary
    use blink::blink::{Blink, RustFit};
    use blink::sim::MachineSpec;
    use blink::workloads::FULL_SCALE;
    for (name, want) in [("svm", 7usize), ("km", 4), ("gbt", 1)] {
        let app = app_by_name(name).unwrap();
        let mut b = RustFit::default();
        let d = Blink::new(&mut b).decide(&app, FULL_SCALE, &MachineSpec::worker_node());
        assert_eq!(d.machines, want, "{name}");
    }
}
