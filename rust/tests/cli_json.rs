//! CLI contract: every subcommand under `--format json` emits exactly one
//! valid JSON document on stdout (parsed with the crate's own
//! `util::json`), so other services can shell out to `blink` and consume
//! the answers without scraping text.

use std::process::Command;

use blink::util::json::{parse, Json};

/// Run the real `blink` binary and return its stdout.
fn blink_cli(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_blink"))
        .args(args)
        .output()
        .expect("spawn blink binary");
    assert!(
        out.status.success(),
        "blink {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

/// Run the real `blink` binary expecting failure; return its stderr.
fn blink_cli_err(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_blink"))
        .args(args)
        .output()
        .expect("spawn blink binary");
    assert!(
        !out.status.success(),
        "blink {args:?} unexpectedly succeeded:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
    String::from_utf8(out.stderr).expect("utf8 stderr")
}

/// Run a subcommand with `--format json` appended; stdout must be one doc.
fn query_json(args: &[&str]) -> Json {
    let mut full = args.to_vec();
    full.extend_from_slice(&["--format", "json"]);
    let stdout = blink_cli(&full);
    parse(&stdout)
        .unwrap_or_else(|e| panic!("blink {full:?}: not a single JSON doc: {e}\n{stdout}"))
}

fn marker(j: &Json, key: &str) -> String {
    j.get(key).and_then(Json::as_str).unwrap_or_default().to_string()
}

#[test]
fn every_subcommand_emits_one_json_document() {
    // small scales keep the debug-mode runs fast; each call must produce
    // a single parseable document carrying its query/experiment marker
    let j = query_json(&["decide", "--app", "svm", "--scale", "200"]);
    assert_eq!(marker(&j, "query"), "recommend");

    let j = query_json(&[
        "advise", "--app", "svm", "--scale", "200", "--catalog", "paper", "--pricing",
        "machine-seconds",
    ]);
    assert_eq!(marker(&j, "query"), "plan");

    let j = query_json(&[
        "simulate", "--app", "svm", "--scale", "50", "--machines", "2", "--instance",
        "gp.xlarge", "--scenario", "none", "--pricing", "hourly",
    ]);
    assert_eq!(marker(&j, "query"), "simulate");

    let j = query_json(&["run", "--app", "svm", "--scale", "50"]);
    assert_eq!(marker(&j, "query"), "run");

    let j = query_json(&["bounds", "--app", "svm", "--machines", "12"]);
    assert_eq!(marker(&j, "query"), "max_scale");

    let j = query_json(&["experiment", "--id", "fig9"]);
    assert_eq!(marker(&j, "experiment"), "fig9");

    let j = query_json(&["apps"]);
    assert_eq!(marker(&j, "query"), "apps");

    let j = query_json(&[
        "synth", "--preset", "smoke", "--seed", "3", "--count", "2", "--scale", "200",
        "--catalog", "paper", "--pricing", "machine-seconds",
    ]);
    assert_eq!(marker(&j, "query"), "synth");
    let workloads = j.path(&["workloads"]).and_then(Json::as_arr).expect("workloads array");
    assert_eq!(workloads.len(), 2);
}

#[test]
fn format_flag_accepts_equals_syntax_and_rejects_unknown() {
    let stdout = blink_cli(&["apps", "--format=json"]);
    let j = parse(&stdout).expect("one JSON doc");
    assert!(j.get("apps").is_some());
    let out = Command::new(env!("CARGO_BIN_EXE_blink"))
        .args(["apps", "--format", "yaml"])
        .output()
        .expect("spawn blink binary");
    assert!(!out.status.success(), "unknown format must fail");
}

#[test]
fn unknown_catalog_and_pricing_errors_list_the_valid_names() {
    // a typo'd name must enumerate every valid spelling, so the error is
    // actionable without opening the docs
    let err = blink_cli_err(&["advise", "--app", "svm", "--scale", "200", "--catalog", "nope"]);
    assert!(err.contains("unknown catalog 'nope'"), "stderr: {err}");
    for name in ["paper", "cloud", "all", "generated:<seed>:<n>"] {
        assert!(err.contains(name), "catalog error must list '{name}': {err}");
    }
    let err = blink_cli_err(&["advise", "--app", "svm", "--scale", "200", "--pricing", "florins"]);
    assert!(err.contains("unknown pricing model 'florins'"), "stderr: {err}");
    for name in ["machine-seconds", "hourly", "per-second", "spot"] {
        assert!(err.contains(name), "pricing error must list '{name}': {err}");
    }
    // simulate shares the pricing lookup
    let err = blink_cli_err(&[
        "simulate", "--app", "svm", "--scale", "50", "--machines", "2", "--pricing", "florins",
    ]);
    assert!(err.contains("unknown pricing model 'florins'"), "stderr: {err}");
}

#[test]
fn advise_handles_generated_catalogs_and_fraction_grids() {
    // `generated:<seed>:<n>` catalogs and an explicit `--fractions` grid
    // surface in the JSON contract: one ranked pick per (type, fraction)
    let j = query_json(&[
        "advise", "--app", "svm", "--scale", "200", "--catalog", "generated:7:6", "--pricing",
        "hourly", "--max-machines", "4", "--fractions", "0.4,0.6",
    ]);
    assert_eq!(marker(&j, "query"), "plan");
    assert_eq!(marker(&j, "catalog"), "generated:7:6");
    let fractions = j.path(&["plan", "fractions"]).and_then(Json::as_arr).expect("fractions");
    assert_eq!(fractions.len(), 2);
    let ranked = j.path(&["plan", "ranked"]).and_then(Json::as_arr).expect("ranked array");
    assert_eq!(ranked.len(), 6 * 2, "one pick per (type, fraction) pair");
    for pick in ranked {
        let f = pick.path(&["candidate", "storage_fraction"]).and_then(Json::as_f64).unwrap();
        assert!(f == 0.4 || f == 0.6, "storage_fraction {f}");
    }
    // a malformed grid is rejected up front, before any profiling work
    let err =
        blink_cli_err(&["advise", "--app", "svm", "--scale", "200", "--fractions", "0.4,nope"]);
    assert!(err.contains("invalid storage fraction"), "stderr: {err}");
    let err = blink_cli_err(&["advise", "--app", "svm", "--scale", "200", "--fractions", "1.5"]);
    assert!(err.contains("out of range"), "stderr: {err}");
}

#[test]
fn experiment_json_nests_the_figure_data() {
    let j = query_json(&["experiment", "--id", "fig9"]);
    let points = j.path(&["data"]).and_then(Json::as_arr).expect("data array");
    assert_eq!(points.len(), 10, "fig9 has 10 sample scales");
    for p in points {
        assert!(p.path(&["cached_mb"]).and_then(Json::as_f64).unwrap() > 0.0);
    }
}

#[test]
fn text_mode_is_unchanged_and_not_json() {
    let stdout = blink_cli(&["bounds", "--app", "svm", "--machines", "12"]);
    assert!(stdout.contains("max eviction-free data scale on 12 machines"));
    assert!(parse(&stdout).is_err(), "text output must not be JSON");
}

#[test]
fn fleet_json_is_a_byte_stable_snapshot() {
    // the multi-tenant path is engine-driven end to end (no wall clock),
    // so the full JSON document — plan grid, realized run, fingerprint —
    // must replay byte-for-byte under a fixed seed
    let args = [
        "fleet", "--apps", "svm,km", "--scale", "200", "--catalog", "paper", "--pricing",
        "machine-seconds", "--max-machines", "6", "--fairness", "shared-lru", "--scenario",
        "none", "--seed", "1", "--format", "json",
    ];
    let first = blink_cli(&args);
    let second = blink_cli(&args);
    assert_eq!(first, second, "fleet JSON must replay byte-for-byte");
    let j = parse(&first).expect("one JSON doc");
    assert_eq!(marker(&j, "query"), "fleet");
    assert_eq!(marker(&j, "fairness"), "shared-lru");
    let tenants = j.get("tenants").and_then(Json::as_arr).expect("tenant rows");
    assert_eq!(tenants.len(), 2);
    let best = j.path(&["plan", "best", "candidate"]).expect("a feasible shared pick");
    assert!(best.get("machines").and_then(Json::as_f64).unwrap() >= 1.0);
    let realized = j.get("realized").expect("realized run present");
    assert_eq!(marker(realized, "seed"), "1");
    let fp = marker(realized, "fingerprint");
    assert!(!fp.is_empty(), "realized fingerprint must be present:\n{first}");
    assert_eq!(
        realized.get("tenants").and_then(Json::as_arr).map(Vec::len),
        Some(2),
        "per-tenant stats for both apps"
    );

    // an unknown fairness knob is rejected listing both valid spellings
    let err = blink_cli_err(&["fleet", "--apps", "svm", "--fairness", "communism"]);
    assert!(
        err.contains("shared-lru") && err.contains("reservation-floors"),
        "stderr must list the fairness knobs: {err}"
    );
}

#[test]
fn serve_answers_a_jsonl_batch_as_one_document() {
    let dir = std::env::temp_dir().join(format!("blink-cli-serve-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let queries = dir.join("queries.jsonl");
    std::fs::write(
        &queries,
        concat!(
            "{\"query\":\"recommend\",\"app\":\"svm\",\"scale\":200}\n",
            "{\"query\":\"max_scale\",\"app\":\"svm\",\"machines\":4}\n",
            "this line is not a json query\n",
            "{\"query\":\"plan\",\"app\":\"km\",\"scale\":200}\n",
        ),
    )
    .unwrap();
    let j = query_json(&["serve", "--queries", queries.to_str().unwrap(), "--threads", "2"]);
    assert_eq!(marker(&j, "query"), "serve");
    assert_eq!(j.get("queries").and_then(Json::as_f64), Some(4.0));
    assert_eq!(j.get("ok").and_then(Json::as_f64), Some(3.0));
    assert_eq!(j.get("errors").and_then(Json::as_f64), Some(1.0));
    // svm + km profiles, each trained exactly once across the batch
    assert_eq!(j.get("profiles").and_then(Json::as_f64), Some(2.0));
    assert_eq!(j.get("sampling_phases").and_then(Json::as_f64), Some(2.0));
    let results = j.get("results").and_then(Json::as_arr).expect("results array");
    assert_eq!(results.len(), 4, "answers stay in line order");
    assert_eq!(marker(&results[0], "query"), "recommend");
    assert_eq!(marker(&results[1], "query"), "max_scale");
    // the malformed line becomes a per-query error doc, not an abort
    assert_eq!(marker(&results[2], "query"), "error");
    assert!(!marker(&results[2], "error").is_empty());
    assert_eq!(marker(&results[3], "query"), "plan");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_preloads_saved_profiles_and_rejects_stale_ones() {
    let dir = std::env::temp_dir().join(format!("blink-cli-stale-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let queries = dir.join("queries.jsonl");
    // a registry app and a seeded synthetic one: the synth profile's
    // fingerprint.app is the *generated* name (synth-smoke-0007), which
    // preload must resolve back to the generator (regression: it used to
    // abort the whole warm restart with "unknown app")
    std::fs::write(
        &queries,
        concat!(
            "{\"query\":\"recommend\",\"app\":\"svm\",\"scale\":200}\n",
            "{\"query\":\"max_scale\",\"app\":\"synth:smoke:7\",\"machines\":4}\n",
        ),
    )
    .unwrap();
    let q = queries.to_str().unwrap();
    let profiles = dir.join("profiles");
    let p = profiles.to_str().unwrap();

    // train once, saving both profiles
    blink_cli(&["serve", "--queries", q, "--save-profiles", p]);
    // a clean reload answers from the preloaded profiles: zero sampling
    let j = query_json(&["serve", "--queries", q, "--profiles", p]);
    assert_eq!(j.get("sampling_phases").and_then(Json::as_f64), Some(0.0));
    assert_eq!(j.get("ok").and_then(Json::as_f64), Some(2.0));

    // tamper: relabel the saved svm profile as km while keeping svm's
    // laws — the fingerprint no longer matches the live app definition
    let file = std::fs::read_dir(&profiles)
        .unwrap()
        .filter_map(|e| e.ok())
        .find(|e| {
            e.file_name().to_string_lossy().starts_with("svm")
                && e.path().extension().is_some_and(|x| x == "json")
        })
        .expect("the saved svm profile")
        .path();
    let text = std::fs::read_to_string(&file).unwrap();
    std::fs::write(&file, text.replace("svm", "km")).unwrap();
    let err = blink_cli_err(&["serve", "--queries", q, "--profiles", p]);
    assert!(err.contains("fingerprint"), "stderr must name the fingerprint check:\n{err}");
    std::fs::remove_dir_all(&dir).ok();
}
