//! The differential matrix over synthetic workloads: generated apps flow
//! through the whole stack (advisor profile → recommend/plan → engine
//! under every scenario) with the testkit's cross-layer invariants
//! asserted for each `(workload × scenario × catalog × pricing)` cell.
//! Any violation panics with the generator seed, so counterexamples
//! reproduce from the log (`blink synth --preset <p> --seed <s> --check`).

use blink::blink::{OutputFormat, Report};
use blink::coordinator::{self, SynthQuery};
use blink::testkit::{run_matrix, MatrixSpec};
use blink::util::json::Json;
use blink::workloads::{Growth, SynthConfig};

#[test]
fn smoke_matrix_is_green_in_debug() {
    // small but complete: every invariant over the full default matrix
    // (5 scenarios × 2 catalogs × 2 pricing models)
    let report = run_matrix(&SynthConfig::smoke(), 1, 10, &MatrixSpec::default());
    assert_eq!(report.workloads, 10);
    assert!(report.checks >= 10 * 20, "matrix too small: {} checks", report.checks);
    report.assert_ok();
}

#[test]
fn uncached_workloads_degenerate_cleanly_through_the_matrix() {
    let spec = MatrixSpec {
        scenario_names: vec!["none", "straggler"],
        catalog_names: vec!["paper"],
        ..Default::default()
    };
    run_matrix(&SynthConfig::uncached(), 50, 5, &spec).assert_ok();
}

#[test]
fn noisy_measurements_do_not_break_the_invariants() {
    // the §4/§6.2 regime: heavily wobbling measured sizes still produce a
    // self-consistent advisor (pick = exhaustive search on predictions)
    let spec = MatrixSpec {
        scenario_names: vec!["none", "spot"],
        catalog_names: vec!["paper"],
        ..Default::default()
    };
    run_matrix(&SynthConfig::noisy(), 90, 8, &spec).assert_ok();
}

#[test]
fn blink_synth_cli_generates_checks_and_reports() {
    let q = SynthQuery {
        preset: "smoke",
        seed: 1,
        count: 5,
        scale: 800.0,
        catalog: "paper",
        pricing: "machine-seconds",
        max_machines: 12,
        check: true,
    };
    let r = coordinator::cmd_synth(&q, OutputFormat::Text).unwrap();
    assert_eq!(r.rows.len(), 5);
    assert!(r.checks > 0, "--check must run invariants");
    assert!(r.violations.is_empty(), "{:#?}", r.violations);
    for (i, row) in r.rows.iter().enumerate() {
        assert!(row.name.starts_with("synth-smoke-"), "{}", row.name);
        assert_eq!(row.seed, 1 + i as u64);
        assert!(row.machines >= 1);
        assert!(row.best_machines >= 1);
        assert!(row.sample_cost_machine_s > 0.0);
    }
    // JSON rendering parses as a single doc carrying the same rows
    let j = blink::util::json::parse(&r.to_json().pretty()).unwrap();
    assert_eq!(j.get("query").and_then(Json::as_str), Some("synth"));
    assert_eq!(j.path(&["workloads"]).unwrap().as_arr().unwrap().len(), 5);
}

#[test]
fn synth_profiles_are_cached_by_the_session_like_paper_apps() {
    use blink::blink::{Advisor, RustFit};
    let cfg = SynthConfig::smoke();
    let app = cfg.generate(7);
    let mut b = RustFit::default();
    let mut advisor = Advisor::builder().build(&mut b);
    let p1 = advisor.profile(&app);
    let p2 = advisor.profile(&app);
    assert_eq!(advisor.sampling_phases(), 1, "same synth app must hit the cache");
    assert_eq!(p1.sample_cost_machine_s, p2.sample_cost_machine_s);
    // a different seed is a different app -> new sampling phase
    advisor.profile(&cfg.generate(8));
    assert_eq!(advisor.sampling_phases(), 2);
}

#[test]
#[ignore = "the full acceptance matrix; run in the release CI job (--include-ignored)"]
fn differential_matrix_over_100_seeded_workloads() {
    // acceptance: ≥ 100 seeded synthetic workloads across ≥ 3 scenarios
    // and ≥ 2 catalogs, every invariant green. Fixed seed blocks per
    // preset keep any failure reproducible from the log.
    let spec = MatrixSpec::default();
    assert!(spec.scenario_names.len() >= 3 && spec.catalog_names.len() >= 2);
    let batches: [(SynthConfig, u64, usize); 7] = [
        (SynthConfig::mixed(), 100, 40),
        (SynthConfig::contended(), 200, 15),
        (SynthConfig::noisy(), 300, 15),
        (SynthConfig::growth_only(Growth::Sublinear), 400, 10),
        (SynthConfig::growth_only(Growth::Superlinear), 500, 10),
        (SynthConfig::smoke(), 600, 10),
        (SynthConfig::uncached(), 700, 5),
    ];
    let mut workloads = 0;
    let mut checks = 0;
    for (cfg, first_seed, count) in batches {
        let report = run_matrix(&cfg, first_seed, count, &spec);
        workloads += report.workloads;
        checks += report.checks;
        report.assert_ok();
    }
    assert!(workloads >= 100, "only {workloads} workloads");
    assert!(checks >= workloads * 20, "only {checks} checks");
    println!("differential matrix: {workloads} workloads, {checks} checks, 0 violations");
}
