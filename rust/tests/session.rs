//! Integration: the session-oriented advisor API.
//!
//! * equivalence — the legacy `Blink` facade and the `Advisor` path give
//!   byte-identical Table 1/2 answers (picks, predictions, selections);
//! * amortization — one `TrainedProfile` serves recommend + plan +
//!   max_scale with exactly one sampling phase, and the sample cost is
//!   counted once, not per query;
//! * reports — every report type's `to_json` output re-parses with
//!   `util::json` and field-checks against the source struct.

use blink::blink::report::{AppsReport, BoundsReport, PlanReport, RecommendReport, RiskSection};
use blink::blink::{
    bounds, normalize_scales, Advisor, Blink, ExecMemoryPredictor, OutputFormat, Report, RustFit,
    SampleRunsManager, SamplingOutcome, ScaleError, SizePredictor, ValidationSpec, DEFAULT_SCALES,
};
use blink::coordinator::{self, SimulateQuery};
use blink::cost::MachineSeconds;
use blink::experiments::sampling_scales;
use blink::sim::{scenario::NoDisturbances, InstanceCatalog, MachineSpec};
use blink::util::json::{parse, Json};
use blink::workloads::{all_apps, app_by_name, FULL_SCALE};

// ======================================================================
// Equivalence: the legacy facade vs the session API
// ======================================================================

#[test]
fn advisor_recommendations_match_legacy_facade_bit_for_bit() {
    // Table 1, both halves: every app, paper scales, 100 % and enlarged
    let machine = MachineSpec::worker_node();
    for app in all_apps() {
        for scale in [FULL_SCALE, app.enlarged_scale] {
            let scales = sampling_scales(&app);
            let mut b1 = RustFit::default();
            let legacy = Blink::new(&mut b1).decide_with_scales(&app, scale, &machine, &scales);
            let mut b2 = RustFit::default();
            let mut advisor = Advisor::builder().scales(&scales).build(&mut b2);
            let d = advisor.profile(&app).recommend(scale, &machine);
            assert_eq!(d.machines, legacy.machines, "{} @ {scale}", app.name);
            assert_eq!(
                d.predicted_cached_mb.to_bits(),
                legacy.predicted_cached_mb.to_bits(),
                "{} @ {scale}: cached prediction",
                app.name
            );
            assert_eq!(
                d.predicted_exec_mb.to_bits(),
                legacy.predicted_exec_mb.to_bits(),
                "{} @ {scale}: exec prediction",
                app.name
            );
            assert_eq!(
                d.sample_cost_machine_s.to_bits(),
                legacy.sample_cost_machine_s.to_bits(),
                "{} @ {scale}: sample cost",
                app.name
            );
            assert_eq!(d.selection, legacy.selection, "{} @ {scale}", app.name);
        }
    }
}

#[test]
fn advisor_table1_picks_at_100pct() {
    // the paper's bold numbers, straight through the session API
    let expect = [
        ("als", 1),
        ("bayes", 7),
        ("gbt", 1),
        ("km", 4),
        ("lr", 5),
        ("pca", 1),
        ("rfc", 4),
        ("svm", 7),
    ];
    let machine = MachineSpec::worker_node();
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().scales(&DEFAULT_SCALES).build(&mut backend);
    for (name, want) in expect {
        let app = app_by_name(name).unwrap();
        let d = advisor.profile(&app).recommend(FULL_SCALE, &machine);
        assert_eq!(d.machines, want, "{name}");
    }
    assert_eq!(advisor.sampling_phases(), 8, "one phase per app, none repeated");
}

#[test]
fn advisor_plan_matches_legacy_advise() {
    let app = app_by_name("als").unwrap();
    let catalog = InstanceCatalog::cloud();
    let mut b1 = RustFit::default();
    let legacy = Blink::new(&mut b1).advise_with_scales(
        &app,
        FULL_SCALE,
        &catalog,
        &MachineSeconds,
        &sampling_scales(&app),
    );
    let mut b2 = RustFit::default();
    let mut advisor = Advisor::builder().build(&mut b2);
    let advice = advisor.profile(&app).plan(FULL_SCALE, &catalog, &MachineSeconds);
    assert_eq!(advice.plan.ranked, legacy.plan.ranked);
    assert_eq!(advice.plan.grid, legacy.plan.grid);
    assert_eq!(advice.plan.pareto, legacy.plan.pareto);
    assert_eq!(
        advice.sample_cost_machine_s.to_bits(),
        legacy.sample_cost_machine_s.to_bits()
    );
}

#[test]
fn advisor_bounds_match_the_hand_rolled_pipeline() {
    // what cmd_bounds used to do by hand must equal TrainedProfile::max_scale
    let app = app_by_name("svm").unwrap();
    let machine = MachineSpec::worker_node();
    let mgr = SampleRunsManager::default();
    let runs = match mgr.run(&app, &sampling_scales(&app)) {
        SamplingOutcome::Profiled(r) => r,
        _ => panic!("svm caches data"),
    };
    let mut b = RustFit::default();
    let sp = SizePredictor::train(&mut b, &runs);
    let ep = ExecMemoryPredictor::train(&mut b, &runs);
    let legacy = bounds::max_scale(&sp, &ep, &machine, 12, 1e-5);

    let mut b2 = RustFit::default();
    let mut advisor = Advisor::builder().build(&mut b2);
    let via_profile = advisor.profile(&app).max_scale(&machine, 12);
    assert_eq!(via_profile.to_bits(), legacy.to_bits());
}

// ======================================================================
// Amortization: one sampling phase, many queries
// ======================================================================

#[test]
fn one_sampling_phase_serves_recommend_plan_bounds_and_validate() {
    let app = app_by_name("svm").unwrap();
    let machine = MachineSpec::worker_node();
    let catalog = InstanceCatalog::paper();
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().build(&mut backend);

    let profile = advisor.profile(&app);
    let rec = profile.recommend(FULL_SCALE, &machine);
    let advice = profile.plan(FULL_SCALE, &catalog, &MachineSeconds);
    let bound = profile.max_scale(&machine, 12);
    let risks = profile.validate(
        300.0,
        &advice.plan,
        &catalog,
        &MachineSeconds,
        &ValidationSpec { scenario: &NoDisturbances, seeds: &[11], top_k: 1 },
    );
    // a second profile() for the same app is a cache hit
    let again = advisor.profile(&app);

    assert_eq!(advisor.sampling_phases(), 1, "five uses, one sampling phase");
    // the sample cost is the SAME phase reported everywhere, not re-spent
    assert!(rec.sample_cost_machine_s > 0.0);
    assert_eq!(rec.sample_cost_machine_s.to_bits(), advice.sample_cost_machine_s.to_bits());
    assert_eq!(rec.sample_cost_machine_s.to_bits(), profile.sample_cost_machine_s.to_bits());
    assert_eq!(rec.sample_cost_machine_s.to_bits(), again.sample_cost_machine_s.to_bits());
    assert!(bound > 0.0);
    assert_eq!(risks.len(), 1);
}

#[test]
fn repeated_recommendations_do_not_drift() {
    // querying the same profile twice is deterministic and free
    let app = app_by_name("lr").unwrap();
    let machine = MachineSpec::worker_node();
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().build(&mut backend);
    let profile = advisor.profile(&app);
    let a = profile.recommend(FULL_SCALE, &machine);
    let b = profile.recommend(FULL_SCALE, &machine);
    assert_eq!(a, b);
}

// ======================================================================
// Reports: golden JSON round trips for every type
// ======================================================================

fn reparse(r: &dyn Report) -> Json {
    // compact and pretty renderings must both re-parse to the same value
    let compact = parse(&r.to_json().to_string()).expect("compact json parses");
    let pretty = parse(&r.render(OutputFormat::Json)).expect("pretty json parses");
    assert_eq!(compact, pretty);
    compact
}

fn num(j: &Json, path: &[&str]) -> f64 {
    j.path(path).and_then(Json::as_f64).unwrap_or_else(|| panic!("missing {path:?}"))
}

#[test]
fn recommend_report_round_trips() {
    let app = app_by_name("svm").unwrap();
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().build(&mut backend);
    let profile = advisor.profile(&app);
    let machine = MachineSpec::worker_node();
    let report = RecommendReport::new("rust-nnls", &profile, FULL_SCALE, &machine, true);
    let j = reparse(&report);
    assert_eq!(j.path(&["query"]).unwrap().as_str(), Some("recommend"));
    assert_eq!(j.path(&["app"]).unwrap().as_str(), Some("svm"));
    assert_eq!(num(&j, &["machines"]) as usize, report.recommendation.machines);
    assert_eq!(num(&j, &["predicted_cached_mb"]), report.recommendation.predicted_cached_mb);
    assert_eq!(
        num(&j, &["selection", "machines"]) as usize,
        report.recommendation.selection.as_ref().unwrap().machines
    );
    assert_eq!(
        j.path(&["models"]).unwrap().as_arr().unwrap().len(),
        report.models.len()
    );
    // the text rendering carries the same headline numbers
    let text = report.render(OutputFormat::Text);
    assert!(text.contains("fit backend: rust-nnls"));
    assert!(text.contains(&format!(
        "recommended cluster size: {} machines",
        report.recommendation.machines
    )));
}

#[test]
fn plan_report_round_trips_including_risk() {
    let app = app_by_name("svm").unwrap();
    let catalog = InstanceCatalog::paper();
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().build(&mut backend);
    let profile = advisor.profile(&app);
    let advice = profile.plan(300.0, &catalog, &MachineSeconds);
    let picks = profile.validate(
        300.0,
        &advice.plan,
        &catalog,
        &MachineSeconds,
        &ValidationSpec { scenario: &NoDisturbances, seeds: &[11], top_k: 1 },
    );
    let report = PlanReport {
        backend: "rust-nnls".into(),
        app: app.name.into(),
        scale: 300.0,
        input_mb: app.input_mb(300.0),
        predicted_cached_mb: advice.predicted_cached_mb,
        predicted_exec_mb: advice.predicted_exec_mb,
        sample_cost_machine_s: advice.sample_cost_machine_s,
        plan: advice.plan.clone(),
        catalog_name: catalog.name.into(),
        catalog_types: catalog.instances.len(),
        pricing: "machine-seconds".into(),
        risk: Some(RiskSection { scenario: "none".into(), picks }),
    };
    let j = reparse(&report);
    assert_eq!(j.path(&["query"]).unwrap().as_str(), Some("plan"));
    let ranked = j.path(&["plan", "ranked"]).unwrap().as_arr().unwrap();
    assert_eq!(ranked.len(), report.plan.ranked.len());
    assert_eq!(
        ranked[0].path(&["candidate", "instance"]).unwrap().as_str(),
        Some(report.plan.ranked[0].candidate.instance.as_str())
    );
    assert_eq!(
        num(&j, &["plan", "best", "candidate", "machines"]) as usize,
        report.plan.best().unwrap().candidate.machines
    );
    let risk_picks = j.path(&["risk", "picks"]).unwrap().as_arr().unwrap();
    assert_eq!(risk_picks.len(), 1);
    assert_eq!(
        risk_picks[0].path(&["collapsed"]).unwrap().as_bool(),
        Some(false)
    );
    let text = report.render(OutputFormat::Text);
    assert!(text.contains("PLAN — catalog 'paper'"));
    assert!(text.contains("RISK — top picks"));
}

#[test]
fn bounds_report_round_trips() {
    let app = app_by_name("svm").unwrap();
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().build(&mut backend);
    let profile = advisor.profile(&app);
    let machine = MachineSpec::worker_node();
    let s = profile.max_scale(&machine, 12);
    let report = BoundsReport {
        app: "svm".into(),
        machines: 12,
        max_scale: s,
        input_mb_at_max: app.input_mb(s),
    };
    let j = reparse(&report);
    assert_eq!(j.path(&["query"]).unwrap().as_str(), Some("max_scale"));
    assert_eq!(num(&j, &["max_scale"]), s);
    assert_eq!(j.path(&["unbounded"]).unwrap().as_bool(), Some(false));
    assert!(report.render(OutputFormat::Text).contains("max eviction-free data scale"));
}

#[test]
fn simulate_report_round_trips() {
    let q = SimulateQuery {
        app: "svm",
        scale: 50.0,
        machines: 2,
        instance: "gp.xlarge",
        scenario: "none",
        pricing: "hourly",
        seed: 1,
    };
    let report = coordinator::cmd_simulate(&q, OutputFormat::Text).unwrap();
    let j = reparse(&report);
    assert_eq!(j.path(&["query"]).unwrap().as_str(), Some("simulate"));
    assert_eq!(num(&j, &["baseline", "duration_s"]), report.baseline.duration_s);
    assert_eq!(num(&j, &["disturbed", "machines_lost"]) as usize, 0);
    assert_eq!(num(&j, &["naive_quote"]), report.naive_quote);
}

#[test]
fn run_report_round_trips() {
    let report = coordinator::cmd_run("svm", 50.0, 1, OutputFormat::Text).unwrap();
    let j = reparse(&report);
    assert_eq!(j.path(&["query"]).unwrap().as_str(), Some("run"));
    assert_eq!(
        j.path(&["recommendation", "query"]).unwrap().as_str(),
        Some("recommend")
    );
    assert_eq!(num(&j, &["actual", "duration_s"]), report.duration_s);
    assert_eq!(num(&j, &["sampling_overhead"]), report.sampling_overhead());
    assert!(report.render(OutputFormat::Text).contains("total cost incl. sampling"));
}

#[test]
fn apps_report_round_trips() {
    let report: AppsReport = coordinator::cmd_apps(OutputFormat::Text);
    let j = reparse(&report);
    let rows = j.path(&["apps"]).unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), all_apps().len());
    for (row, app) in rows.iter().zip(all_apps()) {
        assert_eq!(row.path(&["name"]).unwrap().as_str(), Some(app.name.as_str()));
        assert_eq!(num(row, &["input_mb"]), app.input_mb_full);
    }
}

#[test]
fn decide_and_run_reports_share_the_recommendation() {
    // cmd_run must route through the advisor, not re-derive its own pick
    let d = coordinator::cmd_decide("svm", 50.0, false, OutputFormat::Text).unwrap();
    let r = coordinator::cmd_run("svm", 50.0, 1, OutputFormat::Text).unwrap();
    assert_eq!(d.recommendation, r.decide.recommendation);
}

// ======================================================================
// Intake validation: scales are normalized or rejected, never mis-keyed
// ======================================================================

#[test]
fn advisor_intake_rejects_non_finite_and_negative_scales_typed() {
    let app = app_by_name("svm").unwrap();

    let mut b = RustFit::default();
    let mut advisor = Advisor::builder().scales(&[1.0, f64::NAN, 3.0]).build(&mut b);
    match advisor.try_profile(&app) {
        Err(ScaleError::NonFinite { index, value }) => {
            assert_eq!(index, 1);
            assert!(value.is_nan());
        }
        other => panic!("expected NonFinite, got {other:?}"),
    }
    // the rejection happens at intake: no sampling phase was paid
    assert_eq!(advisor.sampling_phases(), 0);

    let mut b = RustFit::default();
    let mut advisor = Advisor::builder().scales(&[f64::INFINITY, 1.0]).build(&mut b);
    assert!(matches!(
        advisor.try_profile(&app),
        Err(ScaleError::NonFinite { index: 0, .. })
    ));

    let mut b = RustFit::default();
    let mut advisor = Advisor::builder().scales(&[1.0, 2.0, -3.0]).build(&mut b);
    match advisor.try_profile(&app) {
        Err(e @ ScaleError::Negative { index: 2, .. }) => {
            // the Display form names the offending index and value
            let text = e.to_string();
            assert!(text.contains("#2") && text.contains("-3"), "{text}");
        }
        other => panic!("expected Negative, got {other:?}"),
    }
}

#[test]
fn negative_zero_scales_normalize_onto_positive_zero_bits() {
    // -0.0 == 0.0 numerically but differs in bit pattern; since cache
    // keys are exact bit patterns, intake must collapse the two spellings
    // or one logical scale set would split into two cache entries and
    // re-pay the sampling phase
    let normalized = normalize_scales(&[-0.0, 1.0, 2.0]).expect("valid scales");
    assert_eq!(normalized.len(), 3);
    assert_eq!(normalized[0].to_bits(), 0.0f64.to_bits(), "-0.0 must become +0.0");
    assert_eq!(normalized[1].to_bits(), 1.0f64.to_bits());
    // all-positive sets pass through bit-identically
    let passthrough = normalize_scales(&[1.0, 2.5, 1e-300]).unwrap();
    assert_eq!(passthrough[2].to_bits(), 1e-300f64.to_bits());
    // and the panicking entry point still works for valid sets
    let app = app_by_name("svm").unwrap();
    let mut b = RustFit::default();
    let mut advisor = Advisor::builder().build(&mut b);
    let profile = advisor.profile(&app);
    assert_eq!(profile.scales, blink::experiments::sampling_scales(&app));
}
